"""Advisory trend report over the benchmark gate-outcome history.

The regression gate (benchmarks/run.py --check-against --gate-history)
passes or fails each metric within a tolerance band and appends every
outcome's detail string to a JSON history file.  A metric can therefore
drift steadily INSIDE its band — shedding a fraction of a percent per run
— without ever failing.  This script reads that history and flags exactly
that pattern: metrics whose numeric value moved monotonically across the
trailing window of runs while still passing.

    python scripts/plot_gate_history.py gate_history.json [--window 4]

Wired into CI as an ADVISORY step (continue-on-error): a flagged drift
prints a WARN line and the run stays green; ``--strict`` turns flags into
a nonzero exit for local use.  An ASCII sparkline per flagged metric
stands in for a plot — this runs on headless CI runners.
"""

from __future__ import annotations

import argparse
import json
import re
import sys

FLOAT_RE = re.compile(r"-?\d+\.\d+(?:[eE][-+]?\d+)?|-?\d+(?:[eE][-+]?\d+)?")
SPARK = "▁▂▃▄▅▆▇█"


def first_float(detail: str) -> float | None:
    """The leading numeric value of a detail string — the current metric.

    Gate details lead with the current measurement ("|0.83-0.85|=0.02",
    "1.52 vs 1.6", "0.971 (floor 0.75)"); trailing numbers are baselines
    or bands, so only the first is a comparable series."""
    m = FLOAT_RE.search(detail)
    return float(m.group(0)) if m else None


def sparkline(values: list[float]) -> str:
    lo, hi = min(values), max(values)
    if hi == lo:
        return SPARK[0] * len(values)
    return "".join(SPARK[int((v - lo) / (hi - lo) * (len(SPARK) - 1))] for v in values)


def series_by_check(history: list[dict]) -> dict[str, list[tuple[float, bool]]]:
    """check name -> [(value, ok)] across records, keeping record order."""
    out: dict[str, list[tuple[float, bool]]] = {}
    for record in history:
        for check in record.get("checks", []):
            value = first_float(check.get("detail", ""))
            if value is None:
                continue
            out.setdefault(check["name"], []).append((value, bool(check.get("ok"))))
    return out


def monotone_drifts(
    series: dict[str, list[tuple[float, bool]]], window: int
) -> list[dict]:
    """Metrics strictly monotone over the trailing ``window`` records.

    Only PASSING records count — a failing metric already blocks the gate,
    the drift report exists for movement the bands still absorb.  Flat
    segments break monotonicity (a stable metric is not drifting)."""
    flags = []
    for name, points in series.items():
        tail = points[-window:]
        if len(tail) < window or not all(ok for _, ok in tail):
            continue
        values = [v for v, _ in tail]
        diffs = [b - a for a, b in zip(values, values[1:])]
        if all(d > 0 for d in diffs) or all(d < 0 for d in diffs):
            flags.append(
                {
                    "name": name,
                    "direction": "up" if diffs[0] > 0 else "down",
                    "values": values,
                    "total_move": values[-1] - values[0],
                }
            )
    return flags


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("history", help="gate-history JSON (benchmarks/run.py --gate-history)")
    ap.add_argument(
        "--window",
        type=int,
        default=4,
        help="trailing records a metric must move monotonically across to flag",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="exit nonzero when any drift is flagged (CI keeps this off: advisory)",
    )
    args = ap.parse_args()

    try:
        with open(args.history) as f:
            history = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        # Advisory tool: a missing/corrupt history (first run, cache miss)
        # reports and exits clean rather than failing the pipeline.
        print(f"no readable gate history at {args.history}: {e}")
        return 0
    if not isinstance(history, list) or not history:
        print("gate history is empty — nothing to trend yet")
        return 0

    series = series_by_check(history)
    flags = monotone_drifts(series, args.window)
    print(
        f"gate history: {len(history)} record(s), {len(series)} numeric metric(s), "
        f"window={args.window}"
    )
    for flag in sorted(flags, key=lambda x: -abs(x["total_move"])):
        values = flag["values"]
        print(
            f"WARN drift-{flag['direction']} {flag['name']}: "
            f"{values[0]:g} -> {values[-1]:g} "
            f"({flag['total_move']:+g} over {len(values)} runs)  {sparkline(values)}"
        )
    if not flags:
        print("no monotone drift inside the tolerance bands")
    return 1 if flags and args.strict else 0


if __name__ == "__main__":
    sys.exit(main())
