"""Summarize (and gate on) a Chrome trace written by ``infer_gnn --trace``.

Prints per-lane utilization, per-stage time, the pipeline overlap
fraction (how busy slot lanes are with >1 batch in flight — 0.0 for a
serial depth-1 run, > 0 whenever overlap actually happened), top spans,
and flow/counter inventories.  With gating flags it doubles as a CI
check over the trace's *structure*:

    python scripts/trace_summary.py out.json                 # human summary
    python scripts/trace_summary.py out.json --json          # machine summary
    python scripts/trace_summary.py out.json --strict        # schema gate
    python scripts/trace_summary.py out.json --strict \\
        --min-overlap 0.01 --require-flows --require-span refresh

Exit status is nonzero when any requested gate fails:

  --strict            every event passes repro.core.trace.validate_trace
                      (ph/ts/pid/tid present, X spans carry dur >= 0,
                      every flow id has exactly one start and one end)
  --min-overlap F     overlap_fraction >= F (use with pipeline depth > 1)
  --max-overlap F     overlap_fraction <= F (use 0 for a depth-1 run)
  --require-flows     at least one complete flow (enqueue -> retire link)
  --require-span N    at least one span named N (repeatable; e.g.
                      ``--require-span refresh --require-span exchange``)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.core.trace import summarize_trace, validate_trace  # noqa: E402


def _fmt_ms(v: float) -> str:
    return f"{v:10.2f} ms"


def render(summary: dict) -> str:
    lines: list[str] = []
    lines.append(f"trace extent      {_fmt_ms(summary['extent_ms'])}")
    lines.append(f"events            {summary['n_events']:6d}   flows {summary['n_flows']}")
    lines.append(f"overlap fraction  {summary['overlap_fraction']:10.3f}")
    lines.append("")
    lines.append("lane                     busy          util   spans")
    for name, lane in summary["lanes"].items():
        lines.append(
            f"{name:20s} {_fmt_ms(lane['busy_ms'])}   {lane['utilization']:6.1%}   {lane['spans']:5d}"
        )
    lines.append("")
    lines.append("stage                   total   count        max")
    for name, st in summary["stages"].items():
        lines.append(
            f"{name:20s} {st['total_ms']:8.2f}   {st['count']:5d}   {st['max_ms']:8.2f}"
        )
    if summary["top_spans"]:
        lines.append("")
        lines.append(f"top {len(summary['top_spans'])} spans")
        for sp in summary["top_spans"]:
            lines.append(f"  {sp['dur_ms']:8.2f} ms  {sp['lane']:12s}  {sp['name']}")
    if summary["counters"]:
        lines.append("")
        lines.append("counters: " + ", ".join(summary["counters"]))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON written by infer_gnn --trace")
    ap.add_argument("--json", action="store_true", help="print the summary as JSON")
    ap.add_argument("--top", type=int, default=5, help="top spans to list (default 5)")
    ap.add_argument("--strict", action="store_true", help="fail on any schema violation")
    ap.add_argument("--min-overlap", type=float, default=None)
    ap.add_argument("--max-overlap", type=float, default=None)
    ap.add_argument("--require-flows", action="store_true")
    ap.add_argument(
        "--require-span",
        action="append",
        default=[],
        metavar="NAME",
        help="require at least one span with this name (repeatable)",
    )
    args = ap.parse_args(argv)

    with open(args.trace, encoding="utf-8") as fh:
        doc = json.load(fh)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc

    failures: list[str] = []
    if args.strict:
        for err in validate_trace(events):
            failures.append(f"schema: {err}")

    summary = summarize_trace(events, top=args.top)
    if args.min_overlap is not None and summary["overlap_fraction"] < args.min_overlap:
        failures.append(
            f"overlap_fraction {summary['overlap_fraction']:.4f} < --min-overlap {args.min_overlap}"
        )
    if args.max_overlap is not None and summary["overlap_fraction"] > args.max_overlap:
        failures.append(
            f"overlap_fraction {summary['overlap_fraction']:.4f} > --max-overlap {args.max_overlap}"
        )
    if args.require_flows and summary["n_flows"] < 1:
        failures.append("no complete flows in trace (--require-flows)")
    span_names = {e.get("name") for e in events if e.get("ph") == "X"}
    for name in args.require_span:
        if name not in span_names:
            failures.append(f"missing required span {name!r}")

    print(json.dumps(summary, indent=1) if args.json else render(summary))
    if failures:
        print("\nFAIL", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
