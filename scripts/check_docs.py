"""Docs CI: fenced code blocks must parse; referenced repo paths must exist.

Checks, over README.md and docs/*.md:

  1. every ```python block compiles (`compile(..., "exec")` — the same
     bar `python -m compileall` sets, without importing anything);
  2. every ```bash / ```sh block tokenizes line-by-line with shlex
     (continuations joined, comments skipped), and any `python -m <mod>`
     module rooted in this repo (`repro.*` via src/, `benchmarks.*`)
     resolves to a file or package in the tree;
  3. every intra-repo path the prose references — tokens starting with
     src/, docs/, examples/, benchmarks/, scripts/, tests/ or .github/ —
     exists (globs must match at least one file);
  4. every backticked module reference resolves in the tree: dotted
     `repro.*` / `benchmarks.*` modules through the same resolver as
     `python -m`, and `src/repro`-relative prose refs like
     `runtime/sharded_serve.py` or `graph/shard.py` against src/repro/.

Exit nonzero listing every failure:  python scripts/check_docs.py
"""

from __future__ import annotations

import glob
import os
import re
import shlex
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ["README.md"] + sorted(
    os.path.relpath(p, REPO) for p in glob.glob(os.path.join(REPO, "docs", "*.md"))
)
PATH_RE = re.compile(r"(?:src|docs|examples|benchmarks|scripts|tests|\.github)/[\w./*-]+")
FENCE_RE = re.compile(r"^```(\w*)\s*$")
MODULE_RE = re.compile(r"python\s+(?:-\S+\s+)*-m\s+([A-Za-z_][\w.]*)")
# Backticked prose references: `repro.runtime.sharded_serve` (dotted) and
# `runtime/sharded_serve.py` (src/repro-relative, top-level package dirs).
DOTTED_REF_RE = re.compile(r"`((?:repro|benchmarks)(?:\.\w+)+)`")
SRC_REL_RE = re.compile(r"`((?:core|graph|runtime|launch|models|utils)/[\w/]+\.py)`")


def code_blocks(text: str):
    """Yield (language, source, first_line_number) for every fenced block."""
    lang, buf, start = None, [], 0
    for ln, line in enumerate(text.splitlines(), 1):
        m = FENCE_RE.match(line.strip()) if line.strip().startswith("```") else None
        if m and lang is None:
            lang, buf, start = m.group(1) or "", [], ln
        elif line.strip() == "```" and lang is not None:
            yield lang, "\n".join(buf), start
            lang = None
        elif lang is not None:
            buf.append(line)


def module_exists(mod: str) -> bool:
    """Resolve a repo-rooted dotted module to a file/package in the tree."""
    parts = mod.split(".")
    roots = {"repro": "src", "benchmarks": ""}
    if parts[0] not in roots:
        return True  # external tool (pytest, pip, ...) — not ours to check
    rel = os.path.join(roots[parts[0]], *parts)
    return os.path.isfile(os.path.join(REPO, rel + ".py")) or os.path.isdir(
        os.path.join(REPO, rel)
    )


def check_file(relpath: str) -> list[str]:
    errors: list[str] = []
    with open(os.path.join(REPO, relpath)) as f:
        text = f.read()

    for lang, src, ln in code_blocks(text):
        where = f"{relpath}:{ln}"
        if lang == "python":
            try:
                compile(src, where, "exec")
            except SyntaxError as e:
                errors.append(f"{where}: python block does not compile: {e}")
        elif lang in ("bash", "sh", "shell"):
            joined = src.replace("\\\n", " ")
            for cmd in joined.splitlines():
                cmd = cmd.strip()
                if not cmd or cmd.startswith("#"):
                    continue
                try:
                    shlex.split(cmd)
                except ValueError as e:
                    errors.append(f"{where}: bash line does not tokenize ({cmd!r}): {e}")
            for mod in MODULE_RE.findall(joined):
                if not module_exists(mod):
                    errors.append(f"{where}: `python -m {mod}` does not resolve in the tree")

    for mod in sorted(set(DOTTED_REF_RE.findall(text))):
        # a ref may name an attribute (`benchmarks.common.emit`): the
        # module prefix resolving is what we can check statically
        if not (module_exists(mod) or module_exists(mod.rsplit(".", 1)[0])):
            errors.append(f"{relpath}: backticked module `{mod}` does not resolve")
    for ref in sorted(set(SRC_REL_RE.findall(text))):
        if not os.path.isfile(os.path.join(REPO, "src", "repro", ref)):
            errors.append(f"{relpath}: backticked ref `{ref}` not under src/repro/")

    for ref in sorted(set(PATH_RE.findall(text))):
        ref = ref.rstrip(".,;:")
        if "*" in ref:
            if not glob.glob(os.path.join(REPO, ref)):
                errors.append(f"{relpath}: glob `{ref}` matches nothing")
        elif not os.path.exists(os.path.join(REPO, ref)):
            errors.append(f"{relpath}: referenced path `{ref}` does not exist")
    return errors


def main() -> int:
    all_errors: list[str] = []
    for relpath in DOC_FILES:
        all_errors.extend(check_file(relpath))
    for e in all_errors:
        print(f"FAIL {e}")
    print(f"checked {len(DOC_FILES)} docs: {'OK' if not all_errors else f'{len(all_errors)} problem(s)'}")
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main())
