import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf helper: dry-run one (arch, shape) under several variants and print
the three roofline terms side by side.

  PYTHONPATH=src python scripts/perf_compare.py deepseek-v2-236b train_4k \
      baseline moe_shardmap moe_shardmap,batch2d
"""

import sys

from repro.launch.dryrun import dryrun_one
from repro.launch.mesh import HW


def terms(rec):
    h = rec["hlo"]
    coll = sum(h["collective_bytes_per_device"].values())
    return {
        "compute_s": h["flops_per_device"] / HW["peak_flops_bf16"],
        "memory_s": h["dot_bytes_per_device"] / HW["hbm_bw"],
        "collective_s": coll / HW["ici_bw_per_link"],
        "flops": h["flops_per_device"],
        "dot_bytes": h["dot_bytes_per_device"],
        "coll_bytes": coll,
    }


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variants = sys.argv[3:] or ["baseline"]
    rows = {}
    for v in variants:
        rec = dryrun_one(arch, shape, variant=v, verbose=False)
        rows[v] = terms(rec)
    print(f"{arch} x {shape} (16x16, per-device seconds)")
    hdr = f"{'variant':28s} {'compute':>10s} {'memory':>10s} {'collective':>11s} {'dominant':>10s}"
    print(hdr)
    for v, t in rows.items():
        dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
        print(
            f"{v:28s} {t['compute_s']:10.3e} {t['memory_s']:10.3e} "
            f"{t['collective_s']:11.3e} {dom.replace('_s',''):>10s}"
        )
    base = rows.get("baseline")
    if base:
        for v, t in rows.items():
            if v == "baseline":
                continue
            print(
                f"  {v}: compute x{base['compute_s']/max(t['compute_s'],1e-12):.2f}, "
                f"memory x{base['memory_s']/max(t['memory_s'],1e-12):.2f}, "
                f"collective x{base['collective_s']/max(t['collective_s'],1e-12):.2f}"
            )


if __name__ == "__main__":
    main()
