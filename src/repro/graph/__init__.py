from repro.graph.csc import AdjCache, CSCGraph, build_adj_cache, two_level_sort
from repro.graph.datasets import DATASETS, DatasetSpec, SyntheticGraphDataset, load_dataset
from repro.graph.features import FeatureStore, build_feature_cache, plain_feature_store
from repro.graph.sampling import (
    BlockSample,
    DeviceGraph,
    count_visits,
    device_graph,
    sample_blocks,
    sample_neighbors,
)
from repro.graph.shard import (
    ShardedFeatureStore,
    ShardPlan,
    make_shard_plan,
    partition_feature_store,
)

__all__ = [
    "AdjCache",
    "CSCGraph",
    "build_adj_cache",
    "two_level_sort",
    "DATASETS",
    "DatasetSpec",
    "SyntheticGraphDataset",
    "load_dataset",
    "FeatureStore",
    "build_feature_cache",
    "plain_feature_store",
    "BlockSample",
    "DeviceGraph",
    "count_visits",
    "device_graph",
    "sample_blocks",
    "sample_neighbors",
    "ShardedFeatureStore",
    "ShardPlan",
    "make_shard_plan",
    "partition_feature_store",
]
