"""Node-feature storage: host ("UVA") table + DCI hot-feature cache.

The paper locates cached rows "through a hash table" inside the GPU; on
TPU a dense ``position_map: int32[N]`` (−1 = miss) is the idiomatic
equivalent — one vectorized gather instead of pointer chasing (DESIGN.md
§3).  ``gather`` reads hits from the compact hot table and misses from the
full host table, returning the hit mask so the engine can account for
bytes moved over the slow path.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.sampling import pow2_bucket

__all__ = [
    "FeatureStore",
    "FeatureRefreshStats",
    "PrefetchedMisses",
    "build_embedding_cache",
    "build_feature_cache",
    "refresh_feature_cache",
]

# One shared worker for the host-side miss-row pack: the numpy fancy-index
# copy is the heavy part of prefetch staging, and a single worker keeps the
# packs ordered (packs are consumed in submission order by the batch that
# requested them) while the submitting thread builds the index arrays and
# issues their device transfers concurrently.
_PACK_POOL = concurrent.futures.ThreadPoolExecutor(
    max_workers=1, thread_name_prefix="dci-miss-pack"
)


class PrefetchedMisses(typing.NamedTuple):
    """Missed host rows staged onto the device ahead of their gather.

    ``rows`` is the ``device_put`` buffer: the full ``[S, F]`` row set when
    every row missed (``idx is None``), else a ``[P, F]`` power-of-two
    padded pack of just the miss rows.  ``idx`` holds each packed row's
    position in the batch (pad entries point one past the end and are
    dropped by the consuming scatter); ``pack_pos`` is the inverse map —
    each batch row's slot in the pack (0 for hit rows, whose miss source
    is never read) — so the kernel route can address the pack directly
    instead of rebuilding a dense miss source.  ``num_miss`` is the
    unpadded miss count — the staging accounting, so callers need not
    re-derive the miss mask."""

    rows: jax.Array
    idx: jax.Array | None
    pack_pos: jax.Array | None
    num_miss: int


@dataclasses.dataclass(frozen=True)
class FeatureStore:
    host_table: jax.Array  # f32[N, F] — the UVA/HBM-resident full table
    hot_table: jax.Array  # f32[H, F] — device cache (H >= 1; row 0 unused if empty)
    position_map: jax.Array  # int32[N] — slot in hot_table or -1

    @property
    def num_nodes(self) -> int:
        return self.host_table.shape[0]

    @property
    def feat_dim(self) -> int:
        return self.host_table.shape[1]

    @property
    def num_cached(self) -> int:
        return int((self.position_map >= 0).sum())

    def host_np(self) -> np.ndarray:
        """Host-memory mirror of the full feature table (cached lazily).

        The paper's miss path reads host/UVA memory; this is the array the
        prefetch stage copies missed rows *from* with ``jax.device_put``.
        Same float bits as ``host_table``, so a prefetched row is
        bit-identical to a direct device-side miss gather."""
        cached = getattr(self, "_host_np", None)
        if cached is None:
            cached = np.asarray(self.host_table)
            object.__setattr__(self, "_host_np", cached)
        return cached

    def position_np(self) -> np.ndarray:
        """Host-memory mirror of ``position_map`` (cached lazily) — lets
        the prefetch stage find the missed rows without a device round
        trip."""
        cached = getattr(self, "_position_np", None)
        if cached is None:
            cached = np.asarray(self.position_map)
            object.__setattr__(self, "_position_np", cached)
        return cached

    def pad_node_id(self) -> int:
        """A known-CACHED node id for padding device index buffers, or −1
        when nothing is cached.

        The deduped frontier's pow2 bucket tail is filled with this id
        (``dedup_frontier(pad_id=...)``): pad slots then resolve as cache
        hits, so a bucket-wide scan — e.g. a warmup-path
        :meth:`prefetch_misses` without ``num_live`` — can never mistake
        padding for duplicate miss rows.  Computed lazily from the host
        position-map mirror (largest cached id; any cached id would do)."""
        cached = getattr(self, "_pad_node_id", None)
        if cached is None:
            hot = np.nonzero(self.position_np() >= 0)[0]
            cached = int(hot[-1]) if hot.size else -1
            object.__setattr__(self, "_pad_node_id", cached)
        return cached

    def prefetch_misses(
        self,
        nodes: np.ndarray,
        *,
        pack_in_thread: bool = True,
        num_live: int | None = None,
        device=None,
        injector=None,
    ) -> PrefetchedMisses:
        """Stage the missed host rows for a batch onto the device.

        ``jax.device_put`` issues the host→device copy of exactly the
        rows the gather would otherwise pull across the slow link; under
        async dispatch it overlaps whatever the device is running (the
        previous batch's forward, in the pipelined executor).  The miss
        count varies batch to batch, so the pack is padded to a
        power-of-two bucket — the consuming scatter then compiles
        O(log S) programs instead of one per distinct count.

        ``num_live`` marks a live prefix: positions at and beyond it are
        padding (the deduped frontier's pow2 bucket tail) whose gathered
        values are never read, so their misses are not staged — the pack
        holds exactly the DISTINCT missed rows.  The consuming gather
        still covers all of ``nodes``; pad miss rows read pack slot 0,
        which only ever lands in unread pad output rows.

        ``pack_in_thread`` (default on) runs the heavy part of the pack —
        the numpy fancy-index copy of the miss rows and its ``device_put``
        — on a worker thread while the calling thread builds the
        ``idx``/``pack_pos`` index arrays and issues THEIR device
        transfers; the call joins before returning, so the result (and
        everything downstream) is bit-identical either way.

        ``device`` commits the staged buffers to a specific device — the
        sharded path stages each shard's misses onto that shard's device
        so the consuming per-shard gather never mixes committed devices.
        ``None`` (default) keeps the single-device placement.

        ``injector`` (core/faults.py, optional) charges one ``prefetch``
        fault-site call before any staging work — the check precedes every
        state mutation and the staging itself is pure, so a faulted call
        is safely retryable."""
        if injector is not None:
            injector.check("prefetch")
        nodes = np.asarray(nodes)
        live = nodes if num_live is None else nodes[:num_live]
        miss = np.nonzero(self.position_np()[live] < 0)[0].astype(np.int32)
        if miss.size == nodes.size:
            # Every row missed (e.g. no cache): the staged buffer IS the
            # whole row set — no pack, no pad, nothing to overlap.
            return PrefetchedMisses(
                rows=jax.device_put(self.host_np()[nodes], device),
                idx=None,
                pack_pos=None,
                num_miss=int(miss.size),
            )
        bucket = pow2_bucket(miss.size, nodes.size)

        def pack_rows():
            rows = np.zeros((bucket, self.feat_dim), self.host_np().dtype)
            rows[: miss.size] = self.host_np()[nodes[miss]]
            return jax.device_put(rows, device)

        rows_future = _PACK_POOL.submit(pack_rows) if pack_in_thread else None
        idx = np.full(bucket, nodes.size, np.int32)  # pad → one past the end (dropped)
        idx[: miss.size] = miss
        pack_pos = np.zeros(nodes.size, np.int32)  # hit rows point at slot 0 (never read)
        pack_pos[miss] = np.arange(miss.size, dtype=np.int32)
        if device is not None:
            idx, pack_pos = jax.device_put(idx, device), jax.device_put(pack_pos, device)
        else:
            idx, pack_pos = jnp.asarray(idx), jnp.asarray(pack_pos)
        return PrefetchedMisses(
            rows=rows_future.result() if rows_future is not None else pack_rows(),
            idx=idx,
            pack_pos=pack_pos,
            num_miss=int(miss.size),
        )

    def gather(
        self,
        indices: jax.Array,
        *,
        use_kernel: bool = False,
        gather_buffers: int = 2,
        prefetched: PrefetchedMisses | None = None,
        row_block: int | None = None,
        injector=None,
    ) -> tuple[jax.Array, jax.Array]:
        """Two-source gather. Returns ``(features[S, F], hit[S])``.

        ``use_kernel=True`` routes through the double-buffered Pallas
        ``cached_gather`` kernel (compiled on TPU, interpret mode
        elsewhere) with ``gather_buffers`` VMEM row-tile slots.

        ``prefetched`` (from :meth:`prefetch_misses`) replaces the host
        table as the miss source: miss rows come from the already-staged
        pack — scattered over the hot-table gather — instead of
        re-crossing the slow link inside this stage.  The hit mask — and
        therefore all hit/miss accounting — is computed from
        ``position_map`` exactly as in the non-prefetched path, and the
        output is bit-identical (the staged rows are copies of the same
        host rows).

        ``row_block`` (with ``use_kernel``) selects the row-block kernel
        variant: sorted-run index sets (deduped frontiers) collapse to one
        DMA descriptor per ``row_block`` consecutive source rows instead
        of one per row.  Correct for any index order — broken runs fall
        back to per-row copies inside the kernel — so the output stays
        bit-identical to every other route.

        ``injector`` (core/faults.py, optional) charges a ``host_fetch``
        fault-site call (the miss path's host read) and, on the kernel
        route, a ``kernel_gather`` call — both before any device dispatch,
        so a faulted gather is safely retryable.
        """
        if injector is not None:
            injector.check("host_fetch")
            if use_kernel:
                injector.check("kernel_gather")
        indices = indices.astype(jnp.int32)
        pos = self.position_map[indices]
        hit = pos >= 0
        s = indices.shape[0]
        if use_kernel:
            from repro.kernels.cached_gather.kernel import cached_gather, cached_gather_blocks

            if prefetched is None:
                host_src, host_idx = self.host_table, indices
            elif prefetched.idx is None:  # all-miss: the pack is row-aligned
                host_src = prefetched.rows
                host_idx = jnp.arange(s, dtype=jnp.int32)
            else:
                # Address the staged pack directly through its inverse map
                # — no dense [S, F] miss-source rebuild on the gather
                # stage.  Hit rows point at pack slot 0, which the DMA
                # kernel never reads (the hit branch copies the hot row).
                host_src, host_idx = prefetched.rows, prefetched.pack_pos
            if row_block is not None and row_block > 1:
                return (
                    cached_gather_blocks(
                        self.hot_table,
                        host_src,
                        host_idx,
                        pos,
                        row_block=row_block,
                        gather_buffers=gather_buffers,
                    ),
                    hit,
                )
            return (
                cached_gather(
                    self.hot_table, host_src, host_idx, pos, gather_buffers=gather_buffers
                ),
                hit,
            )
        safe_pos = jnp.maximum(pos, 0)
        cached = self.hot_table[jnp.minimum(safe_pos, self.hot_table.shape[0] - 1)]
        if prefetched is None:
            return jnp.where(hit[:, None], cached, self.host_table[indices]), hit
        if prefetched.idx is None:  # all rows missed: straight select
            return jnp.where(hit[:, None], cached, prefetched.rows), hit
        # Misses overwrite their rows of the hot gather — S·F + M·F work
        # instead of the two full gathers + select of the table path.
        return cached.at[prefetched.idx].set(prefetched.rows, mode="drop"), hit

    def gather_cache_only(self, indices: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Degraded-mode gather: hit rows from the device cache, miss rows
        ZERO-FILLED — never touches the host table.

        The fallback the serving layer uses when the miss path is down
        (core/faults.py ``host_fetch``): hit rows are bit-identical to
        :meth:`gather`'s, misses are explicitly wrong (zeros) and the
        request is marked ``degraded`` — availability over fidelity.  The
        hit mask is the usual ``position_map`` lookup, so hit accounting
        stays comparable with the healthy path."""
        indices = indices.astype(jnp.int32)
        pos = self.position_map[indices]
        hit = pos >= 0
        safe_pos = jnp.maximum(pos, 0)
        cached = self.hot_table[jnp.minimum(safe_pos, self.hot_table.shape[0] - 1)]
        return jnp.where(hit[:, None], cached, jnp.zeros_like(cached)), hit


jax.tree_util.register_pytree_node(
    FeatureStore,
    lambda s: ((s.host_table, s.hot_table, s.position_map), None),
    lambda aux, ch: FeatureStore(*ch),
)


def select_hot_rows(node_counts: np.ndarray, budget_rows: int) -> np.ndarray:
    """DCI's sort-free hot-row selection (paper §IV-B).

    Select nodes with ``visits > mean`` directly (no global argsort); if
    capacity remains, top up with below-mean *visited* nodes, then with
    anything else.  O(N) passes; only the (small, under power-law
    workloads) above-mean subset is ever sorted.  Shared by the build-time
    fill and the serve-time delta refresh, so both rank rows identically.
    """
    n = node_counts.shape[0]
    budget_rows = min(max(int(budget_rows), 0), n)
    counts = node_counts.astype(np.float64)
    mean = counts.mean() if n else 0.0
    hot = np.nonzero(counts > mean)[0]
    if hot.shape[0] > budget_rows:
        # More above-mean nodes than capacity: keep the hottest among them.
        hot = hot[np.argsort(-counts[hot], kind="stable")[:budget_rows]]
    elif hot.shape[0] < budget_rows:
        rest = np.nonzero(counts <= mean)[0]
        visited = rest[counts[rest] > 0]
        cold = rest[counts[rest] == 0]
        top_up = np.concatenate([visited, cold])[: budget_rows - hot.shape[0]]
        hot = np.concatenate([hot, top_up])
    return hot


def build_feature_cache(
    features: np.ndarray,
    node_counts: np.ndarray,
    capacity_bytes: int,
) -> FeatureStore:
    """DCI's sort-free feature-cache fill (paper §IV-B)."""
    n, f = features.shape
    row_bytes = f * features.dtype.itemsize
    budget_rows = min(max(int(capacity_bytes) // row_bytes, 0), n)
    # Slots are assigned in ascending NODE-ID order (selection — which
    # rows get cached — is unchanged): consecutive hot node ids land in
    # consecutive hot-table slots, so a sorted deduped frontier's hit
    # positions form the contiguous runs the row-block gather kernel
    # collapses to one DMA each.  Outputs and hit accounting are invariant
    # to slot order — gathers always go through ``position_map``.
    hot = np.sort(select_hot_rows(node_counts, budget_rows))

    position_map = np.full(n, -1, np.int32)
    position_map[hot] = np.arange(hot.shape[0], dtype=np.int32)
    hot_table = features[hot] if hot.shape[0] else np.zeros((1, f), features.dtype)
    return FeatureStore(
        host_table=jnp.asarray(features),
        hot_table=jnp.asarray(hot_table),
        position_map=jnp.asarray(position_map),
    )


@dataclasses.dataclass(frozen=True)
class FeatureRefreshStats:
    """What a delta re-fill actually moved (the bounded-pause accounting)."""

    rows_kept: int  # hot rows that stayed in their slots — zero bytes moved
    rows_inserted: int  # new hot rows scattered into freed slots
    rows_evicted: int  # old hot rows whose slots were reused / invalidated
    physical_rows: int  # device hot-table rows after the refresh
    budget_rows: int  # logical capacity the new allocation pays for

    @property
    def changed(self) -> bool:
        return bool(self.rows_inserted or self.rows_evicted)


def refresh_feature_cache(
    store: FeatureStore,
    node_counts: np.ndarray,
    capacity_bytes: int,
) -> tuple[FeatureStore, FeatureRefreshStats]:
    """Incremental re-fill: move only the rows whose hotness changed.

    Re-runs the sort-free selection on the UPDATED ``node_counts`` (merged
    presample + runtime telemetry), then applies the difference against
    the live store as a delta:

      * rows in both the old and new hot set KEEP their slots — no copy,
        no position_map write, no recompile;
      * evicted rows get ``position_map[v] = -1`` (their slots are freed;
        stale table rows are never read again);
      * inserted rows are packed once host-side and applied as ONE device
        scatter into the freed slots.

    The device hot table only grows (and only when the new budget exceeds
    its physical rows); shrinking budgets reuse the existing array with a
    smaller logical occupancy, so repeated refreshes at a stable split
    compile nothing new.  ``host_table`` is shared with the old store, so
    gathered feature rows stay bit-identical across epochs — a refresh
    changes hit accounting and byte movement, never outputs.
    """
    features = store.host_np()
    n, f = features.shape
    row_bytes = f * features.dtype.itemsize
    budget_rows = min(max(int(capacity_bytes) // row_bytes, 0), n)

    old_pos = store.position_np()
    new_hot = select_hot_rows(node_counts, budget_rows)
    in_new = np.zeros(n, bool)
    in_new[new_hot] = True
    old_nodes = np.nonzero(old_pos >= 0)[0]
    kept_mask = in_new[old_nodes]
    kept_nodes = old_nodes[kept_mask]
    evicted_nodes = old_nodes[~kept_mask]
    in_old = np.zeros(n, bool)
    in_old[old_nodes] = True
    # Ascending insert order mirrors the build-time id-ordered slot
    # assignment: freed slots are filled lowest-id-first, preserving what
    # run contiguity the surviving layout still allows (kept rows pin
    # their slots, so contiguity degrades gracefully across epochs rather
    # than resetting).
    inserted_nodes = np.sort(new_hot[~in_old[new_hot]])

    physical = store.hot_table.shape[0]
    needed = kept_nodes.shape[0] + inserted_nodes.shape[0]
    hot_table = store.hot_table
    if needed > physical:
        # Grow by appending zero rows; kept rows stay device-resident —
        # the host never re-uploads them.  Growth doubles (capped at the
        # node count) so a sequence of refreshes compiles O(log N) gather
        # programs, not one per epoch; shrinking budgets reuse the array
        # with lower logical occupancy and compile nothing.
        grow_to = min(max(needed, 2 * physical), max(n, needed))
        hot_table = jnp.concatenate(
            [hot_table, jnp.zeros((grow_to - physical, f), hot_table.dtype)]
        )
        physical = grow_to

    # Free slots = every physical slot not held by a kept row; inserts fill
    # them in ascending order (deterministic given the same inputs).
    occupied = np.zeros(physical, bool)
    occupied[old_pos[kept_nodes]] = True
    free_slots = np.nonzero(~occupied)[0][: inserted_nodes.shape[0]].astype(np.int32)

    new_pos_np = old_pos.copy()
    new_pos_np[evicted_nodes] = -1
    new_pos_np[inserted_nodes] = free_slots

    def pow2_pad(idx: np.ndarray, fill: int) -> jnp.ndarray:
        # The delta scatters compile per index-array shape; padding the
        # delta to a power-of-two bucket (pad entries point out of range
        # and are dropped) keeps repeated refreshes to O(log N) compiled
        # programs instead of one per distinct delta size.
        out = np.full(pow2_bucket(idx.size), fill, np.int32)
        out[: idx.size] = idx
        return jnp.asarray(out)

    position_map = store.position_map
    if evicted_nodes.size:
        position_map = position_map.at[pow2_pad(evicted_nodes, n)].set(-1, mode="drop")
    if inserted_nodes.size:
        ins = pow2_pad(inserted_nodes, n)
        slots = pow2_pad(free_slots, physical)
        position_map = position_map.at[ins].set(slots, mode="drop")
        rows = np.zeros((slots.shape[0], f), features.dtype)
        rows[: inserted_nodes.size] = features[inserted_nodes]
        hot_table = hot_table.at[slots].set(jnp.asarray(rows), mode="drop")
    new_store = FeatureStore(
        host_table=store.host_table, hot_table=hot_table, position_map=position_map
    )
    # Carry the host mirrors forward: host rows are unchanged, and the new
    # position map is already known host-side — no device round trip.
    object.__setattr__(new_store, "_host_np", features)
    object.__setattr__(new_store, "_position_np", new_pos_np)
    return new_store, FeatureRefreshStats(
        rows_kept=int(kept_nodes.shape[0]),
        rows_inserted=int(inserted_nodes.shape[0]),
        rows_evicted=int(evicted_nodes.shape[0]),
        physical_rows=int(physical),
        budget_rows=int(budget_rows),
    )


def build_embedding_cache(
    table: np.ndarray,
    access_counts: np.ndarray,
    capacity_bytes: int,
) -> FeatureStore:
    """DCI's sort-free fill applied to layer-*k* output EMBEDDINGS.

    The layer-wise executor (runtime/layerwise.py) spills each layer's
    outputs to a host-side table and re-reads them as the next layer's
    inputs; this builds the device cache those re-reads hit — the same
    :class:`FeatureStore` machinery (``position_map`` lookup, two-source
    ``gather``, row-block kernel route) as the input-feature cache, filled
    by :func:`select_hot_rows` over the chunk access pattern.  Unlike the
    presample-estimated feature counts, ``access_counts`` here is EXACT:
    a node's embedding is read once as a chunk member plus once per
    out-edge (``1 + bincount(row_index)``), known from the CSC alone.

    Slots are id-ordered like :func:`build_feature_cache`, so the chunk
    gathers' ascending-id runs hit contiguous hot-table rows — what the
    row-block ``cached_gather`` kernel collapses to one DMA per run.  The
    host mirrors are seeded from ``table`` directly (it already lives on
    the host), so building a per-layer cache never re-downloads the spill
    buffer.  A zero budget degrades to the cache-less store.
    """
    table = np.ascontiguousarray(table)
    n, f = table.shape
    row_bytes = f * table.dtype.itemsize
    budget_rows = min(max(int(capacity_bytes) // row_bytes, 0), n)
    hot = np.sort(select_hot_rows(access_counts, budget_rows))
    position_map = np.full(n, -1, np.int32)
    position_map[hot] = np.arange(hot.shape[0], dtype=np.int32)
    hot_table = table[hot] if hot.shape[0] else np.zeros((1, f), table.dtype)
    store = FeatureStore(
        host_table=jnp.asarray(table),
        hot_table=jnp.asarray(hot_table),
        position_map=jnp.asarray(position_map),
    )
    object.__setattr__(store, "_host_np", table)
    object.__setattr__(store, "_position_np", position_map)
    return store


def plain_feature_store(features: np.ndarray) -> FeatureStore:
    """No cache: everything is a miss except nothing — position map all −1."""
    n, f = features.shape
    return FeatureStore(
        host_table=jnp.asarray(features),
        hot_table=jnp.zeros((1, f), features.dtype),
        position_map=jnp.full((n,), -1, jnp.int32),
    )
