"""Node-feature storage: host ("UVA") table + DCI hot-feature cache.

The paper locates cached rows "through a hash table" inside the GPU; on
TPU a dense ``position_map: int32[N]`` (−1 = miss) is the idiomatic
equivalent — one vectorized gather instead of pointer chasing (DESIGN.md
§3).  ``gather`` reads hits from the compact hot table and misses from the
full host table, returning the hit mask so the engine can account for
bytes moved over the slow path.
"""

from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FeatureStore", "PrefetchedMisses", "build_feature_cache"]


class PrefetchedMisses(typing.NamedTuple):
    """Missed host rows staged onto the device ahead of their gather.

    ``rows`` is the ``device_put`` buffer: the full ``[S, F]`` row set when
    every row missed (``idx is None``), else a ``[P, F]`` power-of-two
    padded pack of just the miss rows.  ``idx`` holds each packed row's
    position in the batch (pad entries point one past the end and are
    dropped by the consuming scatter); ``pack_pos`` is the inverse map —
    each batch row's slot in the pack (0 for hit rows, whose miss source
    is never read) — so the kernel route can address the pack directly
    instead of rebuilding a dense miss source.  ``num_miss`` is the
    unpadded miss count — the staging accounting, so callers need not
    re-derive the miss mask."""

    rows: jax.Array
    idx: jax.Array | None
    pack_pos: jax.Array | None
    num_miss: int


@dataclasses.dataclass(frozen=True)
class FeatureStore:
    host_table: jax.Array  # f32[N, F] — the UVA/HBM-resident full table
    hot_table: jax.Array  # f32[H, F] — device cache (H >= 1; row 0 unused if empty)
    position_map: jax.Array  # int32[N] — slot in hot_table or -1

    @property
    def num_nodes(self) -> int:
        return self.host_table.shape[0]

    @property
    def feat_dim(self) -> int:
        return self.host_table.shape[1]

    @property
    def num_cached(self) -> int:
        return int((self.position_map >= 0).sum())

    def host_np(self) -> np.ndarray:
        """Host-memory mirror of the full feature table (cached lazily).

        The paper's miss path reads host/UVA memory; this is the array the
        prefetch stage copies missed rows *from* with ``jax.device_put``.
        Same float bits as ``host_table``, so a prefetched row is
        bit-identical to a direct device-side miss gather."""
        cached = getattr(self, "_host_np", None)
        if cached is None:
            cached = np.asarray(self.host_table)
            object.__setattr__(self, "_host_np", cached)
        return cached

    def position_np(self) -> np.ndarray:
        """Host-memory mirror of ``position_map`` (cached lazily) — lets
        the prefetch stage find the missed rows without a device round
        trip."""
        cached = getattr(self, "_position_np", None)
        if cached is None:
            cached = np.asarray(self.position_map)
            object.__setattr__(self, "_position_np", cached)
        return cached

    def prefetch_misses(self, nodes: np.ndarray) -> PrefetchedMisses:
        """Stage the missed host rows for a batch onto the device.

        ``jax.device_put`` issues the host→device copy of exactly the
        rows the gather would otherwise pull across the slow link; under
        async dispatch it overlaps whatever the device is running (the
        previous batch's forward, in the pipelined executor).  The miss
        count varies batch to batch, so the pack is padded to a
        power-of-two bucket — the consuming scatter then compiles
        O(log S) programs instead of one per distinct count."""
        nodes = np.asarray(nodes)
        miss = np.nonzero(self.position_np()[nodes] < 0)[0].astype(np.int32)
        if miss.size == nodes.size:
            # Every row missed (e.g. no cache): the staged buffer IS the
            # whole row set — no pack, no pad.
            return PrefetchedMisses(
                rows=jax.device_put(self.host_np()[nodes]),
                idx=None,
                pack_pos=None,
                num_miss=int(miss.size),
            )
        bucket = min(max(1, 1 << int(np.ceil(np.log2(max(miss.size, 1))))), nodes.size)
        idx = np.full(bucket, nodes.size, np.int32)  # pad → one past the end (dropped)
        idx[: miss.size] = miss
        rows = np.zeros((bucket, self.feat_dim), self.host_np().dtype)
        rows[: miss.size] = self.host_np()[nodes[miss]]
        pack_pos = np.zeros(nodes.size, np.int32)  # hit rows point at slot 0 (never read)
        pack_pos[miss] = np.arange(miss.size, dtype=np.int32)
        return PrefetchedMisses(
            rows=jax.device_put(rows),
            idx=jnp.asarray(idx),
            pack_pos=jnp.asarray(pack_pos),
            num_miss=int(miss.size),
        )

    def gather(
        self,
        indices: jax.Array,
        *,
        use_kernel: bool = False,
        gather_buffers: int = 2,
        prefetched: PrefetchedMisses | None = None,
    ) -> tuple[jax.Array, jax.Array]:
        """Two-source gather. Returns ``(features[S, F], hit[S])``.

        ``use_kernel=True`` routes through the double-buffered Pallas
        ``cached_gather`` kernel (compiled on TPU, interpret mode
        elsewhere) with ``gather_buffers`` VMEM row-tile slots.

        ``prefetched`` (from :meth:`prefetch_misses`) replaces the host
        table as the miss source: miss rows come from the already-staged
        pack — scattered over the hot-table gather — instead of
        re-crossing the slow link inside this stage.  The hit mask — and
        therefore all hit/miss accounting — is computed from
        ``position_map`` exactly as in the non-prefetched path, and the
        output is bit-identical (the staged rows are copies of the same
        host rows).
        """
        indices = indices.astype(jnp.int32)
        pos = self.position_map[indices]
        hit = pos >= 0
        s = indices.shape[0]
        if use_kernel:
            from repro.kernels.cached_gather.kernel import cached_gather

            if prefetched is None:
                host_src, host_idx = self.host_table, indices
            elif prefetched.idx is None:  # all-miss: the pack is row-aligned
                host_src = prefetched.rows
                host_idx = jnp.arange(s, dtype=jnp.int32)
            else:
                # Address the staged pack directly through its inverse map
                # — no dense [S, F] miss-source rebuild on the gather
                # stage.  Hit rows point at pack slot 0, which the DMA
                # kernel never reads (the hit branch copies the hot row).
                host_src, host_idx = prefetched.rows, prefetched.pack_pos
            return (
                cached_gather(
                    self.hot_table, host_src, host_idx, pos, gather_buffers=gather_buffers
                ),
                hit,
            )
        safe_pos = jnp.maximum(pos, 0)
        cached = self.hot_table[jnp.minimum(safe_pos, self.hot_table.shape[0] - 1)]
        if prefetched is None:
            return jnp.where(hit[:, None], cached, self.host_table[indices]), hit
        if prefetched.idx is None:  # all rows missed: straight select
            return jnp.where(hit[:, None], cached, prefetched.rows), hit
        # Misses overwrite their rows of the hot gather — S·F + M·F work
        # instead of the two full gathers + select of the table path.
        return cached.at[prefetched.idx].set(prefetched.rows, mode="drop"), hit


jax.tree_util.register_pytree_node(
    FeatureStore,
    lambda s: ((s.host_table, s.hot_table, s.position_map), None),
    lambda aux, ch: FeatureStore(*ch),
)


def build_feature_cache(
    features: np.ndarray,
    node_counts: np.ndarray,
    capacity_bytes: int,
) -> FeatureStore:
    """DCI's sort-free feature-cache fill (paper §IV-B).

    Select nodes with ``visits > mean`` directly (no global argsort); if
    capacity remains, top up with below-mean *visited* nodes, then with
    anything else.  This is the lightweight part: O(N) passes, no O(N log N)
    sort over all nodes.
    """
    n, f = features.shape
    row_bytes = f * features.dtype.itemsize
    budget_rows = min(max(int(capacity_bytes) // row_bytes, 0), n)

    counts = node_counts.astype(np.float64)
    mean = counts.mean() if n else 0.0
    hot = np.nonzero(counts > mean)[0]
    if hot.shape[0] > budget_rows:
        # More above-mean nodes than capacity: keep the hottest among them.
        # (Sorting only the above-mean subset keeps this cheap — the subset
        # is small under power-law workloads.)
        hot = hot[np.argsort(-counts[hot], kind="stable")[:budget_rows]]
    elif hot.shape[0] < budget_rows:
        rest = np.nonzero(counts <= mean)[0]
        visited = rest[counts[rest] > 0]
        cold = rest[counts[rest] == 0]
        top_up = np.concatenate([visited, cold])[: budget_rows - hot.shape[0]]
        hot = np.concatenate([hot, top_up])

    position_map = np.full(n, -1, np.int32)
    position_map[hot] = np.arange(hot.shape[0], dtype=np.int32)
    hot_table = features[hot] if hot.shape[0] else np.zeros((1, f), features.dtype)
    return FeatureStore(
        host_table=jnp.asarray(features),
        hot_table=jnp.asarray(hot_table),
        position_map=jnp.asarray(position_map),
    )


def plain_feature_store(features: np.ndarray) -> FeatureStore:
    """No cache: everything is a miss except nothing — position map all −1."""
    n, f = features.shape
    return FeatureStore(
        host_table=jnp.asarray(features),
        hot_table=jnp.zeros((1, f), features.dtype),
        position_map=jnp.full((n,), -1, jnp.int32),
    )
