"""Node-feature storage: host ("UVA") table + DCI hot-feature cache.

The paper locates cached rows "through a hash table" inside the GPU; on
TPU a dense ``position_map: int32[N]`` (−1 = miss) is the idiomatic
equivalent — one vectorized gather instead of pointer chasing (DESIGN.md
§3).  ``gather`` reads hits from the compact hot table and misses from the
full host table, returning the hit mask so the engine can account for
bytes moved over the slow path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FeatureStore", "build_feature_cache"]


@dataclasses.dataclass(frozen=True)
class FeatureStore:
    host_table: jax.Array  # f32[N, F] — the UVA/HBM-resident full table
    hot_table: jax.Array  # f32[H, F] — device cache (H >= 1; row 0 unused if empty)
    position_map: jax.Array  # int32[N] — slot in hot_table or -1

    @property
    def num_nodes(self) -> int:
        return self.host_table.shape[0]

    @property
    def feat_dim(self) -> int:
        return self.host_table.shape[1]

    @property
    def num_cached(self) -> int:
        return int((self.position_map >= 0).sum())

    def gather(
        self, indices: jax.Array, *, use_kernel: bool = False
    ) -> tuple[jax.Array, jax.Array]:
        """Two-source gather. Returns ``(features[S, F], hit[S])``.

        ``use_kernel=True`` routes through the Pallas ``cached_gather``
        kernel (interpret mode on CPU; compiled on TPU).
        """
        indices = indices.astype(jnp.int32)
        pos = self.position_map[indices]
        hit = pos >= 0
        if use_kernel:
            from repro.kernels.cached_gather.kernel import cached_gather

            return cached_gather(self.hot_table, self.host_table, indices, pos), hit
        safe_pos = jnp.maximum(pos, 0)
        cached = self.hot_table[jnp.minimum(safe_pos, self.hot_table.shape[0] - 1)]
        host = self.host_table[indices]
        return jnp.where(hit[:, None], cached, host), hit


jax.tree_util.register_pytree_node(
    FeatureStore,
    lambda s: ((s.host_table, s.hot_table, s.position_map), None),
    lambda aux, ch: FeatureStore(*ch),
)


def build_feature_cache(
    features: np.ndarray,
    node_counts: np.ndarray,
    capacity_bytes: int,
) -> FeatureStore:
    """DCI's sort-free feature-cache fill (paper §IV-B).

    Select nodes with ``visits > mean`` directly (no global argsort); if
    capacity remains, top up with below-mean *visited* nodes, then with
    anything else.  This is the lightweight part: O(N) passes, no O(N log N)
    sort over all nodes.
    """
    n, f = features.shape
    row_bytes = f * features.dtype.itemsize
    budget_rows = min(max(int(capacity_bytes) // row_bytes, 0), n)

    counts = node_counts.astype(np.float64)
    mean = counts.mean() if n else 0.0
    hot = np.nonzero(counts > mean)[0]
    if hot.shape[0] > budget_rows:
        # More above-mean nodes than capacity: keep the hottest among them.
        # (Sorting only the above-mean subset keeps this cheap — the subset
        # is small under power-law workloads.)
        hot = hot[np.argsort(-counts[hot], kind="stable")[:budget_rows]]
    elif hot.shape[0] < budget_rows:
        rest = np.nonzero(counts <= mean)[0]
        visited = rest[counts[rest] > 0]
        cold = rest[counts[rest] == 0]
        top_up = np.concatenate([visited, cold])[: budget_rows - hot.shape[0]]
        hot = np.concatenate([hot, top_up])

    position_map = np.full(n, -1, np.int32)
    position_map[hot] = np.arange(hot.shape[0], dtype=np.int32)
    hot_table = features[hot] if hot.shape[0] else np.zeros((1, f), features.dtype)
    return FeatureStore(
        host_table=jnp.asarray(features),
        hot_table=jnp.asarray(hot_table),
        position_map=jnp.asarray(position_map),
    )


def plain_feature_store(features: np.ndarray) -> FeatureStore:
    """No cache: everything is a miss except nothing — position map all −1."""
    n, f = features.shape
    return FeatureStore(
        host_table=jnp.asarray(features),
        hot_table=jnp.zeros((1, f), features.dtype),
        position_map=jnp.full((n,), -1, jnp.int32),
    )
