"""Synthetic power-law graph datasets calibrated to the paper's Table II.

The paper evaluates on Reddit / Yelp / Amazon / Ogbn-products /
Ogbn-papers100M.  Those datasets are not shippable in this container, so we
generate *statistically matched* stand-ins: same average degree, feature
width, class count and train/val/test split, power-law in-degree and
popularity (the property DCI's long-tail argument rests on), scaled down by
a configurable node-count factor.  Generation is deterministic per seed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csc import CSCGraph

__all__ = ["DatasetSpec", "SyntheticGraphDataset", "DATASETS", "load_dataset"]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    num_nodes: int  # full-size node count (Table II)
    avg_degree: float
    feat_dim: int
    num_classes: int
    split: tuple[float, float, float]  # train/val/test fractions
    pareto_alpha: float = 1.3  # in-degree tail heaviness
    popularity_gamma: float = 0.9  # zipf exponent for endpoint popularity


# Table II of the paper.
DATASETS: dict[str, DatasetSpec] = {
    "reddit": DatasetSpec("reddit", 232_965, 50.0, 602, 41, (0.66, 0.10, 0.24)),
    "yelp": DatasetSpec("yelp", 716_480, 10.0, 300, 100, (0.75, 0.10, 0.15)),
    "amazon": DatasetSpec("amazon", 1_598_960, 83.0, 200, 107, (0.85, 0.05, 0.10)),
    "ogbn-products": DatasetSpec("ogbn-products", 2_449_029, 25.0, 100, 47, (0.08, 0.02, 0.90)),
    "ogbn-papers100m": DatasetSpec(
        "ogbn-papers100m", 111_059_956, 29.1, 128, 172, (0.78, 0.08, 0.14)
    ),
}


@dataclasses.dataclass(frozen=True)
class SyntheticGraphDataset:
    spec: DatasetSpec
    graph: CSCGraph
    features: np.ndarray  # float32[N, F]
    labels: np.ndarray  # int32[N]
    train_idx: np.ndarray
    val_idx: np.ndarray
    test_idx: np.ndarray

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    def feature_nbytes_per_row(self) -> int:
        return self.features.shape[1] * self.features.dtype.itemsize


def _power_law_degrees(rng: np.random.Generator, n: int, avg: float, alpha: float) -> np.ndarray:
    raw = rng.pareto(alpha, n) + 1.0
    deg = raw * (avg / raw.mean())
    return np.clip(np.round(deg), 1, max(2, n - 1)).astype(np.int64)


def load_dataset(
    name: str,
    *,
    scale: float = 0.01,
    seed: int = 0,
    max_nodes: int | None = None,
) -> SyntheticGraphDataset:
    """Build the scaled synthetic stand-in for dataset ``name``.

    ``scale`` multiplies the Table II node count (default 1% keeps CI
    fast); ``max_nodes`` caps it (papers100M at 1% would still be 1.1M).
    """
    spec = DATASETS[name]
    rng = np.random.default_rng(seed + hash(name) % (2**31))
    n = max(int(spec.num_nodes * scale), 64)
    if max_nodes is not None:
        n = min(n, max_nodes)

    deg = _power_law_degrees(rng, n, spec.avg_degree, spec.pareto_alpha)
    e = int(deg.sum())
    col_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=col_ptr[1:])

    # Endpoint popularity: zipf over a random permutation of node ids, so
    # "hot" nodes are spread across the id space (as in real graphs).
    ranks = np.arange(1, n + 1, dtype=np.float64)
    pop = ranks ** (-spec.popularity_gamma)
    pop /= pop.sum()
    perm = rng.permutation(n)
    # Draw endpoints from the popularity distribution (with replacement;
    # multi-edges are possible and harmless for sampling workloads).
    draws = rng.choice(n, size=e, p=pop)
    row_index = perm[draws].astype(np.int32)

    graph = CSCGraph(col_ptr=col_ptr, row_index=row_index)

    features = rng.standard_normal((n, spec.feat_dim), dtype=np.float32)
    labels = rng.integers(0, spec.num_classes, n).astype(np.int32)

    order = rng.permutation(n)
    n_train = int(n * spec.split[0])
    n_val = int(n * spec.split[1])
    return SyntheticGraphDataset(
        spec=spec,
        graph=graph,
        features=features,
        labels=labels,
        train_idx=np.sort(order[:n_train]).astype(np.int32),
        val_idx=np.sort(order[n_train : n_train + n_val]).astype(np.int32),
        test_idx=np.sort(order[n_train + n_val :]).astype(np.int32),
    )
