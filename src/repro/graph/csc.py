"""Compressed-sparse-column graph storage (paper §II-C, Fig. 4).

The CSC layout is what neighbor sampling reads: ``col_ptr[v] ..
col_ptr[v+1]`` delimits the in-neighbor list of node ``v`` inside
``row_index``.  DCI's adjacency cache (Fig. 6 / Alg. 1) is a *prefix* of a
two-level-sorted copy of these arrays, so this module also implements the
two-level reorder:

  level 1: nodes ordered by total visit count (descending)     -> fill order
  level 2: within each node, neighbors ordered by visit count  -> prefix
           (descending), so the cached prefix holds the hottest elements

All arrays are int32; ``Values`` from the paper is implicit (unweighted
graphs, all ones), matching what sampling actually touches.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "CSCGraph",
    "two_level_sort",
    "node_visit_totals",
    "build_adj_cache",
    "refresh_adj_cache",
    "AdjRefreshStats",
]


@dataclasses.dataclass(frozen=True)
class CSCGraph:
    """An unweighted directed graph in CSC form (host arrays)."""

    col_ptr: np.ndarray  # int64[N+1] offsets (int64: E can exceed int32 at scale)
    row_index: np.ndarray  # int32[E] in-neighbor ids

    def __post_init__(self):
        if self.col_ptr.ndim != 1 or self.row_index.ndim != 1:
            raise ValueError("col_ptr and row_index must be 1-D")
        if self.col_ptr[0] != 0 or self.col_ptr[-1] != self.row_index.shape[0]:
            raise ValueError("col_ptr must start at 0 and end at num_edges")
        if np.any(np.diff(self.col_ptr) < 0):
            raise ValueError("col_ptr must be non-decreasing")

    @property
    def num_nodes(self) -> int:
        return self.col_ptr.shape[0] - 1

    @property
    def num_edges(self) -> int:
        return self.row_index.shape[0]

    def degrees(self) -> np.ndarray:
        return np.diff(self.col_ptr).astype(np.int32)

    def nbytes(self) -> int:
        return self.col_ptr.nbytes + self.row_index.nbytes


def two_level_sort(graph: CSCGraph, edge_counts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Fig. 6(b): sort each node's neighbor list by visit count, descending.

    Returns ``(sorted_row_index, node_totals)``.  ``sorted_row_index`` is a
    full-length copy of ``row_index`` where every column's elements are in
    descending visit-count order (level-2 sort); ``node_totals`` is the
    per-node total visit count used for the level-1 (fill-order) sort.

    Implemented as one vectorized lexsort over (column id asc, count desc)
    instead of a Python loop over nodes — this is part of why DCI's
    preprocessing is lightweight.
    """
    if edge_counts.shape != graph.row_index.shape:
        raise ValueError("edge_counts must align with row_index")
    n = graph.num_nodes
    col_of_edge = np.repeat(np.arange(n, dtype=np.int64), np.diff(graph.col_ptr))
    # lexsort: primary key last. Sort by column asc, then count desc.
    order = np.lexsort((-edge_counts.astype(np.int64), col_of_edge))
    sorted_row_index = graph.row_index[order]
    return sorted_row_index, node_visit_totals(graph, edge_counts)


def node_visit_totals(graph: CSCGraph, edge_counts: np.ndarray) -> np.ndarray:
    """Per-node total visit count — the level-1 (fill-order) sort key.

    Split out of :func:`two_level_sort` because the serve-time refresh
    re-ranks nodes from updated counts WITHOUT re-sorting the row index
    (the sorted order is frozen at build time; see refresh_adj_cache)."""
    n = graph.num_nodes
    # The refresh path feeds decayed (float) counts; only relative order
    # matters for the fill, so keep float inputs un-truncated.
    dtype = np.float64 if np.issubdtype(edge_counts.dtype, np.floating) else np.int64
    if graph.num_edges:
        # reduceat requires start indices < num_edges; zero-degree nodes can
        # point at the very end — clip, then mask them out below.
        starts = np.minimum(graph.col_ptr[:-1], graph.num_edges - 1)
        node_totals = np.add.reduceat(edge_counts.astype(dtype), starts, dtype=dtype)
    else:
        node_totals = np.zeros(n, dtype)
    # reduceat quirk: zero-degree nodes repeat the next segment; mask them.
    return np.where(np.diff(graph.col_ptr) > 0, node_totals, 0)


@dataclasses.dataclass(frozen=True)
class AdjCache:
    """Device-resident prefix cache of the two-level-sorted CSC (Fig. 6c).

    ``cached_len[v]`` elements of node ``v``'s sorted neighbor list live in
    the cache; the sampler's hit test is ``slot < cached_len[v]``.
    """

    cache_ptr: np.ndarray  # int64[N+1] offsets into cache_row_index
    cache_row_index: np.ndarray  # int32[sum(cached_len)]
    cached_len: np.ndarray  # int32[N]

    @property
    def num_cached_elements(self) -> int:
        return self.cache_row_index.shape[0]

    def nbytes(self) -> int:
        # What the budget pays for: the cached elements themselves. The
        # ptr/len arrays are O(N) bookkeeping shared with the host copy.
        return self.cache_row_index.nbytes


BYTES_PER_ADJ_ELEMENT = 4  # int32 row index


def _prefix_lengths(graph: CSCGraph, node_totals: np.ndarray, capacity_bytes: int) -> np.ndarray:
    """Alg. 1's per-node cached-prefix lengths for a given budget.

    If the whole (sorted) CSC fits, cache it all (Alg. 1 lines 2-4).
    Otherwise fill whole nodes in descending ``node_totals`` order, and cut
    the last node's list where the budget runs out (lines 5-17)."""
    n = graph.num_nodes
    degrees = np.diff(graph.col_ptr)
    budget_elems = max(int(capacity_bytes) // BYTES_PER_ADJ_ELEMENT, 0)

    if graph.num_edges * BYTES_PER_ADJ_ELEMENT <= capacity_bytes:
        return degrees.astype(np.int32)
    fill_order = np.argsort(-node_totals, kind="stable")
    csum = np.cumsum(degrees[fill_order])
    fully = csum <= budget_elems
    cached_len = np.zeros(n, np.int64)
    cached_len[fill_order[fully]] = degrees[fill_order[fully]]
    # Partial fill of the first node that did not fully fit.
    n_full = int(fully.sum())
    if n_full < n:
        used = int(csum[n_full - 1]) if n_full > 0 else 0
        v = fill_order[n_full]
        cached_len[v] = min(budget_elems - used, degrees[v])
    return cached_len.astype(np.int32)


def build_adj_cache(
    graph: CSCGraph,
    sorted_row_index: np.ndarray,
    node_totals: np.ndarray,
    capacity_bytes: int,
) -> AdjCache:
    """Algorithm 1: fill the adjacency cache up to ``capacity_bytes``."""
    n = graph.num_nodes
    cached_len = _prefix_lengths(graph, node_totals, capacity_bytes)

    cache_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(cached_len, out=cache_ptr[1:])
    # Gather each node's prefix from the sorted copy — vectorized ragged
    # arange (no per-node Python loop; preprocessing must stay lightweight).
    total = int(cache_ptr[-1])
    if total > 0:
        lens = cached_len.astype(np.int64)
        idx = (
            np.repeat(graph.col_ptr[:-1], lens)
            + np.arange(total, dtype=np.int64)
            - np.repeat(cache_ptr[:-1], lens)
        )
        cache_row_index = sorted_row_index[idx].astype(np.int32)
    else:
        cache_row_index = np.empty(0, np.int32)
    return AdjCache(cache_ptr=cache_ptr, cache_row_index=cache_row_index, cached_len=cached_len)


@dataclasses.dataclass(frozen=True)
class AdjRefreshStats:
    """What an adjacency-cache delta re-fill actually moved."""

    nodes_changed: int  # nodes whose cached prefix length changed
    elements_kept: int  # elements copied segment-wise from the old cache
    elements_regathered: int  # elements re-gathered from the sorted host CSC
    cached_elements: int  # total cached elements after the refresh
    budget_elements: int

    @property
    def changed(self) -> bool:
        return self.nodes_changed > 0


def refresh_adj_cache(
    graph: CSCGraph,
    sorted_row_index: np.ndarray,
    old: AdjCache,
    node_totals: np.ndarray,
    capacity_bytes: int,
) -> tuple[AdjCache, AdjRefreshStats]:
    """Incremental Alg. 1 re-fill against UPDATED per-node visit totals.

    The two-level sort order is frozen at build time: a node's cached
    prefix of length L is always ``sorted_row_index[col_ptr[v] :
    col_ptr[v] + L]``, whatever epoch filled it.  That invariant is what
    makes the refresh a *delta*: only the level-1 ranking (which nodes,
    how much of each list) moves, so

      * nodes whose prefix length is unchanged have their segment copied
        straight from the old cache arrays (compact memcpy, no gather
        into the full E-sized CSC);
      * only changed nodes' segments are re-gathered from the sorted host
        copy;
      * the device-resident ``col_ptr`` / ``row_index`` (the O(E) arrays)
        are never touched or re-uploaded — only the cache-sized arrays
        move, which is the bounded pause the refresh subsystem promises.

    Freezing the level-2 (within-node) order also keeps sampling
    bit-identical across epochs: a cache hit reads the same neighbor the
    sorted host copy holds at that slot, so a refresh changes hit
    accounting and byte movement, never sampled blocks or outputs.
    """
    n = graph.num_nodes
    new_len = _prefix_lengths(graph, node_totals, capacity_bytes)
    old_len = old.cached_len.astype(np.int64)
    changed = new_len.astype(np.int64) != old_len

    cache_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(new_len, out=cache_ptr[1:])
    total = int(cache_ptr[-1])
    if total > 0:
        lens = new_len.astype(np.int64)
        within = np.arange(total, dtype=np.int64) - np.repeat(cache_ptr[:-1], lens)
        elem_changed = np.repeat(changed, lens)
        cache_row_index = np.empty(total, np.int32)
        keep = ~elem_changed
        if keep.any():
            old_pos = np.repeat(old.cache_ptr[:-1], lens)[keep] + within[keep]
            cache_row_index[keep] = old.cache_row_index[old_pos]
        if elem_changed.any():
            new_pos = np.repeat(graph.col_ptr[:-1], lens)[elem_changed] + within[elem_changed]
            cache_row_index[elem_changed] = sorted_row_index[new_pos].astype(np.int32)
        regathered = int(elem_changed.sum())
    else:
        cache_row_index = np.empty(0, np.int32)
        regathered = 0
    new = AdjCache(cache_ptr=cache_ptr, cache_row_index=cache_row_index, cached_len=new_len)
    return new, AdjRefreshStats(
        nodes_changed=int(changed.sum()),
        elements_kept=total - regathered,
        elements_regathered=regathered,
        cached_elements=total,
        budget_elements=max(int(capacity_bytes) // BYTES_PER_ADJ_ELEMENT, 0),
    )
