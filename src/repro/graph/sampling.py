"""Vectorized neighbor sampling (paper §II-B) with optional adjacency cache.

The sampler is pure JAX and jittable: for every seed node it draws
``fanout`` uniform slots ``r ~ U[0, deg)`` and reads the neighbor at that
slot.  With DCI's adjacency cache active, the hit test is the paper's
single compare ``r < cached_len[v]`` (Fig. 6c): hits read from the compact
cache arrays, misses fall back to the (two-level-sorted) host CSC — the
UVA path on the paper's GPU, the HBM full-table path on TPU.

Zero-degree nodes self-loop (counted as hits: no host access is needed).
Sampling is with replacement; see DESIGN.md §3 for why this does not
change the cache algorithms.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csc import AdjCache, CSCGraph

__all__ = [
    "DeviceGraph",
    "LayerSample",
    "BlockSample",
    "DedupFrontier",
    "dedup_frontier",
    "device_graph",
    "pow2_bucket",
    "sample_neighbors",
    "sample_blocks",
]


@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Graph structure as device arrays, with an optional adjacency cache.

    Without a cache, ``row_index`` is the original CSC order and
    ``cached_len`` is all zeros.  With a cache, ``row_index`` MUST be the
    two-level-sorted copy (slots refer to sorted order on both paths).
    """

    col_ptr: jax.Array  # int32[N+1]
    row_index: jax.Array  # int32[E]   ("host"/UVA side)
    cache_ptr: jax.Array  # int32[N+1]
    cache_row_index: jax.Array  # int32[>=1] (padded to at least 1)
    cached_len: jax.Array  # int32[N]

    @property
    def num_nodes(self) -> int:
        return self.col_ptr.shape[0] - 1

    def tree_flatten(self):
        return (
            (self.col_ptr, self.row_index, self.cache_ptr, self.cache_row_index, self.cached_len),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


jax.tree_util.register_pytree_node(
    DeviceGraph, DeviceGraph.tree_flatten, DeviceGraph.tree_unflatten
)


def device_graph(
    graph: CSCGraph,
    *,
    sorted_row_index: np.ndarray | None = None,
    adj_cache: AdjCache | None = None,
) -> DeviceGraph:
    """Stage a CSC graph (and optionally its DCI adjacency cache) on device."""
    n = graph.num_nodes
    if adj_cache is not None:
        if sorted_row_index is None:
            raise ValueError("adjacency cache requires the two-level-sorted row_index")
        row = sorted_row_index
        cache_ptr = adj_cache.cache_ptr.astype(np.int32)
        cache_row = adj_cache.cache_row_index
        cached_len = adj_cache.cached_len
    else:
        row = graph.row_index if sorted_row_index is None else sorted_row_index
        cache_ptr = np.zeros(n + 1, np.int32)
        cache_row = np.empty(0, np.int32)
        cached_len = np.zeros(n, np.int32)
    if cache_row.shape[0] == 0:
        cache_row = np.zeros(1, np.int32)  # keep gathers well-defined
    return DeviceGraph(
        col_ptr=jnp.asarray(graph.col_ptr, jnp.int32),
        row_index=jnp.asarray(row, jnp.int32),
        cache_ptr=jnp.asarray(cache_ptr, jnp.int32),
        cache_row_index=jnp.asarray(cache_row, jnp.int32),
        cached_len=jnp.asarray(cached_len, jnp.int32),
    )


class LayerSample(dict):
    pass


@dataclasses.dataclass(frozen=True)
class DedupFrontier:
    """Sorted-unique view of one frontier, with jit-stable shapes.

    ``unique_ids[:num_unique]`` are the frontier's distinct node ids in
    ascending order; positions at and beyond ``num_unique`` repeat a pad
    id (a valid node, so padded gathers stay well-defined and are simply
    never referenced).  The pad id is the caller-supplied ``pad_id`` — a
    known-CACHED node, so pad slots resolve as cache hits and can never
    stage a duplicate miss row through
    ``FeatureStore.prefetch_misses`` — falling back to the frontier's
    largest id when no pad is given (or none is cached, signalled by
    ``pad_id < 0``).  ``inverse`` maps every frontier position to
    its slot in ``unique_ids`` — ``unique_ids[inverse]`` reconstructs the
    frontier bit-for-bit, which is the identity the whole dedup feature
    path rests on (gathering unique rows then expanding through
    ``inverse`` equals gathering every duplicate directly).  ``num_unique``
    stays a device scalar so the computation is one fused jit program; the
    runtime pulls it host-side once per batch to pick the pow2 gather
    bucket (:func:`pow2_bucket`).
    """

    unique_ids: jax.Array  # int32[S] sorted; tail padded with a cached (or max) id
    inverse: jax.Array  # int32[S] frontier position -> slot in unique_ids
    num_unique: jax.Array  # int32[] distinct-id count (duplication = S / this)

    def tree_flatten(self):
        return ((self.unique_ids, self.inverse, self.num_unique), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


jax.tree_util.register_pytree_node(
    DedupFrontier, DedupFrontier.tree_flatten, DedupFrontier.tree_unflatten
)


@jax.jit
def dedup_frontier(frontier: jax.Array, pad_id: jax.Array | int | None = None) -> DedupFrontier:
    """Sort-and-unique one frontier on device with static output shapes.

    One argsort + one cumsum + two scatters — no host round trip, no
    data-dependent shapes: the unique set lives in a full-frontier-sized
    array and ``num_unique`` marks the live prefix.  Duplicate positions
    scatter the same value to the same slot, so the result is
    deterministic regardless of scatter order.

    ``pad_id`` fills the tail beyond the live prefix.  Pass a known-CACHED
    node id (``FeatureStore.pad_node_id``) so pad slots are feature-cache
    hits: a tail padded with an UNcached id (the old max-id behavior)
    would look like extra copies of a miss row to any consumer that scans
    the whole bucket — e.g. a warmup-path ``prefetch_misses`` call without
    ``num_live`` — staging duplicate miss rows.  ``pad_id`` is a traced
    operand (no recompile per value); ``None`` or a negative value falls
    back to the frontier's largest id, which keeps cache-less policies
    (every row a miss anyway) on the original behavior.
    """
    ids = frontier.astype(jnp.int32)
    order = jnp.argsort(ids)
    sorted_ids = ids[order]
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    )
    rank = (jnp.cumsum(is_new) - 1).astype(jnp.int32)
    fill = sorted_ids[-1]
    if pad_id is not None:
        pad = jnp.asarray(pad_id, jnp.int32)
        fill = jnp.where(pad >= 0, pad, fill)
    unique = jnp.full(ids.shape, fill, jnp.int32).at[rank].set(sorted_ids)
    inverse = jnp.zeros(ids.shape, jnp.int32).at[order].set(rank)
    return DedupFrontier(unique_ids=unique, inverse=inverse, num_unique=rank[-1] + 1)


def pow2_bucket(n: int, cap: int | None = None) -> int:
    """Smallest power of two >= ``max(n, 1)``, optionally capped at ``cap``.

    The one pow2 padding discipline shared by every dynamic-count device
    structure: the deduped frontier's gather bucket, the miss-path
    prefetch pack (:meth:`repro.graph.features.FeatureStore.prefetch_misses`),
    and the cache-refresh delta scatters — so each compiles O(log S)
    programs across batches with varying counts, not one per count.
    """
    bucket = 1 << max(int(n) - 1, 0).bit_length()
    return bucket if cap is None else min(bucket, int(cap))


def sample_neighbors(
    key: jax.Array, g: DeviceGraph, seeds: jax.Array, fanout: int,
    *, full_neighborhood: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sample ``fanout`` in-neighbors per seed (with replacement).

    Returns ``(neighbors[S, fanout], hits[S, fanout], edge_slots[S, fanout])``
    where ``edge_slots`` are global positions ``col_ptr[v] + r`` used for
    visit counting during pre-sampling.

    ``full_neighborhood=True`` (static) replaces the random draw with the
    deterministic enumeration ``r = arange(fanout) % deg``: when a seed's
    degree equals ``fanout`` every neighbor is taken exactly once, making
    the sampled aggregate EXACTLY the full-neighborhood sum — the bridge
    the layer-wise mode's equivalence tests rest on (higher degrees
    truncate to the first ``fanout`` CSC slots, lower ones wrap).  The key
    is ignored in this mode but kept in the signature so call sites and
    RNG bookkeeping are mode-invariant.
    """
    seeds = seeds.astype(jnp.int32)
    start = g.col_ptr[seeds]  # [S]
    deg = g.col_ptr[seeds + 1] - start  # [S]
    safe_deg = jnp.maximum(deg, 1)
    if full_neighborhood:
        r = jnp.arange(fanout, dtype=jnp.int32)[None, :] % safe_deg[:, None]
    else:
        r = jax.random.randint(key, (seeds.shape[0], fanout), 0, safe_deg[:, None])
    edge_slots = start[:, None] + r
    host_nbr = g.row_index[edge_slots]

    clen = g.cached_len[seeds]  # [S]
    hit = r < clen[:, None]
    cache_idx = g.cache_ptr[seeds][:, None] + jnp.minimum(r, jnp.maximum(clen - 1, 0)[:, None])
    cache_nbr = g.cache_row_index[jnp.minimum(cache_idx, g.cache_row_index.shape[0] - 1)]
    nbr = jnp.where(hit, cache_nbr, host_nbr)

    isolated = (deg == 0)[:, None]
    nbr = jnp.where(isolated, seeds[:, None], nbr)
    hit = jnp.where(isolated, True, hit)
    return nbr, hit, edge_slots


@dataclasses.dataclass(frozen=True)
class BlockSample:
    """Layered mini-batch (GraphSAGE-style blocks).

    ``frontiers[0]`` are the batch seeds; ``frontiers[l+1]`` has layout
    ``[frontiers[l] | neighbors_l.reshape(-1)]`` so the model can split a
    feature matrix over frontier ``l+1`` into (self, neighbors) parts by a
    static reshape.  ``input_nodes`` is the deepest frontier — these are the
    rows the feature loader must fetch.
    """

    frontiers: tuple[jax.Array, ...]
    neighbor_hits: tuple[jax.Array, ...]  # per layer, [S_l, fanout_l]
    edge_slots: tuple[jax.Array, ...]
    fanouts: tuple[int, ...]
    # Sorted-unique view of the deepest frontier (``sample_blocks``'s
    # dedup=True mode); None on the default path.  Only the input frontier
    # is deduped: it is the one the feature loader gathers, and every
    # shallower frontier is a prefix of it, so one unique set covers the
    # whole block.
    dedup: DedupFrontier | None = None

    @property
    def input_nodes(self) -> jax.Array:
        return self.frontiers[-1]

    def adj_hit_stats(self) -> tuple[jax.Array, jax.Array]:
        hits = sum(jnp.sum(h) for h in self.neighbor_hits)
        total = sum(h.size for h in self.neighbor_hits)
        return hits, jnp.asarray(total)


@functools.partial(jax.jit, static_argnames=("fanouts", "dedup", "full_neighborhood"))
def sample_blocks(
    key: jax.Array,
    g: DeviceGraph,
    seeds: jax.Array,
    fanouts: tuple[int, ...],
    dedup: bool = False,
    dedup_pad_id: jax.Array | int | None = None,
    full_neighborhood: bool = False,
) -> BlockSample:
    """Multi-layer fan-out sampling producing GraphSAGE blocks.

    ``fanouts`` is listed outermost-layer-first (the paper's '15,10,5'
    convention); layer 0 of the expansion uses the *last* element, matching
    DGL's semantics where fan-outs map to model layers from input to output.

    ``dedup=True`` additionally sorts-and-uniques the deepest frontier on
    device (:func:`dedup_frontier`) inside the same jit program, so the
    feature path can gather each distinct row once and expand through the
    inverse map; sampling itself — frontiers, hits, edge slots, RNG
    consumption — is bit-identical with the flag on or off.
    ``dedup_pad_id`` is the (traced) known-cached pad id forwarded to
    :func:`dedup_frontier` — a plain int or scalar, never static, so a
    refresh-epoch pad change does not recompile the sampler.
    ``full_neighborhood=True`` (static) enumerates neighbor slots
    deterministically per layer instead of drawing them (see
    :func:`sample_neighbors`); the per-layer key splits still happen so
    frontier layouts and shapes are mode-invariant.
    """
    frontiers = [seeds.astype(jnp.int32)]
    hits_all = []
    slots_all = []
    frontier = frontiers[0]
    for i, fanout in enumerate(reversed(fanouts)):
        key, sub = jax.random.split(key)
        nbr, hit, slots = sample_neighbors(
            sub, g, frontier, fanout, full_neighborhood=full_neighborhood
        )
        frontier = jnp.concatenate([frontier, nbr.reshape(-1)])
        frontiers.append(frontier)
        hits_all.append(hit)
        slots_all.append(slots)
    return BlockSample(
        frontiers=tuple(frontiers),
        neighbor_hits=tuple(hits_all),
        edge_slots=tuple(slots_all),
        fanouts=tuple(fanouts),
        dedup=dedup_frontier(frontier, dedup_pad_id) if dedup else None,
    )


jax.tree_util.register_pytree_node(
    BlockSample,
    lambda b: ((b.frontiers, b.neighbor_hits, b.edge_slots, b.dedup), b.fanouts),
    lambda aux, ch: BlockSample(
        frontiers=ch[0], neighbor_hits=ch[1], edge_slots=ch[2], dedup=ch[3], fanouts=aux
    ),
)


def count_visits(
    num_nodes: int, num_edges: int, blocks: Sequence[BlockSample]
) -> tuple[np.ndarray, np.ndarray]:
    """Pre-sampling visit counters (paper §IV-B).

    Node counts = how often each node's *feature* row is loaded (membership
    in input frontiers); edge counts = how often each adjacency element is
    touched by sampling.  Both are one scatter-add per block.
    """
    node_counts = jnp.zeros(num_nodes, jnp.int32)
    edge_counts = jnp.zeros(num_edges, jnp.int32)
    for b in blocks:
        node_counts = node_counts.at[b.input_nodes].add(1)
        for slots in b.edge_slots:
            edge_counts = edge_counts.at[slots.reshape(-1)].add(1)
    return np.asarray(node_counts), np.asarray(edge_counts)
