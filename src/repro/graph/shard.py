"""Node-id-range sharding of the feature table + feature cache.

The sharded serving path (runtime/sharded_serve.py) partitions DCI's
feature side across a ``jax.sharding`` mesh by contiguous node-id range:
each shard holds its range's slice of the host table, a *local* hot table
re-slotted from the global feature cache (same rows, local slot ids), and
a local position map.  The adjacency cache is replicated per shard, so
only feature rows ever cross shards.

The exchange protocol is the all-to-all the dedup path set up in PR 5:
the device-side **sorted** unique ids partition into contiguous per-shard
segments with one ``searchsorted`` (:meth:`ShardedFeatureStore.partition`
— a stable shard-sort that degenerates to the identity for sorted input,
so unsorted/duplicate-carrying frontiers ride the same code path), each
shard gathers only its resident rows from its own hot/host tables, and
the results are copied back to the assembling device, concatenated, and
inverse-permuted — the caller's existing inverse map then reconstructs
the per-visit layout exactly as in the single-device path.  Every route
is a permutation of the same row copies, so outputs and the hit mask are
bit-for-bit identical to ``FeatureStore.gather`` over the same ids
(property-tested in tests/test_shard.py).

Per-shard pow2 buckets follow the one padding discipline
(:func:`~repro.graph.sampling.pow2_bucket`) and pad with a *shard-local*
known-cached id (:meth:`~repro.graph.features.FeatureStore.pad_node_id`
of the local store): pad slots are local-cache hits, never cross-shard
rows, so no shard ever stages a guaranteed-miss row for padding
(regression-tested in tests/test_dedup.py).
"""

from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import InjectedFault
from repro.core.trace import resolve_tracer
from repro.graph.features import FeatureStore, PrefetchedMisses
from repro.graph.sampling import pow2_bucket

__all__ = [
    "ShardPlan",
    "ShardPartition",
    "ShardedPrefetch",
    "ShardedFeatureStore",
    "make_shard_plan",
    "partition_feature_store",
]


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Contiguous node-id-range partition: shard ``s`` owns
    ``[row_starts[s], row_starts[s+1])``."""

    num_nodes: int
    row_starts: np.ndarray  # int64[num_shards + 1], 0 .. num_nodes

    @property
    def num_shards(self) -> int:
        return len(self.row_starts) - 1

    def bounds(self, s: int) -> tuple[int, int]:
        return int(self.row_starts[s]), int(self.row_starts[s + 1])

    def shard_of(self, ids: np.ndarray) -> np.ndarray:
        """Owning shard of each id.  ``side='right'`` maps an id on a
        boundary to the shard whose range *starts* there, so empty shards
        (equal consecutive starts) never receive ids."""
        return np.searchsorted(self.row_starts, np.asarray(ids), side="right") - 1

    def shard_sizes(self) -> np.ndarray:
        return np.diff(self.row_starts)


def make_shard_plan(num_nodes: int, num_shards: int) -> ShardPlan:
    """Balanced contiguous ranges; the first ``num_nodes % num_shards``
    shards get one extra row."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    base, rem = divmod(num_nodes, num_shards)
    sizes = np.full(num_shards, base, np.int64)
    sizes[:rem] += 1
    starts = np.zeros(num_shards + 1, np.int64)
    np.cumsum(sizes, out=starts[1:])
    return ShardPlan(num_nodes=num_nodes, row_starts=starts)


def partition_feature_store(
    store: FeatureStore, plan: ShardPlan, devices=None
) -> list[FeatureStore]:
    """Slice ``store`` into one local :class:`FeatureStore` per shard.

    Each shard's hot table holds exactly the globally-cached rows in its
    id range, re-slotted in ascending-id order — the same slot discipline
    :func:`~repro.graph.features.build_feature_cache` uses globally, so
    sorted segments keep their contiguous runs for the row-block kernel.
    Hot rows are copied from the host mirror (cached rows are always
    bit-identical copies of host rows, across refreshes too), so every
    sharded gather returns the same float bits as the global one.

    ``devices`` (optional, one jax device per shard — entries may repeat)
    commits each shard's arrays to its device; ``None`` leaves them on
    the default device (the co-resident layout the 1-device CI uses).
    """
    host = store.host_np()
    pos = store.position_np()
    shards: list[FeatureStore] = []
    for s in range(plan.num_shards):
        lo, hi = plan.bounds(s)
        local_pos = np.full(hi - lo, -1, np.int32)
        cached = np.nonzero(pos[lo:hi] >= 0)[0]  # ascending local ids
        local_pos[cached] = np.arange(cached.size, dtype=np.int32)
        hot = np.zeros((max(cached.size, 1), store.feat_dim), host.dtype)
        hot[: cached.size] = host[lo + cached]
        host_slice = host[lo:hi]
        dev = devices[s % len(devices)] if devices else None
        put = (lambda x, d=dev: jax.device_put(x, d)) if dev is not None else jnp.asarray
        fs = FeatureStore(
            host_table=put(host_slice),
            hot_table=put(hot),
            position_map=put(local_pos),
        )
        # Seed the host mirrors so per-batch partitioning never round-trips
        # the device (the global store does the same lazily).
        object.__setattr__(fs, "_host_np", host_slice)
        object.__setattr__(fs, "_position_np", local_pos)
        shards.append(fs)
    return shards


class ShardPartition(typing.NamedTuple):
    """One frontier's shard decomposition — shared by the prefetch stage
    and the gather that consumes it, so both see identical per-shard
    buckets.

    ``seg_ids[s]`` is shard ``s``'s pow2-padded **local** id bucket (None
    for shards with no positions); ``seg_len[s]`` of those are real
    frontier positions and ``seg_live[s]`` of those are live (original
    index < ``num_live`` — the dedup bucket's live prefix).  ``order`` is
    the stable shard-sort permutation over the original positions
    (identity for sorted-unique input); ``inv`` undoes it at reassembly
    (None when the identity)."""

    ids: np.ndarray
    asgn: np.ndarray
    order: np.ndarray
    inv: np.ndarray | None
    seg_ids: list
    seg_len: list
    seg_live: list

    @property
    def num_positions(self) -> int:
        return int(self.ids.size)


class ShardedPrefetch(typing.NamedTuple):
    """Per-shard staged miss packs (parallel to the shard list; None for
    empty segments).  ``num_miss`` sums the per-shard live miss counts —
    equal to the single-device staging count for the same frontier."""

    parts: list
    num_miss: int


@dataclasses.dataclass
class ShardedFeatureStore:
    """The feature side of the dual cache, range-partitioned over shards.

    ``devices`` is the per-shard device list (None → all shards
    co-resident on the default device: partitioning, exchange, and
    accounting all still run — the layout the 1-device regression gate
    exercises).  ``assemble_device`` is where exchanged rows land (the
    device the forward runs on)."""

    plan: ShardPlan
    shards: list
    devices: list | None = None
    assemble_device: object | None = None

    @classmethod
    def partition_store(cls, store: FeatureStore, plan: ShardPlan, devices=None):
        shards = partition_feature_store(store, plan, devices)
        assemble = jax.devices()[0] if devices else None
        return cls(plan=plan, shards=shards, devices=devices, assemble_device=assemble)

    @property
    def num_shards(self) -> int:
        return self.plan.num_shards

    def shard_cached_rows(self) -> list[int]:
        return [int((s.position_np() >= 0).sum()) for s in self.shards]

    # ---------------------------------------------------------- partition
    def partition(self, ids: np.ndarray, *, num_live: int | None = None) -> ShardPartition:
        """Decompose a frontier (any order, duplicates allowed) into
        per-shard local-id buckets.

        A stable sort on the shard assignment groups positions by owning
        shard while preserving original order inside each group — for the
        dedup path's sorted unique ids the permutation is the identity
        and segments are contiguous sorted runs, exactly the
        ``searchsorted`` split the exchange protocol describes.  Each
        segment pads to its own pow2 bucket with the shard-LOCAL cached
        pad id (fallback: local row 0, still in-shard), and ``seg_live``
        clamps the live window so padding is never staged as a miss."""
        ids = np.asarray(ids)
        asgn = self.plan.shard_of(ids)
        order = np.argsort(asgn, kind="stable")
        identity = bool(np.array_equal(order, np.arange(ids.size)))
        starts = np.searchsorted(asgn[order], np.arange(self.num_shards + 1))
        live_limit = ids.size if num_live is None else int(num_live)
        seg_ids: list = []
        seg_len: list = []
        seg_live: list = []
        for s in range(self.num_shards):
            seg_pos = order[starts[s] : starts[s + 1]]
            if seg_pos.size == 0:
                seg_ids.append(None)
                seg_len.append(0)
                seg_live.append(0)
                continue
            lo, _ = self.plan.bounds(s)
            local = (ids[seg_pos] - lo).astype(np.int32)
            bucket = pow2_bucket(int(local.size))
            pad = self.shards[s].pad_node_id()
            buf = np.full(bucket, pad if pad >= 0 else 0, np.int32)
            buf[: local.size] = local
            seg_ids.append(buf)
            seg_len.append(int(local.size))
            # Positions inside a segment keep ascending original order
            # (stable sort), so the live ones are a prefix.
            seg_live.append(int(np.searchsorted(seg_pos, live_limit)))
        inv = None
        if not identity:
            inv = np.empty(ids.size, np.int64)
            inv[order] = np.arange(ids.size)
        return ShardPartition(
            ids=ids,
            asgn=asgn,
            order=order,
            inv=inv,
            seg_ids=seg_ids,
            seg_len=seg_len,
            seg_live=seg_live,
        )

    # ----------------------------------------------------------- prefetch
    def prefetch(
        self,
        part: ShardPartition,
        *,
        pack_in_thread: bool = True,
        down: set | None = None,
    ) -> ShardedPrefetch:
        """Stage each shard's live missed rows onto that shard's device.

        Mirrors :meth:`FeatureStore.prefetch_misses` per shard with
        ``num_live=seg_live[s]``: the union of per-shard live windows is
        exactly the frontier's live prefix, so the summed staging count —
        and the rows staged — match the single-device path.  Shards in
        ``down`` (failover, see :meth:`gather`) are skipped — their device
        is lost, and the host-path failover gather reads nothing staged."""
        parts: list = []
        total = 0
        for s, buf in enumerate(part.seg_ids):
            if buf is None or (down is not None and s in down):
                parts.append(None)
                continue
            staged = self.shards[s].prefetch_misses(
                buf,
                pack_in_thread=pack_in_thread,
                num_live=part.seg_live[s],
                device=self.devices[s % len(self.devices)] if self.devices else None,
            )
            parts.append(staged)
            total += staged.num_miss
        return ShardedPrefetch(parts=parts, num_miss=total)

    # ------------------------------------------------------------- gather
    def gather(
        self,
        part: ShardPartition,
        *,
        use_kernel: bool = False,
        gather_buffers: int = 2,
        prefetched: ShardedPrefetch | None = None,
        row_block: int | None = None,
        tracer=None,
        injector=None,
        down: set | None = None,
    ):
        """Per-shard gather + exchange-back + reassembly.

        Returns ``(features[B, F], hit[B])`` over all ``B`` frontier
        positions — bit-for-bit :meth:`FeatureStore.gather` over the same
        ids: every shard's rows are copies of the same host/hot rows, the
        exchange is pure ``device_put``/concat, and the inverse
        permutation restores the original position order.

        ``injector`` (core/faults.py, optional) charges one
        ``shard_exchange`` fault site per participating shard — restricted
        to the rule's named ``shard`` when it has one — with the raised
        :class:`InjectedFault` carrying the victim shard id.  ``down``
        names shards currently failed over: their segments skip the
        device exchange entirely and are served from the shard's HOST
        mirror (numpy, host memory — the path that survives a lost
        device).  Host-mirror rows are the same bits the device tables
        were filled from and the hit mask still comes from the shard's
        position map, so failover changes WHERE bytes come from, never
        values or hit accounting (per-shard sums still tile the global
        counters — tests/test_faults.py).

        ``tracer`` (core/trace.py, optional) records one ``exchange`` span
        per participating shard on its own ``shard s`` lane — the local
        gather dispatch plus the exchange-back ``device_put`` — and a
        ``reassemble`` span for the concat + inverse permutation;
        failed-over segments get a ``failover`` span instead."""
        tracer = resolve_tracer(tracer)
        rule = injector.plan.rule_for("shard_exchange") if injector is not None else None
        parts_f: list = []
        parts_h: list = []
        for s, buf in enumerate(part.seg_ids):
            if buf is None:
                continue
            if down is not None and s in down:
                with tracer.span(
                    "failover",
                    lane=f"shard {s}",
                    args={"rows": part.seg_len[s]} if tracer.enabled else None,
                ):
                    feats_s, hit_s = self._failover_gather(s, buf, part.seg_len[s])
                parts_f.append(feats_s)
                parts_h.append(hit_s)
                continue
            if rule is not None and (rule.shard is None or rule.shard == s):
                try:
                    injector.check("shard_exchange")
                except InjectedFault as err:
                    if err.shard is None:
                        err.shard = s  # attribute the loss to this exchange
                    raise
            with tracer.span(
                "exchange",
                lane=f"shard {s}",
                args={"rows": part.seg_len[s]} if tracer.enabled else None,
            ):
                dev = self.devices[s % len(self.devices)] if self.devices else None
                ids_dev = jax.device_put(buf, dev) if dev is not None else jnp.asarray(buf)
                pf = prefetched.parts[s] if prefetched is not None else None
                feats_s, hit_s = self.shards[s].gather(
                    ids_dev,
                    use_kernel=use_kernel,
                    gather_buffers=gather_buffers,
                    prefetched=pf,
                    row_block=row_block,
                )
                n = part.seg_len[s]
                feats_s, hit_s = feats_s[:n], hit_s[:n]
                if self.assemble_device is not None:
                    feats_s = jax.device_put(feats_s, self.assemble_device)
                    hit_s = jax.device_put(hit_s, self.assemble_device)
            parts_f.append(feats_s)
            parts_h.append(hit_s)
        with tracer.span("reassemble", lane="exchange"):
            feats = parts_f[0] if len(parts_f) == 1 else jnp.concatenate(parts_f, axis=0)
            hit = parts_h[0] if len(parts_h) == 1 else jnp.concatenate(parts_h, axis=0)
            if part.inv is not None:
                inv = jnp.asarray(part.inv.astype(np.int32))
                feats, hit = feats[inv], hit[inv]
        return feats, hit

    def _failover_gather(self, s: int, buf: np.ndarray, n: int):
        """Serve a DOWN shard's segment from its host mirror.

        The numpy host mirror (``_host_np``, seeded at partition time)
        lives in host memory and survives the loss of the shard's device;
        rows are the same bits the device tables hold and the hit mask is
        the same position-map test, so the failover route is bit-for-bit
        the exchange route — only slower (host gather + one device_put of
        the segment).  ``n`` trims the pow2 pad before assembly, exactly
        like the exchange path."""
        fb = self.shards[s]
        local = np.asarray(buf[:n], np.int64)
        feats_np = fb.host_np()[local]
        hit_np = fb.position_np()[local] >= 0
        if self.assemble_device is not None:
            return (
                jax.device_put(feats_np, self.assemble_device),
                jax.device_put(hit_np, self.assemble_device),
            )
        return jnp.asarray(feats_np), jnp.asarray(hit_np)
