"""Pure-jnp oracle: dense attention with causal / sliding-window / softcap."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array,  # [Sq, D]
    k: jax.Array,  # [Sk, D]
    v: jax.Array,  # [Sk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    s = (q @ k.T) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(q.shape[0])[:, None]
    ki = jnp.arange(k.shape[0])[None, :]
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= qi - ki < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    # Fully-masked rows (can happen with tiny windows) produce NaN in
    # softmax; zero them like flash attention does.
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return p @ v
