"""Public op: multi-head attention via the flash kernel or the oracle.

``q``: [B, Hq, Sq, D]; ``k``/``v``: [B, Hkv, Sk, D] with Hq a multiple of
Hkv (GQA/MQA — kv heads are repeated).  The 2-D kernel is vmapped over
(batch, head).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_2d
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["multi_head_attention"]


def _expand_kv(k: jax.Array, num_q_heads: int) -> jax.Array:
    hkv = k.shape[1]
    if hkv == num_q_heads:
        return k
    assert num_q_heads % hkv == 0
    return jnp.repeat(k, num_q_heads // hkv, axis=1)


def multi_head_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Scaled dot-product attention over batched, multi-head inputs.

    Args:
      q: ``[B, Hq, Sq, D]`` queries.
      k, v: ``[B, Hkv, Sk, D]`` keys/values; ``Hq`` must be a multiple of
        ``Hkv`` (GQA/MQA — kv heads are repeated to match).
      causal: query ``i`` attends only to key positions ``j <= i``
        (positions are row indices; query and key sequences are assumed
        aligned at position 0).
      window: optional sliding-window width — query ``i`` attends only to
        keys with ``i - j < window``, i.e. the last ``window`` positions.
      softcap: optional logit soft-capping ``softcap * tanh(x / softcap)``
        (Gemma-2 style) applied before the softmax.
      use_kernel: route through the Pallas flash kernel (compiled on TPU,
        ``interpret=True`` for CPU validation) instead of the jnp oracle.

    Returns:
      ``[B, Hq, Sq, D]`` attention outputs.
    """
    hq = q.shape[1]
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)
    if use_kernel:
        fn = functools.partial(
            flash_attention_2d, causal=causal, window=window, softcap=softcap, interpret=interpret
        )
    else:
        fn = functools.partial(attention_ref, causal=causal, window=window, softcap=softcap)
    return jax.vmap(jax.vmap(fn))(q, k, v)
