"""Pallas TPU kernel: blocked online-softmax attention.

Supports the attention variants the assigned architectures need at
prefill: causal masking, sliding-window (Gemma-2 local layers, the
long_500k dense variant) and logit soft-capping (Gemma-2).  Classic
flash-attention structure: grid = (q blocks, k blocks) with the k axis
sequential; running max / normalizer / weighted accumulator live in VMEM
scratch across k steps.  Block sizes default to 128×128 — MXU-aligned
(the q·kᵀ and p·v contractions are 128-multiple matmuls) and small enough
that scratch (block_q·d + 2·block_q floats) stays a fraction of VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_2d"]

NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    out_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    block_q: int,
    block_k: int,
    causal: bool,
    window: int | None,
    softcap: float | None,
    num_k_blocks: int,
    kv_len: int,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]
    k = k_ref[...]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qi = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    ki = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = ki < kv_len  # padded kv positions never attend
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= qi - ki < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    # Rows where everything so far is masked keep m == NEG_INF; exp() of
    # (NEG_INF - NEG_INF) would be 1, so zero those probabilities.
    p = jnp.where(s <= NEG_INF, 0.0, p)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_new))
    l_new = alpha * l_prev + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v_ref.dtype), v_ref[...], preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        out_ref[...] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret")
)
def flash_attention_2d(
    q: jax.Array,  # [Sq, D]
    k: jax.Array,  # [Sk, D]
    v: jax.Array,  # [Sk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    sq, d = q.shape
    sk = k.shape[0]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, pad_q), (0, 0)))
    if pad_k:
        # Padded kv positions are excluded inside the kernel via the
        # ``ki < kv_len`` mask.
        k = jnp.pad(k, ((0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, pad_k), (0, 0)))
    sq_p, sk_p = q.shape[0], k.shape[0]
    num_k_blocks = sk_p // block_k

    kern = functools.partial(
        _kernel,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        window=window,
        softcap=softcap,
        num_k_blocks=num_k_blocks,
        kv_len=sk,
    )
    out = pl.pallas_call(
        kern,
        grid=(sq_p // block_q, num_k_blocks),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:sq]
