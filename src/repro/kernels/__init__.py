"""Pallas TPU kernels for the perf-critical compute layers.

Three kernels, each with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py) that tests sweep shapes/dtypes against in interpret mode:

  cached_gather/    DCI's two-source feature-row gather (scalar-prefetched
                    position map; hit -> hot table, miss -> full table)
  seg_agg/          padded-neighborhood aggregation (GNN sum/mean)
  flash_attention/  blocked online-softmax attention with sliding-window
                    and logit-softcap variants (Gemma-2, long_500k)
"""

from repro.kernels.cached_gather.ops import cached_feature_gather
from repro.kernels.flash_attention.ops import multi_head_attention
from repro.kernels.seg_agg.ops import aggregate_neighbors

__all__ = ["cached_feature_gather", "multi_head_attention", "aggregate_neighbors"]
