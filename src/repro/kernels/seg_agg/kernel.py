"""Pallas TPU kernel: GNN neighborhood aggregation over padded blocks.

The with-replacement sampler emits dense ``[S, fanout, F]`` neighborhoods,
so aggregation is a contraction over the fanout axis — a VPU reduction,
no MXU involved.  Tiling: rows (dst nodes) in blocks of ``block_s``,
features in 128-lane multiples; the full fanout axis stays inside the tile
(fanouts are small: 2-15), so the VMEM working set per step is
``block_s * fanout * block_f * 4`` bytes — picked to stay well under the
~16 MB v5e VMEM at the defaults (8 * 15 * 512 * 4 ≈ 0.25 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["seg_agg"]


def _kernel(nbr_ref, out_ref, *, mode: str):
    x = nbr_ref[...]
    acc = x.sum(axis=1)
    if mode == "mean":
        acc = acc / x.shape[1]
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "block_s", "block_f", "interpret"))
def seg_agg(
    nbr_feats: jax.Array,  # [S, fanout, F]
    *,
    mode: str = "sum",
    block_s: int = 8,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    if mode not in ("sum", "mean"):
        raise ValueError(f"unknown mode {mode!r}")
    s, fanout, f = nbr_feats.shape
    block_s = min(block_s, s)
    block_f = min(block_f, f)
    pad_s = (-s) % block_s
    pad_f = (-f) % block_f
    if pad_s or pad_f:
        nbr_feats = jnp.pad(nbr_feats, ((0, pad_s), (0, 0), (0, pad_f)))
    sp, fp = nbr_feats.shape[0], nbr_feats.shape[2]

    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode),
        grid=(sp // block_s, fp // block_f),
        in_specs=[pl.BlockSpec((block_s, fanout, block_f), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((block_s, block_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((sp, fp), nbr_feats.dtype),
        interpret=interpret,
    )(nbr_feats)
    return out[:s, :f]
