"""Public op: padded-neighborhood aggregation (sum/mean).

The GNN layers sample a fixed ``fanout`` per destination node, so the
neighborhood tensor is dense/padded — aggregation is a segment reduction
with static segment length.
"""

from __future__ import annotations

import jax

from repro.kernels.seg_agg.kernel import seg_agg
from repro.kernels.seg_agg.ref import seg_agg_ref

__all__ = ["aggregate_neighbors"]


def aggregate_neighbors(
    nbr_feats: jax.Array,
    *,
    mode: str = "sum",
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Reduce each node's padded neighborhood to one vector.

    Args:
      nbr_feats: ``f32[S, fanout, F]`` — for each of ``S`` destination
        nodes, its ``fanout`` sampled neighbors' feature rows.
      mode: ``"sum"`` or ``"mean"`` (mean divides by the static fanout —
        sampling is with replacement, so there are no empty slots).
      use_kernel: route through the Pallas kernel (compiled on TPU,
        ``interpret=True`` for CPU validation) instead of the jnp oracle.

    Returns:
      ``f32[S, F]`` — the aggregated neighborhood per destination node.
    """
    if use_kernel:
        return seg_agg(nbr_feats, mode=mode, interpret=interpret)
    return seg_agg_ref(nbr_feats, mode=mode)
