"""Public op: padded-neighborhood aggregation (sum/mean)."""

from __future__ import annotations

import jax

from repro.kernels.seg_agg.kernel import seg_agg
from repro.kernels.seg_agg.ref import seg_agg_ref

__all__ = ["aggregate_neighbors"]


def aggregate_neighbors(
    nbr_feats: jax.Array,
    *,
    mode: str = "sum",
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    if use_kernel:
        return seg_agg(nbr_feats, mode=mode, interpret=interpret)
    return seg_agg_ref(nbr_feats, mode=mode)
