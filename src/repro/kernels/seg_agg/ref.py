"""Pure-jnp oracle for padded-neighborhood aggregation."""

from __future__ import annotations

import jax

__all__ = ["seg_agg_ref"]


def seg_agg_ref(nbr_feats: jax.Array, *, mode: str = "sum") -> jax.Array:
    """Aggregate ``[S, fanout, F]`` neighbor features to ``[S, F]``."""
    if mode == "sum":
        return nbr_feats.sum(axis=1)
    if mode == "mean":
        return nbr_feats.mean(axis=1)
    raise ValueError(f"unknown mode {mode!r}")
