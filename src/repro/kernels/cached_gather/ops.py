"""Public op: cached feature gather (kernel on TPU, oracle elsewhere).

``use_kernel=True`` routes through the double-buffered Pallas kernel —
compiled when the backend is TPU, interpret mode elsewhere (the default is
resolved per backend by :func:`~repro.kernels.cached_gather.kernel.default_interpret`,
no longer hardcoded).  The kernel DMAs only the winning source tile per
row (hit → hot cache, miss → host table) and overlaps row ``i+1``'s copy
with row ``i``'s write-back via ``gather_buffers`` VMEM slots.
"""

from __future__ import annotations

import jax

from repro.kernels.cached_gather.kernel import cached_gather, default_interpret
from repro.kernels.cached_gather.ref import cached_gather_ref

__all__ = ["cached_feature_gather", "default_interpret"]


def cached_feature_gather(
    hot_table: jax.Array,
    host_table: jax.Array,
    indices: jax.Array,
    positions: jax.Array,
    *,
    use_kernel: bool = False,
    gather_buffers: int = 2,
    interpret: bool | None = None,
) -> jax.Array:
    """Gather feature rows via DCI's dual-source cache.

    Args:
      hot_table: ``f32[H, F]`` — the device-resident feature cache
        (``H >= 1``; row 0 is a placeholder when the cache is empty).
      host_table: ``f32[N, F]`` — the full host/UVA feature table.
      indices: ``int32[S]`` — node ids to gather (``0 <= id < N``).
      positions: ``int32[S]`` — each id's slot in ``hot_table``, or ``-1``
        for a cache miss (the ``FeatureStore.position_map`` lookup).
      use_kernel: route through the Pallas kernel instead of the jnp
        oracle.
      gather_buffers: VMEM row-tile slots in the kernel (1 = serial
        copies, 2 = double buffering).
      interpret: force interpret mode on/off; ``None`` resolves by backend
        (compiled on TPU, interpret elsewhere).

    Returns:
      ``f32[S, F]`` — row ``i`` is ``hot_table[positions[i]]`` on a hit,
      ``host_table[indices[i]]`` on a miss.
    """
    if use_kernel:
        return cached_gather(
            hot_table,
            host_table,
            indices,
            positions,
            gather_buffers=gather_buffers,
            interpret=interpret,
        )
    return cached_gather_ref(hot_table, host_table, indices, positions)
