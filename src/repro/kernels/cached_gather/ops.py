"""Public op: cached feature gather (kernel on TPU, oracle elsewhere).

On a real TPU deployment ``use_kernel=True`` routes through the Pallas
kernel (compiled); on this CPU container the kernel runs in interpret mode
for validation and the oracle is the production path.  Cost note: the
select-based kernel DMAs both candidate tiles per row; a two-pass
hit-partitioned variant would halve DMA traffic at the cost of a stable
partition — recorded as a §Perf candidate.
"""

from __future__ import annotations

import jax

from repro.kernels.cached_gather.kernel import cached_gather
from repro.kernels.cached_gather.ref import cached_gather_ref

__all__ = ["cached_feature_gather"]


def cached_feature_gather(
    hot_table: jax.Array,
    host_table: jax.Array,
    indices: jax.Array,
    positions: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Gather feature rows via DCI's dual-source cache."""
    if use_kernel:
        return cached_gather(hot_table, host_table, indices, positions, interpret=interpret)
    return cached_gather_ref(hot_table, host_table, indices, positions)
