"""Public op: cached feature gather (kernel on TPU, oracle elsewhere).

On a real TPU deployment ``use_kernel=True`` routes through the Pallas
kernel (compiled); on this CPU container the kernel runs in interpret mode
for validation and the oracle is the production path.  Cost note: the
select-based kernel DMAs both candidate tiles per row; a two-pass
hit-partitioned variant would halve DMA traffic at the cost of a stable
partition — recorded as a §Perf candidate.
"""

from __future__ import annotations

import jax

from repro.kernels.cached_gather.kernel import cached_gather
from repro.kernels.cached_gather.ref import cached_gather_ref

__all__ = ["cached_feature_gather"]


def cached_feature_gather(
    hot_table: jax.Array,
    host_table: jax.Array,
    indices: jax.Array,
    positions: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Gather feature rows via DCI's dual-source cache.

    Args:
      hot_table: ``f32[H, F]`` — the device-resident feature cache
        (``H >= 1``; row 0 is a placeholder when the cache is empty).
      host_table: ``f32[N, F]`` — the full host/UVA feature table.
      indices: ``int32[S]`` — node ids to gather (``0 <= id < N``).
      positions: ``int32[S]`` — each id's slot in ``hot_table``, or ``-1``
        for a cache miss (the ``FeatureStore.position_map`` lookup).
      use_kernel: route through the Pallas kernel (compiled on TPU,
        ``interpret=True`` for CPU validation) instead of the jnp oracle.

    Returns:
      ``f32[S, F]`` — row ``i`` is ``hot_table[positions[i]]`` on a hit,
      ``host_table[indices[i]]`` on a miss.
    """
    if use_kernel:
        return cached_gather(hot_table, host_table, indices, positions, interpret=interpret)
    return cached_gather_ref(hot_table, host_table, indices, positions)
