"""Pallas TPU kernel: DCI's two-source cached row gather, double buffered.

TPU adaptation of the paper's cache-hit feature load (DESIGN.md §3): the
row id (``indices``) and cache slot (``positions``) arrays are *scalar
prefetched* — Pallas knows them before any tile DMA, so the kernel issues
exactly one manual HBM→VMEM copy per feature-row tile from the right
source (hot cache on a hit, full host table on a miss), never both.

The copy schedule is double buffered (``gather_buffers`` VMEM row-tile
slots, default 2): row ``i+1``'s HBM→VMEM copy is started while row
``i``'s tile is being written back, so DMA latency hides behind the
select/write of the previous row — the same overlap the staged batch
executor (runtime/pipeline.py) applies one level up across whole batches.
Completed tiles are written straight into the output batch buffer with a
VMEM→HBM copy (no intermediate per-source partitions, no concat); a slot
is only reused once its previous write-back has drained.

Three scalar operands are prefetched: raw positions (hit test), clamped
positions (safe hot addressing), clamped indices (host addressing).  The
feature axis is tiled at up to 512 lanes (multiples of the 128-lane VREG
width) and forms the grid; rows are walked by an inner loop so the slot
rotation lives in one program.

``interpret=None`` resolves by backend: compiled on TPU, interpret mode
elsewhere (this CPU container).  Older JAX releases lack DMA semantics in
interpret mode; :func:`dma_supported` probes once and ``cached_gather``
falls back to the select-based single-buffered kernel
(:func:`cached_gather_select`) so the op keeps working there.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "cached_gather",
    "cached_gather_blocks",
    "cached_gather_select",
    "default_interpret",
    "dma_supported",
]

LANE = 128
ROW_BLOCK = 8  # default rows per DMA tile in the row-block variant


def default_interpret() -> bool:
    """Compiled on TPU, interpret mode everywhere else (CPU validation)."""
    return jax.default_backend() != "tpu"


# --------------------------------------------------------- double buffered


def _db_kernel(
    idx_ref,
    pos_raw_ref,
    pos_clamped_ref,
    hot_hbm,
    host_hbm,
    out_hbm,
    scratch,
    in_sems,
    out_sems,
    *,
    n_rows: int,
    block_f: int,
    n_buffers: int,
):
    j = pl.program_id(0)
    col = pl.ds(j * block_f, block_f)

    # The DMA descriptor is rebuilt identically at start and wait time (the
    # semaphore carries the in-flight state); the hit test picks the source
    # table, so only the winning row is ever copied.
    def in_copy(slot, i, op):
        hit = pos_raw_ref[i] >= 0

        @pl.when(hit)
        def _():
            op(
                pltpu.make_async_copy(
                    hot_hbm.at[pos_clamped_ref[i], col], scratch.at[slot], in_sems.at[slot]
                )
            )

        @pl.when(~hit)
        def _():
            op(
                pltpu.make_async_copy(
                    host_hbm.at[idx_ref[i], col], scratch.at[slot], in_sems.at[slot]
                )
            )

    def out_copy(slot, i):
        return pltpu.make_async_copy(scratch.at[slot], out_hbm.at[i, col], out_sems.at[slot])

    if n_buffers == 1:  # serial ablation: copy, wait, write back, wait
        def serial_body(i, _):
            in_copy(0, i, lambda dma: dma.start())
            in_copy(0, i, lambda dma: dma.wait())
            dma = out_copy(0, i)
            dma.start()
            dma.wait()
            return 0

        jax.lax.fori_loop(0, n_rows, serial_body, 0)
        return

    in_copy(0, 0, lambda dma: dma.start())

    def body(i, _):
        slot = jax.lax.rem(i, n_buffers)
        nxt = jax.lax.rem(i + 1, n_buffers)

        @pl.when(i + 1 < n_rows)
        def _():
            # Reusing a slot: its previous write-back must have drained
            # before the incoming copy may overwrite the tile.
            @pl.when(i + 1 >= n_buffers)
            def _():
                out_copy(nxt, i + 1 - n_buffers).wait()

            in_copy(nxt, i + 1, lambda dma: dma.start())

        in_copy(slot, i, lambda dma: dma.wait())
        out_copy(slot, i).start()
        return 0

    jax.lax.fori_loop(0, n_rows, body, 0)

    tail = jnp.minimum(n_rows, n_buffers)

    def drain(k, _):
        i = n_rows - tail + k

        @pl.when(i < n_rows)
        def _():
            out_copy(jax.lax.rem(i, n_buffers), i).wait()

        return 0

    jax.lax.fori_loop(0, tail, drain, 0)


@functools.partial(jax.jit, static_argnames=("block_f", "gather_buffers", "interpret"))
def _cached_gather_db(
    hot_table: jax.Array,
    host_table: jax.Array,
    indices: jax.Array,
    positions: jax.Array,
    *,
    block_f: int,
    gather_buffers: int,
    interpret: bool,
) -> jax.Array:
    s = indices.shape[0]
    f = host_table.shape[1]
    block_f = min(block_f, f)
    if f % block_f != 0:
        pad = block_f - f % block_f
        hot_table = jnp.pad(hot_table, ((0, 0), (0, pad)))
        host_table = jnp.pad(host_table, ((0, 0), (0, pad)))
    fp = host_table.shape[1]

    idx = jnp.clip(indices.astype(jnp.int32), 0, host_table.shape[0] - 1)
    pos_raw = positions.astype(jnp.int32)
    pos_clamped = jnp.clip(pos_raw, 0, hot_table.shape[0] - 1)

    out = pl.pallas_call(
        functools.partial(_db_kernel, n_rows=s, block_f=block_f, n_buffers=gather_buffers),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(fp // block_f,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),  # hot table stays in HBM
                pl.BlockSpec(memory_space=pltpu.ANY),  # host table stays in HBM
            ],
            out_specs=pl.BlockSpec(memory_space=pltpu.ANY),  # the batch buffer
            scratch_shapes=[
                pltpu.VMEM((gather_buffers, block_f), host_table.dtype),
                pltpu.SemaphoreType.DMA((gather_buffers,)),
                pltpu.SemaphoreType.DMA((gather_buffers,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s, fp), host_table.dtype),
        interpret=interpret,
    )(idx, pos_raw, pos_clamped, hot_table, host_table)
    return out[:, :f]


# ---------------------------------------------------------- row-block tiles


def _blk_kernel(
    idx_ref,
    pos_raw_ref,
    pos_clamped_ref,
    blk_mode_ref,
    blk_start_ref,
    hot_hbm,
    host_hbm,
    out_hbm,
    scratch,
    in_sems,
    out_sems,
    *,
    n_blocks: int,
    row_block: int,
    block_f: int,
    n_buffers: int,
):
    """Row-block variant of :func:`_db_kernel` (same rotation, coarser tiles).

    Sorted unique frontiers make whole row blocks land on *consecutive*
    source rows (hit runs are consecutive hot-table slots because slots are
    assigned in node-id order; miss runs are consecutive prefetch-pack
    slots or dense id ranges).  Per block, the prefetched ``blk_mode``
    says how it was classified host-side: 1 = contiguous hit run → ONE
    HBM→VMEM DMA for all ``row_block`` rows from the hot table, 2 =
    contiguous miss run → one DMA from the host table, 0 = mixed/broken →
    per-row copies into the block's scratch tile (the original
    one-descriptor-per-row schedule, confined to blocks that need it).
    Write-back is always one VMEM→HBM DMA per block — output rows are
    consecutive by construction.  The ``gather_buffers`` slots rotate at
    block granularity.
    """
    j = pl.program_id(0)
    col = pl.ds(j * block_f, block_f)

    def in_copy(slot, b, op):
        mode = blk_mode_ref[b]

        @pl.when(mode == 1)
        def _():
            op(
                pltpu.make_async_copy(
                    hot_hbm.at[pl.ds(blk_start_ref[b], row_block), col],
                    scratch.at[slot],
                    in_sems.at[slot],
                )
            )

        @pl.when(mode == 2)
        def _():
            op(
                pltpu.make_async_copy(
                    host_hbm.at[pl.ds(blk_start_ref[b], row_block), col],
                    scratch.at[slot],
                    in_sems.at[slot],
                )
            )

        @pl.when(mode == 0)
        def _():
            # Broken run: per-row winning-source copies into the block
            # tile.  Starts and waits rebuild identical descriptors on the
            # block's one semaphore, so the wait pass drains exactly the
            # copies the start pass issued.
            def row(r, _):
                i = b * row_block + r
                hit = pos_raw_ref[i] >= 0

                @pl.when(hit)
                def _():
                    op(
                        pltpu.make_async_copy(
                            hot_hbm.at[pos_clamped_ref[i], col],
                            scratch.at[slot, r],
                            in_sems.at[slot],
                        )
                    )

                @pl.when(~hit)
                def _():
                    op(
                        pltpu.make_async_copy(
                            host_hbm.at[idx_ref[i], col],
                            scratch.at[slot, r],
                            in_sems.at[slot],
                        )
                    )

                return 0

            jax.lax.fori_loop(0, row_block, row, 0)

    def out_copy(slot, b):
        return pltpu.make_async_copy(
            scratch.at[slot], out_hbm.at[pl.ds(b * row_block, row_block), col], out_sems.at[slot]
        )

    if n_buffers == 1:  # serial ablation at block granularity
        def serial_body(b, _):
            in_copy(0, b, lambda dma: dma.start())
            in_copy(0, b, lambda dma: dma.wait())
            dma = out_copy(0, b)
            dma.start()
            dma.wait()
            return 0

        jax.lax.fori_loop(0, n_blocks, serial_body, 0)
        return

    in_copy(0, 0, lambda dma: dma.start())

    def body(b, _):
        slot = jax.lax.rem(b, n_buffers)
        nxt = jax.lax.rem(b + 1, n_buffers)

        @pl.when(b + 1 < n_blocks)
        def _():
            @pl.when(b + 1 >= n_buffers)
            def _():
                out_copy(nxt, b + 1 - n_buffers).wait()

            in_copy(nxt, b + 1, lambda dma: dma.start())

        in_copy(slot, b, lambda dma: dma.wait())
        out_copy(slot, b).start()
        return 0

    jax.lax.fori_loop(0, n_blocks, body, 0)

    tail = jnp.minimum(n_blocks, n_buffers)

    def drain(k, _):
        b = n_blocks - tail + k

        @pl.when(b < n_blocks)
        def _():
            out_copy(jax.lax.rem(b, n_buffers), b).wait()

        return 0

    jax.lax.fori_loop(0, tail, drain, 0)


@functools.partial(
    jax.jit, static_argnames=("row_block", "block_f", "gather_buffers", "interpret")
)
def _cached_gather_blocks(
    hot_table: jax.Array,
    host_table: jax.Array,
    indices: jax.Array,
    positions: jax.Array,
    *,
    row_block: int,
    block_f: int,
    gather_buffers: int,
    interpret: bool,
) -> jax.Array:
    s = indices.shape[0]
    f = host_table.shape[1]
    block_f = min(block_f, f)
    if f % block_f != 0:
        pad = block_f - f % block_f
        hot_table = jnp.pad(hot_table, ((0, 0), (0, pad)))
        host_table = jnp.pad(host_table, ((0, 0), (0, pad)))
    fp = host_table.shape[1]

    # Pad the row axis to whole blocks; pad rows are misses of host row 0,
    # gathered into the padded output tail and sliced off.  A pad inside
    # the last block just breaks that block's run (mode 0).
    sp = -(-s // row_block) * row_block
    idx = jnp.clip(indices.astype(jnp.int32), 0, host_table.shape[0] - 1)
    # Both source tables must hold at least one whole row block: the
    # run-DMA slice has a static [row_block, block_f] size, so tracing it
    # (interpret mode evaluates both sides of every pl.when) requires the
    # operand to be that tall even when no run could classify.  Classified
    # runs are in range by construction, so the pad rows are never read.
    if hot_table.shape[0] < row_block:
        hot_table = jnp.pad(hot_table, ((0, row_block - hot_table.shape[0]), (0, 0)))
    if host_table.shape[0] < row_block:
        host_table = jnp.pad(host_table, ((0, row_block - host_table.shape[0]), (0, 0)))
    pos_raw = positions.astype(jnp.int32)
    if sp != s:
        idx = jnp.pad(idx, (0, sp - s))
        pos_raw = jnp.pad(pos_raw, (0, sp - s), constant_values=-1)
    pos_clamped = jnp.clip(pos_raw, 0, hot_table.shape[0] - 1)
    n_blocks = sp // row_block

    # Host-side (well, jnp-side — still on device, still prefetched as
    # scalars) run classification: a block is one DMA when all its rows
    # read the same source at consecutive row indices.
    hit = pos_raw >= 0
    src = jnp.where(hit, pos_clamped, idx).reshape(n_blocks, row_block)
    hit_b = hit.reshape(n_blocks, row_block)
    if row_block > 1:
        contig = jnp.all(src[:, 1:] == src[:, :-1] + 1, axis=1)
    else:
        contig = jnp.ones((n_blocks,), bool)
    all_hit = jnp.all(hit_b, axis=1)
    all_miss = jnp.all(~hit_b, axis=1)
    blk_mode = jnp.where(
        contig & all_hit, 1, jnp.where(contig & all_miss, 2, 0)
    ).astype(jnp.int32)
    # Contiguous runs must fit the source table: the run reads rows
    # [start, start+row_block), and every row of a classified run is an
    # in-range per-row index, so the run itself is in range by
    # construction — blk_start is only read for modes 1/2.
    blk_start = src[:, 0]

    out = pl.pallas_call(
        functools.partial(
            _blk_kernel,
            n_blocks=n_blocks,
            row_block=row_block,
            block_f=block_f,
            n_buffers=gather_buffers,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(fp // block_f,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),  # hot table stays in HBM
                pl.BlockSpec(memory_space=pltpu.ANY),  # host table stays in HBM
            ],
            out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
            scratch_shapes=[
                pltpu.VMEM((gather_buffers, row_block, block_f), host_table.dtype),
                pltpu.SemaphoreType.DMA((gather_buffers,)),
                pltpu.SemaphoreType.DMA((gather_buffers,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((sp, fp), host_table.dtype),
        interpret=interpret,
    )(idx, pos_raw, pos_clamped, blk_mode, blk_start, hot_table, host_table)
    return out[:s, :f]


def cached_gather_blocks(
    hot_table: jax.Array,  # [H, F]
    host_table: jax.Array,  # [N, F]
    indices: jax.Array,  # int32 [S]
    positions: jax.Array,  # int32 [S] (slot or -1)
    *,
    row_block: int = ROW_BLOCK,
    block_f: int = 512,
    gather_buffers: int = 2,
    interpret: bool | None = None,
) -> jax.Array:
    """Row-block two-source gather for sorted-run frontiers.

    Semantics are identical to :func:`cached_gather` for ANY index order —
    blocks that are not contiguous single-source runs fall back to per-row
    copies inside the kernel — but the intended caller hands it a deduped
    (sorted unique) frontier, where most blocks collapse to one DMA
    descriptor per ``row_block`` rows.  Falls back to :func:`cached_gather`
    where interpret-mode DMA is unavailable or ``row_block == 1``.
    """
    if hot_table.shape[1] != host_table.shape[1]:
        raise ValueError("hot and host tables must share the feature dim")
    if gather_buffers < 1:
        raise ValueError(f"gather_buffers must be >= 1, got {gather_buffers}")
    if row_block < 1:
        raise ValueError(f"row_block must be >= 1, got {row_block}")
    if interpret is None:
        interpret = default_interpret()
    if indices.shape[0] == 0:
        return jnp.zeros((0, host_table.shape[1]), host_table.dtype)
    if row_block == 1 or not dma_supported():
        return cached_gather(
            hot_table,
            host_table,
            indices,
            positions,
            block_f=block_f,
            gather_buffers=gather_buffers,
            interpret=interpret,
        )
    return _cached_gather_blocks(
        hot_table,
        host_table,
        indices,
        positions,
        row_block=row_block,
        block_f=block_f,
        gather_buffers=gather_buffers,
        interpret=interpret,
    )


# ------------------------------------------------- select-based (fallback)


def _select_kernel(idx_ref, pos_raw_ref, pos_clamped_ref, hot_ref, host_ref, out_ref):
    del idx_ref, pos_clamped_ref
    i = pl.program_id(0)
    hit = pos_raw_ref[i] >= 0
    out_ref[...] = jnp.where(hit, hot_ref[...], host_ref[...])


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def cached_gather_select(
    hot_table: jax.Array,  # [H, F]
    host_table: jax.Array,  # [N, F]
    indices: jax.Array,  # int32 [S]
    positions: jax.Array,  # int32 [S] (slot or -1)
    *,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Single-buffered variant: BlockSpec index_maps stage BOTH candidate
    tiles per row and the body selects between them — twice the DMA traffic
    of the double-buffered kernel, but it needs no DMA primitives, so it is
    the fallback on JAX versions whose interpret mode lacks them."""
    if hot_table.shape[1] != host_table.shape[1]:
        raise ValueError("hot and host tables must share the feature dim")
    s = indices.shape[0]
    f = host_table.shape[1]
    block_f = min(block_f, f)
    if f % block_f != 0:
        pad = block_f - f % block_f
        hot_table = jnp.pad(hot_table, ((0, 0), (0, pad)))
        host_table = jnp.pad(host_table, ((0, 0), (0, pad)))
    fp = host_table.shape[1]

    idx = jnp.clip(indices.astype(jnp.int32), 0, host_table.shape[0] - 1)
    pos_raw = positions.astype(jnp.int32)
    pos_clamped = jnp.clip(pos_raw, 0, hot_table.shape[0] - 1)

    grid = (s, fp // block_f)
    out = pl.pallas_call(
        _select_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                # hot tile: row picked by the prefetched (clamped) cache slot
                pl.BlockSpec((1, block_f), lambda i, j, idx, praw, pcl: (pcl[i], j)),
                # host tile: row picked by the prefetched node id
                pl.BlockSpec((1, block_f), lambda i, j, idx, praw, pcl: (idx[i], j)),
            ],
            out_specs=pl.BlockSpec((1, block_f), lambda i, j, idx, praw, pcl: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((s, fp), host_table.dtype),
        interpret=interpret,
    )(idx, pos_raw, pos_clamped, hot_table, host_table)
    return out[:, :f]


# ------------------------------------------------------------- public entry

_DMA_PROBE: bool | None = None


def dma_supported() -> bool:
    """Once per process: can this backend/JAX run the manual-DMA kernel?

    TPU always can; in interpret mode older JAX releases lack DMA
    semantics, so a tiny probe call decides (and its failure is the
    fallback signal, not an error)."""
    global _DMA_PROBE
    if jax.default_backend() == "tpu":
        return True
    if _DMA_PROBE is None:
        try:
            hot = jnp.zeros((1, LANE), jnp.float32)
            host = jnp.ones((2, LANE), jnp.float32)
            idx = jnp.zeros((2,), jnp.int32)
            pos = jnp.array([-1, 0], jnp.int32)
            out = _cached_gather_db(
                hot, host, idx, pos, block_f=LANE, gather_buffers=2, interpret=True
            )
            _DMA_PROBE = bool(out[0, 0] == 1.0 and out[1, 0] == 0.0)
        except Exception:  # pragma: no cover - old-JAX interpret mode
            _DMA_PROBE = False
    return _DMA_PROBE


def cached_gather(
    hot_table: jax.Array,  # [H, F]
    host_table: jax.Array,  # [N, F]
    indices: jax.Array,  # int32 [S]
    positions: jax.Array,  # int32 [S] (slot or -1)
    *,
    block_f: int = 512,
    gather_buffers: int = 2,
    interpret: bool | None = None,
) -> jax.Array:
    """Double-buffered two-source gather; see the module docstring.

    ``interpret=None`` resolves by backend (compiled on TPU, interpret
    elsewhere); ``gather_buffers`` is the number of VMEM row-tile slots
    (1 = serial copies, 2 = double buffering, the default).  Falls back to
    :func:`cached_gather_select` where interpret-mode DMA is unavailable.
    """
    if hot_table.shape[1] != host_table.shape[1]:
        raise ValueError("hot and host tables must share the feature dim")
    if gather_buffers < 1:
        raise ValueError(f"gather_buffers must be >= 1, got {gather_buffers}")
    if interpret is None:
        interpret = default_interpret()
    if indices.shape[0] == 0:  # nothing to gather; skip the kernel launch
        return jnp.zeros((0, host_table.shape[1]), host_table.dtype)
    if not dma_supported():
        return cached_gather_select(
            hot_table, host_table, indices, positions, block_f=block_f, interpret=interpret
        )
    return _cached_gather_db(
        hot_table,
        host_table,
        indices,
        positions,
        block_f=block_f,
        gather_buffers=gather_buffers,
        interpret=interpret,
    )
