"""Pallas TPU kernel: DCI's two-source cached row gather.

TPU adaptation of the paper's cache-hit feature load (DESIGN.md §3): the
row id (``indices``) and cache slot (``positions``) arrays are *scalar
prefetched* — Pallas knows them before tile DMA, so each grid step DMAs
exactly one feature-row tile from the right source (hot cache vs full
table) HBM→VMEM.  The feature axis is tiled at up to 512 lanes (multiples
of the 128-lane VREG width); rows are the outer grid dimension.

A hit (`pos >= 0`) reads the hot-table row, a miss reads the host-table
row.  Addressing happens in the BlockSpec index_map (so no gather
instruction runs in the body); the body is a select between the two staged
tiles.  Three scalar operands are prefetched: raw positions (hit test),
clamped positions (safe hot addressing), clamped indices (host addressing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["cached_gather"]

LANE = 128


def _kernel(idx_ref, pos_raw_ref, pos_clamped_ref, hot_ref, host_ref, out_ref):
    del idx_ref, pos_clamped_ref
    i = pl.program_id(0)
    hit = pos_raw_ref[i] >= 0
    out_ref[...] = jnp.where(hit, hot_ref[...], host_ref[...])


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def cached_gather(
    hot_table: jax.Array,  # [H, F]
    host_table: jax.Array,  # [N, F]
    indices: jax.Array,  # int32 [S]
    positions: jax.Array,  # int32 [S] (slot or -1)
    *,
    block_f: int = 512,
    interpret: bool = True,
) -> jax.Array:
    if hot_table.shape[1] != host_table.shape[1]:
        raise ValueError("hot and host tables must share the feature dim")
    s = indices.shape[0]
    f = host_table.shape[1]
    block_f = min(block_f, f)
    if f % block_f != 0:
        pad = block_f - f % block_f
        hot_table = jnp.pad(hot_table, ((0, 0), (0, pad)))
        host_table = jnp.pad(host_table, ((0, 0), (0, pad)))
    fp = host_table.shape[1]

    idx = jnp.clip(indices.astype(jnp.int32), 0, host_table.shape[0] - 1)
    pos_raw = positions.astype(jnp.int32)
    pos_clamped = jnp.clip(pos_raw, 0, hot_table.shape[0] - 1)

    grid = (s, fp // block_f)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                # hot tile: row picked by the prefetched (clamped) cache slot
                pl.BlockSpec((1, block_f), lambda i, j, idx, praw, pcl: (pcl[i], j)),
                # host tile: row picked by the prefetched node id
                pl.BlockSpec((1, block_f), lambda i, j, idx, praw, pcl: (idx[i], j)),
            ],
            out_specs=pl.BlockSpec((1, block_f), lambda i, j, idx, praw, pcl: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((s, fp), host_table.dtype),
        interpret=interpret,
    )(idx, pos_raw, pos_clamped, hot_table, host_table)
    return out[:, :f]
