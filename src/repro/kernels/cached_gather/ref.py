"""Pure-jnp oracle for the DCI two-source cached feature gather."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cached_gather_ref"]


def cached_gather_ref(
    hot_table: jax.Array,  # [H, F]
    host_table: jax.Array,  # [N, F]
    indices: jax.Array,  # int32 [S] node ids
    positions: jax.Array,  # int32 [S] hot slot or -1
) -> jax.Array:
    hit = positions >= 0
    safe_pos = jnp.clip(positions, 0, hot_table.shape[0] - 1)
    safe_idx = jnp.clip(indices, 0, host_table.shape[0] - 1)
    return jnp.where(hit[:, None], hot_table[safe_pos], host_table[safe_idx])
