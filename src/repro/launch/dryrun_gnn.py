import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Pod-scale GNN inference dry-run — DCI's own workload on the production
mesh (beyond-paper: the paper is single-GPU).

Setup: an Ogbn-papers100M-scale graph (111M nodes / 1.6B edges / 128-dim
features) abstractly staged on the 16x16 mesh — features and adjacency
row-sharded across all 256 chips, GNN parameters replicated.  One
mini-batch inference step = fan-out sampling (adjacency gathers) + feature
gather + 3-layer GraphSAGE.

Two variants bracket DCI's dual-cache benefit:

  cold — every gather hits the *sharded* tables: cross-chip traffic
         (the distributed analogue of the paper's UVA miss path);
  hot  — every gather hits a per-chip *replicated* hot cache sized by the
         DCI budget (the 100% hit-rate bound; misses cost ~0 collectives).

At hit rate h the expected collective term is ≈ (1−h)·cold + h·hot; the
paper's measured hit rates (0.7–0.99 at modest budgets) put real traffic
near the hot bound.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun_gnn [--batch 1024]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze_hlo
from repro.launch.mesh import HW, make_production_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

# Ogbn-papers100M scale, padded to multiples of 256 so flat tables shard
# evenly across all chips.
N = 111_059_968  # nodes (111,059,956 padded)
E = 1_615_686_144  # edges (1,615,685,872 padded)
F = 128
FANOUTS = (15, 10, 5)
HOT_ROWS = 4_000_000  # ~1GB bf16 hot feature cache per chip (DCI budget)
HOT_EDGES = 64_000_000  # ~256MB hot adjacency elements per chip


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def frontier_sizes(batch):
    sizes = [batch]
    for f in reversed(FANOUTS):
        sizes.append(sizes[-1] * f)  # neighbor draws per layer
    return sizes


def make_step(variant: str, batch: int):
    """Returns (fn, abstract args, in_specs)."""
    sizes = frontier_sizes(batch)
    n_input = batch
    for f in reversed(FANOUTS):
        n_input *= 1 + f

    def step(col_ptr, row_index, features, hot_feat, params, seeds, key):
        frontier = seeds
        for f in reversed(FANOUTS):
            start = col_ptr[frontier]
            deg = col_ptr[jnp.minimum(frontier + 1, N - 1)] - start
            key, sub = jax.random.split(key)
            r = jax.random.randint(sub, (frontier.shape[0], f), 0, jnp.maximum(deg, 1)[:, None])
            slots = start[:, None] + r
            if variant == "cold":
                nbr = row_index[slots]  # sharded-table gather (cross-chip)
            else:
                nbr = row_index[jnp.minimum(slots, HOT_EDGES - 1)]  # hot prefix
            frontier = jnp.concatenate([frontier, nbr.reshape(-1)])
        if variant == "cold":
            feats = features[frontier]
        else:
            feats = hot_feat[jnp.minimum(frontier, HOT_ROWS - 1)]
        # 3-layer GraphSAGE (replicated params)
        h = feats.astype(jnp.float32)
        for li, f in enumerate(FANOUTS):
            w_self, w_nbr = params[li]
            ndst = h.shape[0] // (1 + list(reversed(FANOUTS))[li])
            self_h = h[:ndst]
            nbr_h = h[ndst:].reshape(ndst, -1, h.shape[-1]).sum(1)
            h = jax.nn.relu(self_h @ w_self + nbr_h @ w_nbr)
        return h

    dims = [F, 128, 128, 47]
    params = tuple(
        (_sds((dims[i], dims[i + 1]), jnp.float32), _sds((dims[i], dims[i + 1]), jnp.float32))
        for i in range(3)
    )
    args = (
        _sds((N,), jnp.int64),  # col_ptr starts (padded; start[v+1]-start[v] via shifted gather)
        _sds((E if variant == "cold" else HOT_EDGES,), jnp.int32),
        _sds((N, F), jnp.bfloat16),
        _sds((HOT_ROWS, F), jnp.bfloat16),
        params,
        _sds((batch,), jnp.int32),
        _sds((2,), jnp.uint32),
    )
    shard_all = ("data", "model")
    in_specs = (
        P(shard_all) if variant == "cold" else P(None),  # col_ptr
        P(shard_all) if variant == "cold" else P(None),  # row_index (hot: per-chip)
        P(shard_all, None),  # features always sharded (too big to replicate)
        P(None, None),  # hot feature cache replicated per chip
        jax.tree.map(lambda _: P(None, None), params),
        P(None),
        P(None),
    )
    return step, args, in_specs


def run(variant: str, batch: int) -> dict:
    mesh = make_production_mesh()
    step, args, in_specs = make_step(variant, batch)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), in_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    with mesh:
        compiled = jax.jit(step, in_shardings=shardings).lower(*args).compile()
    s = analyze_hlo(compiled.as_text())
    coll = sum(s.collective_bytes.values())
    return {
        "variant": variant,
        "collective_bytes_per_dev": coll,
        "collective_s": coll / HW["ici_bw_per_link"],
        "flops_per_dev": s.flops,
        "compute_s": s.flops / HW["peak_flops_bf16"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=1024)
    args = ap.parse_args()
    rows = [run(v, args.batch) for v in ("cold", "hot")]
    for r in rows:
        print(
            f"[gnn-pod] {r['variant']:4s} collective {r['collective_bytes_per_dev']:.3e} B/dev "
            f"({r['collective_s']*1e3:.2f} ms) compute {r['compute_s']*1e3:.2f} ms"
        )
    cold, hot = rows
    saved = cold["collective_bytes_per_dev"] - hot["collective_bytes_per_dev"]
    print(
        f"[gnn-pod] per-chip cross-chip gather traffic eliminated at 100% hit rate: "
        f"{saved:.3e} B/step ({saved / HW['ici_bw_per_link'] * 1e3:.2f} ms of ICI)"
    )
    print("[gnn-pod] at the paper's measured hit rates (0.7-0.99) DCI removes")
    print("          70-99% of that traffic (EXPERIMENTS.md §Dry-run).")


if __name__ == "__main__":
    main()
