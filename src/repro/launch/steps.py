"""Step functions the launcher jits: train_step / prefill_step / serve_step."""

from __future__ import annotations


import jax

from repro.models.lm.config import LMConfig
from repro.models.lm.model import decode_step, prefill, train_loss
from repro.optim.adamw import adamw_update

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step"]


def make_train_step(cfg: LMConfig, *, base_lr: float = 3e-4):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: train_loss(p, batch, cfg))(params)
        new_params, new_opt = adamw_update(params, grads, opt_state, base_lr=base_lr)
        return new_params, new_opt, loss

    return train_step


def make_prefill_step(cfg: LMConfig, *, cache_size: int | None = None, long_mode: bool = False):
    def prefill_step(params, batch):
        return prefill(params, batch, cfg, cache_size=cache_size, long_mode=long_mode)

    return prefill_step


def make_serve_step(cfg: LMConfig, *, long_mode: bool = False, mla_absorb: bool = False):
    def serve_step(params, tokens, caches, cache_len):
        return decode_step(
            params, tokens, caches, cache_len, cfg, long_mode=long_mode, mla_absorb=mla_absorb
        )

    return serve_step
