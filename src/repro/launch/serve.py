"""Serving driver: batched prefill + decode with the DCI serving caches.

``python -m repro.launch.serve --arch gemma-2b --smoke --requests 16``
runs: build model → profile a request sample → Eq.1-allocate the dual
cache (hot embeddings / hot experts) → prefill the batch → decode N tokens,
reporting tokens/s and cache hit rates.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data.tokens import TokenStream
from repro.models.lm.model import decode_step, init_params, prefill
from repro.runtime.lm_cache import build_serving_caches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--cache-mb", type=float, default=4.0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_layers > 0 or cfg.input_mode == "embeds":
        raise SystemExit("serve driver targets decoder-only token archs")
    params = init_params(jax.random.PRNGKey(0), cfg)

    stream = TokenStream(vocab=cfg.vocab, seed=1)
    rng = np.random.default_rng(2)
    prompts = stream.sample(rng, args.requests, args.prompt_len)

    # ---- DCI: profile + allocate + fill the serving dual cache ----------
    sample = stream.sample(rng, 8, args.prompt_len)
    caches_dci = build_serving_caches(
        cfg, params, sample, total_cache_bytes=int(args.cache_mb * 1e6)
    )
    a = caches_dci.allocation
    print(
        f"[dci] Eq.1 split: embed {a.feat_bytes/1e6:.2f} MB "
        f"({caches_dci.embed_cache.num_cached} rows), "
        f"expert {a.adj_bytes/1e6:.2f} MB "
        f"({0 if caches_dci.hot_experts is None else len(caches_dci.hot_experts)} experts)"
    )
    print(f"[dci] embed hit rate on live prompts: {caches_dci.embed_hit_rate(prompts):.3f}")

    # ---- batched prefill + decode ---------------------------------------
    cache_size = args.prompt_len + args.gen_len
    toks = jnp.asarray(prompts)
    t0 = time.perf_counter()
    logits, kv = jax.jit(
        lambda p, b: prefill(p, b, cfg, cache_size=cache_size)
    )(params, {"tokens": toks})
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(lambda p, t, c, l: decode_step(p, t, c, l, cfg))
    out_tokens = [jnp.argmax(logits, -1)[:, None].astype(jnp.int32)]
    t0 = time.perf_counter()
    for i in range(args.gen_len - 1):
        logits, kv = decode(params, out_tokens[-1], kv, jnp.int32(args.prompt_len + i))
        out_tokens.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    jax.block_until_ready(out_tokens[-1])
    t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    tput = args.requests * (args.gen_len - 1) / max(t_decode, 1e-9)
    print(
        f"[serve] {args.requests} reqs: prefill {t_prefill:.2f}s, "
        f"decode {t_decode:.2f}s ({tput:.1f} tok/s), gen hit rate "
        f"{caches_dci.embed_hit_rate(gen):.3f}"
    )


if __name__ == "__main__":
    main()
