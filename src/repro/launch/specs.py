"""Abstract input construction for the dry-run (ShapeDtypeStruct stand-ins).

``input_specs(cfg, shape)`` builds weak-type-correct, shardable abstract
inputs for every model input of the given (architecture × input-shape)
pair — no device allocation (dry-run §2 of the brief).

Shape conventions:
  * train / prefill: tokens or frontend embeddings of ``seq_len`` with
    ``global_batch`` rows (enc-dec adds 4096 encoder frames; train uses
    seq_len frames).
  * decode: ONE new token against caches of ``seq_len`` logical context;
    ``long_500k`` switches long_mode on (ring-buffer windows for dense
    attention, native state for SSM/hybrid).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import InputShape
from repro.models.lm.blocks import init_block_cache
from repro.models.lm.config import LMConfig
from repro.models.lm.model import abstract_params
from repro.optim.adamw import init_adamw

__all__ = [
    "abstract_train_inputs",
    "abstract_prefill_inputs",
    "abstract_decode_inputs",
    "abstract_caches",
    "DECODE_ENC_LEN",
]

DECODE_ENC_LEN = 4096  # encoder frames held fixed for enc-dec decode shapes


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), jnp.dtype(dtype))


def _batch_dict(cfg: LMConfig, b: int, s: int, *, labels: bool) -> dict:
    batch: dict = {}
    if cfg.encoder_layers > 0:
        batch["src_embeds"] = _sds((b, s if labels else min(s, DECODE_ENC_LEN), cfg.d_model), cfg.dtype)
        batch["tokens"] = _sds((b, s), jnp.int32)
    elif cfg.input_mode == "embeds":
        batch["embeds"] = _sds((b, s, cfg.d_model), cfg.dtype)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
    if cfg.rope_kind == "mrope":
        batch["positions"] = _sds((b, s, 3), jnp.int32)
    if labels:
        batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def abstract_train_inputs(cfg: LMConfig, shape: InputShape):
    params = abstract_params(cfg)
    opt_state = jax.eval_shape(init_adamw, params)
    batch = _batch_dict(cfg, shape.global_batch, shape.seq_len, labels=True)
    return params, opt_state, batch


def abstract_prefill_inputs(cfg: LMConfig, shape: InputShape):
    params = abstract_params(cfg)
    batch = _batch_dict(cfg, shape.global_batch, shape.seq_len, labels=False)
    return params, batch


def abstract_caches(
    cfg: LMConfig, batch: int, cache_size: int, *, long_mode: bool
) -> tuple:
    """Stacked (over repeats) abstract caches, one entry per pattern position."""
    enc_len = DECODE_ENC_LEN if cfg.encoder_layers > 0 else None
    dtype = jnp.dtype(cfg.dtype)
    out = []
    for pos in range(cfg.pattern_period):
        def one(p=pos):
            return init_block_cache(
                cfg, p, batch, cache_size, dtype, long_mode=long_mode, enc_len=enc_len
            )

        def stacked():
            return jax.vmap(lambda _: one())(jnp.arange(cfg.n_repeats))

        out.append(jax.eval_shape(stacked))
    return tuple(out)


def abstract_decode_inputs(cfg: LMConfig, shape: InputShape, *, long_mode: bool):
    params = abstract_params(cfg)
    tokens = _sds((shape.global_batch, 1), jnp.int32)
    caches = abstract_caches(cfg, shape.global_batch, shape.seq_len, long_mode=long_mode)
    cache_len = _sds((), jnp.int32)
    return params, tokens, caches, cache_len


def concrete_from_abstract(tree, seed: int = 0):
    """Materialize small abstract trees for smoke tests (not used by dry-run)."""
    rng = np.random.default_rng(seed)

    def leaf(x):
        if jnp.issubdtype(x.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, 2, x.shape), x.dtype)
        return jnp.asarray(rng.standard_normal(x.shape) * 0.02, x.dtype)

    return jax.tree.map(leaf, tree)
