import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

The two lines above MUST run before any other import (jax locks the device
count at first init), which is why they precede the module docstring's
natural position.  Everything here is ShapeDtypeStruct-abstract: no real
tensors are allocated; success of ``.lower().compile()`` plus the memory /
cost / collective analyses are the deliverable (brief: MULTI-POD DRY-RUN,
ROOFLINE ANALYSIS).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  ... --multipod          # 2-pod (2,16,16) mesh instead of (16,16)
"""

import argparse
import json
import time

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    abstract_decode_inputs,
    abstract_prefill_inputs,
    abstract_train_inputs,
)
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.lm.sharding import (
    batch_specs,
    cache_specs,
    data_axes,
    param_specs,
    to_shardings,
)

def _is_long(shape_name: str) -> bool:
    return shape_name == "long_500k"


def build_lowerable(arch: str, shape_name: str, mesh, variant: str = "baseline"):
    """Returns (fn, args, in_specs) for jit.

    ``variant`` is a comma-separated set of §Perf switches:
      baseline      — the paper-faithful / naive configuration
      moe_shardmap  — explicit expert-parallel MoE via shard_map
      mla_absorb    — matrix-absorbed MLA decode (no per-step k/v expansion)
      batch2d       — train/prefill batch sharded over (data, model) [FSDP-
                      style: weights gathered per layer instead of activation
                      all-reduces]
    """
    from repro.models.lm import moe as moe_mod

    variants = set(variant.split(","))
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ba = data_axes(mesh)
    if "batch2d" in variants and shape.kind in ("train", "prefill"):
        ba2 = ba + ("model",)
        n_shards = 1
        for a in ba2:
            n_shards *= mesh.shape[a]
        if shape.global_batch % n_shards == 0:
            ba = ba2  # else: batch too small for the extra axis; keep 1D
    from repro.models.lm import tp as tp_mod

    if "tp_shardmap" in variants:
        tp_mod.set_tp_context(mesh, "model")
    else:
        tp_mod.set_tp_context(None)
        tp_mod.set_bf16_barrier(False)
        tp_mod.set_remat_policy(None)
        tp_mod.set_rwkv_chunked(False)
    tp_mod.set_bf16_barrier("bf16_psum" in variants)
    tp_mod.set_remat_policy("dots" if "remat_dots" in variants else None)
    tp_mod.set_rwkv_chunked("rwkv_chunked" in variants)
    if "moe_shardmap" in variants and cfg.moe is not None:
        moe_data_axes = () if shape.global_batch == 1 else tuple(
            a for a in ba if a != "model"
        )
        moe_mod.set_shard_map_context(mesh, moe_data_axes, "model")
    else:
        moe_mod.set_shard_map_context(None)

    if shape.kind == "train":
        params, opt_state, batch = abstract_train_inputs(cfg, shape)
        fn = make_train_step(cfg)
        in_specs = (param_specs(params), jax.tree.map(lambda *_: None, opt_state), batch_specs(batch, ba))
        # optimizer moments shard like their parameters; step is replicated
        opt_specs = {
            "m": param_specs(params),
            "v": param_specs(params),
            "step": jax.sharding.PartitionSpec(),
        }
        in_specs = (param_specs(params), opt_specs, batch_specs(batch, ba))
        args = (params, opt_state, batch)
    elif shape.kind == "prefill":
        params, batch = abstract_prefill_inputs(cfg, shape)
        fn = make_prefill_step(cfg, cache_size=shape.seq_len)
        in_specs = (param_specs(params), batch_specs(batch, ba))
        args = (params, batch)
    else:  # decode
        long_mode = _is_long(shape_name)
        params, tokens, caches, cache_len = abstract_decode_inputs(cfg, shape, long_mode=long_mode)
        fn = make_serve_step(cfg, long_mode=long_mode, mla_absorb="mla_absorb" in variants)
        bspec = () if shape.global_batch == 1 else ba
        in_specs = (
            param_specs(params),
            batch_specs({"tokens": tokens}, bspec)["tokens"],
            cache_specs(caches, bspec),
            jax.sharding.PartitionSpec(),
        )
        args = (params, tokens, caches, cache_len)
    return fn, args, in_specs


def dryrun_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    verbose: bool = True,
    variant: str = "baseline",
) -> dict:
    from repro.models.lm import moe as moe_mod

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        fn, args, in_specs = build_lowerable(arch, shape_name, mesh, variant)
        in_shardings = to_shardings(mesh, in_specs)

        with mesh:
            lowered = jax.jit(fn, in_shardings=in_shardings).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
    finally:
        moe_mod.set_shard_map_context(None)
        from repro.models.lm import tp as tp_mod

        tp_mod.set_tp_context(None)

    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(mesh.size),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        rec["cost_analysis"] = {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and (k in ("flops", "bytes accessed") or k.startswith("bytes accessed"))
        }
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = repr(e)
    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = repr(e)
    try:
        from repro.analysis.hlo import analyze_hlo

        s = analyze_hlo(compiled.as_text())
        rec["hlo"] = {
            "flops_per_device": s.flops,
            "dot_bytes_per_device": s.dot_bytes,
            "collective_bytes_per_device": s.collective_bytes,
            "collective_counts": s.collective_counts,
            "parameter_bytes_per_device": s.parameter_bytes,
            "num_whiles": s.num_whiles,
            "unresolved_trip_counts": s.unresolved_trip_counts,
        }
    except Exception as e:  # pragma: no cover
        rec["hlo_error"] = repr(e)

    if verbose:
        h = rec.get("hlo", {})
        coll = sum(h.get("collective_bytes_per_device", {}).values())
        print(
            f"[dryrun] {arch:24s} {shape_name:12s} mesh={rec['mesh']:8s} "
            f"compile={rec['compile_s']:7.1f}s flops/dev={h.get('flops_per_device', float('nan')):.3e} "
            f"coll/dev={coll:.3e}B"
        )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all 40 arch x shape combos")
    ap.add_argument("--out", default=None, help="write one JSON per combo under this dir")
    ap.add_argument("--variant", default="baseline", help="comma-separated perf switches")
    args = ap.parse_args()

    combos = (
        [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    records = []
    for arch, shape in combos:
        rec = dryrun_one(arch, shape, multi_pod=args.multipod, variant=args.variant)
        records.append(rec)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = f"{arch}_{shape}_{rec['mesh']}_{args.variant}".replace("/", "-").replace(",", "+")
            tag = tag.replace("_baseline", "")
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
    ok = sum("cost_analysis" in r or "memory_analysis" in r for r in records)
    print(f"[dryrun] {len(records)} combos compiled, {ok} with analyses")


if __name__ == "__main__":
    main()
