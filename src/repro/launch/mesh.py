"""Production meshes (multi-pod dry-run §0/§1 of the brief) and the
sharded-serving mesh.

FUNCTIONS, not module constants: importing this module never touches jax
device state.  Single pod = 256 chips as (data=16, model=16); two pods
= 512 chips as (pod=2, data=16, model=16).  The serving mesh is 1-D over
local devices — one axis, one feature shard per device — sized for the
CPU-mesh CI (`XLA_FLAGS=--xla_force_host_platform_device_count=N`) as
much as for real accelerators.
"""

from __future__ import annotations

import numpy as np

import jax

__all__ = ["make_production_mesh", "make_serving_mesh", "serving_devices", "HW"]

SERVE_AXIS = "shard"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(num_shards: int):
    """A 1-D ``shard`` mesh over the first ``num_shards`` local devices.

    Clamps to the devices actually present, so ``make_serving_mesh(4)``
    on a 1-device host returns a size-1 mesh (the sharded server then
    co-locates its shards — same partition math, same accounting, no
    cross-device traffic).  Built directly from the device array rather
    than ``jax.make_mesh`` so the oldest supported jax still constructs
    it."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    devices = jax.devices()[: max(1, min(num_shards, len(jax.devices())))]
    return jax.sharding.Mesh(np.asarray(devices), (SERVE_AXIS,))


def serving_devices(mesh) -> list:
    """The mesh's devices as a flat per-shard list."""
    return list(np.asarray(mesh.devices).reshape(-1))


# TPU v5e hardware constants for the roofline (per chip).
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw_per_link": 50e9,  # B/s per link
}
