"""Production meshes (multi-pod dry-run §0/§1 of the brief).

A FUNCTION, not a module constant: importing this module never touches
jax device state.  Single pod = 256 chips as (data=16, model=16); two pods
= 512 chips as (pod=2, data=16, model=16).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants for the roofline (per chip).
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw_per_link": 50e9,  # B/s per link
}
