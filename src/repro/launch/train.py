"""Training driver: ``python -m repro.launch.train --arch yi-6b --smoke``.

On this CPU container, training runs the reduced (smoke) configs; on a TPU
slice the same driver takes the full configs under the production mesh
(mesh/sharding reuse the dry-run path).  Checkpoints via repro.checkpoint.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save_checkpoint
from repro.configs import get_config, get_smoke
from repro.data.tokens import TokenStream, batches
from repro.launch.steps import make_train_step
from repro.models.lm.model import default_positions, init_params
from repro.optim.adamw import init_adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt_state = init_adamw(params)
    step_fn = jax.jit(make_train_step(cfg, base_lr=args.lr))

    stream = TokenStream(vocab=cfg.vocab, seed=0)
    t0 = time.perf_counter()
    losses = []
    for i, batch_np in enumerate(batches(stream, batch=args.batch, seq=args.seq, steps=args.steps)):
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if cfg.input_mode == "embeds" and cfg.encoder_layers == 0:
            batch["embeds"] = params["embed"][batch.pop("tokens")].astype(jnp.float32)
        if cfg.encoder_layers > 0:
            batch["src_embeds"] = jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, args.seq, cfg.d_model), jnp.float32
            )
        if cfg.rope_kind == "mrope" and "positions" not in batch:
            batch["positions"] = default_positions(cfg, args.batch, args.seq)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if (i + 1) % args.log_every == 0:
            dt = time.perf_counter() - t0
            print(
                f"step {i+1:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                f"({dt/ (i+1):.2f}s/step)"
            )
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    if args.save:
        save_checkpoint(args.save, {"params": params, "opt": opt_state})
        print(f"saved checkpoint to {args.save}")


if __name__ == "__main__":
    main()
