"""Multi-host bootstrap for real TPU pods.

On actual hardware each host runs the same driver; this module wires
``jax.distributed.initialize`` from the standard env vars and checks the
mesh arithmetic matches the brief's production topology.  The CPU
container never calls this (the dry-run uses host-device emulation); it is
the deployment path (scripts/launch_pod.sh).
"""

from __future__ import annotations

import os

import jax

__all__ = ["initialize_from_env", "assert_production_topology"]


def initialize_from_env() -> None:
    """Initialize jax.distributed from COORDINATOR_ADDRESS/NUM_PROCESSES/
    PROCESS_ID (or TPU metadata auto-detection when unset)."""
    coord = os.environ.get("COORDINATOR_ADDRESS")
    if coord:
        jax.distributed.initialize(
            coordinator_address=coord,
            num_processes=int(os.environ["NUM_PROCESSES"]),
            process_id=int(os.environ["PROCESS_ID"]),
        )
    else:  # TPU pod slices auto-detect
        jax.distributed.initialize()


def assert_production_topology(*, multi_pod: bool) -> None:
    want = 512 if multi_pod else 256
    got = jax.device_count()
    if got != want:
        raise RuntimeError(
            f"production mesh needs {want} chips, found {got} "
            f"({jax.process_count()} processes x {jax.local_device_count()} local)"
        )
