"""GNN inference driver — the paper's system as a CLI.

    PYTHONPATH=src python -m repro.launch.infer_gnn \
        --dataset ogbn-products --policy dci --fanouts 15,10,5 \
        --batch-size 1024 --cache-mb 2
"""

from __future__ import annotations

import argparse
import json

from repro.core.policies import POLICIES
from repro.graph import load_dataset
from repro.runtime.gnn_engine import GNNInferenceEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--policy", default="dci", choices=sorted(POLICIES))
    ap.add_argument("--model", default="graphsage", choices=("graphsage", "gcn"))
    ap.add_argument("--fanouts", default="15,10,5")
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--cache-mb", type=float, default=2.0)
    ap.add_argument("--scale", type=float, default=0.004)
    ap.add_argument("--presample", type=int, default=8)
    ap.add_argument("--max-batches", type=int, default=None)
    ap.add_argument(
        "--pipeline-depth",
        type=int,
        default=1,
        help="batches kept in flight: 1 = serial (per-stage sync, the paper's "
        "timing), 2+ = overlap batch i+1's sample/gather with batch i's compute",
    )
    args = ap.parse_args()

    fanouts = tuple(int(x) for x in args.fanouts.split(","))
    ds = load_dataset(args.dataset, scale=args.scale, max_nodes=200_000)
    eng = GNNInferenceEngine(
        ds,
        model=args.model,
        fanouts=fanouts,
        batch_size=args.batch_size,
        pipeline_depth=args.pipeline_depth,
    )
    eng.prepare(
        args.policy,
        total_cache_bytes=int(args.cache_mb * 1e6),
        n_presample=args.presample,
    )
    rep = eng.run(max_batches=args.max_batches)
    print(json.dumps(rep.summary(), indent=1))


if __name__ == "__main__":
    main()
