"""GNN inference driver — the paper's system as a CLI.

Single stream (the paper's setup):

    PYTHONPATH=src python -m repro.launch.infer_gnn \
        --dataset ogbn-products --policy dci --fanouts 15,10,5 \
        --batch-size 1024 --cache-mb 2

Multi-stream serving (N request streams sharing one DualCache, batches
interleaved through one pipelined executor — runtime/gnn_serve.py):

    PYTHONPATH=src python -m repro.launch.infer_gnn \
        --policy dci --streams 4 --batches-per-stream 8 --pipeline-depth 2
"""

from __future__ import annotations

import argparse
import json

from repro.core.config import INFERENCE_MODES, ServeConfig
from repro.core.policies import ADMISSION_POLICIES, POLICIES
from repro.core.trace import MetricsRegistry, Tracer
from repro.graph import load_dataset
from repro.runtime.cache_refresh import MODES as REFRESH_MODES
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches
from repro.runtime.request_queue import (
    RequestQueueServer,
    burst_trace,
    flash_crowd_trace,
    poisson_trace,
    uniform_seed_batches,
)


def _depth(value: str):
    """--pipeline-depth accepts an int or 'auto' (measured compute:prep)."""
    return "auto" if value == "auto" else int(value)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="ogbn-products")
    ap.add_argument("--policy", default="dci", choices=sorted(POLICIES))
    ap.add_argument("--model", default="graphsage", choices=("graphsage", "gcn"))
    ap.add_argument("--fanouts", default="15,10,5")
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--cache-mb", type=float, default=2.0)
    ap.add_argument("--scale", type=float, default=0.004)
    ap.add_argument("--presample", type=int, default=8)
    ap.add_argument("--max-batches", type=int, default=None)
    ap.add_argument(
        "--mode",
        default="sampling",
        choices=INFERENCE_MODES,
        help="'sampling' (default) = mini-batch neighborhood-sampled inference "
        "over the test seeds; 'layerwise' = full-graph layer-wise scoring — "
        "every layer over ALL nodes in node-range chunks, the DualCache "
        "serving layer-0 features and an embedding cache serving "
        "intermediate layer outputs (runtime/layerwise.py)",
    )
    ap.add_argument(
        "--chunk-size",
        type=int,
        default=None,
        help="node-range chunk for --mode layerwise (default 4096, clamped "
        "to the graph)",
    )
    ap.add_argument(
        "--pipeline-depth",
        type=_depth,
        default=1,
        help="batches kept in flight: 1 = serial (per-stage sync, the paper's "
        "timing), 2+ = overlap batch i+1's sample/gather with batch i's compute, "
        "'auto' = derive the window from a measured compute:prep probe",
    )
    ap.add_argument(
        "--refresh-mode",
        default="off",
        choices=REFRESH_MODES,
        help="online cache refresh: 'interval' re-allocates (Eq. 1 on the "
        "measured serve-time stage ratio) and delta re-fills every "
        "--refresh-interval retired batches; 'events' refreshes on stream "
        "join/leave; 'all' does both.  Off (default) keeps the caches "
        "immutable — bit-for-bit the pre-refresh system",
    )
    ap.add_argument(
        "--refresh-interval",
        type=int,
        default=8,
        help="retired batches between interval refreshes (interval/all modes)",
    )
    ap.add_argument(
        "--refresh-miss-threshold",
        type=float,
        default=None,
        help="SLO-aware refresh trigger: fire a refresh as soon as the live "
        "telemetry window's feature miss rate crosses this value, composing "
        "with the interval/event triggers (needs --refresh-mode != off)",
    )
    ap.add_argument(
        "--dedup",
        action="store_true",
        help="sort-and-unique each input frontier on device and "
        "gather/prefetch/model one row per DISTINCT node, expanding through "
        "the inverse map; outputs and hit accounting are identical, only "
        "the gathered-row count (and wall clock) changes",
    )
    ap.add_argument(
        "--prefetch",
        action="store_true",
        help="stage batch i+1's MISSED host feature rows onto the device "
        "(jax.device_put) while batch i's forward runs; outputs and hit "
        "accounting are identical, only where the miss bytes move changes",
    )
    ap.add_argument(
        "--use-kernel",
        action="store_true",
        help="route feature gathers through the double-buffered Pallas "
        "cached_gather kernel (compiled on TPU, interpret mode elsewhere)",
    )
    ap.add_argument(
        "--gather-buffers",
        type=int,
        default=2,
        help="kernel VMEM row-tile slots: 1 = serial copies, 2 = double "
        "buffering (only meaningful with --use-kernel)",
    )
    ap.add_argument(
        "--streams",
        type=int,
        default=1,
        help="number of independent request streams served against ONE shared "
        "cache (1 = the single-stream engine; >1 = runtime/gnn_serve.py, with "
        "the presample budget split across stream seeds)",
    )
    ap.add_argument(
        "--batches-per-stream",
        type=int,
        default=8,
        help="queue length per stream in multi-stream mode "
        "(--max-batches caps it too, so the flag means the same in both modes)",
    )
    ap.add_argument(
        "--max-inflight",
        type=int,
        default=None,
        help="backpressure cap: window slots one stream may occupy (default: depth)",
    )
    ap.add_argument(
        "--arrival",
        default="none",
        choices=("none", "poisson", "burst", "flash-crowd"),
        help="request-level serving (runtime/request_queue.py): put each "
        "stream's batches on an arrival clock instead of an always-ready "
        "queue.  'poisson' = steady traffic with exponential gaps, 'burst' "
        "= a flash crowd at t=0 colliding with a service-paced steady "
        "stream (always 2 streams), 'flash-crowd' = every stream dumps its "
        "whole queue at t=0.  'none' (default) serves plain queues",
    )
    ap.add_argument(
        "--slo-ms",
        type=float,
        default=None,
        help="relative deadline attached to every request (arrival modes); "
        "reported as deadline hit rate, and enforced by --admission slo",
    )
    ap.add_argument(
        "--admission",
        default="round-robin",
        choices=sorted(ADMISSION_POLICIES),
        help="admission policy for --arrival modes: 'round-robin' (the "
        "bit-for-bit baseline), 'edf' (earliest deadline first), 'slo' "
        "(EDF + shed requests whose deadline already passed)",
    )
    ap.add_argument(
        "--mean-interarrival-ms",
        type=float,
        default=50.0,
        help="mean request gap per stream for --arrival poisson",
    )
    ap.add_argument(
        "--mesh",
        type=int,
        default=0,
        help="shard the feature table + feature cache across this many mesh "
        "devices (runtime/sharded_serve.py); clamps to the devices present "
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=N for a CPU "
        "mesh).  0 (default) keeps the single-device servers; outputs and "
        "hit accounting are bit-identical at any mesh size",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="OUT.json",
        help="record a span/event timeline of the run (core/trace.py) and "
        "write it as Chrome trace-event JSON — load it in Perfetto "
        "(ui.perfetto.dev) or chrome://tracing, or summarize it with "
        "scripts/trace_summary.py.  Off (default) = the NullTracer no-op "
        "path; outputs are bit-for-bit identical either way",
    )
    ap.add_argument(
        "--trace-jax",
        action="store_true",
        help="also bridge every span into jax.profiler.TraceAnnotation so "
        "spans show up inside a JAX/XLA profiler capture (needs --trace)",
    )
    ap.add_argument(
        "--faults",
        default=None,
        metavar="PLAN.json",
        help="inject deterministic faults from a FaultPlan JSON file "
        "(core/faults.py): named serve-path sites (adj_fetch, host_fetch, "
        "prefetch, kernel_gather, shard_exchange, refresh_fill) fail or "
        "delay on seeded per-site schedules.  Replay is a pure function of "
        "the plan — the same plan + same run produces the same faults.  "
        "Off (default) = no injector, the bit-for-bit baseline",
    )
    ap.add_argument(
        "--fault-policy",
        default=None,
        choices=("fail", "retry", "shed"),
        help="what a guarded-site failure does: 'fail' fails fast (default), "
        "'retry' retries with bounded exponential backoff then fails, 'shed' "
        "retries then sheds just the failing request and keeps serving",
    )
    ap.add_argument(
        "--retry",
        action="store_true",
        help="shorthand for --fault-policy retry",
    )
    ap.add_argument(
        "--retry-attempts",
        type=int,
        default=3,
        help="attempts per guarded call including the first (fault policies "
        "retry/shed)",
    )
    ap.add_argument(
        "--retry-backoff-ms",
        type=float,
        default=1.0,
        help="base backoff before attempt 2; doubles per attempt with "
        "deterministic seeded jitter, capped (core/retry.py RetryPolicy)",
    )
    ap.add_argument(
        "--retry-timeout-ms",
        type=float,
        default=None,
        help="per-attempt wall-clock budget; an attempt over budget raises "
        "StageTimeout, which retries like a fault (default: no timeout)",
    )
    ap.add_argument(
        "--degraded-mode",
        action="store_true",
        help="serve degraded instead of failing when the miss path is down: "
        "cache-only feature service (hit rows real, miss rows zero, requests "
        "marked degraded) and prefetch skipping.  Composes with --fault-policy",
    )
    ap.add_argument(
        "--metrics",
        default=None,
        metavar="OUT",
        help="collect a structured metrics snapshot (counters/gauges/"
        "histograms, core/trace.py MetricsRegistry) and write it to OUT: "
        "Prometheus text exposition when OUT ends in .prom/.txt, JSON "
        "otherwise.  The snapshot is also embedded in the printed report "
        "under the 'metrics' key",
    )
    args = ap.parse_args()

    if args.trace_jax and args.trace is None:
        ap.error("--trace-jax requires --trace")
    tracer = Tracer(jax_annotations=args.trace_jax) if args.trace is not None else None
    metrics = MetricsRegistry() if args.metrics is not None else None

    def finish(rep) -> None:
        print(json.dumps(rep.summary(), indent=1))
        if tracer is not None:
            tracer.export(args.trace)
        if metrics is not None:
            text = (
                metrics.to_prometheus()
                if args.metrics.endswith((".prom", ".txt"))
                else metrics.to_json()
            )
            with open(args.metrics, "w", encoding="utf-8") as fh:
                fh.write(text)

    fanouts = tuple(int(x) for x in args.fanouts.split(","))
    if args.arrival == "burst":
        args.streams = 2  # the burst trace is one flash-crowd + one steady stream
    # One typed config object carries every execution knob from here down —
    # the engine, the servers, and the report echoes all read it.
    cfg = ServeConfig.from_args(args)
    ds = load_dataset(args.dataset, scale=args.scale, max_nodes=200_000)
    eng = GNNInferenceEngine(
        ds,
        model=args.model,
        fanouts=fanouts,
        batch_size=args.batch_size,
        pipeline_depth=args.pipeline_depth,
    )
    stream_seeds = [eng.seed + s for s in range(args.streams)] if args.streams > 1 else None
    eng.prepare(
        args.policy,
        config=cfg.engine,
        total_cache_bytes=int(args.cache_mb * 1e6),
        n_presample=args.presample,
        stream_seeds=stream_seeds,
    )
    if args.mode == "layerwise":
        # Full-graph scoring is a whole-dataset pass — the serving
        # front-ends (streams/arrival/mesh) are sampling-mode machinery.
        rep = eng.run(config=cfg.engine, tracer=tracer, metrics=metrics)
        finish(rep)
        return
    if args.arrival != "none":
        per_stream = args.batches_per_stream
        if args.max_batches is not None:
            per_stream = min(per_stream, args.max_batches)
        slo_s = args.slo_ms / 1e3 if args.slo_ms is not None else None
        if args.arrival == "poisson":
            trace = poisson_trace(
                ds,
                num_streams=args.streams,
                requests_per_stream=per_stream,
                batch_size=args.batch_size,
                mean_interarrival_s=args.mean_interarrival_ms / 1e3,
                slo_s=slo_s,
                seed=eng.seed,
            )
        elif args.arrival == "flash-crowd":
            trace = flash_crowd_trace(
                ds,
                num_streams=args.streams,
                requests_per_stream=per_stream,
                batch_size=args.batch_size,
                slo_s=slo_s,
                seed=eng.seed,
            )
        else:  # burst: pace the steady stream at the measured service time
            probe = uniform_seed_batches(
                ds, n_batches=1, batch_size=args.batch_size, seed=eng.seed
            )[0]
            eng.warmup(probe)
            service_s = float(sum(eng._probe_stage_seconds(probe)))
            trace = burst_trace(
                ds,
                burst_requests=per_stream,
                steady_requests=2 * per_stream,
                batch_size=args.batch_size,
                service_estimate_s=service_s,
                slo_s=slo_s,
                seed=eng.seed,
            )
        server = RequestQueueServer(eng, config=cfg, tracer=tracer, metrics=metrics)
        for sid, requests in enumerate(trace):
            server.add_request_stream(requests, seed=eng.seed + sid)
        # Under a fault plan, a fail-fast abort still prints the partial
        # report (with the 'error' field) instead of a traceback.
        rep = server.run(raise_on_error=args.faults is None)
        finish(rep)
    elif args.streams > 1 or args.mesh > 0:
        if args.mesh > 0:
            from repro.runtime.sharded_serve import ShardedServer

            server = ShardedServer(eng, config=cfg, tracer=tracer, metrics=metrics)
        else:
            server = MultiStreamServer(eng, config=cfg, tracer=tracer, metrics=metrics)
        per_stream = args.batches_per_stream
        if args.max_batches is not None:
            per_stream = min(per_stream, args.max_batches)
        queues = make_stream_batches(
            ds,
            num_streams=args.streams,
            batches_per_stream=per_stream,
            batch_size=args.batch_size,
            seed=eng.seed,
        )
        seeds = stream_seeds if stream_seeds is not None else [eng.seed]
        for sid, queue in enumerate(queues):
            server.add_stream(queue, seed=seeds[sid])
        rep = server.run(raise_on_error=args.faults is None)
        finish(rep)
    else:
        # The servers above resolve the injector from cfg.faults; the
        # single-stream engine takes live handles.
        injector = None
        if args.faults is not None:
            from repro.core.faults import FaultInjector, FaultPlan

            injector = FaultInjector(FaultPlan.load(args.faults), tracer=tracer)
        rep = eng.run(
            config=cfg.engine,
            max_batches=args.max_batches,
            tracer=tracer,
            metrics=metrics,
            injector=injector,
            retry_policy=cfg.retry_policy(),
            degraded_mode=cfg.degraded_mode,
        )
        finish(rep)


if __name__ == "__main__":
    main()
