"""Version-compat shims for JAX APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check flag ``check_rep`` → ``check_vma`` along the
way.  ``shard_map_compat`` resolves whichever spelling this JAX exposes.
``trace_annotation_compat`` resolves the profiler span-annotation context
(``jax.profiler.TraceAnnotation``), degrading to a no-op context on builds
without a profiler — the tracer (core/trace.py) uses it to line host spans
up with device kernels under ``--trace-jax``.
"""

from __future__ import annotations

import contextlib

import jax

__all__ = ["shard_map_compat", "trace_annotation_compat"]


def _resolve():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, "check_vma"
    from jax.experimental.shard_map import shard_map  # JAX < 0.6

    return shard_map, "check_rep"


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` with the new-style signature on any supported JAX."""
    fn, flag = _resolve()
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if check_vma is not None:
        kwargs[flag] = check_vma
    return fn(f, **kwargs)


def trace_annotation_compat():
    """A ``name -> context manager`` factory marking a host-side activity
    span for the JAX device profiler, or a null context when this build
    exposes no profiler annotation API."""
    profiler = getattr(jax, "profiler", None)
    annotation = getattr(profiler, "TraceAnnotation", None) if profiler is not None else None
    if annotation is None:  # pragma: no cover - depends on the JAX build
        return lambda name: contextlib.nullcontext()
    return annotation
