"""Wall-clock helpers (pre-sampling stage timing is part of DCI's Eq. 1)."""

from __future__ import annotations

import contextlib
import time

import jax

__all__ = ["Stopwatch", "timed"]


class Stopwatch:
    """Accumulates named wall-clock durations (seconds)."""

    def __init__(self):
        self.totals: dict[str, float] = {}
        self.counts: dict[str, int] = {}

    @contextlib.contextmanager
    def track(self, name: str, *, sync: object = None):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            if sync is not None:
                jax.block_until_ready(sync)
            dt = time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def add(self, name: str, seconds: float) -> None:
        self.totals[name] = self.totals.get(name, 0.0) + seconds
        self.counts[name] = self.counts.get(name, 0) + 1

    def total(self, name: str) -> float:
        return self.totals.get(name, 0.0)


@contextlib.contextmanager
def timed(out: dict, name: str):
    t0 = time.perf_counter()
    yield
    out[name] = out.get(name, 0.0) + time.perf_counter() - t0
