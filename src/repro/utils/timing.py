"""Wall-clock helpers (pre-sampling stage timing is part of DCI's Eq. 1).

``StageClock`` is the overlap-aware stage timer behind the pipelined batch
executor (runtime/pipeline.py): in serial mode it synchronizes (blocks on
device values) at every stage boundary, reproducing the per-stage Eq. 1
decomposition exactly; in overlap mode stages only measure host dispatch
time and the wait for in-flight device work is booked by ``drain()`` at
pipeline-retire boundaries.
"""

from __future__ import annotations

import contextlib
import time

import jax

__all__ = ["StageClock", "Stopwatch", "timed"]


class StageClock:
    """Per-stage wall-clock accounting that understands stage overlap.

    Serial mode (``overlap=False``): :meth:`stage` blocks on the stage's
    ``sync`` value before stopping the timer, so every lap is a fully
    synchronized stage time — the semantics DCI's Eq. 1 stage decomposition
    assumes, and what the pre-pipeline engine measured.

    Overlap mode (``overlap=True``): :meth:`stage` never blocks; laps
    measure host dispatch time only, while JAX async dispatch keeps the
    device busy with earlier batches.  The wait for in-flight work is
    recorded by :meth:`drain` when the pipeline retires a batch and is
    attributed (in ``totals`` only, not ``laps``) to the stage whose output
    is drained, so ``sum(totals.values())`` stays consistent with the
    loop's wall clock.

    Invariants (property-tested in tests/test_pipeline_executor.py):
    every lap is >= 0, ``totals[name] >= sum(laps[name])``, and
    ``sum(totals) == sum(all laps) + drain_seconds``.
    """

    def __init__(self, *, overlap: bool = False):
        self.overlap = overlap
        self.totals: dict[str, float] = {}
        self.laps: dict[str, list[float]] = {}
        self.drain_seconds = 0.0

    @contextlib.contextmanager
    def stage(self, name: str, *, sync: object = None):
        """Time one stage lap.  ``sync`` is the device value (or a callable
        producing it) to block on at the stage boundary in serial mode."""
        t0 = time.perf_counter()
        ok = False
        try:
            yield
            ok = True
        finally:
            # Only evaluate sync when the body succeeded — a failed stage
            # has no output, and a KeyError from the sync callable would
            # mask the stage's real exception.
            if ok and sync is not None and not self.overlap:
                value = sync() if callable(sync) else sync
                if value is not None:
                    jax.block_until_ready(value)
            self._lap(name, time.perf_counter() - t0)

    def drain(self, name: str, value) -> None:
        """Block on an in-flight device value; attribute the wait to ``name``."""
        t0 = time.perf_counter()
        jax.block_until_ready(value)
        dt = time.perf_counter() - t0
        self.drain_seconds += dt
        self.totals[name] = self.totals.get(name, 0.0) + dt

    def _lap(self, name: str, dt: float) -> None:
        self.totals[name] = self.totals.get(name, 0.0) + dt
        self.laps.setdefault(name, []).append(dt)

    def total(self, name: str) -> float:
        return self.totals.get(name, 0.0)


class Stopwatch:
    """Accumulates named wall-clock durations (seconds)."""

    def __init__(self):
        self.totals: dict[str, float] = {}
        self.counts: dict[str, int] = {}

    @contextlib.contextmanager
    def track(self, name: str, *, sync: object = None):
        """Time one block.  ``sync`` is a device value — or, as in
        :meth:`StageClock.stage`, a callable producing one — blocked on
        before the timer stops, so lazily materialized outputs are charged
        to the block that dispatched them."""
        t0 = time.perf_counter()
        ok = False
        try:
            yield
            ok = True
        finally:
            # Evaluate-then-block, and only when the body succeeded —
            # mirrors StageClock.stage so the two timers accept the same
            # sync argument (a failed body has no output to wait for, and
            # an exception from the sync callable must not mask the body's).
            if ok and sync is not None:
                value = sync() if callable(sync) else sync
                if value is not None:
                    jax.block_until_ready(value)
            dt = time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def add(self, name: str, seconds: float) -> None:
        self.totals[name] = self.totals.get(name, 0.0) + seconds
        self.counts[name] = self.counts.get(name, 0) + 1

    def total(self, name: str) -> float:
        return self.totals.get(name, 0.0)


@contextlib.contextmanager
def timed(out: dict, name: str):
    t0 = time.perf_counter()
    yield
    out[name] = out.get(name, 0.0) + time.perf_counter() - t0
