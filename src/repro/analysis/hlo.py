"""Post-optimization HLO analysis for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body ONCE, so a
46-layer scanned model reports ~1/46th of its FLOPs.  This module parses
the optimized HLO text, recovers loop trip counts (scan lowers to
``while`` whose condition compares the induction variable against a bound
that is a constant element of the init tuple), and aggregates:

  * dot FLOPs       — 2 · |result| · |contraction dims|, × trip multiplier
  * dot bytes       — operand + result bytes of every dot (the matmul HBM
                      traffic: weights, activations, KV reads), × multiplier
  * collective bytes — operand bytes of all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute,
                      × multiplier, per op kind

The optimized module is post-SPMD: every shape is one partition's share,
so all numbers here are PER-DEVICE — exactly what the per-chip roofline
terms divide by peak FLOP/s / HBM bw / ICI bw.  Fusion computations are
walked with their caller's multiplier; elementwise fusion traffic is NOT
counted (documented approximation — matmul/collective traffic dominates
every assigned shape).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HLOSummary"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
# Computation headers start at column 0 (instructions are indented).
_COMP_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s+\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_dims(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """All (dtype, dims) array shapes in a type string (handles tuples)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, d))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str  # operand list + attributes (raw)
    comp: str

    def operand_names(self) -> list[str]:
        # names inside the top-level parens, before attributes
        depth = 0
        end = len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth < 0:
                    end = i
                    break
        return re.findall(r"%([\w.\-]+)", self.rest[:end])

    def attr(self, key: str) -> str | None:
        m = re.search(rf"{key}=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None

    def int_set_attr(self, key: str) -> tuple[int, ...]:
        m = re.search(rf"{key}=\{{([0-9,]*)\}}", self.rest)
        if not m or not m.group(1):
            return ()
        return tuple(int(x) for x in m.group(1).split(","))


@dataclasses.dataclass
class HLOSummary:
    flops: float
    dot_bytes: float
    collective_bytes: dict[str, float]
    collective_counts: dict[str, int]
    parameter_bytes: int
    num_whiles: int
    unresolved_trip_counts: int

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _parse(hlo: str) -> tuple[dict[str, Instr], dict[str, list[Instr]], str]:
    instrs: dict[str, Instr] = {}
    comps: dict[str, list[Instr]] = {}
    comp = "?"
    entry = "?"
    for line in hlo.splitlines():
        cm = _COMP_RE.match(line)
        if cm:
            comp = cm.group(2)
            comps.setdefault(comp, [])
            if cm.group(1):
                entry = comp
            continue
        im = _INSTR_RE.match(line)
        if im:
            ins = Instr(im.group(1), im.group(2), im.group(3), im.group(4), comp)
            instrs[ins.name] = ins
            comps.setdefault(comp, []).append(ins)
    return instrs, comps, entry


def _resolve_constant(name: str, instrs: dict[str, Instr]) -> int | None:
    ins = instrs.get(name)
    for _ in range(8):  # follow copies/converts/broadcasts
        if ins is None:
            return None
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            return int(m.group(1)) if m else None
        if ins.op in ("copy", "convert", "broadcast", "bitcast", "reshape"):
            ops = ins.operand_names()
            ins = instrs.get(ops[0]) if ops else None
            continue
        return None
    return None


def _while_trip_count(w: Instr, instrs: dict[str, Instr], comps: dict[str, list[Instr]]) -> int | None:
    """Trip count of a counted loop (lax.scan lowering).

    The condition computation holds the bound as a scalar s32 constant
    (either compared directly or inside a wrapped_compare fusion whose
    constant operand still lives in the condition computation).  Scans
    start at 0 with step 1, so the bound IS the trip count; take the max
    constant to be safe against a stray 0.
    """
    cond_name = w.attr("condition")
    if cond_name is None or cond_name not in comps:
        return None
    vals = []
    for ins in comps[cond_name]:
        if ins.op == "constant" and ins.type_str.strip().startswith("s32[]"):
            v = _resolve_constant(ins.name, instrs)
            if v is not None:
                vals.append(v)
    if not vals:
        return None
    return max(vals)


def analyze_hlo(hlo: str) -> HLOSummary:
    instrs, comps, entry = _parse(hlo)

    # computation multipliers: walk from entry through while/call/fusion.
    mult: dict[str, float] = {}
    num_whiles = 0
    unresolved = 0

    def visit(comp: str, m: float):
        nonlocal num_whiles, unresolved
        mult[comp] = mult.get(comp, 0.0) + m
        for ins in comps.get(comp, []):
            if ins.op == "while":
                num_whiles += 1
                body = ins.attr("body")
                cond = ins.attr("condition")
                tc = _while_trip_count(ins, instrs, comps)
                if tc is None:
                    tc = 1
                    unresolved += 1
                if body in comps:
                    visit(body, m * max(tc, 1))
                if cond in comps:
                    visit(cond, m * max(tc, 1))
            elif ins.op in ("fusion", "call", "custom-call", "map", "reduce", "reduce-window", "sort", "scatter", "select-and-scatter", "conditional"):
                for key in ("calls", "to_apply", "true_computation", "false_computation"):
                    callee = ins.attr(key)
                    if callee in comps:
                        visit(callee, m)

    visit(entry, 1.0)

    flops = 0.0
    dot_bytes = 0.0
    coll_bytes = {op: 0.0 for op in _COLLECTIVES}
    coll_counts = {op: 0 for op in _COLLECTIVES}
    param_bytes = 0

    for name, ins in instrs.items():
        m = mult.get(ins.comp, 0.0)
        if m == 0.0:
            continue
        if ins.op == "dot":
            out_elems = 1
            for _, dims in _shape_dims(ins.type_str):
                for d in dims:
                    out_elems *= d
            lhs_contract = ins.int_set_attr("lhs_contracting_dims")
            ops = ins.operand_names()
            csize = 1
            if ops and ops[0] in instrs:
                lhs_shapes = _shape_dims(instrs[ops[0]].type_str)
                if lhs_shapes:
                    lhs_dims = lhs_shapes[0][1]
                    for d in lhs_contract:
                        if d < len(lhs_dims):
                            csize *= lhs_dims[d]
            flops += m * 2.0 * out_elems * csize
            obytes = sum(_nbytes(instrs[o].type_str) for o in ops if o in instrs)
            dot_bytes += m * (obytes + _nbytes(ins.type_str))
        elif ins.op in _COLLECTIVES:
            ops = ins.operand_names()
            obytes = sum(_nbytes(instrs[o].type_str) for o in ops if o in instrs)
            if obytes == 0:
                obytes = _nbytes(ins.type_str)
            coll_bytes[ins.op] += m * obytes
            coll_counts[ins.op] += int(m)
        elif ins.op == "parameter" and ins.comp == entry:
            param_bytes += _nbytes(ins.type_str)

    return HLOSummary(
        flops=flops,
        dot_bytes=dot_bytes,
        collective_bytes=coll_bytes,
        collective_counts=coll_counts,
        parameter_bytes=param_bytes,
        num_whiles=num_whiles,
        unresolved_trip_counts=unresolved,
    )
