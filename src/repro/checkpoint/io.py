"""Minimal dependency-free checkpointing: pytree ↔ .npz with path keys."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]

_SEP = "||"


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(getattr(p, "idx", p))
            for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            out[key + "@bf16"] = arr.astype(np.float32)
        else:
            out[key] = arr
    return out


def save_checkpoint(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path, **_flatten(tree))


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as data:
        flat = dict(data.items())

    def restore(path_keys, leaf):
        key = _SEP.join(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(getattr(p, "idx", p))
            for p in path_keys
        )
        if key + "@bf16" in flat:
            arr = jnp.asarray(flat[key + "@bf16"], jnp.bfloat16)
        else:
            arr = jnp.asarray(flat[key])
        if arr.shape != leaf.shape:
            raise ValueError(f"checkpoint mismatch at {key}: {arr.shape} vs {leaf.shape}")
        return arr

    return jax.tree_util.tree_map_with_path(restore, like)
