"""Synthetic token pipeline for LM training/serving examples.

Zipfian unigram stream with local n-gram structure (each document draws
from a doc-specific bigram table), so a model trained on it has real
signal to fit — loss decreases — while staying fully offline and
deterministic per seed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenStream", "batches"]


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seed: int = 0
    zipf_a: float = 1.2
    n_states: int = 64  # bigram-ish latent states

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        base = ranks ** (-self.zipf_a)
        self._base = base / base.sum()
        self._perm = rng.permutation(self.vocab)
        # latent-state transition structure: each state prefers a token slice
        self._state_tokens = rng.integers(0, self.vocab, (self.n_states, 32))
        self._trans = rng.integers(0, self.n_states, (self.n_states,))

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int32)
        for b in range(batch):
            state = int(rng.integers(0, self.n_states))
            for t in range(seq):
                if rng.random() < 0.7:
                    tok = self._state_tokens[state, rng.integers(0, 32)]
                else:
                    tok = self._perm[
                        np.searchsorted(np.cumsum(self._base), rng.random())
                    ]
                out[b, t] = min(int(tok), self.vocab - 1)
                state = int(self._trans[state]) if rng.random() < 0.9 else int(
                    rng.integers(0, self.n_states)
                )
        return out


def batches(stream: TokenStream, *, batch: int, seq: int, steps: int, seed: int = 0):
    """Yield ``steps`` training batches: dict(tokens, labels)."""
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        toks = stream.sample(rng, batch, seq + 1)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}
