"""Workload-aware cache-capacity allocation (paper §IV-A, Eq. 1).

Two decisions happen here:

1. *How much memory is available for caching at all* — run a few
   pre-sampling batches, observe the peak workload footprint, subtract it
   plus a safety reserve (the paper reserves 1 GB, following PaGraph) from
   total device memory.
2. *How to split that budget between the two caches* — proportionally to
   the measured stage times (Eq. 1):

       C_adj  = Σ t_sample  / Σ (t_sample + t_feature) · C
       C_feat = Σ t_feature / Σ (t_sample + t_feature) · C
"""

from __future__ import annotations

import dataclasses

__all__ = [
    "CacheAllocation",
    "LayerwiseAllocation",
    "allocate_capacity",
    "allocate_layerwise_capacity",
    "available_budget",
    "reallocate_capacity",
    "shard_allocations",
    "DEFAULT_RESERVE_BYTES",
]

DEFAULT_RESERVE_BYTES = 1 << 30  # 1 GB, the paper's reference reserve


@dataclasses.dataclass(frozen=True)
class CacheAllocation:
    total_bytes: int
    adj_bytes: int
    feat_bytes: int
    sample_fraction: float  # Σt_sample / Σ(t_sample+t_feature)

    def __post_init__(self):
        assert self.adj_bytes + self.feat_bytes <= self.total_bytes + 1


def available_budget(
    device_memory_bytes: int,
    peak_workload_bytes: int,
    reserve_bytes: int = DEFAULT_RESERVE_BYTES,
) -> int:
    """Workload-aware total budget C: what's left after the live workload."""
    return max(device_memory_bytes - peak_workload_bytes - reserve_bytes, 0)


def allocate_capacity(
    sample_times: list[float],
    feature_times: list[float],
    total_bytes: int,
    *,
    adj_need_bytes: int | None = None,
    feat_need_bytes: int | None = None,
) -> CacheAllocation:
    """Eq. 1: split ``total_bytes`` by the measured stage-time ratio.

    Saturation-aware spill (beyond-paper refinement): when the Eq. 1 share
    of one cache exceeds what that cache can usefully hold (``*_need``),
    the excess spills to the other.  With a budget covering the whole
    dataset both caches saturate — matching the paper's Fig. 9 observation
    that all strategies coincide once everything fits.
    """
    if len(sample_times) != len(feature_times) or not sample_times:
        raise ValueError("need equal, non-empty per-batch stage time lists")
    t_s = float(sum(sample_times))
    t_f = float(sum(feature_times))
    denom = t_s + t_f
    frac = 0.5 if denom <= 0 else t_s / denom
    total = int(total_bytes)
    adj = int(total * frac)
    feat = total - adj
    if adj_need_bytes is not None and adj > adj_need_bytes:
        feat += adj - adj_need_bytes
        adj = adj_need_bytes
    if feat_need_bytes is not None and feat > feat_need_bytes:
        spill = feat - feat_need_bytes
        feat = feat_need_bytes
        adj = min(adj + spill, adj_need_bytes) if adj_need_bytes is not None else adj + spill
    return CacheAllocation(
        total_bytes=total,
        adj_bytes=adj,
        feat_bytes=feat,
        sample_fraction=frac,
    )


def reallocate_capacity(
    base: CacheAllocation,
    sample_times: list[float],
    feature_times: list[float],
    *,
    adj_need_bytes: int | None = None,
    feat_need_bytes: int | None = None,
) -> CacheAllocation:
    """Eq. 1 re-run at serve time: same total budget, measured stage ratio.

    The online cache-refresh subsystem (runtime/cache_refresh.py) calls
    this with the *serve-time* stage laps — pre-sampling laps plus the
    runtime telemetry window — so the adj/feat split follows the workload
    as it drifts instead of staying frozen at the preprocessing-time
    ratio.  The total budget is the one decision that does NOT move: it
    was sized against device memory (available_budget), which serving
    does not change."""
    return allocate_capacity(
        sample_times,
        feature_times,
        base.total_bytes,
        adj_need_bytes=adj_need_bytes,
        feat_need_bytes=feat_need_bytes,
    )


@dataclasses.dataclass(frozen=True)
class LayerwiseAllocation:
    """Eq. 1's split re-targeted at the layer-wise mode's two caches.

    In layer-wise full-graph inference the two device caches competing for
    the budget are the layer-0 INPUT-FEATURE cache and the intermediate
    EMBEDDING cache (layer-k outputs re-read as layer-k+1 inputs).  Only
    one embedding cache is ever live at a time — each layer's store is
    transient — so ``embed_bytes`` is the full per-layer embedding budget,
    not a per-layer slice."""

    total_bytes: int
    feat_bytes: int  # layer-0 input-feature cache share
    embed_bytes: int  # per-layer intermediate-embedding cache share
    feat_fraction: float  # Σt_feat_gather / Σ(t_feat_gather + t_embed_gather)

    def __post_init__(self):
        assert self.feat_bytes + self.embed_bytes <= self.total_bytes + 1


def allocate_layerwise_capacity(
    feat_gather_times: list[float],
    embed_gather_times: list[float],
    total_bytes: int,
    *,
    feat_need_bytes: int | None = None,
    embed_need_bytes: int | None = None,
) -> LayerwiseAllocation:
    """Eq. 1 over the layer-wise mode's probed chunk gather laps.

    Same proportional-to-measured-stage-time split (and the same
    saturation-aware spill) as :func:`allocate_capacity`, with the roles
    re-mapped: the "sample" slot carries the layer-0 feature-gather laps,
    the "feature" slot the intermediate embedding-gather laps.  The probe
    chunks play presampling's part — a few chunks' synchronized gather
    laps at each source's row width — so the cache that moves more bytes
    per chunk gets the proportionally larger share."""
    alloc = allocate_capacity(
        feat_gather_times,
        embed_gather_times,
        total_bytes,
        adj_need_bytes=feat_need_bytes,
        feat_need_bytes=embed_need_bytes,
    )
    return LayerwiseAllocation(
        total_bytes=alloc.total_bytes,
        feat_bytes=alloc.adj_bytes,
        embed_bytes=alloc.feat_bytes,
        feat_fraction=alloc.sample_fraction,
    )


def shard_allocations(
    base: CacheAllocation,
    shard_weights,
    *,
    sample_times: list[float],
    feature_times: list[float],
    adj_need_bytes: int | None = None,
    feat_need_bytes: int | None = None,
) -> list[CacheAllocation]:
    """Eq. 1 run per shard on per-shard telemetry (sharded serving).

    Each shard re-runs :func:`allocate_capacity` on its own slice of the
    workload: ``shard_weights`` carries the shard's share of the
    telemetry window (its range's visit counts — see
    ``TelemetryWindow.shard_slice``), which scales both its budget share
    of ``base.total_bytes`` and its stage times.  Because Eq. 1's split
    fraction is invariant under uniform time scaling, every shard lands
    on the *same* ``sample_fraction`` as the global allocation — the
    coordination property that lets the globally-ranked fill be
    partitioned by id range without changing a single cached row
    (tested in tests/test_allocation.py / tests/test_sharded_serve.py).
    The per-shard ``total_bytes`` sum to the global budget (remainder
    bytes go to the last shard).
    """
    weights = [max(float(w), 0.0) for w in shard_weights]
    if not weights:
        raise ValueError("shard_allocations needs at least one shard weight")
    denom = sum(weights)
    fracs = [w / denom if denom > 0 else 1.0 / len(weights) for w in weights]
    t_s = float(sum(sample_times))
    t_f = float(sum(feature_times))
    allocs: list[CacheAllocation] = []
    spent = 0
    for i, f in enumerate(fracs):
        budget = base.total_bytes - spent if i == len(fracs) - 1 else int(base.total_bytes * f)
        spent += budget
        allocs.append(
            allocate_capacity(
                [t_s * f] if t_s or t_f else [0.0],
                [t_f * f] if t_s or t_f else [0.0],
                budget,
                adj_need_bytes=None if adj_need_bytes is None else int(adj_need_bytes * f),
                feat_need_bytes=None if feat_need_bytes is None else int(feat_need_bytes * f),
            )
        )
    return allocs
