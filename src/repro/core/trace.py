"""End-to-end tracing and metrics for the inference runtime.

DCI's premise is that cache decisions should follow *measured* workload
behaviour — Eq. 1 splits on stage times, refresh triggers on live miss
rates — but aggregates in ``InferenceReport``/``ServeReport`` cannot show
*when* things happened: whether the pipeline actually overlapped, where a
request sat in the queue, what a refresh epoch paused.  This module is the
timeline half of that story (SALIENT validates its pipelining with exactly
this kind of per-stage timeline analysis):

* :class:`Tracer` — a low-overhead in-memory span/event recorder.  Spans
  (``with tracer.span("gather", lane="slot 0")``), instant events, counter
  tracks, and flow links, all timestamped in microseconds off one
  ``perf_counter`` epoch.  :meth:`Tracer.export` writes Chrome trace-event
  JSON loadable in Perfetto / ``chrome://tracing``.
* :class:`NullTracer` — the disabled path.  Every method is a no-op and
  ``span`` returns a shared reusable context, so instrumented code costs
  one attribute check (``tracer.enabled``) or one no-op call per batch —
  effectively free (gated in ``benchmarks/bench_trace.py``).
* :class:`MetricsRegistry` — labelled counters / gauges / histograms
  (``feat_hit_rate{stream=...,epoch=...}``, ``request_latency_ms``),
  snapshotted into reports and dumpable as JSON or Prometheus text.

Lane model
----------
A *lane* is one horizontal track in the timeline (a Chrome ``tid``).  The
executor maps each pipeline window slot to a lane (``slot 0`` … ``slot
d-1``), so depth-``d`` overlap is *visible* as d stacked lanes with
concurrent batch spans; serving layers add one request-lifecycle lane per
stream (``req:s0`` …), the refresh manager a ``refresh`` lane, sharded
serving an exchange lane per shard.  Lanes are created on first use and
named via Chrome ``M`` (metadata) events.

Tracing is observational only: it reads wall clocks and appends to a host
list, never touching RNG streams, device buffers, or dispatch order — so
traced runs are bit-for-bit identical to untraced runs (equivalence-tested
across the dedup × prefetch × refresh knob grid in tests/test_trace.py).

:func:`validate_trace` / :func:`summarize_trace` are the analysis half,
shared by ``scripts/trace_summary.py`` and the test suite.
"""

from __future__ import annotations

import bisect
import itertools
import json
import math
import time
from typing import Any, Callable, Iterable, Mapping

__all__ = [
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "resolve_tracer",
    "summarize_trace",
    "validate_trace",
]

_PID = 1  # single-process runtime; one Chrome "process" named via metadata


class _Span:
    """A single reusable span context (one per ``Tracer.span`` call).

    Timestamps are taken inside ``__enter__``/``__exit__`` so the recorded
    duration brackets exactly the ``with`` body (plus the optional JAX
    annotation enter/exit, which is what lines device kernels up with the
    host span under ``--trace-jax``).
    """

    __slots__ = ("_tracer", "name", "tid", "args", "_t0", "_jax")

    def __init__(self, tracer: "Tracer", name: str, tid: int, args):
        self._tracer = tracer
        self.name = name
        self.tid = tid
        self.args = args
        self._t0 = 0.0
        self._jax = None

    def __enter__(self) -> "_Span":
        ann = self._tracer._annotate
        if ann is not None:
            self._jax = ann(self.name)
            self._jax.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        if self._jax is not None:
            self._jax.__exit__(exc_type, exc, tb)
        tr = self._tracer
        ev: dict[str, Any] = {
            "name": self.name,
            "ph": "X",
            "ts": (self._t0 - tr._epoch) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "pid": _PID,
            "tid": self.tid,
        }
        if self.args:
            ev["args"] = self.args
        tr._events.append(ev)
        return False


class Tracer:
    """Records spans/instants/counters/flows; exports Chrome trace JSON.

    All timestamps are microseconds relative to the tracer's creation
    (``time.perf_counter`` epoch).  ``jax_annotations=True`` additionally
    wraps every span in ``jax.profiler.TraceAnnotation`` so host spans show
    up alongside device kernels in a ``jax.profiler`` device trace.
    """

    enabled = True

    def __init__(self, *, jax_annotations: bool = False, process_name: str = "repro-infer"):
        self._epoch = time.perf_counter()
        self._events: list[dict[str, Any]] = []
        self._lanes: dict[str, int] = {}
        self._next_flow = itertools.count(1)
        self._annotate: Callable[[str], Any] | None = None
        if jax_annotations:
            from repro.utils.jax_compat import trace_annotation_compat

            self._annotate = trace_annotation_compat()
        self._meta(0, "process_name", {"name": process_name})

    # -- time ----------------------------------------------------------
    def now_us(self) -> float:
        """Current timestamp on this tracer's clock (µs since creation)."""
        return (time.perf_counter() - self._epoch) * 1e6

    def ts_from(self, perf_t: float) -> float:
        """Convert a raw ``time.perf_counter()`` stamp to tracer µs."""
        return (perf_t - self._epoch) * 1e6

    # -- lanes ---------------------------------------------------------
    def lane(self, name: str) -> int:
        """The ``tid`` for lane ``name``, creating + naming it on first use.

        Lanes sort in creation order (``thread_sort_index``), so the call
        sites control the top-to-bottom layout in Perfetto."""
        tid = self._lanes.get(name)
        if tid is None:
            tid = len(self._lanes) + 1
            self._lanes[name] = tid
            self._meta(tid, "thread_name", {"name": name})
            self._meta(tid, "thread_sort_index", {"sort_index": tid})
        return tid

    def _meta(self, tid: int, what: str, args: dict) -> None:
        self._events.append(
            {"name": what, "ph": "M", "ts": 0.0, "pid": _PID, "tid": tid, "args": args}
        )

    # -- events --------------------------------------------------------
    def span(self, name: str, *, lane: str = "main", args: dict | None = None) -> _Span:
        """Context manager recording one complete (``ph:"X"``) event."""
        return _Span(self, name, self.lane(lane), args)

    def complete(
        self,
        name: str,
        *,
        lane: str,
        ts_us: float,
        dur_us: float,
        args: dict | None = None,
    ) -> None:
        """Record a complete event from explicit timestamps — for spans
        whose start and end are observed in different frames (a batch's
        dispatch→retire window, a request's enqueue→admit wait)."""
        ev: dict[str, Any] = {
            "name": name,
            "ph": "X",
            "ts": ts_us,
            "dur": max(dur_us, 0.0),
            "pid": _PID,
            "tid": self.lane(lane),
        }
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(
        self, name: str, *, lane: str = "main", args: dict | None = None, ts_us: float | None = None
    ) -> None:
        ev: dict[str, Any] = {
            "name": name,
            "ph": "i",
            "s": "t",
            "ts": self.now_us() if ts_us is None else ts_us,
            "pid": _PID,
            "tid": self.lane(lane),
        }
        if args:
            ev["args"] = args
        self._events.append(ev)

    def counter(self, name: str, values: Mapping[str, float], *, ts_us: float | None = None) -> None:
        """One sample on counter track ``name`` (one series per key)."""
        self._events.append(
            {
                "name": name,
                "ph": "C",
                "ts": self.now_us() if ts_us is None else ts_us,
                "pid": _PID,
                "tid": 0,
                "args": dict(values),
            }
        )

    # -- flows ---------------------------------------------------------
    def next_flow_id(self) -> int:
        return next(self._next_flow)

    def _flow(self, ph: str, fid: int, name: str, lane: str, ts_us: float | None) -> None:
        ev: dict[str, Any] = {
            "name": name,
            "cat": "flow",
            "ph": ph,
            "id": fid,
            "ts": self.now_us() if ts_us is None else ts_us,
            "pid": _PID,
            "tid": self.lane(lane),
        }
        if ph == "f":
            ev["bp"] = "e"  # bind to the enclosing slice, not the next one
        self._events.append(ev)

    def flow_start(self, fid: int, name: str, *, lane: str, ts_us: float | None = None) -> None:
        self._flow("s", fid, name, lane, ts_us)

    def flow_step(self, fid: int, name: str, *, lane: str, ts_us: float | None = None) -> None:
        self._flow("t", fid, name, lane, ts_us)

    def flow_end(self, fid: int, name: str, *, lane: str, ts_us: float | None = None) -> None:
        self._flow("f", fid, name, lane, ts_us)

    # -- export --------------------------------------------------------
    @property
    def events(self) -> list[dict[str, Any]]:
        return self._events

    def to_chrome(self) -> dict:
        """The trace as a Chrome trace-event JSON object."""
        order = {"M": 0}  # metadata first; everything else by timestamp
        events = sorted(self._events, key=lambda e: (order.get(e["ph"], 1), e["ts"]))
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_chrome(), fh)


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The tracing-off fast path: every method is a no-op.

    ``span`` hands back one shared, stateless context object, so a fully
    instrumented hot loop executes a handful of attribute lookups and empty
    calls per batch when tracing is disabled — the overhead gate in
    ``benchmarks/bench_trace.py`` holds this under 1% of end-to-end time.
    Call sites guard any non-trivial argument construction (building an
    ``args`` dict, reading queue depths) behind ``tracer.enabled``.
    """

    enabled = False

    def now_us(self) -> float:
        return 0.0

    def ts_from(self, perf_t: float) -> float:
        return 0.0

    def lane(self, name: str) -> int:
        return 0

    def span(self, name: str, *, lane: str = "main", args: dict | None = None) -> _NullSpan:
        return _NULL_SPAN

    def complete(self, name: str, *, lane: str, ts_us: float, dur_us: float, args=None) -> None:
        pass

    def instant(self, name: str, *, lane: str = "main", args=None, ts_us=None) -> None:
        pass

    def counter(self, name: str, values, *, ts_us=None) -> None:
        pass

    def next_flow_id(self) -> int:
        return 0

    def flow_start(self, fid: int, name: str, *, lane: str, ts_us=None) -> None:
        pass

    def flow_step(self, fid: int, name: str, *, lane: str, ts_us=None) -> None:
        pass

    def flow_end(self, fid: int, name: str, *, lane: str, ts_us=None) -> None:
        pass

    @property
    def events(self) -> tuple:
        return ()


NULL_TRACER = NullTracer()


def resolve_tracer(tracer: Tracer | NullTracer | None) -> Tracer | NullTracer:
    """``tracer`` or the shared no-op singleton — the idiom every runtime
    entry point uses so ``tracer=None`` (the default) costs nothing."""
    return tracer if tracer is not None else NULL_TRACER


# ---------------------------------------------------------------------------
# Trace analysis — shared by scripts/trace_summary.py and tests.
# ---------------------------------------------------------------------------


def _lane_names(events: Iterable[Mapping]) -> dict[int, str]:
    names: dict[int, str] = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names[e["tid"]] = e.get("args", {}).get("name", str(e["tid"]))
    return names


def _union(intervals: list[tuple[float, float]]) -> list[tuple[float, float]]:
    """Union of ``[start, end)`` intervals, as a sorted disjoint list."""
    out: list[tuple[float, float]] = []
    for s, e in sorted(i for i in intervals if i[1] > i[0]):
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def validate_trace(events: Iterable[Mapping]) -> list[str]:
    """Schema errors in a Chrome trace-event list (empty list == valid).

    Checks the acceptance contract: every event carries ``ph/ts/pid/tid``
    and a name, complete events have a non-negative ``dur``, and every flow
    start (``s``) pairs with exactly one flow end (``f``) of the same id.
    """
    errors: list[str] = []
    starts: dict[Any, int] = {}
    ends: dict[Any, int] = {}
    for i, e in enumerate(events):
        for key in ("ph", "ts", "pid", "tid"):
            if key not in e:
                errors.append(f"event {i}: missing {key!r}: {e!r}")
        ph = e.get("ph")
        if ph != "M" and not isinstance(e.get("ts"), (int, float)):
            errors.append(f"event {i}: non-numeric ts: {e!r}")
        if "name" not in e:
            errors.append(f"event {i}: missing name: {e!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i}: complete event needs dur >= 0: {e!r}")
        if ph in ("s", "t", "f"):
            if "id" not in e:
                errors.append(f"event {i}: flow event needs id: {e!r}")
            elif ph == "s":
                starts[e["id"]] = starts.get(e["id"], 0) + 1
            elif ph == "f":
                ends[e["id"]] = ends.get(e["id"], 0) + 1
        if ph == "i" and e.get("s") not in (None, "t", "p", "g"):
            errors.append(f"event {i}: bad instant scope: {e!r}")
    for fid, n in starts.items():
        if n != 1:
            errors.append(f"flow {fid}: {n} start events (want 1)")
        if ends.get(fid, 0) != 1:
            errors.append(f"flow {fid}: {ends.get(fid, 0)} end events (want 1)")
    for fid in ends:
        if fid not in starts:
            errors.append(f"flow {fid}: end without start")
    return errors


def summarize_trace(events: Iterable[Mapping], *, top: int = 5, slot_prefix: str = "slot") -> dict:
    """Aggregate a trace for human / CI consumption.

    Returns per-lane busy time and utilization (busy / trace extent),
    per-span-name totals ("stages"), the pipeline *overlap fraction* —
    of the wall time during which at least one ``slot*`` lane was busy,
    the share during which two or more were busy concurrently (exactly 0
    for a serial depth-1 run; > 0 whenever batches overlapped) — and the
    ``top`` longest individual spans.  Slot-lane busy time is measured on
    batch spans (each slot's enclosing dispatch→retire window), which are
    non-nested per lane, so nested stage spans don't double-count.
    """
    events = list(events)
    lane_of = _lane_names(events)
    spans = [e for e in events if e.get("ph") == "X"]
    flows = [e for e in events if e.get("ph") in ("s", "t", "f")]
    counters = sorted({e["name"] for e in events if e.get("ph") == "C"})
    if not spans:
        return {
            "extent_ms": 0.0,
            "lanes": {},
            "stages": {},
            "overlap_fraction": 0.0,
            "top_spans": [],
            "n_events": len(events),
            "n_flows": len({e.get("id") for e in flows}) if flows else 0,
            "counters": counters,
        }
    t_lo = min(e["ts"] for e in spans)
    t_hi = max(e["ts"] + e["dur"] for e in spans)
    extent = max(t_hi - t_lo, 1e-9)

    by_lane: dict[str, list[tuple[float, float]]] = {}
    stages: dict[str, dict[str, float]] = {}
    for e in spans:
        lane = lane_of.get(e["tid"], f"tid {e['tid']}")
        by_lane.setdefault(lane, []).append((e["ts"], e["ts"] + e["dur"]))
        st = stages.setdefault(e["name"], {"total_ms": 0.0, "count": 0, "max_ms": 0.0})
        st["total_ms"] += e["dur"] / 1e3
        st["count"] += 1
        st["max_ms"] = max(st["max_ms"], e["dur"] / 1e3)

    lanes = {}
    for lane, ivals in sorted(by_lane.items()):
        busy = sum(e - s for s, e in _union(ivals))
        lanes[lane] = {
            "busy_ms": busy / 1e3,
            "utilization": busy / extent,
            "spans": len(ivals),
        }

    # Overlap: sweep the per-slot-lane busy unions, counting concurrently
    # busy slot lanes.  Batch spans within one lane never overlap (a slot
    # holds one batch at a time), so per-lane union ≡ that slot's busy set.
    slot_unions = [
        _union(ivals) for lane, ivals in by_lane.items() if lane.startswith(slot_prefix)
    ]
    edges = sorted({t for u in slot_unions for iv in u for t in iv})
    busy_us = overlap_us = 0.0
    starts_per_union = [[iv[0] for iv in u] for u in slot_unions]
    for lo, hi in zip(edges, edges[1:]):
        mid = (lo + hi) / 2
        active = 0
        for u, starts in zip(slot_unions, starts_per_union):
            j = bisect.bisect_right(starts, mid) - 1
            if j >= 0 and u[j][1] > mid:
                active += 1
        if active >= 1:
            busy_us += hi - lo
        if active >= 2:
            overlap_us += hi - lo

    top_spans = sorted(spans, key=lambda e: -e["dur"])[:top]
    return {
        "extent_ms": extent / 1e3,
        "lanes": lanes,
        "stages": dict(sorted(stages.items(), key=lambda kv: -kv[1]["total_ms"])),
        "overlap_fraction": (overlap_us / busy_us) if busy_us > 0 else 0.0,
        "top_spans": [
            {
                "name": e["name"],
                "lane": lane_of.get(e["tid"], f"tid {e['tid']}"),
                "ts_ms": (e["ts"] - t_lo) / 1e3,
                "dur_ms": e["dur"] / 1e3,
                "args": e.get("args", {}),
            }
            for e in top_spans
        ],
        "n_events": len(events),
        "n_flows": len({e.get("id") for e in flows}) if flows else 0,
        "counters": counters,
    }


# ---------------------------------------------------------------------------
# Metrics registry — counters / gauges / histograms with labels.
# ---------------------------------------------------------------------------

# Default histogram buckets, in milliseconds — spans request latencies from
# sub-ms cache hits to multi-second cold batches.
DEFAULT_BUCKETS_MS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0)


def _label_key(labels: Mapping[str, Any]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _escape(value: Any) -> str:
    return str(value).replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


class _Counter:
    __slots__ = ("value",)

    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a gauge")
        self.value += amount

    def sample(self) -> float:
        return self.value


class _Gauge:
    __slots__ = ("value",)

    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def sample(self) -> float:
        return self.value


class _Histogram:
    __slots__ = ("buckets", "counts", "sum", "observations")

    kind = "histogram"

    def __init__(self, buckets: tuple[float, ...]):
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)  # +1 for +Inf
        self.sum = 0.0
        self.observations: list[float] = []

    def observe(self, value: float) -> None:
        value = float(value)
        self.sum += value
        self.observations.append(value)
        self.counts[bisect.bisect_left(self.buckets, value)] += 1

    @property
    def count(self) -> int:
        return len(self.observations)

    def quantile(self, q: float) -> float:
        if not self.observations:
            return math.nan
        xs = sorted(self.observations)
        return xs[min(int(q * len(xs)), len(xs) - 1)]

    def sample(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": min(self.observations) if self.observations else math.nan,
            "max": max(self.observations) if self.observations else math.nan,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Labelled counters, gauges, and histograms.

    ``registry.counter("batches_total", stream=0).inc()`` — each distinct
    (name, labels) pair is its own series; a name is bound to one metric
    kind for the registry's lifetime.  :meth:`snapshot` returns a JSON-safe
    dict (embedded in reports), :meth:`to_prometheus` the text exposition
    format (``--metrics out.prom``).
    """

    def __init__(self):
        self._series: dict[tuple[str, str], Any] = {}
        self._kinds: dict[str, str] = {}

    def _get(self, name: str, labels: Mapping[str, Any], factory, kind: str):
        bound = self._kinds.setdefault(name, kind)
        if bound != kind:
            raise ValueError(f"metric {name!r} already registered as {bound}, not {kind}")
        key = (name, _label_key(labels))
        series = self._series.get(key)
        if series is None:
            series = self._series[key] = factory()
        return series

    def counter(self, name: str, **labels) -> _Counter:
        return self._get(name, labels, _Counter, "counter")

    def gauge(self, name: str, **labels) -> _Gauge:
        return self._get(name, labels, _Gauge, "gauge")

    def histogram(self, name: str, *, buckets: tuple[float, ...] | None = None, **labels) -> _Histogram:
        make = lambda: _Histogram(buckets if buckets is not None else DEFAULT_BUCKETS_MS)
        return self._get(name, labels, make, "histogram")

    def snapshot(self) -> dict:
        out: dict[str, dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        for (name, lkey), series in sorted(self._series.items()):
            out[series.kind + "s"][name + lkey] = series.sample()
        return out

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), indent=2, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format, one family per metric name."""
        lines: list[str] = []
        by_name: dict[str, list[tuple[str, Any]]] = {}
        for (name, lkey), series in sorted(self._series.items()):
            by_name.setdefault(name, []).append((lkey, series))
        for name, entries in by_name.items():
            lines.append(f"# TYPE {name} {self._kinds[name]}")
            for lkey, series in entries:
                if series.kind == "histogram":
                    cum = 0
                    for edge, n in zip(series.buckets, series.counts):
                        cum += n
                        lines.append(f"{name}_bucket{_with_le(lkey, edge)} {cum}")
                    cum += series.counts[-1]
                    lines.append(f'{name}_bucket{_with_le(lkey, "+Inf")} {cum}')
                    lines.append(f"{name}_sum{lkey} {series.sum:.6g}")
                    lines.append(f"{name}_count{lkey} {series.count}")
                else:
                    lines.append(f"{name}{lkey} {series.sample():.6g}")
        return "\n".join(lines) + "\n"


def _with_le(lkey: str, edge) -> str:
    le = f'le="{edge}"'
    if not lkey:
        return "{" + le + "}"
    return lkey[:-1] + "," + le + "}"
