"""Cache/preparation policies: DCI and every baseline the paper compares.

Each ``prepare_*`` returns a :class:`PreparedPipeline` — caches (or none),
an optional batch schedule (RAIN), and the measured preprocessing wall
time, which is itself a headline metric in the paper (Tables IV, Fig. 10).
The prepared pipeline is a shared runtime object: one instance serves a
single engine, the staged batch executor at any ``pipeline_depth``, or
every stream of the multi-stream server (runtime/gnn_serve.py)
simultaneously.  Its caches are immutable by default; the online refresh
subsystem (runtime/cache_refresh.py) may swap them to a new epoch as a
delta re-fill, which consumers pick up at their next stage dispatch.

Presampling policies (``dci``/``sci``/``aci``/``ducati``) profile the
workload before filling.  Two modes:

  - single stream (default): ``n_presample`` batches from one seed — the
    paper's setup (hit rates stabilize at ~8 batches, Fig. 11);
  - shared across streams (``stream_seeds=[...]``): the SAME total
    presampling budget split evenly over the streams' seeds and merged
    (:func:`repro.core.presample.merge_stats`), so the one shared cache is
    allocated and filled for the union workload at no extra preprocessing
    cost — the amortization bench_multistream.py measures against N
    private per-stream preparations.

  - ``dci``     the paper's system: Eq. 1 split + lightweight fill
  - ``sci``     single-cache baseline: whole budget to node features
  - ``dgl``     no caches (DGL's stock pipeline)
  - ``ducati``  DUCATI's dual-cache population: value curves + slope fit +
                knapsack-style density fill (heavier preprocessing, the
                paper's point)
  - ``rain``    RAIN: LSH clustering of batches + cross-batch feature reuse
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.allocation import CacheAllocation, allocate_capacity
from repro.core.cache import DualCache
from repro.core.presample import PresampleStats, merge_stats, run_presampling
from repro.graph.datasets import SyntheticGraphDataset

__all__ = [
    "ADMISSION_POLICIES",
    "AdmissionPolicy",
    "EDFAdmission",
    "POLICIES",
    "PreparedPipeline",
    "RoundRobinAdmission",
    "SLOAdmission",
    "prepare",
]


@dataclasses.dataclass(frozen=True)
class PreparedPipeline:
    name: str
    caches: DualCache
    prep_seconds: float
    presample: PresampleStats | None = None
    batch_order: np.ndarray | None = None  # RAIN: inference-order permutation of batches
    reuse_prev_batch: bool = False  # RAIN: reuse previous batch's features
    # Default execution knobs for runs against this pipeline (overridable
    # per run; outputs and hit accounting are knob-invariant):
    prefetch: bool = False  # stage missed host rows for batch i+1 during batch i's compute
    use_kernel: bool = False  # route gathers through the Pallas cached_gather kernel
    gather_buffers: int = 2  # kernel VMEM row-tile slots (1 serial, 2 double buffered)
    dedup: bool = False  # gather/prefetch/model on sorted-unique frontiers only


# ---------------------------------------------------------------- DCI / SCI


def _presample_profile(
    dataset: SyntheticGraphDataset,
    *,
    fanouts: tuple[int, ...],
    batch_size: int,
    n_presample: int,
    seed: int,
    pipeline_depth: int,
    stream_seeds,
) -> PresampleStats:
    """One workload profile, single- or multi-stream.

    With ``stream_seeds`` the total ``n_presample`` budget is split across
    the streams (remainder batches go to the first streams, so the total
    is exact) and the per-stream profiles merged — constant preprocessing
    cost regardless of how many streams share the cache.  Every stream is
    profiled at least once, so with more streams than budget the total
    grows to one batch per stream — the floor at which the merged profile
    still covers every stream's workload."""
    if not stream_seeds:
        return run_presampling(
            dataset,
            fanouts=fanouts,
            batch_size=batch_size,
            n_batches=n_presample,
            seed=seed,
            pipeline_depth=pipeline_depth,
        )
    base, extra = divmod(n_presample, len(stream_seeds))
    return merge_stats(
        [
            run_presampling(
                dataset,
                fanouts=fanouts,
                batch_size=batch_size,
                n_batches=max(1, base + (1 if i < extra else 0)),
                seed=s,
                pipeline_depth=pipeline_depth,
            )
            for i, s in enumerate(stream_seeds)
        ]
    )


def prepare_dci(
    dataset: SyntheticGraphDataset,
    *,
    total_cache_bytes: int,
    fanouts: tuple[int, ...],
    batch_size: int,
    n_presample: int = 8,
    seed: int = 0,
    pipeline_depth: int = 1,
    stream_seeds=None,
    _feat_only: bool = False,
    _adj_only: bool = False,
) -> PreparedPipeline:
    stats = _presample_profile(
        dataset,
        fanouts=fanouts,
        batch_size=batch_size,
        n_presample=n_presample,
        seed=seed,
        pipeline_depth=pipeline_depth,
        stream_seeds=stream_seeds,
    )
    # Preprocessing cost = steady-state pre-sampling work + allocation +
    # cache filling.  The one-time jit compile inside run_presampling's
    # warmup is excluded (it is paid once per process, not per preparation).
    t0 = time.perf_counter() - sum(stats.sample_times) - sum(stats.feature_times)
    if _feat_only:  # SCI: the single-cache state of the art
        alloc = CacheAllocation(
            total_bytes=total_cache_bytes,
            adj_bytes=0,
            feat_bytes=total_cache_bytes,
            sample_fraction=0.0,
        )
    elif _adj_only:  # ACI ablation: adjacency-only cache
        alloc = CacheAllocation(
            total_bytes=total_cache_bytes,
            adj_bytes=total_cache_bytes,
            feat_bytes=0,
            sample_fraction=1.0,
        )
    else:
        alloc = allocate_capacity(
            stats.sample_times,
            stats.feature_times,
            total_cache_bytes,
            adj_need_bytes=dataset.graph.num_edges * 4,
            feat_need_bytes=dataset.features.nbytes,
        )
    caches = DualCache.build(
        dataset,
        node_counts=stats.node_counts,
        edge_counts=stats.edge_counts,
        allocation=alloc,
    )
    name = "dci"
    if _feat_only:
        name = "sci"
    elif _adj_only:
        name = "aci"
    return PreparedPipeline(
        name=name,
        caches=caches,
        prep_seconds=time.perf_counter() - t0,
        presample=stats,
    )


def prepare_sci(dataset, **kw) -> PreparedPipeline:
    return prepare_dci(dataset, _feat_only=True, **kw)


def prepare_aci(dataset, **kw) -> PreparedPipeline:
    """Ablation: the whole budget to the ADJACENCY cache (no feature cache).
    Not a paper baseline — isolates each cache's contribution next to SCI."""
    return prepare_dci(dataset, _adj_only=True, **kw)


# ---------------------------------------------------------------------- DGL


def prepare_dgl(dataset: SyntheticGraphDataset, **_kw) -> PreparedPipeline:
    t0 = time.perf_counter()
    caches = DualCache.none(dataset)
    return PreparedPipeline(name="dgl", caches=caches, prep_seconds=time.perf_counter() - t0)


# ------------------------------------------------------------------- DUCATI


def prepare_ducati(
    dataset: SyntheticGraphDataset,
    *,
    total_cache_bytes: int,
    fanouts: tuple[int, ...],
    batch_size: int,
    n_presample: int = 8,
    seed: int = 0,
    pipeline_depth: int = 1,
    stream_seeds=None,
) -> PreparedPipeline:
    """DUCATI's dual-cache population, adapted to inference.

    DUCATI (training-oriented) builds *value curves* for nfeat and adj
    entries (counts sorted descending — a full O(n log n) sort over both
    populations), fits slopes by curve fitting, and fills a knapsack by
    value density.  Amortizable over training epochs, expensive for
    inference — exactly the comparison in Fig. 10.  We reproduce the
    algorithmic structure: global sorts + polynomial slope fits + joint
    density-greedy fill; the capacity split *emerges* from the knapsack
    instead of Eq. 1.
    """
    # DUCATI gathers statistics over substantially more batches (epoch-level
    # in training); we follow with 4x DCI's presampling.  Jit-compile time
    # is excluded the same way as prepare_dci.
    stats = _presample_profile(
        dataset,
        fanouts=fanouts,
        batch_size=batch_size,
        n_presample=4 * n_presample,
        seed=seed,
        pipeline_depth=pipeline_depth,
        stream_seeds=stream_seeds,
    )
    t0 = time.perf_counter() - sum(stats.sample_times) - sum(stats.feature_times)
    row_bytes = dataset.feature_nbytes_per_row()
    deg = np.diff(dataset.graph.col_ptr)

    # --- value curves + slope fitting (the expensive part) -----------------
    nfeat_curve = np.sort(stats.node_counts)[::-1].astype(np.float64)
    starts = np.minimum(dataset.graph.col_ptr[:-1], max(dataset.graph.num_edges - 1, 0))
    node_totals = np.add.reduceat(stats.edge_counts.astype(np.int64), starts)
    node_totals = np.where(deg > 0, node_totals, 0)
    adj_curve = np.sort(node_totals)[::-1].astype(np.float64)
    for curve in (nfeat_curve, adj_curve):
        x = np.arange(1, curve.shape[0] + 1, dtype=np.float64)
        with np.errstate(divide="ignore"):
            np.polyfit(np.log(x), np.log(curve + 1.0), deg=2)  # slope fit

    # --- joint knapsack by value density ------------------------------------
    # nfeat entry v: value = visits, size = row_bytes
    # adj entry v:   value = total visits of v's list, size = deg[v]*4 bytes
    n = dataset.num_nodes
    sizes = np.concatenate([np.full(n, row_bytes, np.int64), deg.astype(np.int64) * 4])
    values = np.concatenate([stats.node_counts.astype(np.float64), node_totals.astype(np.float64)])
    density = values / np.maximum(sizes, 1)
    order = np.argsort(-density, kind="stable")  # global O(n log n) sort
    csum = np.cumsum(sizes[order])
    chosen = order[csum <= total_cache_bytes]
    feat_nodes = chosen[chosen < n]
    adj_nodes = chosen[chosen >= n] - n

    feat_bytes = int(len(feat_nodes) * row_bytes)
    adj_bytes = int(deg[adj_nodes].sum() * 4)
    alloc = CacheAllocation(
        total_bytes=total_cache_bytes,
        adj_bytes=adj_bytes,
        feat_bytes=min(feat_bytes, total_cache_bytes - adj_bytes),
        sample_fraction=float(adj_bytes) / max(total_cache_bytes, 1),
    )
    # Fill with the knapsack's own selections: bias counts so exactly the
    # chosen entries rank on top, then reuse the standard fill paths.
    node_counts_sel = np.zeros(n, np.int64)
    node_counts_sel[feat_nodes] = stats.node_counts[feat_nodes].astype(np.int64) + 1
    edge_counts_sel = stats.edge_counts.copy()
    caches = DualCache.build(
        dataset,
        node_counts=node_counts_sel,
        edge_counts=edge_counts_sel,
        allocation=alloc,
    )
    return PreparedPipeline(
        name="ducati",
        caches=caches,
        prep_seconds=time.perf_counter() - t0,
        presample=stats,
    )


# --------------------------------------------------------------------- RAIN


def _minhash_signatures(batches: np.ndarray, num_hashes: int, seed: int) -> np.ndarray:
    """MinHash signature per batch over its seed set (RAIN's LSH front end)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 2**31 - 1, num_hashes, dtype=np.int64)
    b = rng.integers(0, 2**31 - 1, num_hashes, dtype=np.int64)
    p = np.int64(2**31 - 1)
    # batches: [num_batches, batch_size] node ids
    h = (batches[:, None, :] * a[None, :, None] + b[None, :, None]) % p
    return h.min(axis=2)  # [num_batches, num_hashes]


def prepare_rain(
    dataset: SyntheticGraphDataset,
    *,
    batch_size: int,
    num_hashes: int = 32,
    bands: int = 8,
    seed: int = 0,
    **_kw,
) -> PreparedPipeline:
    """RAIN: LSH-cluster similar batches, run them adjacently, reuse features.

    No device cache is built; the win comes from cross-batch reuse.  The
    preprocessing cost is the signature + banding pass over *every* test
    batch — O(#batches · batch_size · num_hashes), the linear-but-heavy
    term of Table IV.
    """
    t0 = time.perf_counter()
    test = dataset.test_idx
    nb = max(len(test) // batch_size, 1)
    if len(test) < nb * batch_size:  # tiny datasets: cycle to fill one batch
        test = np.tile(test, -(-nb * batch_size // max(len(test), 1)))
    trimmed = test[: nb * batch_size].reshape(nb, batch_size).astype(np.int64)
    sig = _minhash_signatures(trimmed, num_hashes, seed)
    # Band the signatures; batches sharing any band bucket are "similar".
    per_band = num_hashes // bands
    keys = np.zeros(nb, np.int64)
    buckets: dict[tuple, list[int]] = {}
    for i in range(nb):
        for band in range(bands):
            k = (band, *sig[i, band * per_band : (band + 1) * per_band].tolist())
            buckets.setdefault(k, []).append(i)
    # Greedy cluster ordering: walk buckets, emit unseen members together.
    order: list[int] = []
    seen = np.zeros(nb, bool)
    for members in buckets.values():
        for m in members:
            if not seen[m]:
                seen[m] = True
                order.append(m)
    del keys
    caches = DualCache.none(dataset)
    return PreparedPipeline(
        name="rain",
        caches=caches,
        prep_seconds=time.perf_counter() - t0,
        batch_order=np.asarray(order, np.int64),
        reuse_prev_batch=True,
    )


POLICIES = {
    "dci": prepare_dci,
    "sci": prepare_sci,
    "aci": prepare_aci,
    "dgl": prepare_dgl,
    "ducati": prepare_ducati,
    "rain": prepare_rain,
}


# ------------------------------------------------------- admission policies
#
# Cache policies above decide WHAT to keep on device; admission policies
# decide WHICH queued request the serving front-end
# (runtime/request_queue.py) dispatches next.  They are pure ordering
# logic over duck-typed requests (``arrival_s``, optional ``deadline_s``,
# and ``admission_deadline_s`` — the deadline as admission should see it,
# None for a deferred/blown request): the server applies the mechanical
# parts — in-flight caps, the progress fallback, and the round-robin
# cursor — so a policy here never touches runtime state and stays
# property-testable in isolation (tests/test_request_queue.py).


class AdmissionPolicy:
    """Order the admissible requests of one serving step.

    ``order(candidates, now)`` receives ``(stream_key, head_request)``
    pairs — one per stream whose head request has arrived by ``now`` —
    and returns them in service-preference order (most urgent first), or
    ``None`` to defer to the server's own round-robin cursor.  ``sheds``
    marks policies that drop (or defer) requests whose deadline has
    already passed before selecting."""

    name = "fifo"
    sheds = False

    def order(self, candidates, now):
        del now
        return sorted(candidates, key=lambda c: (c[1].arrival_s, c[0]))


class RoundRobinAdmission(AdmissionPolicy):
    """The bit-for-bit baseline: defer entirely to the server's
    round-robin cursor (returning ``None``), so a request-queue serve
    with zero arrival offsets reproduces ``MultiStreamServer``'s
    admission log — and outputs — exactly."""

    name = "round-robin"

    def order(self, candidates, now):
        del candidates, now
        return None


class EDFAdmission(AdmissionPolicy):
    """Earliest-deadline-first.

    Deadline-free requests sort last (a deadline is a promise; absence of
    one is best-effort), ties break by arrival then stream key, so the
    order is total and deterministic.  For a single machine serving
    sequential batches EDF minimizes maximum lateness (Jackson's rule) —
    under a burst this approximates global FCFS over the backlog, which
    is what beats round-robin's interleaving on p99."""

    name = "edf"

    def order(self, candidates, now):
        del now
        inf = float("inf")

        def key(c):
            stream_key, req = c
            dl = getattr(req, "admission_deadline_s", req.deadline_s)
            return (inf if dl is None else dl, req.arrival_s, stream_key)

        return sorted(candidates, key=key)


class SLOAdmission(EDFAdmission):
    """EDF plus SLO enforcement at admission time.

    Before selecting, the server drops every arrived request whose
    deadline has already passed (``blown="shed"`` — the request never
    runs and is accounted as shed) or demotes it to best-effort
    (``blown="defer"`` — it keeps its batch but sorts after every
    deadline-carrying request, via ``admission_deadline_s = None``).
    Either way a blown request can no longer delay ones that can still
    meet their deadlines."""

    name = "slo"
    sheds = True

    def __init__(self, blown: str = "shed"):
        if blown not in ("shed", "defer"):
            raise ValueError(f"blown must be 'shed' or 'defer', got {blown!r}")
        self.blown = blown


ADMISSION_POLICIES = {
    "round-robin": RoundRobinAdmission,
    "edf": EDFAdmission,
    "slo": SLOAdmission,
}


def prepare(policy: str, dataset: SyntheticGraphDataset, **kw) -> PreparedPipeline:
    """Dispatch to a policy's ``prepare_*``.

    Presampling policies accept two extra knobs, both forwarded to
    :func:`repro.core.presample.run_presampling`:

      - ``pipeline_depth`` (default 1 = serial, the Eq. 1 timing
        semantics; >1 overlaps presample batches through the staged
        executor);
      - ``stream_seeds`` (default None): profile the union workload of
        several request streams, splitting the same total presampling
        budget across them — used when one cache will be shared by the
        multi-stream server (runtime/gnn_serve.py).

    Execution knobs (``prefetch``, ``use_kernel``, ``gather_buffers``,
    ``dedup``) are policy-independent: they are recorded on the returned
    :class:`PreparedPipeline` as the defaults every engine run and every
    serving stream resolves against, without changing what gets cached.
    ``dedup`` routes the feature path through sorted-unique frontiers
    (gather each distinct row once, expand through the inverse map); like
    the others it never changes outputs or hit accounting, only how many
    rows move.

    ``dgl`` and ``rain`` build no presampled caches; the extra knobs are
    ignored for them."""
    if policy not in POLICIES:
        raise KeyError(f"unknown policy {policy!r}; have {sorted(POLICIES)}")
    if kw.get("pipeline_depth") == "auto":
        # "auto" sizes the RUN-time executor window (the engine resolves it
        # from a measured compute:prep probe); presampling stays serial —
        # Eq. 1's stage-time ratio assumes fully synchronized stages.
        kw["pipeline_depth"] = 1
    exec_kw = {
        "prefetch": bool(kw.pop("prefetch", False)),
        "use_kernel": bool(kw.pop("use_kernel", False)),
        "gather_buffers": int(kw.pop("gather_buffers", 2)),
        "dedup": bool(kw.pop("dedup", False)),
    }
    if exec_kw["gather_buffers"] < 1:
        raise ValueError(f"gather_buffers must be >= 1, got {exec_kw['gather_buffers']}")
    fn = POLICIES[policy]
    if policy == "dgl":
        pipe = fn(dataset)
    elif policy == "rain":
        pipe = fn(
            dataset,
            batch_size=kw["batch_size"],
            seed=kw.get("seed", 0),
        )
    else:
        pipe = fn(dataset, **kw)
    return dataclasses.replace(pipe, **exec_kw)
