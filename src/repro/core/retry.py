"""Bounded, deterministic retry with per-attempt timeouts.

The fault-tolerance layer's policy half: core/faults.py decides *when*
something breaks; this module decides what a guarded call site *does*
about it.  :func:`call_with_retry` re-invokes an idempotent thunk up to
``max_attempts`` times, sleeping a bounded exponential backoff between
attempts, and converts an attempt that overruns ``timeout_s`` into a
retryable :class:`StageTimeout` — the slow-host case a ``kind="delay"``
fault models.

Determinism: the jitter on every backoff is drawn from a Philox stream
seeded ``[policy.seed, crc32(key)]``, so the full delay schedule is a
pure function of ``(policy, key)`` — replaying a fault plan replays the
exact same waits (property-tested in tests/test_faults.py).  Bounds are
closed-form: each delay is at most ``max_backoff_s * (1 + jitter)`` and
the total sleep over a call is at most :meth:`RetryPolicy.total_backoff_bound`.

Call sites must only wrap *pure/idempotent* operations (the cache
gathers, prefetch staging, and injector checks all are): an attempt that
fails must leave no state behind, or the retry would double-apply it.
"""

from __future__ import annotations

import dataclasses
import time
import zlib

import numpy as np

__all__ = [
    "RetryPolicy",
    "RetryExhausted",
    "StageTimeout",
    "call_with_retry",
]


class StageTimeout(RuntimeError):
    """An attempt overran its per-attempt wall budget."""

    def __init__(self, elapsed_s: float, timeout_s: float):
        self.elapsed_s = elapsed_s
        self.timeout_s = timeout_s
        super().__init__(f"attempt took {elapsed_s * 1e3:.2f} ms > timeout {timeout_s * 1e3:.2f} ms")


class RetryExhausted(RuntimeError):
    """Every attempt in the budget failed; ``last`` is the final error."""

    def __init__(self, attempts: int, last: BaseException):
        self.attempts = attempts
        self.last = last
        super().__init__(f"exhausted {attempts} attempts; last error: {last!r}")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Budget + backoff shape for one class of guarded calls.

    ``backoff_s`` is the base delay before attempt 2, growing by
    ``backoff_multiplier`` per retry and clamped to ``max_backoff_s``;
    ``jitter`` spreads each delay uniformly over ``±jitter`` of itself
    (seeded — see module docstring).  ``timeout_s`` is a *per-attempt*
    wall bound (``None`` = no timeout)."""

    max_attempts: int = 3
    backoff_s: float = 1e-3
    backoff_multiplier: float = 2.0
    max_backoff_s: float = 0.05
    jitter: float = 0.5
    timeout_s: float | None = None
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff_s and max_backoff_s must be >= 0")
        if self.backoff_multiplier < 1.0:
            raise ValueError(f"backoff_multiplier must be >= 1, got {self.backoff_multiplier}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        if self.timeout_s is not None and self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {self.timeout_s}")

    def backoff_delays(self, key=0) -> list[float]:
        """The full (deterministic) delay schedule for one guarded call:
        ``max_attempts - 1`` sleeps, attempt ``i``'s retry waiting
        ``min(max_backoff, backoff * multiplier**i) * (1 ± jitter)``."""
        rng = np.random.default_rng([self.seed, zlib.crc32(repr(key).encode())])
        delays = []
        for i in range(self.max_attempts - 1):
            base = min(self.max_backoff_s, self.backoff_s * self.backoff_multiplier**i)
            u = float(rng.uniform(-1.0, 1.0)) if self.jitter > 0 else 0.0
            delays.append(max(0.0, base * (1.0 + self.jitter * u)))
        return delays

    def total_backoff_bound(self) -> float:
        """Closed-form upper bound on the summed sleeps of one call."""
        return (self.max_attempts - 1) * self.max_backoff_s * (1.0 + self.jitter)


def call_with_retry(
    fn,
    *,
    policy: RetryPolicy,
    key=0,
    retryable: tuple = (Exception,),
    on_retry=None,
    sleep=time.sleep,
    clock=time.perf_counter,
):
    """Invoke ``fn()`` with the policy's retry/timeout budget.

    ``key`` seeds the jitter schedule (use something stable per call
    site, e.g. ``(site, call_index)``).  ``on_retry(attempt, delay_s,
    err)`` fires before each backoff sleep — the hook serving layers use
    for retry counters and trace marks.  Raises :class:`RetryExhausted`
    (wrapping the last error) once the budget is spent; non-retryable
    exceptions propagate immediately.
    """
    delays = policy.backoff_delays(key)
    last: BaseException | None = None
    for attempt in range(policy.max_attempts):
        t0 = clock()
        try:
            result = fn()
        except retryable as err:  # noqa: PERF203 - per-attempt handling is the point
            last = err
        else:
            elapsed = clock() - t0
            if policy.timeout_s is not None and elapsed > policy.timeout_s:
                # The attempt "succeeded" too late to count: the result is
                # discarded and the overrun becomes a retryable failure.
                last = StageTimeout(elapsed, policy.timeout_s)
            else:
                return result
        if attempt < policy.max_attempts - 1:
            delay = delays[attempt]
            if on_retry is not None:
                on_retry(attempt + 1, delay, last)
            if delay > 0:
                sleep(delay)
    raise RetryExhausted(policy.max_attempts, last)
