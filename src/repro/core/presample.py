"""Pre-sampling workload profiler (paper §IV-A/B).

Runs ``n`` mini-batches through the *uncached* pipeline, measuring per-batch
sampling and feature-loading wall time (the Eq. 1 inputs) and accumulating
node / adjacency-element visit counts (the cache-filling inputs).  The
paper shows hit rates stabilize at ~8 pre-sampling batches (Fig. 11);
``n_batches=8`` is the default.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.datasets import SyntheticGraphDataset
from repro.graph.features import plain_feature_store
from repro.graph.sampling import device_graph, sample_blocks

__all__ = ["PresampleStats", "run_presampling"]


@dataclasses.dataclass
class PresampleStats:
    node_counts: np.ndarray  # int[N]  feature-row visit counts
    edge_counts: np.ndarray  # int[E]  adjacency-element visit counts
    sample_times: list[float]
    feature_times: list[float]
    peak_workload_bytes: int
    n_batches: int

    @property
    def mean_node_visits(self) -> float:
        return float(self.node_counts.mean())


def _batch_seeds(test_idx: np.ndarray, batch_size: int, i: int) -> np.ndarray:
    """Cyclic, padded batch slicing — static shapes keep the sampler jitted."""
    start = (i * batch_size) % max(len(test_idx), 1)
    seeds = test_idx[start : start + batch_size]
    if len(seeds) < batch_size:
        seeds = np.concatenate([seeds, test_idx[: batch_size - len(seeds)]])
    return seeds


def run_presampling(
    dataset: SyntheticGraphDataset,
    *,
    fanouts: tuple[int, ...],
    batch_size: int,
    n_batches: int = 8,
    seed: int = 0,
) -> PresampleStats:
    g = device_graph(dataset.graph)
    store = plain_feature_store(dataset.features)
    key = jax.random.PRNGKey(seed)

    node_counts = jnp.zeros(dataset.num_nodes, jnp.int32)
    edge_counts = jnp.zeros(dataset.graph.num_edges, jnp.int32)
    sample_times: list[float] = []
    feature_times: list[float] = []
    peak_bytes = 0

    # Untimed warmup: compile the sampler/gather once so Eq. 1's stage-time
    # ratio measures steady-state work, not jit compilation.
    wseeds = jnp.asarray(_batch_seeds(dataset.test_idx, batch_size, 0))
    wblock = sample_blocks(key, g, wseeds, tuple(fanouts))
    wfeats, _ = store.gather(wblock.input_nodes)
    jax.block_until_ready(wfeats)

    for i in range(n_batches):
        key, sub = jax.random.split(key)
        seeds = jnp.asarray(_batch_seeds(dataset.test_idx, batch_size, i))

        t0 = time.perf_counter()
        block = sample_blocks(sub, g, seeds, tuple(fanouts))
        jax.block_until_ready(block.frontiers[-1])
        sample_times.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        feats, _ = store.gather(block.input_nodes)
        jax.block_until_ready(feats)
        feature_times.append(time.perf_counter() - t0)

        node_counts = node_counts.at[block.input_nodes].add(1)
        for slots in block.edge_slots:
            edge_counts = edge_counts.at[slots.reshape(-1)].add(1)
        # Live workload footprint of this batch (frontier ids + gathered
        # features) — the "workload-aware" part of the budget.
        batch_bytes = int(feats.size * feats.dtype.itemsize) + sum(
            int(f.size * 4) for f in block.frontiers
        )
        peak_bytes = max(peak_bytes, batch_bytes)

    return PresampleStats(
        node_counts=np.asarray(node_counts),
        edge_counts=np.asarray(edge_counts),
        sample_times=sample_times,
        feature_times=feature_times,
        peak_workload_bytes=peak_bytes,
        n_batches=n_batches,
    )
