"""Pre-sampling workload profiler (paper §IV-A/B).

Runs ``n`` mini-batches through the *uncached* pipeline, measuring per-batch
sampling and feature-loading wall time (the Eq. 1 inputs) and accumulating
node / adjacency-element visit counts (the cache-filling inputs).  The
paper shows hit rates stabilize at ~8 pre-sampling batches (Fig. 11);
``n_batches=8`` is the default.

Batches run through the same staged executor as inference
(:mod:`repro.runtime.pipeline` — one code path for Eq. 1 stage times and
filling counts).  ``pipeline_depth=1`` (the default) keeps every stage
fully synchronized, which is what Eq. 1's stage-time ratio assumes;
``depth>1`` overlaps batches, leaving the visit counts unchanged but
turning the per-stage laps into dispatch times.

Multi-stream serving (runtime/gnn_serve.py) profiles the *union* workload:
one small presampling run per request stream, combined by
:func:`merge_stats` — visit counts sum (the shared cache is filled for the
combined traffic) and stage-time laps concatenate (Eq. 1's ratio then
reflects every stream's measured mix).  The total presampling budget stays
constant (Fig. 11's ~8 batches split across streams), which is exactly the
amortization a shared cache buys over per-stream private preparation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.datasets import SyntheticGraphDataset
from repro.graph.features import plain_feature_store
from repro.graph.sampling import device_graph, sample_blocks
from repro.runtime.pipeline import PipelinedExecutor, Stage
from repro.utils.timing import StageClock

__all__ = ["PresampleStats", "merge_stats", "run_presampling"]


@dataclasses.dataclass
class PresampleStats:
    node_counts: np.ndarray  # int[N]  feature-row visit counts
    edge_counts: np.ndarray  # int[E]  adjacency-element visit counts
    sample_times: list[float]
    feature_times: list[float]
    peak_workload_bytes: int
    n_batches: int

    @property
    def mean_node_visits(self) -> float:
        return float(self.node_counts.mean())


def merge_stats(stats: "list[PresampleStats]") -> PresampleStats:
    """Combine per-stream presampling profiles into one shared profile.

    Visit counts sum (the shared cache is filled for the union workload),
    stage-time laps concatenate (Eq. 1's sample:feature ratio then averages
    over every stream's traffic), and the peak live-workload footprint is
    the max across streams (streams interleave; only one batch's arrays are
    materialized per pipeline slot)."""
    if not stats:
        raise ValueError("merge_stats needs at least one PresampleStats")
    return PresampleStats(
        node_counts=np.sum([s.node_counts for s in stats], axis=0),
        edge_counts=np.sum([s.edge_counts for s in stats], axis=0),
        sample_times=[t for s in stats for t in s.sample_times],
        feature_times=[t for s in stats for t in s.feature_times],
        peak_workload_bytes=max(s.peak_workload_bytes for s in stats),
        n_batches=sum(s.n_batches for s in stats),
    )


def _batch_seeds(test_idx: np.ndarray, batch_size: int, i: int) -> np.ndarray:
    """Cyclic, padded batch slicing — static shapes keep the sampler jitted."""
    start = (i * batch_size) % max(len(test_idx), 1)
    seeds = test_idx[start : start + batch_size]
    if len(seeds) < batch_size:
        seeds = np.concatenate([seeds, test_idx[: batch_size - len(seeds)]])
    return seeds


def run_presampling(
    dataset: SyntheticGraphDataset,
    *,
    fanouts: tuple[int, ...],
    batch_size: int,
    n_batches: int = 8,
    seed: int = 0,
    pipeline_depth: int = 1,
) -> PresampleStats:
    g = device_graph(dataset.graph)
    store = plain_feature_store(dataset.features)

    # Untimed warmup: compile the sampler/gather once so Eq. 1's stage-time
    # ratio measures steady-state work, not jit compilation.
    key = jax.random.PRNGKey(seed)
    wseeds = jnp.asarray(_batch_seeds(dataset.test_idx, batch_size, 0))
    wblock = sample_blocks(key, g, wseeds, tuple(fanouts))
    wfeats, _ = store.gather(wblock.input_nodes)
    jax.block_until_ready(wfeats)

    state = {"key": key}
    counts = {
        "node": jnp.zeros(dataset.num_nodes, jnp.int32),
        "edge": jnp.zeros(dataset.graph.num_edges, jnp.int32),
        "peak_bytes": 0,
    }

    def sample_stage(ctx):
        state["key"], sub = jax.random.split(state["key"])
        return sample_blocks(sub, g, jnp.asarray(ctx.payload), tuple(fanouts))

    def feature_stage(ctx):
        feats, _ = store.gather(ctx.outputs["sample"].input_nodes)
        return feats

    def on_retire(ctx):
        block, feats = ctx.outputs["sample"], ctx.outputs["feature"]
        counts["node"] = counts["node"].at[block.input_nodes].add(1)
        for slots in block.edge_slots:
            counts["edge"] = counts["edge"].at[slots.reshape(-1)].add(1)
        # Live workload footprint of this batch (frontier ids + gathered
        # features) — the "workload-aware" part of the budget.
        batch_bytes = int(feats.size * feats.dtype.itemsize) + sum(
            int(f.size * 4) for f in block.frontiers
        )
        counts["peak_bytes"] = max(counts["peak_bytes"], batch_bytes)

    clock = StageClock(overlap=pipeline_depth > 1)
    executor = PipelinedExecutor(
        [
            Stage("sample", sample_stage, lambda c: c.outputs["sample"].frontiers[-1]),
            Stage("feature", feature_stage, lambda c: c.outputs["feature"]),
        ],
        depth=pipeline_depth,
        clock=clock,
        on_retire=on_retire,
    )
    executor.run(_batch_seeds(dataset.test_idx, batch_size, i) for i in range(n_batches))

    return PresampleStats(
        node_counts=np.asarray(counts["node"]),
        edge_counts=np.asarray(counts["edge"]),
        sample_times=list(clock.laps.get("sample", [])),
        feature_times=list(clock.laps.get("feature", [])),
        peak_workload_bytes=counts["peak_bytes"],
        n_batches=n_batches,
    )
