"""DCI core: the paper's contribution (allocation + filling + dual cache)."""

from repro.core.allocation import (
    DEFAULT_RESERVE_BYTES,
    CacheAllocation,
    allocate_capacity,
    available_budget,
)
from repro.core.cache import DualCache
from repro.core.policies import POLICIES, PreparedPipeline, prepare
from repro.core.presample import PresampleStats, run_presampling

__all__ = [
    "DEFAULT_RESERVE_BYTES",
    "CacheAllocation",
    "allocate_capacity",
    "available_budget",
    "DualCache",
    "POLICIES",
    "PreparedPipeline",
    "prepare",
    "PresampleStats",
    "run_presampling",
]
