"""DualCache — the runtime bundle of DCI's two caches, versioned by epoch.

``DualCache`` owns the device-resident adjacency cache (inside
``DeviceGraph``) and the feature cache (inside ``FeatureStore``) plus the
allocation that produced them.  It is what the inference engine actually
runs against; policies (core/policies.py) are factories for it.

Since the online refresh subsystem (runtime/cache_refresh.py) it is a
*versioned, mutable-by-delta* runtime object rather than a frozen value:
``refresh()`` swaps in a new allocation's worth of cache contents as an
incremental delta (only changed feature rows / adjacency segments move,
never the O(N)/O(E) host structures) and bumps ``epoch``.  Consumers read
``caches.dgraph`` / ``caches.store`` at stage-dispatch time, so every
stream picks up the new epoch at its next batch without coordination.
Refreshes never change sampled blocks, gathered rows, or logits — the
two-level sort order and the host feature table are frozen at build time —
only hit accounting and byte movement (tests/test_cache_refresh.py).
Without refresh enabled nothing mutates and the object behaves exactly
like the former frozen dataclass.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.allocation import CacheAllocation
from repro.graph.csc import (
    AdjCache,
    AdjRefreshStats,
    build_adj_cache,
    node_visit_totals,
    refresh_adj_cache,
    two_level_sort,
)
from repro.graph.datasets import SyntheticGraphDataset
from repro.graph.features import (
    FeatureRefreshStats,
    FeatureStore,
    build_feature_cache,
    plain_feature_store,
    refresh_feature_cache,
)
from repro.graph.sampling import DeviceGraph, device_graph

__all__ = ["DualCache", "CacheRefreshDelta"]


@dataclasses.dataclass(frozen=True)
class CacheRefreshDelta:
    """One epoch transition: what moved, and what it cost."""

    epoch: int  # the epoch this delta produced
    allocation: CacheAllocation
    feat: FeatureRefreshStats
    adj: AdjRefreshStats

    @property
    def changed(self) -> bool:
        return self.feat.changed or self.adj.changed


@dataclasses.dataclass
class DualCache:
    dgraph: DeviceGraph
    store: FeatureStore
    allocation: CacheAllocation | None
    epoch: int = 0
    # Frozen refresh context, captured by ``build``: the host CSC, the
    # two-level-sorted row order, and the host-side adjacency cache the
    # delta re-fill copies unchanged segments from.  ``None`` for cacheless
    # builds (``none()``), which have nothing to refresh.
    _graph: object | None = dataclasses.field(default=None, repr=False)
    _sorted_row: np.ndarray | None = dataclasses.field(default=None, repr=False)
    _adj_cache: AdjCache | None = dataclasses.field(default=None, repr=False)

    @property
    def adj_cached_elements(self) -> int:
        return int(np.asarray(self.dgraph.cached_len).sum())

    @property
    def feat_cached_rows(self) -> int:
        return self.store.num_cached

    @property
    def refreshable(self) -> bool:
        return self._graph is not None and self._sorted_row is not None

    @classmethod
    def build(
        cls,
        dataset: SyntheticGraphDataset,
        *,
        node_counts: np.ndarray,
        edge_counts: np.ndarray,
        allocation: CacheAllocation,
    ) -> "DualCache":
        """Fill both caches per §IV-B with the given capacity split."""
        sorted_row, node_totals = two_level_sort(dataset.graph, edge_counts)
        adj_cache = build_adj_cache(dataset.graph, sorted_row, node_totals, allocation.adj_bytes)
        dgraph = device_graph(dataset.graph, sorted_row_index=sorted_row, adj_cache=adj_cache)
        store = build_feature_cache(dataset.features, node_counts, allocation.feat_bytes)
        return cls(
            dgraph=dgraph,
            store=store,
            allocation=allocation,
            _graph=dataset.graph,
            _sorted_row=sorted_row,
            _adj_cache=adj_cache,
        )

    @classmethod
    def none(cls, dataset: SyntheticGraphDataset) -> "DualCache":
        """The DGL baseline: no caches at all."""
        return cls(
            dgraph=device_graph(dataset.graph),
            store=plain_feature_store(dataset.features),
            allocation=None,
        )

    # ------------------------------------------------------------- refresh
    def refresh(
        self,
        *,
        allocation: CacheAllocation,
        node_counts: np.ndarray,
        edge_counts: np.ndarray,
        injector=None,
    ) -> CacheRefreshDelta:
        """Swap both caches to a new allocation/ranking as a delta re-fill.

        No full ``build``: the two-level sort is never re-run, unchanged
        feature rows stay device-resident in their slots, unchanged
        adjacency segments are copied from the previous cache, and the
        O(E) device arrays are untouched.  In-flight batches that already
        dispatched against the previous epoch's arrays keep them alive
        (JAX arrays are immutable) and retire normally — the swap is a
        pointer flip on this object, visible to the next stage dispatch.

        The swap is TRANSACTIONAL: exactly five attributes mutate
        (``dgraph``, ``store``, ``allocation``, ``_adj_cache``, ``epoch``),
        and any failure mid-apply — including an injected ``refresh_fill``
        fault (core/faults.py), charged deliberately *between* the
        attribute writes to model a re-fill dying half-applied — restores
        all five from a snapshot before re-raising.  The old epoch's
        arrays are immutable and still referenced by the snapshot, so
        rollback is a pointer flip back: membership, epoch, and every
        byte of cache state are exactly the pre-refresh values
        (property-tested in tests/test_faults.py), and the caller keeps
        serving the stale epoch.
        """
        if not self.refreshable:
            raise ValueError("this DualCache was built without refresh context (none())")
        snapshot = (self.dgraph, self.store, self.allocation, self._adj_cache, self.epoch)
        try:
            node_totals = node_visit_totals(self._graph, edge_counts)
            new_adj, adj_stats = refresh_adj_cache(
                self._graph, self._sorted_row, self._adj_cache, node_totals, allocation.adj_bytes
            )
            new_store, feat_stats = refresh_feature_cache(
                self.store, node_counts, allocation.feat_bytes
            )
            cache_row = new_adj.cache_row_index
            # Pad the device copy to a grow-only power-of-two physical size:
            # the sampler's programs specialize on this array's SHAPE, so an
            # exact-size copy would force a sample_blocks recompile on every
            # epoch (and the recompile would land inside the next window's
            # sample lap, feeding back into the Eq. 1 ratio).  Padded tail
            # entries are never read — the hit test is ``r < cached_len``.
            phys = max(self.dgraph.cache_row_index.shape[0], 1)
            while phys < cache_row.shape[0]:
                phys *= 2
            if cache_row.shape[0] < phys:
                cache_row = np.concatenate(
                    [cache_row, np.zeros(phys - cache_row.shape[0], np.int32)]
                )
            self.dgraph = dataclasses.replace(
                self.dgraph,
                cache_ptr=jnp.asarray(new_adj.cache_ptr, jnp.int32),
                cache_row_index=jnp.asarray(cache_row, jnp.int32),
                cached_len=jnp.asarray(new_adj.cached_len, jnp.int32),
            )
            self.store = new_store
            if injector is not None:
                # Mid-apply on purpose: dgraph/store already swapped, the
                # rest not — the worst-case partial state rollback must
                # cleanly undo.
                injector.check("refresh_fill")
            self.allocation = allocation
            self._adj_cache = new_adj
            self.epoch += 1
        except BaseException:
            (self.dgraph, self.store, self.allocation, self._adj_cache, self.epoch) = snapshot
            raise
        return CacheRefreshDelta(
            epoch=self.epoch, allocation=allocation, feat=feat_stats, adj=adj_stats
        )
