"""DualCache — the runtime bundle of DCI's two caches.

``DualCache`` owns the device-resident adjacency cache (inside
``DeviceGraph``) and the feature cache (inside ``FeatureStore``) plus the
allocation that produced them.  It is what the inference engine actually
runs against; policies (core/policies.py) are factories for it.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.allocation import CacheAllocation
from repro.graph.csc import build_adj_cache, two_level_sort
from repro.graph.datasets import SyntheticGraphDataset
from repro.graph.features import FeatureStore, build_feature_cache, plain_feature_store
from repro.graph.sampling import DeviceGraph, device_graph

__all__ = ["DualCache"]


@dataclasses.dataclass(frozen=True)
class DualCache:
    dgraph: DeviceGraph
    store: FeatureStore
    allocation: CacheAllocation | None

    @property
    def adj_cached_elements(self) -> int:
        return int(np.asarray(self.dgraph.cached_len).sum())

    @property
    def feat_cached_rows(self) -> int:
        return self.store.num_cached

    @classmethod
    def build(
        cls,
        dataset: SyntheticGraphDataset,
        *,
        node_counts: np.ndarray,
        edge_counts: np.ndarray,
        allocation: CacheAllocation,
    ) -> "DualCache":
        """Fill both caches per §IV-B with the given capacity split."""
        sorted_row, node_totals = two_level_sort(dataset.graph, edge_counts)
        adj_cache = build_adj_cache(dataset.graph, sorted_row, node_totals, allocation.adj_bytes)
        dgraph = device_graph(dataset.graph, sorted_row_index=sorted_row, adj_cache=adj_cache)
        store = build_feature_cache(dataset.features, node_counts, allocation.feat_bytes)
        return cls(dgraph=dgraph, store=store, allocation=allocation)

    @classmethod
    def none(cls, dataset: SyntheticGraphDataset) -> "DualCache":
        """The DGL baseline: no caches at all."""
        return cls(
            dgraph=device_graph(dataset.graph),
            store=plain_feature_store(dataset.features),
            allocation=None,
        )
