"""Deterministic fault injection for the serving stack.

Production GNN serving treats the host↔device data path as an unreliable,
contended resource (BGL, SALIENT); every fault-tolerance claim this repo
makes (retries, degraded modes, refresh rollback, shard failover) is only
testable if faults can be *reproduced*.  This module provides that: a
seeded :class:`FaultPlan` names the sites that may fail and with what
schedule, and a :class:`FaultInjector` replays the plan deterministically
— the same plan against the same call sequence triggers the same faults,
run after run, machine after machine.

Fault sites (``SITES``) are the stack's external-dependency edges:

  ==================  ====================================================
  site                guarded operation
  ==================  ====================================================
  ``adj_fetch``       adjacency/neighbor expansion (``StreamRuntime.sample``)
  ``host_fetch``      host-table feature rows on the gather miss path
  ``prefetch``        miss-row staging (``FeatureStore.prefetch_misses``)
  ``kernel_gather``   the Pallas cached-gather kernel route
  ``shard_exchange``  a shard's gather + exchange-back in the mesh path
  ``refresh_fill``    the delta re-fill applying a refresh epoch
  ==================  ====================================================

The injector is *optional everywhere*: every guarded call site reads
``injector=None`` (or ``self.injector is None``) and skips the check
entirely, so a run without an injector is bit-for-bit the pre-fault
code path — no RNG draws, no extra branches inside jitted code, nothing
on the trace.  This mirrors the ``NULL_TRACER`` discipline in
core/trace.py.

Determinism
-----------
Each site gets an independent ``numpy`` Philox stream seeded
``[plan.seed, site_index]``; the k-th ``check()`` on a site consumes the
k-th draw regardless of whether the rule's burst window is armed, so a
fault decision is a pure function of ``(plan, site, call index)``.
Schedules compose per rule: ``start_after`` arms the rule after N calls,
``burst_period``/``burst_length`` arm only the first L calls of every
period, ``probability`` thins the armed window, and ``max_faults`` caps
the total.  ``kind="fail"`` raises :class:`InjectedFault`; ``kind="delay"``
sleeps ``latency_s`` and proceeds (the slow-host case that per-stage
timeouts in core/retry.py turn into retryable failures).
"""

from __future__ import annotations

import dataclasses
import json
import time
import zlib

import numpy as np

from repro.core.trace import resolve_tracer

__all__ = [
    "SITES",
    "FaultPlan",
    "FaultRule",
    "FaultInjector",
    "InjectedFault",
]

SITES = (
    "adj_fetch",
    "host_fetch",
    "prefetch",
    "kernel_gather",
    "shard_exchange",
    "refresh_fill",
)

KINDS = ("fail", "delay")


class InjectedFault(RuntimeError):
    """A fault triggered by the plan — carries the site and call index so
    handlers can route policy per site (and, for ``shard_exchange``, the
    victim shard)."""

    def __init__(self, site: str, call: int, shard: int | None = None):
        self.site = site
        self.call = call
        self.shard = shard
        at = f" shard {shard}" if shard is not None else ""
        super().__init__(f"injected fault at {site}{at} (call {call})")


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One site's fault schedule.  All windows are in units of ``check()``
    calls on that site."""

    site: str
    probability: float = 1.0  # per-call trigger probability inside armed windows
    kind: str = "fail"  # "fail" raises InjectedFault; "delay" sleeps latency_s
    latency_s: float = 0.0  # injected delay for kind="delay"
    start_after: int = 0  # calls before the rule arms
    max_faults: int | None = None  # cap on total triggered faults (None = unbounded)
    burst_period: int | None = None  # arm only the first burst_length calls ...
    burst_length: int | None = None  # ... of every burst_period-call window
    shard: int | None = None  # shard_exchange: the victim shard id
    down_for: int | None = None  # shard_exchange: retired batches before rejoin

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"site must be one of {SITES}, got {self.site!r}")
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if not 0.0 <= self.probability <= 1.0:
            raise ValueError(f"probability must be in [0, 1], got {self.probability}")
        if self.latency_s < 0:
            raise ValueError(f"latency_s must be >= 0, got {self.latency_s}")
        if self.start_after < 0:
            raise ValueError(f"start_after must be >= 0, got {self.start_after}")
        if self.max_faults is not None and self.max_faults < 0:
            raise ValueError(f"max_faults must be >= 0, got {self.max_faults}")
        if (self.burst_period is None) != (self.burst_length is None):
            raise ValueError("burst_period and burst_length must be set together")
        if self.burst_period is not None:
            if self.burst_period < 1 or not 0 <= self.burst_length <= self.burst_period:
                raise ValueError(
                    f"need burst_period >= 1 and 0 <= burst_length <= burst_period, "
                    f"got {self.burst_period}/{self.burst_length}"
                )

    def armed(self, call: int) -> bool:
        """Whether the schedule's deterministic windows cover this call
        (before the probability thinning and the max_faults cap)."""
        if call < self.start_after:
            return False
        if self.burst_period is not None:
            return (call - self.start_after) % self.burst_period < self.burst_length
        return True

    def to_dict(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items() if v is not None}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultRule":
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"unknown FaultRule fields: {sorted(unknown)}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, JSON-serializable set of :class:`FaultRule` schedules —
    the artifact CI commits (``benchmarks/faults_smoke.json``) and
    ``infer_gnn --faults PLAN.json`` loads."""

    seed: int = 0
    rules: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        seen = set()
        for r in self.rules:
            if not isinstance(r, FaultRule):
                raise TypeError(f"rules must be FaultRule, got {type(r).__name__}")
            if r.site in seen:
                raise ValueError(f"duplicate rule for site {r.site!r}")
            seen.add(r.site)

    @property
    def sites(self) -> tuple:
        return tuple(r.site for r in self.rules)

    def rule_for(self, site: str) -> FaultRule | None:
        for r in self.rules:
            if r.site == site:
                return r
        return None

    def to_dict(self) -> dict:
        return {"seed": self.seed, "rules": [r.to_dict() for r in self.rules]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls(
            seed=int(d.get("seed", 0)),
            rules=tuple(FaultRule.from_dict(r) for r in d.get("rules", [])),
        )

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path, encoding="utf-8") as fh:
            return cls.from_dict(json.load(fh))

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.to_dict(), fh, indent=1)
            fh.write("\n")


def _site_stream(seed: int, site: str) -> np.random.Generator:
    # Site-keyed independent stream: decisions on one site never shift
    # another site's sequence, so adding a rule cannot perturb replay.
    return np.random.default_rng([seed, zlib.crc32(site.encode())])


class FaultInjector:
    """Replays a :class:`FaultPlan` at named call sites.

    ``check(site)`` consumes one call on the site's deterministic schedule
    and either returns (no fault), sleeps (``kind="delay"``), or raises
    :class:`InjectedFault` (``kind="fail"``).  Triggered faults are
    counted per site and — when a tracer is attached — recorded as
    zero-duration ``fault`` spans on a ``faults`` lane, so
    ``trace_summary.py --require-span fault`` can gate that a chaos run
    actually injected something.
    """

    def __init__(self, plan: FaultPlan, *, tracer=None, sleep=time.sleep):
        self.plan = plan
        self.tracer = resolve_tracer(tracer)
        self._sleep = sleep
        self._rules = {r.site: r for r in plan.rules}
        self._rng = {site: _site_stream(plan.seed, site) for site in self._rules}
        self.calls = dict.fromkeys(SITES, 0)
        self.faults = dict.fromkeys(SITES, 0)
        self.delays = dict.fromkeys(SITES, 0)

    @property
    def enabled(self) -> bool:
        return bool(self._rules)

    def active(self, site: str) -> bool:
        """Whether the plan has a rule for this site at all — call sites
        may use it to skip fault plumbing entirely."""
        return site in self._rules

    def call_index(self, site: str) -> int:
        return self.calls[site]

    def check(self, site: str) -> None:
        """One call on ``site``'s schedule; raises / delays when the plan
        says so.  A no-op (beyond the call count) for unlisted sites."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}")
        call = self.calls[site]
        self.calls[site] = call + 1
        rule = self._rules.get(site)
        if rule is None:
            return
        # One draw per call whenever the rule is probabilistic, armed or
        # not — the decision at call k never depends on window phase.
        hit = True
        if rule.probability < 1.0:
            hit = bool(self._rng[site].random() < rule.probability)
        if not rule.armed(call) or not hit:
            return
        if rule.max_faults is not None and self.faults[site] >= rule.max_faults:
            return
        self.faults[site] += 1
        if self.tracer.enabled:
            now = self.tracer.now_us()
            self.tracer.complete(
                "fault",
                lane="faults",
                ts_us=now,
                dur_us=0.0,
                args={"site": site, "call": call, "kind": rule.kind},
            )
        if rule.kind == "delay":
            self.delays[site] += 1
            if rule.latency_s > 0:
                self._sleep(rule.latency_s)
            return
        raise InjectedFault(site, call, shard=rule.shard)

    def counts(self) -> dict:
        """JSON-safe per-site accounting for reports and benchmarks."""
        return {
            site: {"calls": self.calls[site], "faults": self.faults[site]}
            for site in SITES
            if self.calls[site] or self.faults[site]
        }
