"""Cache-filling algorithms (paper §IV-B), re-exported from their homes.

The implementations live next to the data structures they fill:
  * adjacency cache (Alg. 1): ``repro.graph.csc.two_level_sort`` +
    ``repro.graph.csc.build_adj_cache``
  * feature cache (sort-free above-mean fill):
    ``repro.graph.features.build_feature_cache``
  * LM-serving variants (hot embeddings / hot experts):
    ``repro.runtime.lm_cache.build_serving_caches``

This module is the documented entry point for "the filling algorithm" as a
concept; ``core.cache.DualCache.build`` composes them.
"""

from repro.graph.csc import build_adj_cache, two_level_sort
from repro.graph.features import build_feature_cache

__all__ = ["build_adj_cache", "two_level_sort", "build_feature_cache"]
