"""Typed execution configs — the one object the engine/serve knobs live in.

Before this module the execution knobs (``pipeline_depth``, ``prefetch``,
``use_kernel``, ``gather_buffers``, ``dedup``, the refresh triggers, the
serving caps, the mesh width) flowed as ~10 loose keyword arguments through
``PreparedPipeline`` → engine → serving layers → CLI, each layer re-listing
and re-defaulting them by hand.  Adding a second inference *mode*
(layer-wise full-graph scoring, ``runtime/layerwise.py``) made that sprawl
untenable, so the knobs now consolidate into two frozen dataclasses:

  - :class:`EngineConfig` — everything one inference run needs: the mode
    (``sampling`` | ``layerwise``), the executor window, the four gather
    knobs, the layer-wise chunk size, and the online-refresh trigger
    fields.  ``None`` fields mean "inherit the prepared pipeline's (or the
    engine's) default" — a *resolved* config (every field concrete) is
    what reports carry and :meth:`EngineConfig.to_dict` echoes.
  - :class:`ServeConfig` — an :class:`EngineConfig` plus the serving-layer
    knobs (in-flight cap, admission policy, SLO, arrival process, mesh).

Every consumer (``GNNInferenceEngine``, ``MultiStreamServer``,
``RequestQueueServer``, ``ShardedServer``, the benchmarks, ``infer_gnn``)
accepts a single ``config`` object; the old loose keywords keep working
for one release through :func:`coalesce` — passing any of them merges the
non-``None`` values over the config and emits a ``DeprecationWarning``.
The merged path is bit-for-bit the old path (tested across the dedup ×
prefetch × refresh knob grid in tests/test_config.py).

Refresh fields are kept inline (mode/interval/threshold) rather than
nesting a :class:`~repro.runtime.cache_refresh.RefreshConfig` so this
module stays import-cycle-free (core must not import runtime at module
level); :meth:`EngineConfig.refresh_config` constructs the runtime object
lazily.
"""

from __future__ import annotations

import dataclasses
import warnings

__all__ = [
    "DEFAULT_CHUNK_SIZE",
    "EngineConfig",
    "INFERENCE_MODES",
    "ServeConfig",
    "coalesce",
]

INFERENCE_MODES = ("sampling", "layerwise")
# Mirrors runtime.cache_refresh.MODES (asserted in tests/test_config.py);
# duplicated here so core never imports runtime at module scope.
REFRESH_MODES = ("off", "interval", "events", "all")
DEFAULT_CHUNK_SIZE = 4096


def _check(value, allowed, what):
    if value is not None and value not in allowed:
        raise ValueError(f"{what} must be one of {allowed}, got {value!r}")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Execution knobs for one inference run.

    ``None`` means "inherit the default" (the engine's ``pipeline_depth``,
    the prepared pipeline's gather knobs, the mode's chunk size); reports
    carry the *resolved* config with every field concrete.  Outputs and
    hit accounting are invariant under every knob except ``mode`` — the
    knobs only move bytes (and wall clock)."""

    mode: str = "sampling"  # "sampling" (mini-batch) | "layerwise" (full graph)
    pipeline_depth: int | str | None = None  # executor window; int or "auto"
    prefetch: bool | None = None  # stage missed host rows ahead of their gather
    use_kernel: bool | None = None  # route gathers through the Pallas kernel
    gather_buffers: int | None = None  # kernel VMEM row-tile slots
    dedup: bool | None = None  # sorted-unique frontier gathers (sampling mode)
    chunk_size: int | None = None  # layer-wise node-range chunk (layerwise mode)
    # Online cache refresh (runtime/cache_refresh.py), inline to avoid a
    # core → runtime import cycle; refresh_config() builds the real object.
    refresh_mode: str = "off"
    refresh_interval: int = 8
    refresh_miss_threshold: float | None = None

    def __post_init__(self):
        _check(self.mode, INFERENCE_MODES, "mode")
        _check(self.refresh_mode, REFRESH_MODES, "refresh_mode")
        if self.pipeline_depth is not None and self.pipeline_depth != "auto":
            if int(self.pipeline_depth) < 1:
                raise ValueError(f"pipeline_depth must be >= 1, got {self.pipeline_depth}")
        if self.gather_buffers is not None and self.gather_buffers < 1:
            raise ValueError(f"gather_buffers must be >= 1, got {self.gather_buffers}")
        if self.chunk_size is not None and self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")

    # ------------------------------------------------------------ plumbing
    def replace(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        """JSON-safe field dict — the knob echo reports embed verbatim.
        Round-trips through :meth:`from_dict` field-for-field."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "EngineConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    @classmethod
    def from_args(cls, args) -> "EngineConfig":
        """Build from ``launch/infer_gnn.py``'s parsed argparse namespace."""
        return cls(
            mode=args.mode,
            pipeline_depth=args.pipeline_depth,
            prefetch=args.prefetch,
            use_kernel=args.use_kernel,
            gather_buffers=args.gather_buffers,
            dedup=args.dedup,
            chunk_size=args.chunk_size,
            refresh_mode=args.refresh_mode,
            refresh_interval=args.refresh_interval,
            refresh_miss_threshold=args.refresh_miss_threshold,
        )

    def refresh_config(self):
        """The runtime :class:`~repro.runtime.cache_refresh.RefreshConfig`
        these fields describe, or ``None`` with refresh off (lazy import —
        see the module docstring)."""
        if self.refresh_mode == "off":
            return None
        from repro.runtime.cache_refresh import RefreshConfig

        return RefreshConfig(
            mode=self.refresh_mode,
            interval_batches=self.refresh_interval,
            miss_threshold=self.refresh_miss_threshold,
        )

    def resolved(self, pipe=None, *, pipeline_depth=None, chunk_size=None) -> "EngineConfig":
        """Fill every ``None`` field from the prepared pipeline's knob
        defaults (and the given resolved depth / chunk size) — the concrete
        config a report echoes."""
        return self.replace(
            pipeline_depth=(
                self.pipeline_depth if pipeline_depth is None else pipeline_depth
            ),
            prefetch=(pipe.prefetch if pipe else False) if self.prefetch is None else self.prefetch,
            use_kernel=(
                (pipe.use_kernel if pipe else False)
                if self.use_kernel is None
                else self.use_kernel
            ),
            gather_buffers=(
                (pipe.gather_buffers if pipe else 2)
                if self.gather_buffers is None
                else self.gather_buffers
            ),
            dedup=(pipe.dedup if pipe else False) if self.dedup is None else self.dedup,
            chunk_size=(
                chunk_size
                if chunk_size is not None
                else (DEFAULT_CHUNK_SIZE if self.chunk_size is None else self.chunk_size)
            ),
        )


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-layer knobs wrapped around an :class:`EngineConfig`.

    ``engine.pipeline_depth`` doubles as the server's executor window
    (``None`` → the server's default of 2); the remaining fields are the
    serving front-end's own: backpressure cap, admission policy, SLO,
    arrival process, and the sharding mesh width."""

    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    max_inflight: int | None = None  # backpressure cap (None → the window depth)
    admission: str = "round-robin"  # request_queue admission policy name
    slo_ms: float | None = None  # relative deadline attached to every request
    arrival: str = "none"  # none | poisson | burst | flash-crowd
    mean_interarrival_ms: float = 50.0  # poisson arrival spacing
    mesh: int = 0  # shard the feature store across this many mesh devices
    # Fault tolerance (core/faults.py + core/retry.py).  ``faults`` is a
    # FaultPlan JSON path (None = no injector, the bit-for-bit baseline);
    # ``fault_policy`` is what a guarded-site failure does: "fail" fails
    # fast, "retry" retries with bounded backoff then fails, "shed"
    # retries then sheds just the failing request and keeps serving.
    faults: str | None = None
    fault_policy: str = "fail"  # fail | retry | shed
    retry_attempts: int = 3  # per guarded call, incl. the first attempt
    retry_backoff_ms: float = 1.0  # base backoff before attempt 2
    retry_timeout_ms: float | None = None  # per-attempt wall budget
    degraded_mode: bool = False  # cache-only fallback when the miss path is down

    def __post_init__(self):
        _check(self.arrival, ("none", "poisson", "burst", "flash-crowd"), "arrival")
        _check(self.fault_policy, ("fail", "retry", "shed"), "fault_policy")
        if self.max_inflight is not None and self.max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {self.max_inflight}")
        if self.mesh < 0:
            raise ValueError(f"mesh must be >= 0, got {self.mesh}")
        if self.retry_attempts < 1:
            raise ValueError(f"retry_attempts must be >= 1, got {self.retry_attempts}")
        if self.retry_backoff_ms < 0:
            raise ValueError(f"retry_backoff_ms must be >= 0, got {self.retry_backoff_ms}")
        if self.retry_timeout_ms is not None and self.retry_timeout_ms <= 0:
            raise ValueError(f"retry_timeout_ms must be > 0, got {self.retry_timeout_ms}")

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["engine"] = self.engine.to_dict()
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "ServeConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        if isinstance(kw.get("engine"), dict):
            kw["engine"] = EngineConfig.from_dict(kw["engine"])
        return cls(**kw)

    @classmethod
    def from_args(cls, args) -> "ServeConfig":
        fault_policy = getattr(args, "fault_policy", None)
        if fault_policy is None:
            fault_policy = "retry" if getattr(args, "retry", False) else "fail"
        return cls(
            engine=EngineConfig.from_args(args),
            max_inflight=args.max_inflight,
            admission=args.admission,
            slo_ms=args.slo_ms,
            arrival=args.arrival,
            mean_interarrival_ms=args.mean_interarrival_ms,
            mesh=args.mesh,
            faults=getattr(args, "faults", None),
            fault_policy=fault_policy,
            retry_attempts=getattr(args, "retry_attempts", 3),
            retry_backoff_ms=getattr(args, "retry_backoff_ms", 1.0),
            retry_timeout_ms=getattr(args, "retry_timeout_ms", None),
            degraded_mode=getattr(args, "degraded_mode", False),
        )

    def retry_policy(self):
        """The :class:`~repro.core.retry.RetryPolicy` these fields
        describe, or ``None`` under fail-fast (``fault_policy="fail"``)."""
        if self.fault_policy == "fail":
            return None
        from repro.core.retry import RetryPolicy

        return RetryPolicy(
            max_attempts=self.retry_attempts,
            backoff_s=self.retry_backoff_ms * 1e-3,
            timeout_s=None if self.retry_timeout_ms is None else self.retry_timeout_ms * 1e-3,
        )


def coalesce(config, cls=EngineConfig, *, _context="this call", **legacy):
    """Merge deprecated loose knob kwargs over a config object.

    The one-release compatibility shim: call sites that still pass
    ``prefetch=...`` / ``depth=...`` etc. get those values merged over
    ``config`` (``None`` values — "not specified" — are ignored) with a
    ``DeprecationWarning`` naming the offending keywords.  With no legacy
    kwargs this just defaults a missing config, so the config path pays
    nothing.  The merged config is what execution reads, which is what
    makes the two call styles bit-for-bit equivalent."""
    used = {k: v for k, v in legacy.items() if v is not None}
    if config is None:
        config = cls()
    elif not isinstance(config, cls):
        raise TypeError(f"config must be a {cls.__name__}, got {type(config).__name__}")
    if used:
        warnings.warn(
            f"{_context}: loose execution-knob kwargs ({', '.join(sorted(used))}) are "
            f"deprecated — pass config={cls.__name__}(...) instead",
            DeprecationWarning,
            stacklevel=3,
        )
        config = config.replace(**used)
    return config
