"""Serve-time workload telemetry — the runtime half of DCI's profile.

DCI profiles the workload once, before serving, with ~8 pre-sampling
batches (core/presample.py).  Long-lived multi-stream serving breaks that
assumption: the seed distribution drifts and streams join/leave, so the
pre-sampled visit counts and the Eq. 1 stage-time ratio go stale.  This
module accumulates the same three signals the presampler measures — but
from the *live* serve path, at retire time, out of accounting the executor
already produces:

  * per-node feature visit AND miss counts (from the gather's hit mask);
  * per-element adjacency fetch counts (from the sampler's edge slots);
  * per-batch sample/feature/compute stage laps (from the stream
    StageClocks — sample:feature feeds the Eq. 1 split, prep:compute
    feeds the refresh-aware ``pipeline_depth="auto"`` re-derivation).

``WorkloadTelemetry`` is windowed: the refresh manager
(runtime/cache_refresh.py) snapshots a window, folds it into its decayed
history, and resets it.  Recording costs one device→host transfer of the
hit mask and edge slots per batch, so it is only attached when a refresh
mode is enabled — the default serve path records nothing and stays
bit-for-bit identical to a telemetry-free build.

Deduped batches (the unique-frontier feature path) record through the same
entry point with ``multiplicities``: counts are scatter-added once per
UNIQUE node, weighted by how often the batch visited it, which produces
bit-identical counters to the per-visit form at a fraction of the scatter
width.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TelemetryWindow", "WorkloadTelemetry", "merge_windows"]

STAGE_LAPS = ("sample", "feature", "compute")


@dataclasses.dataclass(frozen=True)
class TelemetryWindow:
    """An immutable snapshot of one accumulation window.

    Count arrays are int64 from a single accumulator; a weighted
    :func:`merge_windows` produces float64 (the decayed history consuming
    them is float either way)."""

    node_counts: np.ndarray  # [N] feature-row visits
    node_miss_counts: np.ndarray  # [N] feature-row misses (drift signal)
    edge_counts: np.ndarray  # [E] adjacency-element fetches
    sample_times: list[float]
    feature_times: list[float]
    compute_times: list[float]
    batches: int

    @property
    def feat_lookups(self) -> int:
        return int(self.node_counts.sum())

    @property
    def feat_misses(self) -> int:
        return int(self.node_miss_counts.sum())

    @property
    def miss_rate(self) -> float:
        return self.feat_misses / max(self.feat_lookups, 1)

    def shard_slice(self, lo: int, hi: int) -> "TelemetryWindow":
        """The window as shard ``[lo, hi)`` of a node-id-range partition
        sees it (sharded serving, runtime/sharded_serve.py).

        Node-indexed arrays are sliced to the range — the shard's own
        feature traffic.  The adjacency cache is *replicated* per shard,
        so ``edge_counts`` passes through whole (every replica serves the
        full edge workload).  Stage laps are wall-clock facts of the whole
        pipeline, not per-shard observables, so they pass through too;
        per-shard Eq. 1 scales them by the shard's visit share instead
        (:func:`repro.core.allocation.shard_allocations`)."""
        return TelemetryWindow(
            node_counts=self.node_counts[lo:hi],
            node_miss_counts=self.node_miss_counts[lo:hi],
            edge_counts=self.edge_counts,
            sample_times=self.sample_times,
            feature_times=self.feature_times,
            compute_times=self.compute_times,
            batches=self.batches,
        )


def merge_windows(windows, weights=None) -> TelemetryWindow:
    """Fold several streams' windows into one, optionally weighted.

    The count arrays are summed with per-window ``weights`` (float64 —
    the decayed history they feed is float anyway); stage-lap lists are
    concatenated UNweighted (a lap is a wall-clock fact, not a vote) and
    ``batches`` summed, so the Eq. 1 stage ratio and the refresh-window
    bookkeeping stay physical while the *ranking* signal tilts toward
    pressured streams.  ``weights=None`` (or all-1) reproduces the shared
    single-accumulator counts exactly.  Negative weights are clamped to 0
    — a merge can emphasize a stream, never subtract one (leave-time
    subtraction is the refresh manager's remnant path).
    """
    windows = list(windows)
    if not windows:
        raise ValueError("merge_windows needs at least one window")
    if weights is None:
        weights = [1.0] * len(windows)
    if len(weights) != len(windows):
        raise ValueError(f"{len(windows)} windows but {len(weights)} weights")
    node = np.zeros_like(windows[0].node_counts, np.float64)
    miss = np.zeros_like(windows[0].node_miss_counts, np.float64)
    edge = np.zeros_like(windows[0].edge_counts, np.float64)
    sample_times: list[float] = []
    feature_times: list[float] = []
    compute_times: list[float] = []
    batches = 0
    for win, w in zip(windows, weights):
        w = max(float(w), 0.0)
        node += w * win.node_counts
        miss += w * win.node_miss_counts
        edge += w * win.edge_counts
        sample_times.extend(win.sample_times)
        feature_times.extend(win.feature_times)
        compute_times.extend(win.compute_times)
        batches += win.batches
    return TelemetryWindow(
        node_counts=node,
        node_miss_counts=miss,
        edge_counts=edge,
        sample_times=sample_times,
        feature_times=feature_times,
        compute_times=compute_times,
        batches=batches,
    )


class WorkloadTelemetry:
    """Mutable per-window accumulator fed from the executor's retire path.

    One instance can be shared by several streams (the counts are the
    union workload — exactly what the shared cache is filled for); stage
    laps are pulled from each stream's own clock by :meth:`pull_times`
    with per-clock cursors, so laps are never double-counted across
    windows.  ``miss_rate`` is maintained as two running scalars so the
    SLO trigger can poll it per retired batch without an O(N) reduction.
    """

    def __init__(self, num_nodes: int, num_edges: int):
        self.num_nodes = num_nodes
        self.num_edges = num_edges
        self._lap_cursors: dict[int, dict[str, int]] = {}
        self.reset()

    def reset(self) -> None:
        """Start a new accumulation window (lap cursors persist)."""
        self.node_counts = np.zeros(self.num_nodes, np.int64)
        self.node_miss_counts = np.zeros(self.num_nodes, np.int64)
        self.edge_counts = np.zeros(self.num_edges, np.int64)
        self.sample_times: list[float] = []
        self.feature_times: list[float] = []
        self.compute_times: list[float] = []
        self.batches = 0
        self._lookups = 0
        self._misses = 0

    # ---------------------------------------------------------- recording
    def observe_batch(self, nodes, feat_hit, edge_slots, *, multiplicities=None) -> None:
        """Fold one retired batch's accounting into the current window.

        ``nodes`` is the batch's input frontier, ``feat_hit`` the gather's
        boolean hit mask over it, ``edge_slots`` the per-layer global
        adjacency positions the sampler touched.  All three already exist
        on the retire path — telemetry adds the host conversion and two
        scatter-adds, nothing new on the device.

        With ``multiplicities``, ``nodes``/``feat_hit`` cover only the
        batch's UNIQUE input nodes and ``multiplicities[i]`` is how many
        frontier positions visited ``nodes[i]`` — the deduped feature
        path's form.  Every counter (visits, misses, the running
        lookup/miss scalars) comes out bit-identical to the per-visit
        call, because a node's hit bit is the same for every one of its
        visits in a batch.
        """
        nodes = np.asarray(nodes)
        hit = np.asarray(feat_hit)
        if multiplicities is None:
            np.add.at(self.node_counts, nodes, 1)
            miss_nodes = nodes[~hit]
            if miss_nodes.size:
                np.add.at(self.node_miss_counts, miss_nodes, 1)
            self._lookups += int(nodes.size)
            self._misses += int(miss_nodes.size)
        else:
            mult = np.asarray(multiplicities, np.int64)
            np.add.at(self.node_counts, nodes, mult)
            miss = ~hit
            if miss.any():
                np.add.at(self.node_miss_counts, nodes[miss], mult[miss])
            self._lookups += int(mult.sum())
            self._misses += int(mult[miss].sum())
        for slots in edge_slots:
            idx = np.asarray(slots).reshape(-1)
            # A zero-degree node at the CSC tail emits slot == num_edges;
            # the presample path's JAX scatter drops out-of-bounds indices
            # silently — match it (np.add.at would raise instead).
            np.add.at(self.edge_counts, idx[idx < self.num_edges], 1)
        self.batches += 1

    def pull_times(self, clock) -> None:
        """Append the clock's NEW stage laps since the last pull.

        In serial mode (depth=1) laps are fully synchronized stage times —
        the exact Eq. 1 semantics.  At depth>1 they are dispatch times;
        the ratio still tracks where host-side prep time goes, which is
        the signal the re-allocation needs (documented in
        docs/ARCHITECTURE.md).  Compute laps feed the refresh-aware
        ``pipeline_depth="auto"`` re-derivation, not the Eq. 1 split.
        """
        cursors = self._lap_cursors.setdefault(
            id(clock), {name: 0 for name in STAGE_LAPS}
        )
        for name, out in (
            ("sample", self.sample_times),
            ("feature", self.feature_times),
            ("compute", self.compute_times),
        ):
            laps = clock.laps.get(name, [])
            out.extend(laps[cursors[name] :])
            cursors[name] = len(laps)

    # ----------------------------------------------------------- live view
    @property
    def feat_lookups(self) -> int:
        return self._lookups

    @property
    def feat_misses(self) -> int:
        return self._misses

    @property
    def miss_rate(self) -> float:
        """Feature miss rate of the window accumulated SO FAR — the live
        signal the SLO-aware refresh trigger polls per retired batch."""
        return self._misses / max(self._lookups, 1)

    # ----------------------------------------------------------- snapshot
    def snapshot(self) -> TelemetryWindow:
        return TelemetryWindow(
            node_counts=self.node_counts.copy(),
            node_miss_counts=self.node_miss_counts.copy(),
            edge_counts=self.edge_counts.copy(),
            sample_times=list(self.sample_times),
            feature_times=list(self.feature_times),
            compute_times=list(self.compute_times),
            batches=self.batches,
        )
