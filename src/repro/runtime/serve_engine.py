"""Slot-based batched serving engine (continuous batching, vLLM-lite).

``BatchedServer`` owns a fixed number of decode *slots* sharing one jitted
``decode_step`` whose ``cache_len`` is a per-slot vector: requests of
different lengths decode together, each attending only to its own logical
prefix (the per-batch ring mask in ``models/lm/attention.py``).  When a
slot finishes (max tokens here; EOS in a real deployment) it is refilled
from the queue by a single-request prefill whose caches are scattered into
the slot — admission never stalls the running batch.

Decoder-only token architectures; greedy sampling.  MoE capacity is shared
across slots in a decode step (documented coupling — capacity_factor is
ample at decode batch sizes).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm.blocks import init_block_cache
from repro.models.lm.config import LMConfig
from repro.models.lm.model import decode_step, prefill

__all__ = ["BatchedServer", "Request"]


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # [L] int32
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    def __init__(self, cfg: LMConfig, params, *, slots: int = 4, max_len: int = 256):
        if cfg.encoder_layers > 0 or cfg.input_mode == "embeds":
            raise ValueError("BatchedServer targets decoder-only token archs")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        dtype = jnp.dtype(cfg.dtype)
        self.caches = tuple(
            jax.vmap(lambda _: init_block_cache(cfg, p, slots, max_len, dtype, long_mode=False))(
                jnp.arange(cfg.n_repeats)
            )
            for p in range(cfg.pattern_period)
        )
        self.cache_len = np.zeros(slots, np.int32)
        self.last_token = np.zeros(slots, np.int32)
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._decode = jax.jit(
            lambda p, t, c, l: decode_step(p, t, c, l, cfg)
        )
        self._prefill = jax.jit(
            lambda p, b: prefill(p, b, cfg, cache_size=max_len)
        )

    # ------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, max_new: int, req_id: int | None = None) -> Request:
        req = Request(req_id if req_id is not None else len(self.queue), np.asarray(prompt, np.int32), max_new)
        self.queue.append(req)
        return req

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, new_caches = self._prefill(self.params, {"tokens": jnp.asarray(req.prompt[None, :])})
            tok = int(jnp.argmax(logits[0, : self.cfg.vocab]))
            req.generated.append(tok)
            # scatter the single-request caches into slot s (batch dim = 2
            # for attn kv [R,B,S,...]; mamba/rwkv leaves also have B at 1)
            def insert(slot_leaf, new_leaf):
                return slot_leaf.at[:, s].set(new_leaf[:, 0])

            self.caches = jax.tree.map(insert, self.caches, new_caches)
            self.cache_len[s] = len(req.prompt)
            self.last_token[s] = tok
            self.active[s] = req

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        tokens = jnp.asarray(self.last_token[:, None])
        lens = jnp.asarray(self.cache_len)
        logits, self.caches = self._decode(self.params, tokens, self.caches, lens)
        next_tok = np.asarray(jnp.argmax(logits[:, : self.cfg.vocab], axis=-1), np.int32)
        n_active = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.cache_len[s] += 1
            req.generated.append(int(next_tok[s]))
            self.last_token[s] = next_tok[s]
            if len(req.generated) >= req.max_new or self.cache_len[s] >= self.max_len - 1:
                req.done = True
                self.finished.append(req)
                self.active[s] = None
                self.cache_len[s] = 0
            else:
                n_active += 1
        return n_active + len(self.queue)

    def run(self) -> list[Request]:
        t0 = time.perf_counter()
        steps = 0
        while self.step() or self.queue or any(r is not None for r in self.active):
            steps += 1
            if steps > 100_000:  # safety
                break
        self.elapsed = time.perf_counter() - t0
        return sorted(self.finished, key=lambda r: r.req_id)
