"""Staged, double-buffered batch executor for sampled GNN inference.

DCI attacks sampling and feature-loading *cost*; SALIENT and BGL show the
remaining end-to-end gap is inter-stage *idle* time when sample → gather →
compute run strictly serially with a device sync after every stage.  This
executor removes those barriers: each mini-batch's stages are dispatched
back-to-back and up to ``depth`` batches are kept in flight, so batch
``i+1``'s sampling and feature gather are enqueued (and, under JAX async
dispatch, executing) while batch ``i``'s GNN forward is still running.

Semantics
---------
``depth=1`` reproduces the serial engine bit-for-bit: every stage is
synchronized inside its timer (via :class:`~repro.utils.timing.StageClock`
in serial mode) and a batch fully retires before the next one starts —
including RAIN's cross-batch reuse ordering and the per-batch hit-rate
accounting.  ``depth>1`` changes *only* the synchronization pattern: the
same ops are dispatched in the same order with the same RNG stream, so
logits, hit counts, and batch order are identical (equivalence-tested in
tests/test_pipeline_executor.py); stage timers measure dispatch time and
the in-flight wait is booked by ``StageClock.drain`` at retire boundaries.

Stages communicate through a per-batch :class:`BatchContext`; cross-batch
state (RNG keys, RAIN's reuse map, visit counters) lives in closures of the
stage functions, which are always invoked in batch order.  The same
executor drives both the inference engine (runtime/gnn_engine.py) and the
pre-sampling profiler (core/presample.py), so Eq. 1 stage times and the
cache-filling visit counts come from one code path.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Iterable, Sequence

from repro.utils.timing import StageClock

__all__ = ["BatchContext", "PipelinedExecutor", "Stage"]


class BatchContext:
    """One mini-batch flowing through the pipeline.

    ``payload`` is the batch input (seed node ids); ``outputs[name]`` holds
    each completed stage's result.
    """

    __slots__ = ("index", "payload", "outputs")

    def __init__(self, index: int, payload: Any):
        self.index = index
        self.payload = payload
        self.outputs: dict[str, Any] = {}


@dataclasses.dataclass(frozen=True)
class Stage:
    """A named pipeline stage.

    ``fn(ctx)`` computes the stage's output from ``ctx.payload`` and
    earlier stages' ``ctx.outputs``.  ``sync(ctx)`` returns the device
    value that marks the stage complete: in serial mode the clock blocks on
    it at the stage boundary; for the final stage it is also what retire
    drains in overlap mode.
    """

    name: str
    fn: Callable[[BatchContext], Any]
    sync: Callable[[BatchContext], Any] | None = None


class PipelinedExecutor:
    """Run batches through ``stages`` keeping up to ``depth`` in flight.

    ``depth=1`` → serial: dispatch + sync every stage, retire, then start
    the next batch (the pre-pipeline engine loop).  ``depth=2`` → double
    buffering: batch ``i`` retires only after batch ``i+1`` has fully
    dispatched.  ``on_retire(ctx)`` runs once per batch, in order, after
    the batch's final stage output is ready — the place for host-side
    accounting (hit counters, logits collection) that would otherwise force
    a sync mid-pipeline.
    """

    def __init__(
        self,
        stages: Sequence[Stage],
        *,
        depth: int = 1,
        clock: StageClock | None = None,
        on_retire: Callable[[BatchContext], None] | None = None,
    ):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = list(stages)
        self.depth = depth
        self.clock = clock if clock is not None else StageClock(overlap=depth > 1)
        self.on_retire = on_retire

    def run(self, payloads: Iterable[Any]) -> list[BatchContext]:
        """Dispatch every payload through all stages; return retired contexts
        in batch order.

        Retired contexts come back with ``outputs`` cleared — extraction
        belongs in ``on_retire``.  Holding every batch's device arrays
        (blocks, features, logits) until the run ends would grow memory
        O(num_batches) instead of O(depth) on exactly the long runs
        pipelining targets."""
        window: collections.deque[BatchContext] = collections.deque()
        retired: list[BatchContext] = []
        for i, payload in enumerate(payloads):
            ctx = BatchContext(i, payload)
            for st in self.stages:
                sync = None
                if st.sync is not None:
                    sync = (lambda s=st, c=ctx: s.sync(c))
                with self.clock.stage(st.name, sync=sync):
                    ctx.outputs[st.name] = st.fn(ctx)
            window.append(ctx)
            while len(window) > self.depth - 1:
                retired.append(self._retire(window.popleft()))
        while window:  # drain whatever is still in flight
            retired.append(self._retire(window.popleft()))
        return retired

    def _retire(self, ctx: BatchContext) -> BatchContext:
        if self.clock.overlap:
            # Drain every stage's sync value, in stage order, attributing
            # each wait to its own stage — otherwise in-flight work from
            # earlier stages would be waited on untimed inside on_retire
            # and the stage totals would under-count the loop's wall clock.
            for st in self.stages:
                if st.sync is not None:
                    self.clock.drain(st.name, st.sync(ctx))
        if self.on_retire is not None:
            self.on_retire(ctx)
        ctx.outputs.clear()
        return ctx
