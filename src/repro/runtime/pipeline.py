"""Staged, double-buffered batch executor for sampled GNN inference.

DCI attacks sampling and feature-loading *cost*; SALIENT and BGL show the
remaining end-to-end gap is inter-stage *idle* time when sample → gather →
compute run strictly serially with a device sync after every stage.  This
executor removes those barriers: each mini-batch's stages are dispatched
back-to-back and up to ``depth`` batches are kept in flight, so batch
``i+1``'s sampling and feature gather are enqueued (and, under JAX async
dispatch, executing) while batch ``i``'s GNN forward is still running.

Semantics
---------
``depth=1`` reproduces the serial engine bit-for-bit: every stage is
synchronized inside its timer (via :class:`~repro.utils.timing.StageClock`
in serial mode) and a batch fully retires before the next one starts —
including RAIN's cross-batch reuse ordering and the per-batch hit-rate
accounting.  ``depth>1`` changes *only* the synchronization pattern: the
same ops are dispatched in the same order with the same RNG stream, so
logits, hit counts, and batch order are identical (equivalence-tested in
tests/test_pipeline_executor.py); stage timers measure dispatch time and
the in-flight wait is booked by ``StageClock.drain`` at retire boundaries.

Stages communicate through a per-batch :class:`BatchContext`; cross-batch
state (RNG keys, RAIN's reuse map, visit counters) lives in closures of the
stage functions, which are always invoked in batch order.  The same
executor drives both the inference engine (runtime/gnn_engine.py) and the
pre-sampling profiler (core/presample.py), so Eq. 1 stage times and the
cache-filling visit counts come from one code path.

Prefetch boundary
-----------------
Because a batch's stages dispatch back-to-back while *earlier* batches are
still in flight, any stage inserted between two others is a prefetch hook:
a stage placed between ``sample`` and ``feature`` runs for batch ``i+1``
while batch ``i``'s compute occupies the device — the boundary the
feature-miss prefetch stage (``StreamRuntime.prefetch_stage``) uses to
``jax.device_put`` missed host rows ahead of the gather that consumes
them.  Optional stages are passed as ``None`` entries in ``stages`` and
dropped, so call sites can write ``[sample, prefetch if on else None,
feature, compute]`` without changing the executor schedule when the knob
is off.

Multi-stream
------------
Batches from several independent request streams can interleave through
one executor schedule: :meth:`PipelinedExecutor.run_tagged` accepts
``(stream, payload)`` pairs and stamps the stream onto
``BatchContext.stream``, and the ``clock_for`` hook routes each batch's
stage laps *and* its retire-boundary drains to that stream's own
:class:`~repro.utils.timing.StageClock`.  Stage functions resolve
per-stream state (RNG, reuse maps, hit counters) through ``ctx.stream``,
so the serial-equivalence guarantee above holds *per stream* — the
foundation of the multi-stream serving layer (runtime/gnn_serve.py).
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Any, Callable, Iterable, Sequence

from repro.core.trace import resolve_tracer
from repro.utils.timing import StageClock

__all__ = ["BatchContext", "DRAIN", "PipelinedExecutor", "Stage"]


class _Drain:
    """Sentinel a :meth:`PipelinedExecutor.run_tagged` item stream may
    yield to flush the window: every in-flight batch retires, no new batch
    is admitted, and the item index does not advance.  The request-queue
    serving layer uses it while waiting for future arrivals — retiring
    work it has already admitted instead of idling with a full window —
    which keeps enqueue→retire latency accounting honest."""

    __slots__ = ()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return "DRAIN"


DRAIN = _Drain()


def _stream_label(stream: Any) -> Any:
    """Compact trace label for a batch's stream tag — the numeric
    ``stream_id`` when the tag is a stream-state object, else ``str``."""
    sid = getattr(stream, "stream_id", None)
    return sid if sid is not None else str(stream)


class BatchContext:
    """One mini-batch flowing through the pipeline.

    ``payload`` is the batch input (seed node ids); ``outputs[name]`` holds
    each completed stage's result.  ``stream`` tags the request stream the
    batch belongs to (``None`` for single-stream runs); multi-stream stage
    functions use it to resolve per-stream state.  ``epoch`` is the cache
    epoch the batch ran against (stamped by the first stage that reads the
    caches — see ``StreamRuntime.sample``): under online refresh
    (runtime/cache_refresh.py) an epoch boundary can fall between two
    in-flight batches, and retire-time accounting attributes each batch to
    the epoch it actually dispatched against.

    ``slot`` is the pipeline window slot the batch occupies while in
    flight — the executor reuses the lowest free slot, so with depth ``d``
    at most slots ``0..d-1`` exist.  It keys the batch's trace lane
    (``slot 0`` …), making depth-``d`` overlap visible as ``d`` stacked
    timeline lanes; ``trace_t0`` is the tracer timestamp of the batch's
    dispatch start (µs), recorded only when tracing is enabled.
    """

    __slots__ = ("index", "payload", "stream", "epoch", "outputs", "slot", "trace_t0")

    def __init__(self, index: int, payload: Any, stream: Any = None):
        self.index = index
        self.payload = payload
        self.stream = stream
        self.epoch = 0
        self.outputs: dict[str, Any] = {}
        self.slot = 0
        self.trace_t0 = 0.0


@dataclasses.dataclass(frozen=True)
class Stage:
    """A named pipeline stage.

    ``fn(ctx)`` computes the stage's output from ``ctx.payload`` and
    earlier stages' ``ctx.outputs``.  ``sync(ctx)`` returns the device
    value that marks the stage complete: in serial mode the clock blocks on
    it at the stage boundary; for the final stage it is also what retire
    drains in overlap mode.
    """

    name: str
    fn: Callable[[BatchContext], Any]
    sync: Callable[[BatchContext], Any] | None = None


class PipelinedExecutor:
    """Run batches through ``stages`` keeping up to ``depth`` in flight.

    ``depth=1`` → serial: dispatch + sync every stage, retire, then start
    the next batch (the pre-pipeline engine loop).  ``depth=2`` → double
    buffering: batch ``i`` retires only after batch ``i+1`` has fully
    dispatched.  ``on_retire(ctx)`` runs once per batch, in order, after
    the batch's final stage output is ready — the place for host-side
    accounting (hit counters, logits collection) that would otherwise force
    a sync mid-pipeline.

    Failure semantics: an exception escaping a stage mid-window drains
    every in-flight batch (their ``on_retire`` accounting runs, their
    slots release) before the first error re-raises — no deadlock, no
    silently dropped batches.  ``on_batch_error(ctx, err)``, when set,
    is consulted first: returning ``True`` drops just the failing batch
    (its slot and index are reused) and the run continues — the serving
    layer's request-shedding hook.
    """

    def __init__(
        self,
        stages: Sequence[Stage | None],
        *,
        depth: int = 1,
        clock: StageClock | None = None,
        clock_for: Callable[[BatchContext], StageClock] | None = None,
        on_retire: Callable[[BatchContext], None] | None = None,
        on_batch_error: Callable[[BatchContext, BaseException], bool] | None = None,
        tracer=None,
    ):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        stages = [st for st in stages if st is not None]  # optional stages, off
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = list(stages)
        self.depth = depth
        self.clock = clock if clock is not None else StageClock(overlap=depth > 1)
        self.clock_for = clock_for
        self.on_retire = on_retire
        self.on_batch_error = on_batch_error
        self.tracer = resolve_tracer(tracer)
        self._free_slots: list[int] = []  # min-heap of released window slots
        self._next_slot = 0

    def _acquire_slot(self) -> int:
        """Lowest-numbered slot not held by an in-flight batch.  Lowest-
        first reuse keeps the trace's slot lanes dense: a depth-``d`` run
        uses exactly lanes ``slot 0 … slot d-1``, and a serial run stays
        entirely on ``slot 0`` (overlap fraction exactly 0)."""
        if self._free_slots:
            return heapq.heappop(self._free_slots)
        slot = self._next_slot
        self._next_slot += 1
        return slot

    @staticmethod
    def slot_lane(ctx: BatchContext) -> str:
        """The trace lane of the window slot ``ctx`` occupies — serving
        layers use it to anchor request flow steps onto the batch span."""
        return f"slot {ctx.slot}"

    def _clock(self, ctx: BatchContext) -> StageClock:
        """The clock a batch's laps and drains are booked on: the stream's
        own clock when ``clock_for`` is set (per-stream accounting), else
        the executor-wide default."""
        if self.clock_for is not None:
            return self.clock_for(ctx)
        return self.clock

    def run(self, payloads: Iterable[Any]) -> list[BatchContext]:
        """Dispatch every payload through all stages; return retired contexts
        in batch order.

        Retired contexts come back with ``outputs`` cleared — extraction
        belongs in ``on_retire``.  Holding every batch's device arrays
        (blocks, features, logits) until the run ends would grow memory
        O(num_batches) instead of O(depth) on exactly the long runs
        pipelining targets."""
        return self.run_tagged((None, p) for p in payloads)

    def run_tagged(self, items: Iterable[tuple[Any, Any]]) -> list[BatchContext]:
        """Like :meth:`run` over ``(stream, payload)`` pairs.

        The stream tag is stamped onto each :class:`BatchContext` before
        its stages run; the pairs may come from a *lazy* admission
        generator — it is pulled exactly when a window slot is about to be
        filled, so it can consult live in-flight occupancy (the serving
        layer's backpressure hook).  An item that is the module-level
        :data:`DRAIN` sentinel retires everything in flight without
        admitting a batch — the generator's way to flush the window while
        it waits on an external clock (request arrivals)."""
        window: collections.deque[BatchContext] = collections.deque()
        retired: list[BatchContext] = []
        tracer = self.tracer
        index = 0
        try:
            for item in items:
                if item is DRAIN:
                    while window:
                        retired.append(self._retire(window.popleft()))
                    continue
                stream, payload = item
                ctx = BatchContext(index, payload, stream)
                index += 1
                clock = self._clock(ctx)
                lane, args = "slot 0", None
                ctx.slot = self._acquire_slot()
                if tracer.enabled:
                    lane = f"slot {ctx.slot}"
                    args = {"batch": ctx.index}
                    if ctx.stream is not None:
                        args["stream"] = _stream_label(ctx.stream)
                    ctx.trace_t0 = tracer.now_us()
                try:
                    for st in self.stages:
                        sync = None
                        if st.sync is not None:
                            sync = (lambda s=st, c=ctx: s.sync(c))
                        # The trace span wraps the clock lap, so in serial
                        # mode it covers the stage's sync too — span
                        # durations and Eq. 1 stage laps agree (asserted in
                        # tests/test_trace.py).
                        with tracer.span(st.name, lane=lane, args=args):
                            with clock.stage(st.name, sync=sync):
                                ctx.outputs[st.name] = st.fn(ctx)
                except BaseException as err:
                    if self.on_batch_error is not None and self.on_batch_error(ctx, err):
                        # Handled: the batch is dropped (never enters the
                        # window, never retires) and its slot/index are
                        # reusable, so the next admission sees the same
                        # window occupancy a successful retire would leave.
                        heapq.heappush(self._free_slots, ctx.slot)
                        ctx.outputs.clear()
                        index -= 1
                        continue
                    raise
                window.append(ctx)
                while len(window) > self.depth - 1:
                    retired.append(self._retire(window.popleft()))
            while window:  # drain whatever is still in flight
                retired.append(self._retire(window.popleft()))
        except BaseException:
            # A stage (or retire sync) failed mid-window: drain every
            # in-flight batch best-effort so completed work still retires
            # (accounting runs, slots release, nothing is silently
            # dropped), then re-raise the FIRST error.
            while window:
                ctx = window.popleft()
                try:
                    self._retire(ctx)
                except BaseException:  # noqa: S110 - first error wins
                    pass
            raise
        return retired

    def _retire(self, ctx: BatchContext) -> BatchContext:
        clock = self._clock(ctx)
        tracer = self.tracer
        lane = f"slot {ctx.slot}" if tracer.enabled else "slot 0"
        if clock.overlap:
            # Drain every stage's sync value, in stage order, attributing
            # each wait to its own stage — otherwise in-flight work from
            # earlier stages would be waited on untimed inside on_retire
            # and the stage totals would under-count the loop's wall clock.
            for st in self.stages:
                if st.sync is not None:
                    with tracer.span(f"drain:{st.name}" if tracer.enabled else "drain", lane=lane):
                        clock.drain(st.name, st.sync(ctx))
        if self.on_retire is not None:
            self.on_retire(ctx)
        if tracer.enabled:
            # The batch's enclosing span: dispatch start → retired.  Slot
            # lanes carry one such span per in-flight batch, so stacked
            # batch spans across lanes *are* the pipeline overlap.
            tracer.complete(
                "batch",
                lane=lane,
                ts_us=ctx.trace_t0,
                dur_us=tracer.now_us() - ctx.trace_t0,
                args={"batch": ctx.index, "epoch": ctx.epoch},
            )
        heapq.heappush(self._free_slots, ctx.slot)
        ctx.outputs.clear()
        return ctx
