"""Online cache refresh: serve-time re-allocation + delta re-fill.

DCI allocates and fills both caches once, from pre-sampling statistics
(§IV-A Eq. 1, §IV-B).  Long-lived serving breaks the one-shot assumption:
the seed distribution drifts and request streams join/leave, so the
pre-sampled ranking goes stale and hit rates decay.  This module closes
the loop at serve time:

  telemetry window          re-allocation               delta re-fill
  (core/telemetry.py)  ──►  Eq. 1 on measured     ──►  DualCache.refresh
  miss/visit counts,        serve-time stage ratio      (epoch += 1, only
  stage laps                (core/allocation.py)        changed rows/segments
                                                        move)

``CacheRefreshManager`` owns the loop.  It keeps a *decayed history* of
visit counts seeded from the preparation-time presample profile: each
refresh folds the latest telemetry window in as

    history = history_decay * history + window_counts

so sustained drift re-ranks the caches within a few windows while
one-window noise cannot evict the steady hot set.  Stage-time history is
blended the same way, so the Eq. 1 split follows the measured serve-time
sample:feature ratio.

Refresh triggers (``RefreshConfig.mode``):

  * ``interval`` — every ``interval_batches`` retired batches;
  * ``events``   — on stream join/leave (the serving layer's hooks);
  * ``all``      — both; ``off`` — never (the default; the serve path then
    records no telemetry and is bit-for-bit identical to a refresh-free
    build).

``miss_threshold`` (CLI: ``--refresh-miss-threshold``) adds an SLO-aware
trigger that composes with any enabled mode: the manager polls the live
telemetry window's feature miss rate once per retired batch and fires a
refresh as soon as it crosses the threshold (subject to
``min_window_batches``), instead of waiting out the interval — the knob
for "refresh when service quality degrades", not "refresh on a timer".

A refresh runs *between* batch dispatches (the executor's retire path), so
up to ``depth-1`` in-flight batches may straddle an epoch boundary: they
keep the previous epoch's (immutable) device arrays and retire normally,
while the next dispatched stage reads the new epoch.  That is safe because
a refresh never changes sampled blocks or gathered rows — the two-level
sort order and the host tables are frozen at build time — only hit
accounting and byte movement (pinned by tests/test_cache_refresh.py).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.allocation import reallocate_capacity
from repro.core.cache import CacheRefreshDelta
from repro.core.presample import run_presampling
from repro.core.telemetry import WorkloadTelemetry, merge_windows
from repro.core.trace import NULL_TRACER
from repro.graph.csc import BYTES_PER_ADJ_ELEMENT

__all__ = ["RefreshConfig", "RefreshEvent", "RefreshFailure", "CacheRefreshManager"]

MODES = ("off", "interval", "events", "all")
STREAM_WEIGHTINGS = ("none", "queue-depth", "slo-pressure")


@dataclasses.dataclass(frozen=True)
class RefreshConfig:
    """Knobs for the online refresh loop (CLI: --refresh-mode/-interval)."""

    mode: str = "off"  # off | interval | events | all
    interval_batches: int = 0  # refresh period, in retired batches
    history_decay: float = 0.5  # weight of prior counts per refresh
    min_window_batches: int = 1  # skip interval refreshes on thinner windows
    join_presample_batches: int = 2  # presample budget for a joining stream
    # Bounded re-allocation: the adj share may move at most this fraction
    # of the total budget per refresh.  Serve-time stage laps are noisier
    # than the synchronized presample profile (and at depth>1 they are
    # dispatch times), so an unclamped Eq. 1 re-run can slosh the whole
    # budget between the caches on one noisy window; the step bound turns
    # that into a damped walk toward the measured ratio.  None = unclamped.
    max_split_step: float | None = 0.15
    # SLO-aware trigger: fire a refresh as soon as the live window's
    # feature miss rate crosses this value (None = disabled).  Composes
    # with the interval/event triggers in any enabled mode.
    miss_threshold: float | None = None
    # Per-stream telemetry merging.  "none" keeps the single shared
    # accumulator (every stream records into one union window — the
    # pre-existing behavior, bit-for-bit).  "queue-depth" / "slo-pressure"
    # give each stream its OWN accumulator; at refresh time the windows
    # are folded with weights the serving layer supplies
    # (:meth:`CacheRefreshManager.set_weight_fn` — queue depth + in-flight
    # occupancy, plus deadline urgency under "slo-pressure"), so the
    # re-ranking follows the streams that are actually backed up rather
    # than weighting every stream by raw batch count.
    stream_weighting: str = "none"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"refresh mode must be one of {MODES}, got {self.mode!r}")
        if self.stream_weighting not in STREAM_WEIGHTINGS:
            raise ValueError(
                f"stream_weighting must be one of {STREAM_WEIGHTINGS}, "
                f"got {self.stream_weighting!r}"
            )
        if self.mode in ("interval", "all") and self.interval_batches < 1:
            raise ValueError("interval/all refresh modes need interval_batches >= 1")
        if not 0.0 <= self.history_decay <= 1.0:
            raise ValueError("history_decay must be in [0, 1]")
        if self.max_split_step is not None and not 0.0 < self.max_split_step <= 1.0:
            raise ValueError("max_split_step must be in (0, 1] or None")
        if self.miss_threshold is not None and not 0.0 < self.miss_threshold <= 1.0:
            raise ValueError("miss_threshold must be in (0, 1] or None")

    @property
    def enabled(self) -> bool:
        return self.mode != "off"

    @property
    def on_interval(self) -> bool:
        return self.mode in ("interval", "all")

    @property
    def on_events(self) -> bool:
        return self.mode in ("events", "all")


@dataclasses.dataclass(frozen=True)
class RefreshEvent:
    """One completed refresh: trigger, outcome, and pause cost."""

    epoch: int
    reason: str  # "interval" | "miss-threshold" | "stream-join" | "stream-leave" | "manual"
    delta: CacheRefreshDelta
    pause_seconds: float  # wall time the re-allocation + delta re-fill took
    window_batches: int  # telemetry batches folded into this refresh
    window_miss_rate: float  # feature miss rate of the folded window
    suggested_depth: int | None = None  # re-derived "auto" window (None: no compute laps yet)

    def summary(self) -> dict:
        return {
            "epoch": self.epoch,
            "reason": self.reason,
            "pause_s": round(self.pause_seconds, 4),
            "window_batches": self.window_batches,
            "window_miss_rate": round(self.window_miss_rate, 4),
            "suggested_depth": self.suggested_depth,
            "adj_bytes": self.delta.allocation.adj_bytes,
            "feat_bytes": self.delta.allocation.feat_bytes,
            "feat_rows_inserted": self.delta.feat.rows_inserted,
            "feat_rows_evicted": self.delta.feat.rows_evicted,
            "feat_rows_kept": self.delta.feat.rows_kept,
            "adj_nodes_changed": self.delta.adj.nodes_changed,
            "adj_elements_regathered": self.delta.adj.elements_regathered,
        }


@dataclasses.dataclass(frozen=True)
class RefreshFailure:
    """One refresh that failed mid-apply and rolled back.

    ``DualCache.refresh`` is transactional, so a failure leaves the cache
    byte-for-byte on the old (still servable) epoch — ``epoch`` here is
    that stale epoch, unchanged.  The telemetry window folded into history
    before the apply STAYS folded: the next trigger retries the
    re-allocation from the richer history rather than replaying the lost
    window."""

    reason: str  # the trigger that fired the failed refresh
    error: str  # repr of the exception that aborted the apply
    epoch: int  # the epoch still being served (pre-refresh, post-rollback)
    pause_seconds: float
    window_batches: int

    def summary(self) -> dict:
        return {
            "reason": self.reason,
            "error": self.error,
            "epoch": self.epoch,
            "pause_s": round(self.pause_seconds, 4),
            "window_batches": self.window_batches,
        }


class CacheRefreshManager:
    """Drives telemetry → Eq. 1 re-allocation → DualCache delta re-fills.

    One manager per served pipeline.  The engine/serving layer calls
    :meth:`note_retired` once per retired batch (the interval trigger) and
    the stream hooks on membership changes (the event trigger); both
    funnel into :meth:`refresh`.
    """

    def __init__(self, pipeline, dataset, *, fanouts, batch_size, config: RefreshConfig):
        if not config.enabled:
            raise ValueError("CacheRefreshManager needs an enabled RefreshConfig")
        if not pipeline.caches.refreshable:
            raise ValueError(
                f"policy {pipeline.name!r} built no refreshable caches; online refresh "
                "needs a presampled dual cache (dci/sci/aci/ducati)"
            )
        self.pipeline = pipeline
        self.dataset = dataset
        self.fanouts = tuple(fanouts)
        self.batch_size = batch_size
        self.config = config
        # Settable observability handle (core/trace.py): the owning
        # engine/server installs its tracer; refreshes then land as epoch
        # spans + allocation-split counters on the "refresh" lane.
        self.tracer = NULL_TRACER
        # Settable fault-injection handle (core/faults.py): when the
        # owning server installs one, each apply charges a ``refresh_fill``
        # site call; a triggered fault rolls back (see RefreshFailure).
        self.injector = None
        self.failures: list[RefreshFailure] = []
        self.telemetry = WorkloadTelemetry(dataset.num_nodes, dataset.graph.num_edges)
        # Weighted-merge mode: per-stream accumulators keyed by the
        # serving layer's stream key; empty under "none" (shared sink).
        self._stream_telemetry: dict = {}
        self._weight_fn = None
        self.events: list[RefreshEvent] = []
        self._clocks: list = []
        self._retired_since_refresh = 0
        # Decayed count/stage-time history, seeded from the preparation
        # profile so the first refresh starts from the same ranking the
        # build used.
        stats = pipeline.presample
        if stats is not None:
            self._node_counts = stats.node_counts.astype(np.float64)
            self._edge_counts = stats.edge_counts.astype(np.float64)
            self._sample_s = float(sum(stats.sample_times))
            self._feature_s = float(sum(stats.feature_times))
        else:
            self._node_counts = np.zeros(dataset.num_nodes, np.float64)
            self._edge_counts = np.zeros(dataset.graph.num_edges, np.float64)
            self._sample_s = self._feature_s = 0.0
        # Compute-lap history (serve-time only — presampling runs no
        # forward) and the "auto" executor window it implies.  Updated per
        # refresh; consumers with pipeline_depth="auto" apply
        # ``suggested_depth`` to the live executor between batches.
        self._compute_s = 0.0
        self.suggested_depth: int | None = None
        # Per-seed presample contributions for join/leave re-merging
        # (populated on join; initial streams' individual profiles were
        # merged away during preparation, so a leave before any join
        # relies on decay).  Each entry is decayed in lockstep with the
        # history, so a leave subtracts exactly the remnant of the join
        # that is still IN the history — not the original raw counts.
        self._stream_stats: dict[int, dict] = {}

    # ----------------------------------------------------------- triggers
    def register_clock(self, clock, key=None) -> None:
        """Track a stream's StageClock so its laps feed the Eq. 1 ratio.

        ``key`` is accepted for symmetry with :meth:`telemetry_for`; laps
        always pool into the shared accumulator — a stage lap is a
        wall-clock fact shared by the whole pipeline, only the COUNT
        merge is weighted."""
        del key
        if clock not in self._clocks:
            self._clocks.append(clock)

    def telemetry_for(self, key) -> WorkloadTelemetry:
        """The sink a stream's retire path should record into.

        Shared accumulator under ``stream_weighting="none"`` (the
        pre-existing union-window behavior); otherwise one accumulator
        per stream key, folded by :func:`merge_windows` with the serving
        layer's weights at each refresh."""
        if self.config.stream_weighting == "none":
            return self.telemetry
        sink = self._stream_telemetry.get(key)
        if sink is None:
            sink = self._stream_telemetry[key] = WorkloadTelemetry(
                self.dataset.num_nodes, self.dataset.graph.num_edges
            )
        return sink

    def set_weight_fn(self, fn) -> None:
        """``fn(key) -> float`` supplies each stream's merge weight at
        refresh time (the serving layer's queue-depth / SLO-pressure
        view).  Ignored under ``stream_weighting="none"``."""
        self._weight_fn = fn

    def shard_allocations(self, plan):
        """Eq. 1 per shard on the decayed workload history, sliced by the
        plan's node-id ranges (the sharded serving layer calls this after
        every refresh so each shard's capacity follows ITS range's share
        of the traffic).  The per-shard split fractions all equal the
        global ``sample_fraction`` (Eq. 1 is scale-invariant), which is
        what keeps the globally-coordinated fill partitionable — see
        ``repro.core.allocation.shard_allocations``."""
        from repro.core.allocation import shard_allocations

        weights = [
            float(self._node_counts[lo:hi].sum())
            for lo, hi in (plan.bounds(s) for s in range(plan.num_shards))
        ]
        if not any(weights):
            weights = [float(hi - lo) for lo, hi in (plan.bounds(s) for s in range(plan.num_shards))]
        return shard_allocations(
            self.pipeline.caches.allocation,
            weights,
            sample_times=[self._sample_s],
            feature_times=[self._feature_s],
            adj_need_bytes=self.dataset.graph.num_edges * BYTES_PER_ADJ_ELEMENT,
            feat_need_bytes=self.dataset.features.nbytes,
        )

    def _window_batches(self) -> int:
        return self.telemetry.batches + sum(
            t.batches for t in self._stream_telemetry.values()
        )

    def _window_miss_rate(self) -> float:
        lookups = self.telemetry.feat_lookups
        misses = self.telemetry.feat_misses
        for t in self._stream_telemetry.values():
            lookups += t.feat_lookups
            misses += t.feat_misses
        return misses / max(lookups, 1)

    def note_retired(self) -> RefreshEvent | None:
        """Per-retired-batch triggers: SLO miss-rate threshold, then interval.

        The miss-threshold check runs first (in any enabled mode — it is a
        quality signal, not a schedule) so a degrading window refreshes as
        soon as it crosses the SLO instead of waiting out the interval;
        the interval trigger then proceeds as before.  Both share
        ``min_window_batches`` so one thin noisy window cannot fire either.
        """
        self._retired_since_refresh += 1
        cfg = self.config
        if (
            cfg.miss_threshold is not None
            and self._window_batches() >= cfg.min_window_batches
            and self._window_miss_rate() >= cfg.miss_threshold
        ):
            return self.refresh("miss-threshold")
        if not cfg.on_interval:
            return None
        if self._retired_since_refresh < cfg.interval_batches:
            return None
        if self._window_batches() < cfg.min_window_batches:
            return None
        return self.refresh("interval")

    def on_stream_join(self, seed: int) -> RefreshEvent | None:
        """A stream joined at serve time: presample its seed, fold the
        profile into the merged history, and (in event modes) refresh so
        the shared cache serves the NEW union workload."""
        stats = run_presampling(
            self.dataset,
            fanouts=self.fanouts,
            batch_size=self.batch_size,
            n_batches=self.config.join_presample_batches,
            seed=seed,
        )
        self._stream_stats[seed] = {
            "node_counts": stats.node_counts.astype(np.float64),
            "edge_counts": stats.edge_counts.astype(np.float64),
            "sample_s": float(sum(stats.sample_times)),
            "feature_s": float(sum(stats.feature_times)),
        }
        self._node_counts += stats.node_counts
        self._edge_counts += stats.edge_counts
        self._sample_s += float(sum(stats.sample_times))
        self._feature_s += float(sum(stats.feature_times))
        if not self.config.on_events:
            return None
        return self.refresh("stream-join")

    def on_stream_leave(self, seed: int) -> RefreshEvent | None:
        """A stream left: subtract what REMAINS of its join-time presample
        contribution (the stored profile is decayed in lockstep with the
        history, so shared hot nodes' counts from other streams are
        untouched) and refresh; departed live traffic also washes out of
        the decayed history over subsequent windows.

        Every subtraction is clamped elementwise at zero.  The lockstep
        decay makes history − remnant non-negative in exact arithmetic,
        but the two sides round differently in floating point (the
        history decays ``decay*(h+P)+w`` as a sum, the remnant decays
        ``decay*P`` alone), so an unclamped subtraction can leave tiny
        negative per-node counts — which the next Eq. 1 re-allocation and
        hot-row selection would silently treat as anti-visits.  The clamp
        is the invariant the join→serve→leave regression test pins."""
        remnant = self._stream_stats.pop(seed, None)
        if remnant is not None:
            self._node_counts = np.maximum(self._node_counts - remnant["node_counts"], 0.0)
            self._edge_counts = np.maximum(self._edge_counts - remnant["edge_counts"], 0.0)
            self._sample_s = max(self._sample_s - remnant["sample_s"], 0.0)
            self._feature_s = max(self._feature_s - remnant["feature_s"], 0.0)
        if not self.config.on_events:
            return None
        return self.refresh("stream-leave")

    def _clamp_step(self, current, desired):
        """Bound the per-refresh budget move (see RefreshConfig.max_split_step)."""
        from repro.core.allocation import CacheAllocation

        step = self.config.max_split_step
        total = desired.total_bytes
        if step is None or total <= 0:
            return desired
        bound = int(step * total)
        adj = int(min(max(desired.adj_bytes, current.adj_bytes - bound), current.adj_bytes + bound))
        adj = max(0, min(adj, total, self.dataset.graph.num_edges * BYTES_PER_ADJ_ELEMENT))
        feat = min(total - adj, self.dataset.features.nbytes)
        return CacheAllocation(
            total_bytes=total,
            adj_bytes=adj,
            feat_bytes=feat,
            sample_fraction=desired.sample_fraction,
        )

    # ------------------------------------------------------------ refresh
    def refresh(self, reason: str = "manual") -> RefreshEvent | None:
        """Fold the current telemetry window into history, re-run Eq. 1 on
        the measured stage ratio, and apply the delta re-fill.

        Returns ``None`` when the apply failed and rolled back (recorded
        in :attr:`failures`) — the caches are byte-for-byte on the old
        epoch and serving continues against it."""
        with self.tracer.span("refresh", lane="refresh", args={"reason": reason}):
            event = self._refresh(reason)
        if event is None:
            return None
        if self.tracer.enabled:
            # The Eq. 1 split the epoch landed on, as counter tracks — the
            # timeline shows allocation drift across refreshes at a glance.
            self.tracer.counter(
                "allocation_bytes",
                {
                    "adj": float(event.delta.allocation.adj_bytes),
                    "feat": float(event.delta.allocation.feat_bytes),
                },
            )
            self.tracer.counter(
                "refresh_window", {"miss_rate": float(event.window_miss_rate)}
            )
            self.tracer.instant(
                "epoch", lane="refresh", args={"epoch": event.epoch, "reason": reason}
            )
        return event

    def _refresh(self, reason: str) -> RefreshEvent | None:
        t0 = time.perf_counter()
        for clock in self._clocks:
            self.telemetry.pull_times(clock)
        if self._stream_telemetry:
            # Weighted merge: counts from the per-stream accumulators,
            # tilted by the serving layer's pressure weights; laps/batches
            # pooled unweighted (see merge_windows).
            parts = [self.telemetry.snapshot()]
            weights = [1.0]
            for key, sink in self._stream_telemetry.items():
                parts.append(sink.snapshot())
                weights.append(1.0 if self._weight_fn is None else self._weight_fn(key))
                sink.reset()
            window = merge_windows(parts, weights)
        else:
            window = self.telemetry.snapshot()
        self.telemetry.reset()
        self._retired_since_refresh = 0
        decay = self.config.history_decay
        if window.batches:
            self._node_counts = decay * self._node_counts + window.node_counts
            self._edge_counts = decay * self._edge_counts + window.edge_counts
            self._sample_s = decay * self._sample_s + float(sum(window.sample_times))
            self._feature_s = decay * self._feature_s + float(sum(window.feature_times))
            self._compute_s = decay * self._compute_s + float(sum(window.compute_times))
            # Decay the recorded per-stream join contributions in lockstep,
            # so a later leave subtracts only what the history still holds.
            for remnant in self._stream_stats.values():
                remnant["node_counts"] *= decay
                remnant["edge_counts"] *= decay
                remnant["sample_s"] *= decay
                remnant["feature_s"] *= decay
        caches = self.pipeline.caches
        allocation = reallocate_capacity(
            caches.allocation,
            [self._sample_s],
            [self._feature_s],
            adj_need_bytes=self.dataset.graph.num_edges * BYTES_PER_ADJ_ELEMENT,
            feat_need_bytes=self.dataset.features.nbytes,
        )
        allocation = self._clamp_step(caches.allocation, allocation)
        try:
            delta = caches.refresh(
                allocation=allocation,
                node_counts=self._node_counts,
                edge_counts=self._edge_counts,
                injector=self.injector,
            )
        except Exception as err:
            # DualCache.refresh already rolled its state back; record the
            # failure and keep serving the stale epoch (see RefreshFailure).
            failure = RefreshFailure(
                reason=reason,
                error=repr(err),
                epoch=caches.epoch,
                pause_seconds=time.perf_counter() - t0,
                window_batches=window.batches,
            )
            self.failures.append(failure)
            if self.tracer.enabled:
                self.tracer.instant(
                    "refresh-rollback",
                    lane="refresh",
                    args={"reason": reason, "epoch": caches.epoch, "error": type(err).__name__},
                )
            return None
        if self._compute_s > 0.0:
            # Refresh-aware "auto" pipeline depth: re-derive the executor
            # window from the refreshed prep:compute ratio (the same
            # formula the warmup-time probe uses), so a refresh that
            # shifts the stage balance also resizes the overlap window.
            from repro.runtime.gnn_engine import auto_pipeline_depth

            derived = auto_pipeline_depth(
                self._sample_s + self._feature_s, self._compute_s
            )
            # A degenerate window (~zero measured prep → depth 1) is not a
            # usable live resize: mid-run the clocks are already in overlap
            # mode, so keep the previous suggestion and re-derive from the
            # next window's laps instead.
            if derived >= 2:
                self.suggested_depth = derived
        event = RefreshEvent(
            epoch=delta.epoch,
            reason=reason,
            delta=delta,
            pause_seconds=time.perf_counter() - t0,
            window_batches=window.batches,
            window_miss_rate=window.miss_rate,
            suggested_depth=self.suggested_depth,
        )
        self.events.append(event)
        return event
