"""Sharded dual-cache serving across a JAX device mesh.

Layout (ARCHITECTURE §10): the feature table + feature cache are
range-partitioned over a 1-D ``shard`` mesh (graph/shard.py — each shard
holds its id range's host slice and a local hot table re-slotted from the
global fill), while the adjacency cache is **replicated** per shard so
sampling never crosses devices.  Streams round-robin over the replicas;
each batch's frontier rides the all-to-all exchange: the dedup path's
sorted unique ids split into contiguous per-shard segments, every shard
gathers only its resident rows on its own device, and the results are
exchanged back to the assembling device and reassembled through the
existing inverse map.

Per-shard Eq. 1 allocation runs on per-shard telemetry — each shard's
slice of the visit counts scales its budget and stage times
(:func:`repro.core.allocation.shard_allocations`) — and because Eq. 1's
split fraction is scale-invariant, every shard's adj:feat split matches
the global one: the globally-ranked fill partitions by id range without
moving a single row.  That coordination is what makes sharded serving
**bit-for-bit** equivalent to the single-device path — logits, hit masks,
per-epoch counters, and refresh deltas are all identical across mesh
sizes and the full knob grid (tests/test_sharded_serve.py, run on a
4-virtual-device CPU mesh in CI via
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

Online refresh stays globally coordinated: the shared
:class:`~repro.runtime.cache_refresh.CacheRefreshManager` re-allocates
and delta-refills the base caches, and the server then *repartitions*
the per-shard stores and replicas to the new epoch on the same retire
boundary, recording genuinely per-shard allocations from the sliced
history.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.allocation import shard_allocations
from repro.core.faults import InjectedFault
from repro.graph.csc import BYTES_PER_ADJ_ELEMENT
from repro.graph.sampling import DedupFrontier
from repro.graph.shard import ShardedFeatureStore, make_shard_plan
from repro.launch.mesh import make_serving_mesh, serving_devices
from repro.runtime.gnn_engine import StreamRuntime, modeled_transfer_seconds
from repro.runtime.gnn_serve import MultiStreamServer, ServeReport
from repro.runtime.pipeline import BatchContext

__all__ = ["ShardedDualCache", "ShardedStreamRuntime", "ShardedServer"]


@dataclasses.dataclass
class ShardedDualCache:
    """The DualCache's sharded runtime view: per-shard feature stores +
    per-device adjacency replicas, rebuilt (repartitioned) whenever the
    base caches move to a new epoch.

    ``base`` stays the single source of truth — the sample stage's dedup
    pad id, the refresh manager, and the epoch counter all read it — so
    the sharded layout can never drift from the global fill."""

    base: object  # core.cache.DualCache
    plan: object  # graph.shard.ShardPlan
    store: ShardedFeatureStore
    adj_replicas: list
    devices: list | None
    epoch: int
    # Failover state (core/faults.py shard_exchange site): shard id →
    # retired batches left until rejoin (-1 = until process end).  While a
    # shard is down its id-range is served from the host-mirror fallback
    # (ShardedFeatureStore._failover_gather) — values and hit accounting
    # bit-identical, only the byte route changes.
    down: dict = dataclasses.field(default_factory=dict)
    failovers: list = dataclasses.field(default_factory=list)

    @property
    def down_set(self) -> set:
        return set(self.down)

    def mark_down(self, shard: int, *, down_for: int | None = None, call: int = 0) -> None:
        """Record a lost shard; idempotent while already down."""
        if shard not in self.down:
            self.down[shard] = -1 if down_for is None else int(down_for)
            self.failovers.append(
                {"shard": int(shard), "down_for": self.down[shard], "call": int(call)}
            )

    def note_retired(self) -> list[int]:
        """Tick rejoin countdowns at a retire boundary; returns the shards
        that just rejoined (their device exchange resumes on the next
        batch — the host fallback was bit-identical, so rejoin is also
        invisible to outputs)."""
        rejoined = []
        for shard in list(self.down):
            if self.down[shard] < 0:
                continue
            self.down[shard] -= 1
            if self.down[shard] <= 0:
                del self.down[shard]
                rejoined.append(shard)
        return rejoined

    @classmethod
    def build(cls, caches, num_shards: int, devices=None) -> "ShardedDualCache":
        plan = make_shard_plan(caches.store.num_nodes, num_shards)
        return cls(
            base=caches,
            plan=plan,
            store=ShardedFeatureStore.partition_store(caches.store, plan, devices),
            adj_replicas=cls._replicate_adj(caches.dgraph, devices),
            devices=devices,
            epoch=caches.epoch,
        )

    @staticmethod
    def _replicate_adj(dgraph, devices) -> list:
        """One adjacency replica per shard device (deduplicated: shards
        mapped to the same physical device share one copy; the
        co-resident layout shares the base arrays outright)."""
        if not devices:
            return [dgraph]
        copies: dict = {}
        return [copies.setdefault(d, jax.device_put(dgraph, d)) for d in devices]

    def adj_replica(self, i: int):
        return self.adj_replicas[i % len(self.adj_replicas)]

    def repartition(self) -> dict:
        """Re-slice the per-shard stores and replicas from the base caches
        (call after a base refresh lands).  Returns the per-shard delta —
        cached-row counts before/after — for the repartition log."""
        before = self.store.shard_cached_rows()
        self.store = ShardedFeatureStore.partition_store(self.base.store, self.plan, self.devices)
        self.adj_replicas = self._replicate_adj(self.base.dgraph, self.devices)
        self.epoch = self.base.epoch
        return {
            "epoch": self.epoch,
            "rows_before": before,
            "rows_after": self.store.shard_cached_rows(),
        }


class ShardedStreamRuntime(StreamRuntime):
    """A :class:`StreamRuntime` whose cache accesses route through the
    sharded layout.  Only the three cache-access hooks (and host-side
    per-shard accounting) differ from the base class: control flow, RNG,
    and every counter the reports surface stay byte-identical."""

    def __init__(self, *args, sharded: ShardedDualCache, replica: int = 0, **kwargs):
        super().__init__(*args, **kwargs)
        self.sharded = sharded
        self.replica = replica
        k = sharded.plan.num_shards
        self.shard_feat_hits = np.zeros(k, np.int64)
        self.shard_feat_lookups = np.zeros(k, np.int64)
        self.shard_gathered_rows = np.zeros(k, np.int64)
        self.shard_prefetched_rows = np.zeros(k, np.int64)

    # --------------------------------------------------- cache-access hooks
    def _sample_graph(self):
        return self.sharded.adj_replica(self.replica)

    def _resolve_dedup(self, ctx, block):
        view = super()._resolve_dedup(ctx, block)
        assemble = self.sharded.store.assemble_device
        if assemble is not None:
            # The inverse map was produced on this stream's sampling
            # replica; the forward consumes it together with the
            # exchanged rows on the assembling device, so re-home it here
            # (a pure copy — the reconstruction stays bit-identical).
            dd, nu, bucket, uids = view
            dd = DedupFrontier(
                unique_ids=dd.unique_ids,
                inverse=jax.device_put(dd.inverse, assemble),
                num_unique=dd.num_unique,
            )
            view = (dd, nu, bucket, uids)
            ctx.outputs["_dedup"] = view
        return view

    def _partition(self, ctx, ids):
        part = ctx.outputs.get("_shardpart")
        if part is None:
            num_live = self._dedup_view(ctx)[1] if self.dedup else None
            part = self.sharded.store.partition(np.asarray(ids), num_live=num_live)
            ctx.outputs["_shardpart"] = part
        return part

    def _prefetch(self, ctx, nodes, num_live=None):
        del num_live  # the partition's per-shard live windows carry it
        if self.injector is not None:
            # Charged ONCE per batch at the runtime level (the per-shard
            # fan-out below is one logical staging op), mirroring the
            # single-device FeatureStore.prefetch_misses site.
            self.injector.check("prefetch")
        staged = self.sharded.store.prefetch(
            self._partition(ctx, nodes), down=self.sharded.down_set or None
        )
        for s, p in enumerate(staged.parts):
            if p is not None:
                self.shard_prefetched_rows[s] += p.num_miss
        return staged

    def _gather(self, ctx, indices, **gather_kw):
        if self.injector is not None:
            # Same once-per-batch charging as FeatureStore.gather: the
            # whole-frontier host path, then the kernel route when on.
            self.injector.check("host_fetch")
            if gather_kw.get("use_kernel"):
                self.injector.check("kernel_gather")
        part = self._partition(ctx, indices)
        for s, buf in enumerate(part.seg_ids):
            if buf is not None:
                self.shard_gathered_rows[s] += len(buf)
        while True:
            try:
                return self.sharded.store.gather(
                    part,
                    tracer=self.tracer,
                    injector=self.injector,
                    down=self.sharded.down_set or None,
                    **gather_kw,
                )
            except InjectedFault as err:
                if err.site != "shard_exchange" or err.shard is None:
                    raise
                # Lost device mid-exchange: fail the shard over to its
                # host mirror and redo the gather — already-exchanged
                # segments re-gather the same bits, the victim's segment
                # takes the fallback route, and the loop converges (a
                # downed shard is never charged again).
                rule = self.injector.plan.rule_for("shard_exchange")
                self.sharded.mark_down(
                    err.shard,
                    down_for=rule.down_for if rule is not None else None,
                    call=err.call,
                )
                if self.tracer.enabled:
                    self.tracer.complete(
                        "shard-down",
                        lane="faults",
                        ts_us=self.tracer.now_us(),
                        dur_us=0.0,
                        args={"shard": err.shard, "call": err.call},
                    )

    # ----------------------------------------------------------- accounting
    def record(self, ctx) -> None:
        super().record(ctx)
        part = ctx.outputs.get("_shardpart")
        if part is None:
            return
        feature_out = ctx.outputs["feature"]
        if self.dedup:
            # Per-VISIT accounting by owning shard: each unique node's hit
            # bit weighted by its visit multiplicity — sums across shards
            # to the global per-visit counters (tests/test_shard.py).
            dd, nu, _, _ = self._dedup_view(ctx)
            mult = np.bincount(np.asarray(dd.inverse), minlength=nu)[:nu].astype(np.int64)
            hit_u = np.asarray(feature_out[3])[:nu].astype(bool)
            asgn = part.asgn[:nu]
            np.add.at(self.shard_feat_lookups, asgn, mult)
            np.add.at(self.shard_feat_hits, asgn[hit_u], mult[hit_u])
        else:
            hit = np.asarray(feature_out[1]).astype(bool)
            self.shard_feat_lookups += np.bincount(
                part.asgn, minlength=self.sharded.plan.num_shards
            ).astype(np.int64)
            self.shard_feat_hits += np.bincount(
                part.asgn[hit], minlength=self.sharded.plan.num_shards
            ).astype(np.int64)


class ShardedServer(MultiStreamServer):
    """:class:`MultiStreamServer` over the sharded dual cache.

    ``mesh`` (or ``num_shards``) picks the layout: shards map round-robin
    onto the mesh's devices, and when the mesh has a single device the
    shards co-reside there — same partition math, same per-shard
    accounting, no cross-device copies (mesh size 1 is bit-for-bit the
    base server; asserted in tests/test_sharded_serve.py).  All base
    knobs (depth, prefetch, kernel, dedup, refresh, admission subclasses)
    compose unchanged."""

    def __init__(self, engine, *, num_shards: int | None = None, mesh=None, **kwargs):
        super().__init__(engine, **kwargs)
        if num_shards is None and self.config.mesh:
            # ServeConfig.mesh is the requested shard count (0 = derive
            # from the device mesh); the ``mesh`` keyword here is the JAX
            # mesh object itself and stays a live parameter.
            num_shards = self.config.mesh
        if mesh is None:
            mesh = make_serving_mesh(num_shards or 1)
        devices = serving_devices(mesh)
        if num_shards is None:
            num_shards = len(devices)
        self.mesh = mesh
        self.num_shards = num_shards
        shard_devices = [devices[s % len(devices)] for s in range(num_shards)]
        if len(set(devices)) == 1:
            # One physical device → co-resident shards; skip the (no-op
            # but not free) cross-device transfer plumbing entirely.
            shard_devices = None
        self.sharded = ShardedDualCache.build(
            engine.pipeline.caches, num_shards, shard_devices
        )
        self.repartition_log: list[dict] = []
        self.shard_allocations = self._initial_shard_allocations()

    # ----------------------------------------------------------- plumbing
    def _make_runtime(self, sid: int, seed: int, *, collect_outputs: bool):
        return ShardedStreamRuntime(
            self.engine.pipeline,
            self.engine.params,
            model=self.engine.model,
            fanouts=self.engine.fanouts,
            num_nodes=self.engine.dataset.num_nodes,
            key=jax.random.PRNGKey(seed + 1),
            collect_outputs=collect_outputs,
            prefetch=self.prefetch,
            use_kernel=self.use_kernel,
            gather_buffers=self.gather_buffers,
            dedup=self.dedup,
            injector=self.injector,
            retry_policy=self.retry_policy,
            degraded_mode=self.degraded_mode,
            sharded=self.sharded,
            replica=sid % self.num_shards,
        )

    def _initial_shard_allocations(self):
        """Per-shard Eq. 1 from the presample profile (the same counts
        the global fill ranked on); None for cacheless policies."""
        alloc = self.engine.pipeline.caches.allocation
        if alloc is None:
            return None
        plan = self.sharded.plan
        ps = self.engine.pipeline.presample
        if ps is not None:
            counts = np.asarray(ps.node_counts, np.float64)
            weights = [float(counts[lo:hi].sum()) for lo, hi in map(plan.bounds, range(plan.num_shards))]
            sample_times = list(ps.sample_times)
            feature_times = list(ps.feature_times)
        else:
            weights = []
            sample_times = [alloc.sample_fraction]
            feature_times = [1.0 - alloc.sample_fraction]
        if not any(w > 0 for w in weights):
            weights = [float(hi - lo) for lo, hi in map(plan.bounds, range(plan.num_shards))]
        return shard_allocations(
            alloc,
            weights,
            sample_times=sample_times,
            feature_times=feature_times,
            adj_need_bytes=self.engine.dataset.graph.num_edges * BYTES_PER_ADJ_ELEMENT,
            feat_need_bytes=self.engine.dataset.features.nbytes,
        )

    def _on_retire(self, ctx) -> None:
        super()._on_retire(ctx)
        if self.sharded.down:
            # Failover rejoin ticks on the same retire boundary every
            # other epoch-style transition uses, so no batch ever sees a
            # mixed layout mid-flight.
            for shard in self.sharded.note_retired():
                if self.tracer.enabled:
                    self.tracer.instant("shard-rejoin", lane="faults", args={"shard": shard})

    def _apply_refresh_event(self, event) -> None:
        super()._apply_refresh_event(event)
        # The manager refreshed the BASE caches (global Eq. 1 + globally
        # ranked delta re-fill); re-slice the shards to the new epoch on
        # the same retire boundary so no batch ever sees a mixed layout,
        # and record the genuinely per-shard allocations from the sliced
        # history.
        stats = self.sharded.repartition()
        stats["reason"] = event.reason
        self.repartition_log.append(stats)
        if self.refresh_manager is not None:
            self.shard_allocations = self.refresh_manager.shard_allocations(self.sharded.plan)

    # ---------------------------------------------------------------- run
    def _warmup_sharded(self, seeds: np.ndarray) -> None:
        """Compile each replica's sampler + the per-shard gathers + the
        forward outside the timed loop, using a scratch runtime per
        replica (stream state and RNG sequences untouched)."""
        for r in range(min(self.num_shards, len(self.sharded.adj_replicas))):
            rt = self._make_runtime(r, self.engine.seed, collect_outputs=False)
            # Warmup must not consume fault-plan draws (the serve loop's
            # replay is a pure function of plan + serve-path call index)
            # nor fault before serving starts.
            rt.injector = None
            rt.retry_policy = None
            ctx = BatchContext(-1 - r, np.asarray(seeds))
            ctx.outputs["sample"] = rt.sample(ctx)
            if self.prefetch:
                ctx.outputs["prefetch"] = rt.prefetch_stage(ctx)
            ctx.outputs["feature"] = rt.feature(ctx)
            jax.block_until_ready(rt.compute(ctx))

    def run(self, *, warmup: bool = True, raise_on_error: bool = True) -> ServeReport:
        if warmup:
            seeds = self._warmup_seeds()
            if seeds is not None:
                self._warmup_sharded(seeds)
        return super().run(warmup=False, raise_on_error=raise_on_error)

    # ------------------------------------------------------------- report
    def _shard_summaries(self) -> list[dict]:
        k = self.num_shards
        hits = np.zeros(k, np.int64)
        lookups = np.zeros(k, np.int64)
        gathered = np.zeros(k, np.int64)
        prefetched = np.zeros(k, np.int64)
        adj_hits = np.zeros(k, np.int64)
        adj_lookups = np.zeros(k, np.int64)
        for s in self.streams:
            rt = s.runtime
            hits += rt.shard_feat_hits
            lookups += rt.shard_feat_lookups
            gathered += rt.shard_gathered_rows
            prefetched += rt.shard_prefetched_rows
            # Adjacency traffic lands on the stream's sampling replica.
            adj_hits[rt.replica % k] += rt.adj_hits
            adj_lookups[rt.replica % k] += rt.adj_lookups
        row_bytes = self.engine.dataset.feature_nbytes_per_row()
        rows_cached = self.sharded.store.shard_cached_rows()
        out = []
        for i in range(k):
            entry = {
                "shard": i,
                "rows_cached": rows_cached[i],
                "feat_hits": int(hits[i]),
                "feat_lookups": int(lookups[i]),
                "adj_hits": int(adj_hits[i]),
                "adj_lookups": int(adj_lookups[i]),
                "gathered_rows": int(gathered[i]),
                "prefetched_rows": int(prefetched[i]),
                # Each shard drives its own HBM/PCIe link pair, so the
                # mesh's modeled transfer time is the max over shards —
                # the sharded-scaling metric bench_multistream gates.
                "modeled_transfer_s": modeled_transfer_seconds(
                    feat_lookups=int(lookups[i]),
                    feat_hits=int(hits[i]),
                    adj_lookups=int(adj_lookups[i]),
                    adj_hits=int(adj_hits[i]),
                    feat_row_bytes=row_bytes,
                ),
            }
            if self.shard_allocations is not None:
                a = self.shard_allocations[i]
                entry["allocation"] = {
                    "total_bytes": a.total_bytes,
                    "adj_bytes": a.adj_bytes,
                    "feat_bytes": a.feat_bytes,
                    "sample_fraction": round(a.sample_fraction, 6),
                }
            out.append(entry)
        return out

    def _resolved_config(self):
        # Echo the shard count actually built (mesh=0 requests derive it
        # from the device mesh, so the request alone doesn't say).
        return super()._resolved_config().replace(mesh=self.num_shards)

    def _serve_report(self, wall: float) -> ServeReport:
        rep = super()._serve_report(wall)
        rep.num_shards = self.num_shards
        rep.shards = self._shard_summaries()
        if self.sharded.failovers:
            for shard, entry in enumerate(rep.shards):
                entry["failed_over"] = any(
                    f["shard"] == shard for f in self.sharded.failovers
                )
            rep.failovers = list(self.sharded.failovers)
        return rep
