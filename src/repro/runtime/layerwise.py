"""Layer-wise full-graph inference — the second execution mode beside sampling.

Sampling amortizes per-seed neighborhood explosion; once the whole graph
needs scoring, every node's features are re-gathered once per seed batch
that touches them.  Layer-wise execution inverts the loop: run layer *k*
over ALL nodes before starting layer *k+1*, walking the node range in
fixed-size chunks.  Each node's input rows are then read exactly
``1 + out_degree`` times per layer — once as a chunk member, once per
out-edge — a bound no sampled schedule meets, at the price of
materializing every intermediate layer.

The executor reuses the whole DCI stack:

  - chunks flow through the staged :class:`~repro.runtime.pipeline.
    PipelinedExecutor` (chunk *i+1*'s gather overlaps chunk *i*'s layer
    compute at ``depth > 1``, same clock semantics as the sampled engine);
  - layer-0 input rows come from the feature :class:`~repro.graph.
    features.FeatureStore` (optionally delta re-filled for the layer-wise
    access pattern, which is EXACT — ``1 + bincount(row_index)`` — where
    presampling could only estimate);
  - layer-*k* outputs spill to a host-side table and come back as layer
    *k+1* inputs through a per-layer EMBEDDING cache
    (:func:`~repro.graph.features.build_embedding_cache`) — the same
    allocation/fill machinery, position-map gather, prefetch staging and
    row-block kernel route as the input features;
  - the budget splits between the two caches by Eq. 1 over probed chunk
    gather laps (:func:`~repro.core.allocation.allocate_layerwise_capacity`).

``dedup`` does not apply here — chunk gathers are range-structured (the
self block IS sorted-unique; neighbor lists duplicate only across
multi-edges) — and the knob is ignored.  ``pipeline_depth="auto"``
resolves to 2: chunk prep is pure gather, so one overlap slot already
hides it behind compute.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import LayerwiseAllocation, allocate_layerwise_capacity
from repro.core.config import EngineConfig
from repro.core.trace import resolve_tracer
from repro.core.policies import PreparedPipeline
from repro.graph.datasets import SyntheticGraphDataset
from repro.graph.features import (
    FeatureStore,
    build_embedding_cache,
    plain_feature_store,
    refresh_feature_cache,
)
from repro.graph.sampling import pow2_bucket
from repro.kernels.cached_gather.kernel import ROW_BLOCK
from repro.models import gnn as gnn_models
from repro.runtime.gnn_engine import modeled_transfer_seconds
from repro.runtime.pipeline import PipelinedExecutor, Stage
from repro.utils.timing import StageClock

__all__ = [
    "ChunkPlan",
    "LayerwiseReport",
    "layerwise_access_counts",
    "plan_chunks",
    "run_layerwise",
]


def layerwise_access_counts(graph) -> np.ndarray:
    """Exact per-node reads per layer: once as a chunk member plus once per
    out-edge (each appearance in ``row_index`` is one neighbor gather).
    The same counts govern the layer-0 feature cache and every
    intermediate embedding cache — the access pattern is the CSC itself,
    not a sampled estimate."""
    return 1 + np.bincount(graph.row_index, minlength=graph.num_nodes).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class ChunkSpec:
    """One node-range chunk's layer-invariant geometry.

    The gather reads one concatenated index vector ``[self | neighbors]``:
    ``chunk_size`` self ids (range ``[lo, lo+cnt)``, tail clipped — the
    clipped rows are dropped at spill) followed by the range's in-edge
    sources padded to a pow2 bucket, so chunks sharing a bucket share
    compiled gather/forward programs (O(log E) distinct shapes).  Pad
    positions are marked in ``pad_mask`` and re-pointed per layer at that
    layer's cached pad id; their gathered rows land in the dropped extra
    segment / clipped tail and are never read, and the ``live`` mask keeps
    them out of the hit accounting either way."""

    lo: int
    cnt: int  # live chunk nodes (== chunk_size except the last chunk)
    n_edges: int  # live in-edges of the range
    base_ids: np.ndarray  # int32[chunk_size + bucket], pads = 0
    pad_mask: np.ndarray  # bool, True at pad positions of base_ids
    seg_ids: jax.Array  # int32[bucket] — edge → local dst, pads → chunk_size
    degrees: jax.Array  # f32[chunk_size] — true in-degrees, pad tail 0
    live: jax.Array  # bool[chunk_size + bucket] — non-pad positions


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """The full chunk schedule, built ONCE and shared by every layer (the
    geometry depends only on the CSC and the chunk size)."""

    chunk_size: int
    chunks: list[ChunkSpec]

    @property
    def num_chunks(self) -> int:
        return len(self.chunks)


def plan_chunks(graph, chunk_size: int) -> ChunkPlan:
    n = graph.num_nodes
    col_ptr = np.asarray(graph.col_ptr)
    row_index = np.asarray(graph.row_index)
    deg = np.diff(col_ptr)
    chunks: list[ChunkSpec] = []
    for lo in range(0, n, chunk_size):
        hi = min(lo + chunk_size, n)
        cnt = hi - lo
        e0, e1 = int(col_ptr[lo]), int(col_ptr[hi])
        n_edges = e1 - e0
        bucket = pow2_bucket(n_edges)
        ids = np.zeros(chunk_size + bucket, np.int32)
        # Self block: the range itself; the tail past ``cnt`` is padding.
        ids[:chunk_size] = np.minimum(np.arange(lo, lo + chunk_size), n - 1)
        ids[chunk_size : chunk_size + n_edges] = row_index[e0:e1]
        pad_mask = np.ones(chunk_size + bucket, bool)
        pad_mask[:cnt] = False
        pad_mask[chunk_size : chunk_size + n_edges] = False
        seg = np.full(bucket, chunk_size, np.int32)  # pads → the dropped segment
        seg[:n_edges] = np.repeat(
            np.arange(cnt, dtype=np.int32), deg[lo:hi].astype(np.int64)
        )
        degrees = np.zeros(chunk_size, np.float32)
        degrees[:cnt] = deg[lo:hi]
        chunks.append(
            ChunkSpec(
                lo=lo,
                cnt=cnt,
                n_edges=n_edges,
                base_ids=ids,
                pad_mask=pad_mask,
                seg_ids=jnp.asarray(seg),
                degrees=jnp.asarray(degrees),
                live=jnp.asarray(~pad_mask),
            )
        )
    return ChunkPlan(chunk_size=chunk_size, chunks=chunks)


@dataclasses.dataclass
class LayerwiseReport:
    """Stage-time / hit-rate report for one layer-wise full-graph run —
    the mode's analogue of :class:`~repro.runtime.gnn_engine.
    InferenceReport`, with the feature accounting split by source (layer-0
    input rows vs intermediate embedding rows)."""

    policy: str
    num_nodes: int
    num_layers: int
    chunk_size: int
    num_chunks: int
    num_edges: int
    gather_seconds: float
    compute_seconds: float
    spill_seconds: float
    fill_seconds: float  # per-layer embedding-cache builds (mid-run)
    prep_seconds: float  # split probe + allocation + layer-0 cache re-fill
    feat_hits: int
    feat_lookups: int
    embed_hits: int
    embed_lookups: int
    feat_row_bytes: int
    embed_row_bytes: int
    pipeline_depth: int = 1
    prefetch_seconds: float = 0.0
    prefetched_rows: int = 0
    allocation: LayerwiseAllocation | None = None
    config: EngineConfig | None = None  # the resolved knobs this run used
    outputs: np.ndarray | None = dataclasses.field(default=None, repr=False)
    # MetricsRegistry.snapshot() at report time (``--metrics``); else None.
    metrics: dict | None = None

    @property
    def total_seconds(self) -> float:
        return (
            self.gather_seconds
            + self.prefetch_seconds
            + self.compute_seconds
            + self.spill_seconds
            + self.fill_seconds
        )

    @property
    def feat_hit_rate(self) -> float:
        return self.feat_hits / max(self.feat_lookups, 1)

    @property
    def embed_hit_rate(self) -> float:
        return self.embed_hits / max(self.embed_lookups, 1)

    def modeled_transfer_seconds(self) -> float:
        """Byte movement projected on the same slow/fast link pair as the
        sampled engine — the machine-independent side of the crossover
        benchmark (benchmarks/bench_layerwise.py).  Every layer moves the
        edge list once (the chunk schedule's adjacency reads are
        sequential host slices — all misses)."""
        return modeled_transfer_seconds(
            feat_lookups=self.feat_lookups,
            feat_hits=self.feat_hits,
            adj_lookups=self.num_layers * self.num_edges,
            adj_hits=0,
            feat_row_bytes=self.feat_row_bytes,
        ) + modeled_transfer_seconds(
            feat_lookups=self.embed_lookups,
            feat_hits=self.embed_hits,
            adj_lookups=0,
            adj_hits=0,
            feat_row_bytes=self.embed_row_bytes,
        )

    def summary(self) -> dict:
        out = {
            "policy": self.policy,
            "mode": "layerwise",
            "nodes": self.num_nodes,
            "layers": self.num_layers,
            "chunk_size": self.chunk_size,
            "chunks": self.num_chunks,
            "pipeline_depth": self.pipeline_depth,
            "gather_s": round(self.gather_seconds, 4),
            "prefetch_s": round(self.prefetch_seconds, 4),
            "compute_s": round(self.compute_seconds, 4),
            "spill_s": round(self.spill_seconds, 4),
            "fill_s": round(self.fill_seconds, 4),
            "total_s": round(self.total_seconds, 4),
            "prep_s": round(self.prep_seconds, 4),
            "feat_hit_rate": round(self.feat_hit_rate, 4),
            "embed_hit_rate": round(self.embed_hit_rate, 4),
            "modeled_transfer_s": round(self.modeled_transfer_seconds(), 6),
        }
        if self.config is not None:
            out["config"] = self.config.to_dict()
        if self.metrics is not None:
            out["metrics"] = self.metrics
        return out


def _probe_gather_seconds(store: FeatureStore, ids: jax.Array, reps: int = 2) -> float:
    """Best-of-``reps`` synchronized gather lap over one chunk's index set —
    the layer-wise analogue of presampling's per-stage laps (Eq. 1 input)."""
    feats, _ = store.gather(ids)  # warm the compile outside the lap
    jax.block_until_ready(feats)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        feats, _ = store.gather(ids)
        jax.block_until_ready(feats)
        best = min(best, time.perf_counter() - t0)
    return best


def _intermediate_width(params) -> int:
    """Widest intermediate layer output — what sizes the embedding cache's
    need bound (the spill tables are [N, dims[k]] for k = 1..L-1)."""
    widths = [p["w_self"].shape[1] for p in params[:-1]]
    return max(widths) if widths else int(params[-1]["w_self"].shape[1])


def run_layerwise(
    dataset: SyntheticGraphDataset,
    pipe: PreparedPipeline,
    params,
    *,
    model: str,
    config: EngineConfig,
    tracer=None,
    metrics=None,
) -> LayerwiseReport:
    """Score EVERY node: L chained chunked layer passes over the node range.

    ``config`` must be resolved (every knob concrete — the engine's
    dispatch does this); ``config.dedup`` is ignored (see module
    docstring).  Outputs match an L-layer full-neighborhood sampled
    forward within fp tolerance (summation order differs:
    ``segment_sum`` vs the sampled reshape-reduce) —
    tests/test_layerwise.py."""
    tracer = resolve_tracer(tracer)
    graph = dataset.graph
    n = graph.num_nodes
    num_layers = len(params)
    chunk_size = min(int(config.chunk_size), n)
    depth = 2 if config.pipeline_depth == "auto" else int(config.pipeline_depth)
    use_kernel = bool(config.use_kernel)
    gather_buffers = int(config.gather_buffers)
    prefetch = bool(config.prefetch)
    row_block = ROW_BLOCK if use_kernel else None

    plan = plan_chunks(graph, chunk_size)
    access_counts = layerwise_access_counts(graph)

    # ---- Eq. 1 split between the layer-0 feature cache and the (transient,
    # one-live-at-a-time) embedding cache, from probed chunk gather laps.
    t_prep = time.perf_counter()
    total_bytes = pipe.caches.allocation.total_bytes if pipe.caches.allocation else 0
    embed_width = _intermediate_width(params)
    embed_row_bytes = embed_width * 4
    alloc = None
    feat_store = pipe.caches.store
    embed_bytes = 0
    if total_bytes > 0 and num_layers > 1:
        probe_ids = jnp.asarray(plan.chunks[0].base_ids)
        t_feat = _probe_gather_seconds(pipe.caches.store, probe_ids)
        ghost = plain_feature_store(np.zeros((n, embed_width), np.float32))
        t_embed = _probe_gather_seconds(ghost, probe_ids)
        alloc = allocate_layerwise_capacity(
            [t_feat],
            [t_embed],
            total_bytes,
            feat_need_bytes=dataset.features.nbytes,
            embed_need_bytes=n * embed_row_bytes,
        )
        embed_bytes = alloc.embed_bytes
        # Delta re-fill the layer-0 cache for the layer-wise access pattern
        # (exact counts) at its new share.  The pipe's own store is NOT
        # mutated — the sampled path keeps its epoch and contents.
        feat_store, _ = refresh_feature_cache(pipe.caches.store, access_counts, alloc.feat_bytes)
    elif total_bytes > 0:  # single layer: no intermediates, whole budget to feats
        feat_store, _ = refresh_feature_cache(pipe.caches.store, access_counts, total_bytes)
    prep_seconds = time.perf_counter() - t_prep

    clock = StageClock(overlap=depth > 1)
    state = {
        "feat_hits": 0,
        "feat_lookups": 0,
        "embed_hits": 0,
        "embed_lookups": 0,
        "prefetched_rows": 0,
        "spill_s": 0.0,
        "fill_s": 0.0,
    }
    out_host: np.ndarray | None = None

    for layer in range(num_layers):
        store = feat_store if layer == 0 else build_store
        relu = layer < num_layers - 1
        out_dim = int(params[layer]["w_self"].shape[1])
        out_host = np.empty((n, out_dim), np.float32)
        pad_id = max(store.pad_node_id(), 0)
        hits_key = "feat_hits" if layer == 0 else "embed_hits"
        lookups_key = "feat_lookups" if layer == 0 else "embed_lookups"

        def gather_fn(ctx, store=store):
            spec, ids = ctx.payload
            staged = ctx.outputs.get("prefetch")
            feats, hit = store.gather(
                jnp.asarray(ids),
                use_kernel=use_kernel,
                gather_buffers=gather_buffers,
                prefetched=staged,
                row_block=row_block,
            )
            return feats, jnp.sum(hit & spec.live)

        def prefetch_fn(ctx, store=store):
            # Pads point at a cached id, so (like the deduped sampled
            # path) they can never stage phantom miss rows; duplicate live
            # misses stage duplicate rows, matching the sampled non-dedup
            # semantics bit for bit.
            _, ids = ctx.payload
            staged = store.prefetch_misses(ids)
            state["prefetched_rows"] += staged.num_miss
            return staged

        def compute_fn(ctx, layer=layer, relu=relu):
            spec, _ = ctx.payload
            feats = ctx.outputs["gather"][0]
            return gnn_models.forward_layer(
                params[layer],
                feats[:chunk_size],
                feats[chunk_size:],
                spec.seg_ids,
                spec.degrees,
                model=model,
                num_dst=chunk_size,
                relu=relu,
            )

        def on_retire(ctx, out_host=out_host, hk=hits_key, lk=lookups_key):
            spec, _ = ctx.payload
            t0 = time.perf_counter()
            h = np.asarray(ctx.outputs["compute"])
            out_host[spec.lo : spec.lo + spec.cnt] = h[: spec.cnt]
            state["spill_s"] += time.perf_counter() - t0
            state[hk] += int(ctx.outputs["gather"][1])
            state[lk] += spec.cnt + spec.n_edges

        executor = PipelinedExecutor(
            [
                Stage("prefetch", prefetch_fn, lambda c: c.outputs["prefetch"])
                if prefetch
                else None,
                Stage("gather", gather_fn, lambda c: c.outputs["gather"]),
                Stage("compute", compute_fn, lambda c: c.outputs["compute"]),
            ],
            depth=depth,
            clock=clock,
            on_retire=on_retire,
            tracer=tracer,
        )
        payloads = []
        for spec in plan.chunks:
            ids = spec.base_ids if pad_id == 0 else np.where(spec.pad_mask, pad_id, spec.base_ids)
            payloads.append((spec, np.asarray(ids, np.int32)))
        # Warm one representative chunk per distinct bucket shape, so the
        # first-of-a-shape compiles land outside the timed laps.
        seen = set()
        for spec, ids in payloads:
            shape = spec.base_ids.shape[0]
            if shape in seen:
                continue
            seen.add(shape)
            feats, _ = store.gather(
                jnp.asarray(ids),
                use_kernel=use_kernel,
                gather_buffers=gather_buffers,
                row_block=row_block,
            )
            jax.block_until_ready(
                gnn_models.forward_layer(
                    params[layer],
                    feats[:chunk_size],
                    feats[chunk_size:],
                    spec.seg_ids,
                    spec.degrees,
                    model=model,
                    num_dst=chunk_size,
                    relu=relu,
                )
            )
        # One enclosing span per layer pass on the "layers" lane; the
        # executor's slot lanes carry the per-chunk batch/stage spans
        # nested under it in time, so a trace shows L layer blocks each
        # filled with its chunk pipeline.
        with tracer.span(
            f"layer {layer}",
            lane="layers",
            args={"layer": layer, "chunks": plan.num_chunks} if tracer.enabled else None,
        ):
            executor.run(payloads)

        if relu:
            # Next layer's input store: the spilled table behind a fresh
            # embedding cache.  Only one is live at a time, so it gets the
            # full per-layer embedding share.
            t0 = time.perf_counter()
            with tracer.span("embed-fill", lane="layers", args={"layer": layer}):
                build_store = build_embedding_cache(out_host, access_counts, embed_bytes)
            state["fill_s"] += time.perf_counter() - t0

    report = LayerwiseReport(
        policy=pipe.name,
        num_nodes=n,
        num_layers=num_layers,
        chunk_size=chunk_size,
        num_chunks=plan.num_chunks,
        num_edges=graph.num_edges,
        gather_seconds=clock.total("gather"),
        compute_seconds=clock.total("compute"),
        spill_seconds=state["spill_s"],
        fill_seconds=state["fill_s"],
        prep_seconds=prep_seconds,
        feat_hits=state["feat_hits"],
        feat_lookups=state["feat_lookups"],
        embed_hits=state["embed_hits"],
        embed_lookups=state["embed_lookups"],
        feat_row_bytes=dataset.feature_nbytes_per_row(),
        embed_row_bytes=embed_row_bytes,
        pipeline_depth=depth,
        prefetch_seconds=clock.total("prefetch"),
        prefetched_rows=state["prefetched_rows"],
        allocation=alloc,
        config=config,
        outputs=out_host,
    )
    if metrics is not None:
        metrics.counter("chunks_total", mode="layerwise").inc(num_layers * plan.num_chunks)
        metrics.gauge("feat_hit_rate", mode="layerwise").set(report.feat_hit_rate)
        metrics.gauge("embed_hit_rate", mode="layerwise").set(report.embed_hit_rate)
        for name in ("gather", "prefetch", "compute"):
            metrics.gauge("stage_seconds", mode="layerwise", stage=name).set(clock.total(name))
        report.metrics = metrics.snapshot()
    return report
