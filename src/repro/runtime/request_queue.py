"""Request-level SLO serving front-end over the multi-stream server.

:class:`~repro.runtime.gnn_serve.MultiStreamServer` serves *queues*: every
batch is eligible the moment serving starts, so its latency numbers
measure pipeline residency, not service.  Real GNN inference serving is
request-driven — work arrives on a clock (steady Poisson traffic, bursts,
flash crowds), often with a deadline attached, and the serving system is
judged on enqueue→retire tail latency against that clock.  This module
adds exactly that layer, changing NOTHING below it:

  * a :class:`Request` carries its seed batch plus arrival time, optional
    deadline, and lifecycle stamps (admitted/retired/shed);
  * trace builders (:func:`poisson_trace`, :func:`burst_trace`,
    :func:`flash_crowd_trace`) generate per-stream request timelines from
    the same seed-content generators the drift benchmark uses, so a
    "flash crowd" means the same thing in both;
  * :class:`RequestQueueServer` subclasses the multi-stream server and
    replaces only *admission*: a pluggable policy
    (:data:`~repro.core.policies.ADMISSION_POLICIES` — round-robin, EDF,
    SLO-aware shedding) ranks the streams whose HEAD request has arrived,
    while the executor schedule, per-stream runtimes, caps, and cursor
    mechanics are inherited unchanged.  With ``admission="round-robin"``
    and all arrivals at 0 the admission log — and therefore every output,
    RNG draw, and hit counter — is bit-for-bit the base server's
    (tests/test_request_queue.py).

Arrival-clock semantics: time 0 is the start of the serve loop
(``_serve_t0``); a request whose ``arrival_s`` is in the future is
invisible to admission.  While waiting for arrivals the generator yields
the executor's :data:`~repro.runtime.pipeline.DRAIN` sentinel (retire
admitted work rather than idle with a full window) and only ``sleep``\\ s
once nothing is in flight — keeping enqueue→retire accounting honest.
Per-request latency is ``retired_s - arrival_s`` (queueing included),
which is what the p50/p95/p99 columns in ``StreamReport``/``ServeReport``
report under this front-end.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.policies import ADMISSION_POLICIES, AdmissionPolicy
from repro.core.retry import StageTimeout
from repro.runtime.gnn_serve import MultiStreamServer, ServeReport, StreamReport, StreamState
from repro.runtime.pipeline import DRAIN

__all__ = [
    "Request",
    "RequestQueueServer",
    "burst_trace",
    "flash_crowd_seed_batches",
    "flash_crowd_trace",
    "poisson_trace",
    "uniform_seed_batches",
]


@dataclasses.dataclass
class Request:
    """One inference request: a seed batch on an arrival clock.

    ``arrival_s``/``deadline_s`` are seconds on the serve clock (0 = serve
    start).  ``admitted_s``/``retired_s`` are stamped by the server;
    ``shed`` marks a request the SLO policy dropped (it never ran) OR one
    the fault-shedding policy dropped after its retries exhausted (it ran
    and failed — ``timed_out`` says whether a stage timeout killed it),
    ``deferred`` one whose blown deadline was demoted to best-effort (it
    still runs, after everything that can still meet a deadline).
    ``degraded`` marks a request answered from cache only (miss path
    down — hit rows real, miss rows zero); ``retries`` counts the backoff
    retries its batch needed."""

    request_id: int
    stream_id: int
    seeds: np.ndarray
    arrival_s: float = 0.0
    deadline_s: float | None = None
    admitted_s: float | None = None
    retired_s: float | None = None
    shed: bool = False
    deferred: bool = False
    timed_out: bool = False
    degraded: bool = False
    retries: int = 0

    @property
    def latency_s(self) -> float | None:
        """Enqueue→retire latency; None until retired (or if shed)."""
        if self.retired_s is None:
            return None
        return max(self.retired_s - self.arrival_s, 0.0)

    @property
    def deadline_met(self) -> bool | None:
        """None when no deadline; shed / never-retired counts as a miss."""
        if self.deadline_s is None:
            return None
        if self.shed or self.retired_s is None:
            return False
        return self.retired_s <= self.deadline_s

    @property
    def admission_deadline_s(self) -> float | None:
        """The deadline as admission policies should see it: a deferred
        (blown, demoted) request sorts as deadline-free."""
        return None if self.deferred else self.deadline_s


# ------------------------------------------------------------ seed content
def uniform_seed_batches(dataset, *, n_batches: int, batch_size: int, seed: int = 0):
    """Batches drawn uniformly over the test set — one stream's worth of
    :func:`~repro.runtime.gnn_serve.make_stream_batches` content (same rng
    discipline, so request traces and queue serves are content-comparable)."""
    rng = np.random.default_rng(seed)
    ids = rng.permutation(dataset.test_idx)
    need = n_batches * batch_size
    if len(ids) < need:  # tiny datasets: cycle to fill
        ids = np.tile(ids, -(-need // max(len(ids), 1)))
    return list(ids[:need].reshape(n_batches, batch_size))


def flash_crowd_seed_batches(dataset, *, n_batches: int, batch_size: int, seed: int = 0):
    """Every batch a fresh permutation of ONE small fixed seed pool — the
    concentrated hot set of benchmarks/bench_drift.py's phase B (shared so
    "flash crowd" is the same workload there and here)."""
    rng = np.random.default_rng(seed)
    pool_size = min(batch_size, len(dataset.test_idx))
    pool = rng.choice(dataset.test_idx, size=pool_size, replace=False)
    if pool_size < batch_size:  # tiny test sets: cycle the pool to fill
        pool = np.tile(pool, -(-batch_size // pool_size))[:batch_size]
    return [rng.permutation(pool) for _ in range(n_batches)]


# ------------------------------------------------------------ trace builders
def _with_deadline(arrival: float, slo_s: float | None) -> float | None:
    return None if slo_s is None else float(arrival) + float(slo_s)


def poisson_trace(
    dataset,
    *,
    num_streams: int,
    requests_per_stream: int,
    batch_size: int,
    mean_interarrival_s: float,
    slo_s: float | None = None,
    seed: int = 0,
) -> list[list[Request]]:
    """Steady traffic: each stream's inter-arrival gaps are exponential
    with the given mean (a Poisson process per stream), content uniform
    over the test set.  ``slo_s`` attaches a relative deadline to every
    request."""
    out: list[list[Request]] = []
    for sid in range(num_streams):
        batches = uniform_seed_batches(
            dataset, n_batches=requests_per_stream, batch_size=batch_size, seed=seed + sid
        )
        rng = np.random.default_rng([seed, sid, 1])  # distinct from the content rng
        arrivals = np.cumsum(rng.exponential(mean_interarrival_s, size=requests_per_stream))
        out.append(
            [
                Request(
                    request_id=i,
                    stream_id=sid,
                    seeds=b,
                    arrival_s=float(t),
                    deadline_s=_with_deadline(t, slo_s),
                )
                for i, (b, t) in enumerate(zip(batches, arrivals))
            ]
        )
    return out


def burst_trace(
    dataset,
    *,
    burst_requests: int,
    steady_requests: int,
    batch_size: int,
    service_estimate_s: float,
    slo_s: float | None = None,
    seed: int = 0,
) -> list[list[Request]]:
    """A flash-crowd burst colliding with a steady stream — the workload
    where admission order moves the p99.

    Stream 0 (the burst) dumps ``burst_requests`` flash-crowd batches at
    t=0; stream 1 (steady) spaces uniform-content requests one service
    time apart, so it alone would run at ~100% utilization with ~zero
    queueing.  Round-robin interleaves the two, roughly doubling the
    burst's drain time (tail ≈ 2·B·service); EDF with a uniform SLO
    drains the burst's backlog first — its deadlines are earliest — for a
    tail ≈ B·service, the ~2x p99 gap bench_multistream's tail gate
    measures."""
    burst_batches = flash_crowd_seed_batches(
        dataset, n_batches=burst_requests, batch_size=batch_size, seed=seed
    )
    burst = [
        Request(
            request_id=i,
            stream_id=0,
            seeds=b,
            arrival_s=0.0,
            deadline_s=_with_deadline(0.0, slo_s),
        )
        for i, b in enumerate(burst_batches)
    ]
    steady_batches = uniform_seed_batches(
        dataset, n_batches=steady_requests, batch_size=batch_size, seed=seed + 1
    )
    steady = [
        Request(
            request_id=i,
            stream_id=1,
            seeds=b,
            arrival_s=i * service_estimate_s,
            deadline_s=_with_deadline(i * service_estimate_s, slo_s),
        )
        for i, b in enumerate(steady_batches)
    ]
    return [burst, steady]


def flash_crowd_trace(
    dataset,
    *,
    num_streams: int,
    requests_per_stream: int,
    batch_size: int,
    slo_s: float | None = None,
    seed: int = 0,
) -> list[list[Request]]:
    """Every stream dumps its whole (flash-crowd content) queue at t=0 —
    the all-at-once saturation case; with an SLO attached, most of the
    backlog is shed-able, which is what exercises the shed/defer paths."""
    out: list[list[Request]] = []
    for sid in range(num_streams):
        batches = flash_crowd_seed_batches(
            dataset, n_batches=requests_per_stream, batch_size=batch_size, seed=seed + sid
        )
        out.append(
            [
                Request(
                    request_id=i,
                    stream_id=sid,
                    seeds=b,
                    arrival_s=0.0,
                    deadline_s=_with_deadline(0.0, slo_s),
                )
                for i, b in enumerate(batches)
            ]
        )
    return out


# ---------------------------------------------------------------- the server
class RequestQueueServer(MultiStreamServer):
    """Serve request traces (arrival times + deadlines) instead of queues.

    Streams are registered with :meth:`add_request_stream`; each keeps its
    requests in a per-stream arrival-ordered deque (``state.requests``)
    while the base class's ``state.queue`` stays empty — every inherited
    mechanism that counts *admitted* work (in-flight caps, clocks,
    runtimes, telemetry, refresh) is reused as is.  ``admission`` picks
    the policy: ``"round-robin"`` (the bit-for-bit baseline), ``"edf"``,
    ``"slo"`` (EDF + shed), a policy class, or an instance.
    """

    def __init__(self, engine, *, admission=None, **kw):
        super().__init__(engine, **kw)
        if admission is None:
            # ``admission`` stays a live keyword (it accepts policy classes
            # and instances, which ServeConfig's string field cannot carry);
            # when omitted it resolves from the coalesced ServeConfig.
            admission = self.config.admission
        if isinstance(admission, str):
            try:
                admission = ADMISSION_POLICIES[admission]
            except KeyError:
                raise ValueError(
                    f"unknown admission policy {admission!r}; "
                    f"known: {sorted(ADMISSION_POLICIES)}"
                ) from None
        if isinstance(admission, type):
            admission = admission()
        if not isinstance(admission, AdmissionPolicy):
            raise TypeError(f"admission must be an AdmissionPolicy, got {type(admission)!r}")
        self.policy = admission
        self.total_shed = 0

    # ------------------------------------------------------------- intake
    def add_request_stream(
        self,
        requests: Sequence[Request],
        *,
        seed: int | None = None,
        collect_outputs: bool = False,
    ) -> StreamState:
        """Register one stream's request trace (sorted by arrival)."""
        state = super().add_stream([], seed=seed, collect_outputs=collect_outputs)
        state.requests = collections.deque(sorted(requests, key=lambda r: r.arrival_s))
        state.completed = []
        state.shed_requests = []
        state._inflight_reqs = {}
        return state

    def remove_stream(self, stream_id: int) -> StreamState:
        state = self.streams[stream_id]
        if hasattr(state, "requests"):
            state.requests.clear()
        return super().remove_stream(stream_id)

    # -------------------------------------------------------------- clock
    def _now(self) -> float:
        """Seconds on the serve clock (0 until the loop starts)."""
        if self._serve_t0 is None:
            return 0.0
        return time.perf_counter() - self._serve_t0

    def _inflight_total(self) -> int:
        return sum(s.inflight for s in self.streams)

    def _warmup_seeds(self):
        heads = [s.requests[0] for s in self.streams if getattr(s, "requests", None)]
        if not heads:
            return None
        return min(heads, key=lambda r: (r.arrival_s, r.stream_id)).seeds

    # ---------------------------------------------------------- admission
    def _shed_blown(self, pending, now):
        """Drop (or demote) every ARRIVED request whose deadline already
        passed; future requests are untouched — their deadlines are judged
        when they arrive.  Returns the streams that still have requests."""
        still = []
        for s in pending:
            keep = collections.deque()
            for req in s.requests:
                blown = (
                    req.deadline_s is not None
                    and not req.deferred
                    and req.arrival_s <= now
                    and req.deadline_s < now
                )
                if blown and self.policy.blown == "shed":
                    req.shed = True
                    s.shed_requests.append(req)
                    self.total_shed += 1
                    if self.tracer.enabled:
                        self.tracer.instant(
                            "shed",
                            lane=f"req:s{s.stream_id}",
                            args={"request": req.request_id, "deadline_s": req.deadline_s},
                        )
                        self.tracer.counter("shed", {"total": float(self.total_shed)})
                    continue
                if blown:
                    req.deferred = True  # keeps its slot, sorts deadline-free
                keep.append(req)
            s.requests = keep
            if s.requests:
                still.append(s)
        return still

    def _select(self, arrived, now) -> StreamState:
        """Policy-ranked choice over streams whose head request arrived.

        ``order() -> None`` (round-robin) delegates to the inherited
        cursor; otherwise the first ranked stream under its in-flight cap
        wins, falling back to the most urgent one when all are saturated
        (admission must make progress — the cap bounds relative occupancy,
        mirroring the base class)."""
        ranked = self.policy.order([(s.stream_id, s.requests[0]) for s in arrived], now)
        if ranked is None:
            return self._next_stream(arrived)
        by_id = {s.stream_id: s for s in arrived}
        for key, _req in ranked:
            s = by_id[key]
            if s.inflight < self.max_inflight:
                return s
        return by_id[ranked[0][0]]

    def _admission(self):
        """Arrival-aware lazy admission for the executor.

        Each pull: shed blown work (SLO policies), then admit the policy's
        pick among streams whose head has arrived.  No arrivals yet →
        DRAIN the window if anything is in flight (so retires — and their
        latency stamps — happen at the time work finishes, not at the next
        admission), else sleep the gap to the next arrival."""
        while True:
            pending = [s for s in self.streams if getattr(s, "requests", None)]
            if not pending:
                return
            now = self._now()
            if self.policy.sheds:
                pending = self._shed_blown(pending, now)
                if not pending:
                    continue
            arrived = [s for s in pending if s.requests[0].arrival_s <= now]
            if not arrived:
                if self._inflight_total():
                    yield DRAIN
                    continue
                gap = min(s.requests[0].arrival_s for s in pending) - self._now()
                if gap > 0:
                    time.sleep(gap)
                continue
            s = self._select(arrived, now)
            req = s.requests.popleft()
            req.admitted_s = self._now()
            self.admission_log.append((s.stream_id, s.submitted))
            s._admit_times[s.submitted] = time.perf_counter()
            s._inflight_reqs[s.submitted] = req
            s.submitted += 1
            s.inflight += 1
            s.max_inflight_seen = max(s.max_inflight_seen, s.inflight)
            if self.tracer.enabled:
                self._trace_admit(s, batch=s.submitted - 1)
            yield (s, req.seeds)

    def _enqueue_ts_us(self, s: StreamState, batch: int) -> float:
        """Requests enqueue when they *arrive*, so the ``queued`` trace
        span starts on the request's arrival clock — its full duration is
        the queueing wait the enqueue→retire latency columns report."""
        req = s._inflight_reqs.get(batch)
        if req is None or self._serve_t0 is None:
            return super()._enqueue_ts_us(s, batch)
        return self.tracer.ts_from(self._serve_t0 + req.arrival_s)

    # ------------------------------------------------------------- retire
    def _on_retire(self, ctx) -> None:
        s: StreamState = ctx.stream
        req: Request = s._inflight_reqs.pop(s.retired)  # retiring batch's index
        super()._on_retire(ctx)
        req.retired_s = self._now()
        req.retries = int(ctx.outputs.get("_retried", 0))
        req.degraded = bool(ctx.outputs.get("_degraded", False))
        # The base class booked admit→retire; requests are judged on
        # enqueue→retire (queueing wait included).
        s.latencies[-1] = max(req.retired_s - req.arrival_s, 0.0)
        s.completed.append(req)

    def _shed_inflight(self, s: StreamState, idx: int, root: BaseException) -> None:
        """Fault-shedding under the request front-end: the dying batch is
        carrying exactly one request — pop it off the in-flight map (so
        retire-side bookkeeping can never also complete it: shed XOR
        completed, counted exactly once) and mark why it died."""
        req = s._inflight_reqs.pop(idx, None)
        if req is not None:
            req.shed = True
            req.timed_out = isinstance(root, StageTimeout)
            s.shed_requests.append(req)
            self.total_shed += 1
        super()._shed_inflight(s, idx, root)

    # ----------------------------------------------------------- reporting
    def _stream_weight(self, key) -> float:
        """Queue-depth pressure plus SLO pressure: requests that have
        arrived and will (at the stream's median latency) finish at or
        past their deadline each add 1."""
        s = self.streams[key]
        reqs = getattr(s, "requests", ())
        base = 1.0 + len(reqs) + s.inflight
        now = self._now()
        est = float(np.median(s.latencies)) if s.latencies else 0.0
        pressure = sum(
            1
            for r in reqs
            if r.deadline_s is not None and r.arrival_s <= now and r.deadline_s <= now + est
        )
        return base + pressure

    def _stream_report(self, s: StreamState) -> StreamReport:
        rep = super()._stream_report(s)
        completed = getattr(s, "completed", [])
        shed = getattr(s, "shed_requests", [])
        # Timed-out requests are excluded from the SLO denominator: a
        # stage timeout is an infrastructure failure, reported on its own
        # axis (``requests_timed_out``), not a scheduling miss — folding
        # it into deadline_hit_rate would double-charge one event to two
        # rates.  Counted exactly once either way: shed XOR completed.
        with_deadline = [
            r for r in (*completed, *shed) if r.deadline_s is not None and not r.timed_out
        ]
        rep.requests_shed = len(shed)
        rep.requests_timed_out = sum(1 for r in (*completed, *shed) if r.timed_out)
        rep.requests_retried = sum(1 for r in completed if r.retries)
        rep.requests_degraded = sum(1 for r in completed if r.degraded)
        rep.deadline_total = len(with_deadline)
        rep.deadline_hits = sum(1 for r in with_deadline if r.deadline_met)
        return rep

    def _unserved(self) -> int:
        return sum(len(getattr(s, "requests", ())) for s in self.streams)

    def _resolved_config(self):
        # Echo the policy actually installed (a class/instance passed via
        # the ``admission`` keyword may differ from the config string).
        return super()._resolved_config().replace(admission=self.policy.name)

    def _serve_report(self, wall: float) -> ServeReport:
        rep = super()._serve_report(wall)
        rep.admission = self.policy.name
        rep.requests_shed = sum(s.requests_shed for s in rep.streams)
        rep.deadline_hits = sum(s.deadline_hits for s in rep.streams)
        rep.deadline_total = sum(s.deadline_total for s in rep.streams)
        return rep
