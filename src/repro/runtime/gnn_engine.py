"""End-to-end sampled GNN inference engine (the system Fig. 5 describes).

Pipeline per mini-batch: sample blocks (adjacency cache aware) → gather
input-frontier features (feature cache aware; RAIN reuses the previous
batch instead) → run the GNN.  The engine times each stage exactly the way
the paper decomposes Fig. 1/7, counts cache hits, and also reports a
*modeled* transfer time using bandwidth constants so the CPU-only container
can be projected onto the paper's PCIe/GPU (or a TPU host-HBM) topology.

Batch execution is delegated to the staged executor in
:mod:`repro.runtime.pipeline`, controlled by the ``pipeline_depth`` knob:
``depth=1`` is the paper's serial loop (a device sync after every stage —
the timing semantics of Fig. 1/7), ``depth>1`` keeps that many batches in
flight so batch *i+1*'s sampling/gather overlap batch *i*'s GNN forward.
Four further execution knobs — ``prefetch`` (stage batch *i+1*'s missed
host feature rows onto the device during batch *i*'s forward),
``use_kernel`` (route gathers through the double-buffered Pallas
``cached_gather`` kernel), ``gather_buffers`` (the kernel's VMEM slot
count), and ``dedup`` (sort-and-unique each input frontier on device and
gather/prefetch/model one row per DISTINCT node, expanding through the
inverse map) — default from the prepared pipeline.  Outputs, hit counts,
and batch order are identical under every knob combination; only where
the bytes move (and therefore wall clock) changes.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import EngineConfig, coalesce
from repro.core.faults import InjectedFault
from repro.core.policies import PreparedPipeline, prepare
from repro.core.retry import RetryExhausted, StageTimeout, call_with_retry
from repro.core.trace import resolve_tracer
from repro.graph.datasets import SyntheticGraphDataset
from repro.graph.sampling import pow2_bucket, sample_blocks
from repro.kernels.cached_gather.kernel import ROW_BLOCK
from repro.models import gnn as gnn_models
from repro.runtime.pipeline import PipelinedExecutor, Stage
from repro.utils.timing import StageClock

__all__ = [
    "GNNInferenceEngine",
    "InferenceReport",
    "StreamRuntime",
    "auto_pipeline_depth",
    "stream_stages",
    "summarize_epoch_counters",
]

# Link speeds for the modeled-transfer projection (bytes/s).
PCIE4_BW = 25e9  # paper's RTX 4090 host link (the UVA miss path)
HBM_BW = 819e9  # TPU v5e HBM (the cache-hit path)

ADJ_ENTRY_BYTES = 4  # one int32 neighbor id per adjacency lookup


def modeled_transfer_seconds(
    *,
    feat_lookups: int,
    feat_hits: int,
    adj_lookups: int,
    adj_hits: int,
    feat_row_bytes: int,
    slow_bw: float = PCIE4_BW,
    fast_bw: float = HBM_BW,
) -> float:
    """Project byte movement onto a slow (miss) / fast (hit) link pair.

    The one transfer model shared by the per-engine
    :class:`InferenceReport` and the aggregate multi-stream
    :class:`~repro.runtime.gnn_serve.ServeReport`."""
    miss_bytes = (feat_lookups - feat_hits) * feat_row_bytes + (
        adj_lookups - adj_hits
    ) * ADJ_ENTRY_BYTES
    hit_bytes = feat_hits * feat_row_bytes + adj_hits * ADJ_ENTRY_BYTES
    return miss_bytes / slow_bw + hit_bytes / fast_bw


@dataclasses.dataclass
class InferenceReport:
    policy: str
    num_batches: int
    sample_seconds: float
    feature_seconds: float
    compute_seconds: float
    prep_seconds: float
    adj_hits: int
    adj_lookups: int
    feat_hits: int
    feat_lookups: int
    feat_row_bytes: int
    pipeline_depth: int = 1
    prefetch: bool = False
    prefetch_seconds: float = 0.0
    prefetched_rows: int = 0
    # Unique-frontier accounting: ``unique_rows`` sums each batch's
    # distinct input nodes, ``gathered_rows`` the rows the feature stage
    # actually pulled (the pow2 gather buckets under dedup, every
    # duplicate otherwise).  feat_lookups stays the per-visit count, so
    # hit rates are dedup-invariant.
    dedup: bool = False
    unique_rows: int = 0
    gathered_rows: int = 0
    # Online-refresh accounting (empty/None when refresh is off, keeping
    # the report — and every baseline comparison over it — unchanged):
    refresh_events: list = dataclasses.field(default_factory=list)
    epoch_hits: dict | None = None  # epoch -> per-epoch hit-rate summary
    # The RESOLVED config the run actually executed with (every knob
    # concrete, server-level overrides applied) — the single source the
    # knob echo comes from, so it can never drift from execution.
    config: EngineConfig | None = None
    # MetricsRegistry.snapshot() at report time when the run was given a
    # registry (``--metrics``); None otherwise.
    metrics: dict | None = None

    @property
    def total_seconds(self) -> float:
        # With pipeline_depth > 1 the stage seconds are dispatch times plus
        # each stage's retire-boundary drain, so the sum still tracks the
        # loop's wall clock — overlapped waiting is simply no longer
        # double-counted across stages.  The prefetch stage (off by
        # default) books the host→device staging of missed rows.
        return (
            self.sample_seconds
            + self.prefetch_seconds
            + self.feature_seconds
            + self.compute_seconds
        )

    @property
    def adj_hit_rate(self) -> float:
        return self.adj_hits / max(self.adj_lookups, 1)

    @property
    def feat_hit_rate(self) -> float:
        return self.feat_hits / max(self.feat_lookups, 1)

    @property
    def duplication_factor(self) -> float:
        """Mean input-frontier duplication: per-visit lookups over distinct
        rows — the redundancy the dedup path removes (1.0 when off)."""
        if not self.unique_rows:
            return 1.0
        return self.feat_lookups / self.unique_rows

    def modeled_transfer_seconds(self, slow_bw: float = PCIE4_BW, fast_bw: float = HBM_BW) -> float:
        """Project byte movement onto a slow (miss) / fast (hit) link pair."""
        return modeled_transfer_seconds(
            feat_lookups=self.feat_lookups,
            feat_hits=self.feat_hits,
            adj_lookups=self.adj_lookups,
            adj_hits=self.adj_hits,
            feat_row_bytes=self.feat_row_bytes,
            slow_bw=slow_bw,
            fast_bw=fast_bw,
        )

    def to_dict(self) -> dict:
        """The report as one JSON-safe dict: the summary metrics plus the
        resolved :class:`~repro.core.config.EngineConfig` echo.  Knobs are
        read off ``config`` when present — NOT re-listed by hand — so a
        server-level override (e.g. a per-stream depth) can never drift
        from what actually executed."""
        return self.summary()

    def summary(self) -> dict:
        out = {
            "policy": self.policy,
            "batches": self.num_batches,
            "pipeline_depth": (
                self.config.pipeline_depth if self.config is not None else self.pipeline_depth
            ),
            "prefetch": self.config.prefetch if self.config is not None else self.prefetch,
            "dedup": self.config.dedup if self.config is not None else self.dedup,
            "sample_s": round(self.sample_seconds, 4),
            "prefetch_s": round(self.prefetch_seconds, 4),
            "feature_s": round(self.feature_seconds, 4),
            "compute_s": round(self.compute_seconds, 4),
            "total_s": round(self.total_seconds, 4),
            "prep_s": round(self.prep_seconds, 4),
            "adj_hit_rate": round(self.adj_hit_rate, 4),
            "feat_hit_rate": round(self.feat_hit_rate, 4),
            "modeled_transfer_s": round(self.modeled_transfer_seconds(), 6),
        }
        if self.config is not None:
            out["config"] = self.config.to_dict()
        if self.dedup:
            out["unique_rows"] = self.unique_rows
            out["gathered_rows"] = self.gathered_rows
            out["duplication_factor"] = round(self.duplication_factor, 2)
        if self.refresh_events:
            # Per-epoch rates replace the single lifetime aggregate as the
            # headline when the cache changed mid-run — a lifetime mean
            # hides exactly the recovery a refresh exists to produce.
            out["refresh_events"] = [e.summary() for e in self.refresh_events]
            out["per_epoch"] = self.epoch_hits
        if self.metrics is not None:
            out["metrics"] = self.metrics
        return out


class StreamRuntime:
    """Cross-batch state and stage logic for ONE stream of mini-batches.

    Owns the stream's RNG key sequence, RAIN's previous-batch reuse state,
    the hit counters, and (optionally) the collected logits.  The engine
    runs exactly one ``StreamRuntime``; the multi-stream server
    (:mod:`repro.runtime.gnn_serve`) runs one per request stream against a
    single shared :class:`~repro.core.cache.DualCache` — the stage methods
    only *read* the caches (they are immutable at serve time), so batches
    from different streams interleave freely while each stream's RNG
    sequence, reuse ordering, and hit accounting stay bit-identical to a
    solo run (tested in tests/test_gnn_serve.py).

    Stage methods are invoked in per-stream batch order at any pipeline
    depth (the executor dispatches in admission order), which is what the
    mutable ``key`` / ``prev_*`` state relies on.
    """

    def __init__(
        self,
        pipe: PreparedPipeline,
        params,
        *,
        model: str,
        fanouts: tuple[int, ...],
        num_nodes: int,
        key,
        collect_outputs: bool = False,
        prefetch: bool | None = None,
        use_kernel: bool | None = None,
        gather_buffers: int | None = None,
        dedup: bool | None = None,
        injector=None,
        retry_policy=None,
        degraded_mode: bool = False,
    ):
        self.pipe = pipe
        self.params = params
        self.model = model
        self.fanouts = tuple(fanouts)
        self.key = key
        # Execution knobs default from the prepared pipeline so every
        # consumer (engine, presampler, serving layer) resolves them the
        # same way; explicit arguments override per run.
        self.prefetch = pipe.prefetch if prefetch is None else prefetch
        self.use_kernel = pipe.use_kernel if use_kernel is None else use_kernel
        self.gather_buffers = pipe.gather_buffers if gather_buffers is None else gather_buffers
        # RAIN's cross-batch reuse map addresses individual frontier
        # positions of the previous batch, which is exactly the layout
        # dedup collapses — and RAIN already removes the cross-batch share
        # of the redundancy dedup targets — so the two are mutually
        # exclusive and reuse wins.
        self.dedup = (pipe.dedup if dedup is None else dedup) and not pipe.reuse_prev_batch
        # Fault-tolerance wiring (core/faults.py, core/retry.py): with the
        # injector absent and no retry policy, every guard below is a single
        # ``is not None`` test — the stage bytecode, RNG draws, and all
        # accounting are bit-identical to a build without this subsystem.
        self.injector = injector
        self.retry_policy = retry_policy
        self.degraded_mode = degraded_mode
        self.stage_retries = 0  # backoff retries across all sites
        self.degraded_batches = 0  # batches served cache-only (miss path down)
        self.kernel_fallbacks = 0  # kernel_gather faults rerouted to the table path
        self._retry_seq = 0  # per-stream retry-key sequence (deterministic jitter)
        self.adj_hits = 0
        self.adj_lookups = 0
        self.feat_hits = 0
        self.feat_lookups = 0
        self.prefetched_rows = 0
        self.unique_rows = 0  # sum of per-batch distinct input nodes (dedup)
        self.gathered_rows = 0  # rows the feature stage actually gathered
        # Per-cache-epoch hit counters: epoch -> [adj_hits, adj_lookups,
        # feat_hits, feat_lookups, batches].  With refresh off everything
        # lands in epoch 0 and the lifetime counters above tell the whole
        # story; with refresh on the split is what the drift benchmark and
        # serve reports surface.
        self.epoch_counters: dict[int, list[int]] = {}
        # Serve-time telemetry sink (set by the refresh manager); None in
        # the default path, which then records nothing at retire.
        self.telemetry = None
        # Observability handle (core/trace.py), installed by the owning
        # engine/server; the no-op default keeps stage methods free.
        self.tracer = resolve_tracer(None)
        self.outputs: list[np.ndarray] | None = [] if collect_outputs else None
        # RAIN cross-batch reuse state (only touched when the policy asks).
        self._prev_map = np.full(num_nodes, -1, np.int64)
        self._prev_feats = None
        self._prev_nodes = None

    # ---------------------------------------------------- fault tolerance
    def _with_retry(self, ctx, site: str, fn):
        """Run ``fn`` under the stream's retry policy, charging backoff
        retries to ``site``.  Only *injected* faults and per-stage timeouts
        are retryable — real bugs propagate on the first attempt.  The
        jitter key is ``(site, seq)`` with a per-stream sequence counter, so
        the delay schedule is a pure function of the policy seed and the
        order faults land, never of wall-clock."""
        if self.retry_policy is None:
            return fn()
        self._retry_seq += 1
        seq = self._retry_seq

        def _on_retry(attempt, delay, err):
            self.stage_retries += 1
            ctx.outputs["_retried"] = ctx.outputs.get("_retried", 0) + 1
            if self.tracer.enabled:
                self.tracer.complete(
                    "retry",
                    lane="faults",
                    ts_us=self.tracer.now_us(),
                    dur_us=delay * 1e6,
                    args={"site": site, "attempt": attempt},
                )

        return call_with_retry(
            fn,
            policy=self.retry_policy,
            key=(site, seq),
            retryable=(InjectedFault, StageTimeout),
            on_retry=_on_retry,
        )

    def _mark_degraded(self, ctx) -> None:
        """Flag the batch as served degraded (cache-only hit rows, zero
        miss rows) so retire-time accounting and the serve report surface
        it per request."""
        self.degraded_batches += 1
        ctx.outputs["_degraded"] = True
        if self.tracer.enabled:
            self.tracer.complete(
                "degraded",
                lane="faults",
                ts_us=self.tracer.now_us(),
                dur_us=0.0,
                args={"site": "host_fetch"},
            )

    # ------------------------------------------------------------- stages
    def sample(self, ctx):
        # Stamp the cache epoch the batch dispatches against — retire-time
        # accounting attributes its hits to this epoch even if a refresh
        # lands while the batch is still in flight.
        ctx.epoch = self.pipe.caches.epoch
        if self.injector is not None and self.injector.active("adj_fetch"):
            # Charge BEFORE the RNG key split so a retried attempt replays
            # the exact same batch — the fault site is idempotent.  There
            # is no degraded fallback for adjacency: without the graph
            # there is nothing to sample, so exhausted retries propagate.
            self._with_retry(ctx, "adj_fetch", lambda: self.injector.check("adj_fetch"))
        self.key, sub = jax.random.split(self.key)
        block = sample_blocks(
            sub,
            self._sample_graph(),
            jnp.asarray(ctx.payload),
            self.fanouts,
            dedup=self.dedup,
            # Pad the unique bucket's tail with a known-cached id (traced
            # operand — a refresh-epoch pad change recompiles nothing), so
            # pad slots are feature-cache hits, never phantom miss rows.
            dedup_pad_id=self.pipe.caches.store.pad_node_id() if self.dedup else None,
        )
        # Dispatch the hit-stat reductions here, in-pipeline: dispatched
        # at retire time they would queue behind the *next* batch's
        # stages on the device stream and serialize the pipeline.
        bh, bt = block.adj_hit_stats()
        if self.dedup:
            # Resolve the unique view HERE so the one forced sync the
            # dedup path needs (pulling the num_unique scalar — the
            # analogue of the prefetch stage's miss-index read) is booked
            # to the sampling stage that produced it; the downstream
            # stages then only dispatch against the already-sliced bucket.
            self._resolve_dedup(ctx, block)
        return block, bh, bt

    def _resolve_dedup(self, ctx, block):
        """Cache the batch's unique-frontier view on its context:
        ``(dedup, num_unique, bucket, unique_ids[:bucket])``.

        The bucket is each batch's own pow2 ceiling, so ``gathered_rows <=
        2 * unique_rows`` holds per batch (the bound the dedup gate and
        docs state) and batches with the same bucket share compiled
        gather/forward programs — O(log S) distinct shapes worst case,
        each compiled once on first use."""
        dd = block.dedup
        nu = int(dd.num_unique)
        bucket = pow2_bucket(nu, int(dd.unique_ids.shape[0]))
        view = (dd, nu, bucket, dd.unique_ids[:bucket])
        ctx.outputs["_dedup"] = view
        return view

    def _dedup_view(self, ctx):
        return ctx.outputs["_dedup"]

    # ------------------------------------------------- cache-access hooks
    # The sharded serving layer (runtime/sharded_serve.py) overrides these
    # three — and ONLY these — so every stage's control flow, RNG use, and
    # accounting stays byte-identical across layouts.
    def _sample_graph(self):
        """The DeviceGraph the sample stage expands against (per-shard
        adjacency replica in the sharded path)."""
        return self.pipe.caches.dgraph

    def _prefetch(self, ctx, nodes, num_live=None):
        """Stage a batch's missed host rows; returns an object exposing
        ``num_miss`` that the consuming ``_gather`` accepts via its
        ``prefetched`` keyword."""
        del ctx
        return self.pipe.caches.store.prefetch_misses(
            nodes, num_live=num_live, injector=self.injector
        )

    def _gather(self, ctx, indices, **gather_kw):
        """Two-source feature gather over ``indices`` → ``(feats, hit)``."""
        del ctx
        return self.pipe.caches.store.gather(indices, injector=self.injector, **gather_kw)

    def _gather_ft(self, ctx, indices, **gather_kw):
        """``_gather`` under the fault-tolerance envelope.

        With no injector this IS ``_gather`` (one ``is None`` test).  With
        one, the gather runs under retry; when retries exhaust (or the
        policy is fail-fast) the recovery depends on the faulted site:

        * ``kernel_gather`` — reroute to the table gather (``use_kernel``
          off).  Numerically bit-identical by the kernel-parity contract,
          so the batch is NOT degraded; only ``kernel_fallbacks`` counts.
        * ``host_fetch`` with ``degraded_mode`` — serve cache-only: hit
          rows real, miss rows zero, batch marked degraded
          (:meth:`FeatureStore.gather_cache_only`).
        * otherwise — propagate.
        """
        if self.injector is None:
            return self._gather(ctx, indices, **gather_kw)
        try:
            return self._with_retry(
                ctx, "host_fetch", lambda: self._gather(ctx, indices, **gather_kw)
            )
        except (InjectedFault, RetryExhausted, StageTimeout) as err:
            root = err.last if isinstance(err, RetryExhausted) else err
            site = getattr(root, "site", None)
            if site == "kernel_gather":
                self.kernel_fallbacks += 1
                if self.tracer.enabled:
                    self.tracer.complete(
                        "kernel-fallback",
                        lane="faults",
                        ts_us=self.tracer.now_us(),
                        dur_us=0.0,
                        args={"site": site},
                    )
                fallback_kw = dict(gather_kw)
                fallback_kw["use_kernel"] = False
                fallback_kw.pop("row_block", None)
                return self._gather(ctx, indices, **fallback_kw)
            if site == "host_fetch" and self.degraded_mode:
                self._mark_degraded(ctx)
                return self._gather_cache_only(ctx, indices)
            raise

    def _gather_cache_only(self, ctx, indices):
        """Degraded-mode gather: cached rows only (overridable hook)."""
        del ctx
        return self.pipe.caches.store.gather_cache_only(indices)

    def prefetch_stage(self, ctx):
        """Stage the *missed* host rows for this batch onto the device.

        Sits between ``sample`` and ``feature``: with ``depth > 1`` this
        runs for batch ``i+1`` while batch ``i``'s GNN forward is still in
        flight, so the host→device copy of the miss rows hides behind
        compute — the transfer-inefficiency DCI targets on the miss path.
        The feature stage then reads misses from the staged buffer; the
        hit mask (and all accounting) still comes from ``position_map``,
        so hit/miss counts are bit-identical with prefetch on or off.
        Under ``dedup`` only the batch's DISTINCT missed rows are staged —
        the gather consuming the pack runs over the unique bucket."""
        if self.dedup:
            _, nu, _, uids = self._dedup_view(ctx)
            stage = lambda: self._prefetch(ctx, np.asarray(uids), num_live=nu)  # noqa: E731
        else:
            nodes = np.asarray(ctx.outputs["sample"][0].input_nodes)
            stage = lambda: self._prefetch(ctx, nodes)  # noqa: E731
        if self.injector is None:
            staged = stage()
        else:
            try:
                staged = self._with_retry(ctx, "prefetch", stage)
            except (InjectedFault, RetryExhausted, StageTimeout) as err:
                root = err.last if isinstance(err, RetryExhausted) else err
                if getattr(root, "site", None) != "prefetch" or not self.degraded_mode:
                    raise
                # Prefetch down: skip staging and let the feature stage
                # gather misses over the ordinary host path.  Outputs and
                # hit accounting are bit-identical (prefetch only moves
                # bytes early), so the batch is NOT marked degraded.
                return None
        self.prefetched_rows += staged.num_miss
        return staged

    def feature(self, ctx):
        block = ctx.outputs["sample"][0]
        gather_kw = dict(
            use_kernel=self.use_kernel,
            gather_buffers=self.gather_buffers,
            prefetched=ctx.outputs.get("prefetch"),
        )
        if self.dedup:
            # Gather each distinct row once (sorted ids → the row-block
            # kernel's contiguous runs when the kernel route is on); the
            # per-visit hit mask is the unique mask expanded through the
            # inverse map, so every count downstream is bit-identical to
            # the duplicate-carrying gather.
            dd, nu, bucket, uids = self._dedup_view(ctx)
            feats_u, hit_u = self._gather_ft(
                ctx, uids, row_block=ROW_BLOCK if self.use_kernel else None, **gather_kw
            )
            hit = hit_u[dd.inverse]
            self.unique_rows += nu
            self.gathered_rows += bucket
            return feats_u, hit, jnp.sum(hit), hit_u
        self.gathered_rows += int(block.input_nodes.shape[0])
        if self.pipe.reuse_prev_batch and self._prev_feats is not None:
            nodes = np.asarray(block.input_nodes)
            pos = self._prev_map[nodes]
            hit_np = pos >= 0
            reused = self._prev_feats[jnp.asarray(np.maximum(pos, 0))]
            fresh, _ = self._gather_ft(ctx, block.input_nodes, **gather_kw)
            feats = jnp.where(jnp.asarray(hit_np)[:, None], reused, fresh)
            hit = jnp.asarray(hit_np)
        else:
            feats, hit = self._gather_ft(ctx, block.input_nodes, **gather_kw)
        if self.pipe.reuse_prev_batch:
            # The *next* batch's gather reads this state, so it must be
            # updated here rather than at retire time — with depth > 1
            # batch i retires only after batch i+1 has dispatched.
            if self._prev_nodes is not None:
                self._prev_map[self._prev_nodes] = -1
            self._prev_nodes = np.asarray(block.input_nodes)
            self._prev_map[self._prev_nodes] = np.arange(len(self._prev_nodes))
            self._prev_feats = feats
        return feats, hit, jnp.sum(hit)

    def compute(self, ctx):
        feats = ctx.outputs["feature"][0]
        # Read the inverse off the resolved dedup view (not the raw block):
        # the sharded runtime re-homes it onto the assembling device there,
        # and for the base path the view holds the block's inverse as-is.
        inverse = self._dedup_view(ctx)[0].inverse if self.dedup else None
        return gnn_models.forward(
            self.params, feats, model=self.model, fanouts=self.fanouts, inverse_index=inverse
        )

    def record(self, ctx) -> None:
        """Host-side accounting; runs per batch, in order, after the batch's
        stage outputs (incl. the stat scalars) are ready, so the int()
        conversions only pay a tiny device→host transfer."""
        block, bh, bt = ctx.outputs["sample"]
        feature_out = ctx.outputs["feature"]
        hit, hsum = feature_out[1], feature_out[2]
        bh, bt, hsum, lookups = int(bh), int(bt), int(hsum), int(hit.shape[0])
        self.adj_hits += bh
        self.adj_lookups += bt
        self.feat_hits += hsum
        self.feat_lookups += lookups
        per_epoch = self.epoch_counters.setdefault(ctx.epoch, [0, 0, 0, 0, 0])
        per_epoch[0] += bh
        per_epoch[1] += bt
        per_epoch[2] += hsum
        per_epoch[3] += lookups
        per_epoch[4] += 1
        if self.telemetry is not None:
            if self.dedup:
                # Scatter once per unique node, weighted by its visit
                # multiplicity — counters come out bit-identical to the
                # per-visit form (a node's hit bit is the same for every
                # visit within a batch).
                dd, nu, _, uids = self._dedup_view(ctx)
                mult = np.bincount(np.asarray(dd.inverse), minlength=nu)[:nu]
                self.telemetry.observe_batch(
                    np.asarray(uids)[:nu],
                    np.asarray(feature_out[3])[:nu],
                    block.edge_slots,
                    multiplicities=mult,
                )
            else:
                self.telemetry.observe_batch(block.input_nodes, hit, block.edge_slots)
        if self.outputs is not None:
            self.outputs.append(np.asarray(ctx.outputs["compute"]))

    def epoch_hit_rates(self) -> dict[int, dict]:
        """Per-epoch hit-rate summary (one entry per cache epoch served)."""
        return summarize_epoch_counters(self.epoch_counters)


def stream_stages(runtime_of, *, prefetch: bool = False) -> list[Stage]:
    """The sample → [prefetch] → feature → compute pipeline over
    :class:`StreamRuntime`s.

    ``runtime_of(ctx)`` resolves the runtime a batch belongs to: the engine
    passes a constant (one stream), the serving layer reads it off
    ``ctx.stream``.  Sync values mirror what each stage leaves in flight —
    they are what the serial clock blocks on and the overlap clock drains.

    ``prefetch=True`` inserts the miss-row staging stage between sample
    and feature (see :meth:`StreamRuntime.prefetch_stage`); the executor
    drops the ``None`` placeholder when it is off, so the stage list —
    and with it the depth=1 serial timing semantics — is unchanged by
    default.
    """
    return [
        Stage(
            "sample",
            lambda c: runtime_of(c).sample(c),
            lambda c: (c.outputs["sample"][0].frontiers[-1], c.outputs["sample"][1]),
        ),
        Stage(
            "prefetch",
            lambda c: runtime_of(c).prefetch_stage(c),
            lambda c: c.outputs["prefetch"],
        )
        if prefetch
        else None,
        Stage(
            "feature",
            lambda c: runtime_of(c).feature(c),
            lambda c: (c.outputs["feature"][0], c.outputs["feature"][2]),
        ),
        Stage("compute", lambda c: runtime_of(c).compute(c), lambda c: c.outputs["compute"]),
    ]


def summarize_epoch_counters(counters: dict[int, list[int]]) -> dict[int, dict]:
    """Per-epoch hit-rate summary from ``[adj_hits, adj_lookups, feat_hits,
    feat_lookups, batches]`` counter lists (the StreamRuntime layout) —
    shared by the per-stream and the serve-aggregate reports."""
    return {
        epoch: {
            "batches": c[4],
            "adj_hit_rate": round(c[0] / max(c[1], 1), 4),
            "feat_hit_rate": round(c[2] / max(c[3], 1), 4),
        }
        for epoch, c in sorted(counters.items())
    }


# Below this, a measured stage lap is indistinguishable from clock noise —
# a cache-hit-everything first batch can legitimately measure ~0 prep, and
# a ratio against a ~0 denominator would pin the derived depth at the cap.
DEGENERATE_LAP_SECONDS = 1e-6


def auto_pipeline_depth(prep_seconds: float, compute_seconds: float, *, max_depth: int = 4) -> int:
    """Pick an executor window from the measured compute:prep ratio.

    The pipeline hides batch *i+1*'s preparation (sample + gather) behind
    batch *i*'s forward, so ``depth=2`` already wins everything when
    compute >= prep.  When prep dominates, a deeper window keeps the
    device fed across several short forwards — roughly one extra slot per
    compute-sized chunk of prep — saturating at ``max_depth`` (beyond
    that the run is prep-bound and more slots only hold memory).

    Degenerate probes: a ~zero PREP lap means there is nothing to hide
    behind compute — return 1 (serial; callers treat it as "re-derive on
    the next window" rather than caching it).  A ~zero COMPUTE lap with
    real prep used to divide by ~0 and pin the depth at the cap; it now
    returns the 2 a compute-free measurement actually supports.
    """
    if prep_seconds <= DEGENERATE_LAP_SECONDS:
        return 1
    if compute_seconds <= DEGENERATE_LAP_SECONDS:
        return 2
    return max(2, min(max_depth, 1 + round(prep_seconds / compute_seconds)))


class GNNInferenceEngine:
    def __init__(
        self,
        dataset: SyntheticGraphDataset,
        *,
        model: str = "graphsage",
        fanouts: tuple[int, ...] = (15, 10, 5),
        batch_size: int = 1024,
        seed: int = 0,
        params=None,
        pipeline_depth: int | str = 1,
    ):
        self.dataset = dataset
        self.model = model
        self.fanouts = tuple(fanouts)
        self.batch_size = batch_size
        self.seed = seed
        self.pipeline_depth = pipeline_depth
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else gnn_models.init_params(
            key, model, dataset.spec.feat_dim, dataset.spec.num_classes
        )
        self.pipeline: PreparedPipeline | None = None
        self.last_outputs: list[np.ndarray] | None = None
        self._auto_depth: int | None = None  # resolved "auto" depth, cached

    # ------------------------------------------------------------ prepare
    def prepare(
        self,
        policy: str,
        *,
        config: EngineConfig | None = None,
        total_cache_bytes: int = 0,
        n_presample: int = 8,
        pipeline_depth: int = 1,
        stream_seeds: list[int] | None = None,
        prefetch: bool | None = None,
        use_kernel: bool | None = None,
        gather_buffers: int | None = None,
        dedup: bool | None = None,
    ):
        # Presampling defaults to serial (depth=1): its per-stage times feed
        # Eq. 1, and the paper's split assumes fully synchronized stages.
        # Visit counts are depth-invariant, so overlapped presampling only
        # shifts the measured sample:feature ratio toward dispatch cost.
        # ``stream_seeds`` profiles the union workload of several request
        # streams (multi-stream serving) at the same total presample budget.
        # ``config`` carries the gather knobs recorded on the prepared
        # pipeline as the defaults for every run (and every serving stream)
        # against it; the loose keyword forms are deprecated (coalesce).
        cfg = coalesce(
            config,
            _context="GNNInferenceEngine.prepare",
            prefetch=prefetch,
            use_kernel=use_kernel,
            gather_buffers=gather_buffers,
            dedup=dedup,
        )
        self.pipeline = prepare(
            policy,
            self.dataset,
            total_cache_bytes=total_cache_bytes,
            fanouts=self.fanouts,
            batch_size=self.batch_size,
            n_presample=n_presample,
            seed=self.seed,
            pipeline_depth=pipeline_depth,
            stream_seeds=stream_seeds,
            prefetch=bool(cfg.prefetch),
            use_kernel=bool(cfg.use_kernel),
            gather_buffers=2 if cfg.gather_buffers is None else cfg.gather_buffers,
            dedup=bool(cfg.dedup),
        )
        return self.pipeline

    # ---------------------------------------------------------------- run
    def _batches(self, max_batches: int | None) -> list[np.ndarray]:
        test = self.dataset.test_idx
        nb = max(len(test) // self.batch_size, 1)
        need = nb * self.batch_size
        if len(test) < need:  # tiny datasets: cycle to fill one batch
            reps = -(-need // max(len(test), 1))
            test = np.tile(test, reps)
        arr = test[:need].reshape(nb, self.batch_size)
        order = (
            self.pipeline.batch_order
            if self.pipeline is not None and self.pipeline.batch_order is not None
            else np.arange(nb)
        )
        if max_batches is not None:
            order = order[:max_batches]
        return [arr[i] for i in order]

    def warmup(
        self,
        seeds: np.ndarray,
        *,
        prefetch: bool | None = None,
        use_kernel: bool | None = None,
        gather_buffers: int | None = None,
        dedup: bool | None = None,
    ) -> None:
        """Trigger compilation outside any timed region (cache array shapes
        differ per policy/budget, so each prepared pipeline compiles once —
        shared by every stream that serves against it).  The gather is
        warmed with the same execution knobs the run will use (prefetch
        scatter / kernel route / dedup bucket compile to different
        programs).

        Under ``dedup`` the gather and forward programs specialize on the
        per-batch pow2 unique bucket.  Warming the probe batch's bucket
        covers every batch sharing it (unique counts are stable within a
        workload, so that is usually all of them); a batch landing in a
        different bucket pays one in-run compile — the same exposure as
        any first-of-a-shape dispatch.
        """
        if self.pipeline is None:
            raise RuntimeError("call prepare() first")
        pipe = self.pipeline
        prefetch = pipe.prefetch if prefetch is None else prefetch
        use_kernel = pipe.use_kernel if use_kernel is None else use_kernel
        gather_buffers = pipe.gather_buffers if gather_buffers is None else gather_buffers
        dedup = (pipe.dedup if dedup is None else dedup) and not pipe.reuse_prev_batch
        dgraph, store = pipe.caches.dgraph, pipe.caches.store
        wblock = sample_blocks(
            jax.random.PRNGKey(self.seed + 1), dgraph, jnp.asarray(seeds), self.fanouts,
            dedup=dedup,
            dedup_pad_id=store.pad_node_id() if dedup else None,
        )
        s = int(wblock.input_nodes.shape[0])
        if dedup:
            nu = int(wblock.dedup.num_unique)
            bucket = pow2_bucket(nu, s)
            gather_ids = wblock.dedup.unique_ids[:bucket]
            inverse = wblock.dedup.inverse
            row_block = ROW_BLOCK if use_kernel else None
        else:
            nu = None
            gather_ids, inverse, row_block = wblock.input_nodes, None, None
        # num_live mirrors the serve path's prefetch stage: only the live
        # prefix can stage misses, so warmup packs the same bucket sizes
        # the run will (and, with the cached pad id, the tail could not
        # stage duplicate miss rows even without it).
        prefetched = (
            store.prefetch_misses(np.asarray(gather_ids), num_live=nu) if prefetch else None
        )
        wfeats, _ = store.gather(
            gather_ids,
            use_kernel=use_kernel,
            gather_buffers=gather_buffers,
            prefetched=prefetched,
            row_block=row_block,
        )
        if prefetch:
            # The miss count varies per batch, so the staged pack's padded
            # bucket size — and with it the consuming gather program —
            # varies too.  Warm every possible bucket (O(log S) of them)
            # with synthetic all-pad packs, so no batch's first-of-a-bucket
            # gather compiles inside a timed run.
            from repro.graph.features import PrefetchedMisses

            g = int(gather_ids.shape[0])
            bucket = 1
            while bucket <= g:
                synth = PrefetchedMisses(
                    rows=jnp.zeros((min(bucket, g), store.feat_dim), store.host_table.dtype),
                    idx=jnp.full((min(bucket, g),), g, jnp.int32),
                    pack_pos=jnp.zeros((g,), jnp.int32),
                    num_miss=0,
                )
                store.gather(
                    gather_ids,
                    use_kernel=use_kernel,
                    gather_buffers=gather_buffers,
                    prefetched=synth,
                    row_block=row_block,
                )
                bucket <<= 1
        jax.block_until_ready(
            gnn_models.forward(
                self.params, wfeats, model=self.model, fanouts=self.fanouts,
                inverse_index=inverse,
            )
        )

    def warmup_refresh_growth(
        self,
        seeds: np.ndarray,
        *,
        use_kernel: bool | None = None,
        gather_buffers: int | None = None,
        dedup: bool | None = None,
    ) -> None:
        """Pre-compile the gather at the hot table's NEXT growth bucket.

        ``refresh_feature_cache`` grows the device hot table by doubling
        (capped at the node count), and the gather program specializes on
        the table's physical row count — so the first batch after a
        growing refresh would otherwise pay that compile *inside* the
        serve loop, exactly the pause a delta re-fill exists to avoid.
        This warms the post-growth program off the serve path against a
        zero-filled ghost table at the doubled size: same position map,
        same index shapes, same route (kernel/prefetched knobs), so the
        compiled program is the one the post-refresh store dispatches.
        A no-op when the table cannot grow (already at the node count) or
        the policy built no refreshable caches.
        """
        if self.pipeline is None:
            raise RuntimeError("call prepare() first")
        pipe = self.pipeline
        if not pipe.caches.refreshable:
            return
        from repro.graph.features import FeatureStore

        store = pipe.caches.store
        use_kernel = pipe.use_kernel if use_kernel is None else use_kernel
        gather_buffers = pipe.gather_buffers if gather_buffers is None else gather_buffers
        dedup = (pipe.dedup if dedup is None else dedup) and not pipe.reuse_prev_batch
        physical = int(store.hot_table.shape[0])
        grow_to = min(2 * physical, store.num_nodes)
        if grow_to <= physical:
            return
        ghost = FeatureStore(
            host_table=store.host_table,
            hot_table=jnp.zeros((grow_to, store.feat_dim), store.hot_table.dtype),
            position_map=store.position_map,
        )
        object.__setattr__(ghost, "_host_np", store.host_np())
        object.__setattr__(ghost, "_position_np", store.position_np())
        wblock = sample_blocks(
            jax.random.PRNGKey(self.seed + 1), pipe.caches.dgraph, jnp.asarray(seeds),
            self.fanouts, dedup=dedup,
            dedup_pad_id=store.pad_node_id() if dedup else None,
        )
        if dedup:
            bucket = pow2_bucket(int(wblock.dedup.num_unique), int(wblock.input_nodes.shape[0]))
            gather_ids = wblock.dedup.unique_ids[:bucket]
            row_block = ROW_BLOCK if use_kernel else None
        else:
            gather_ids, row_block = wblock.input_nodes, None
        feats, _ = ghost.gather(
            gather_ids, use_kernel=use_kernel, gather_buffers=gather_buffers,
            row_block=row_block,
        )
        jax.block_until_ready(feats)

    # ------------------------------------------------------ adaptive depth
    def resolve_pipeline_depth(self, depth=None, *, seeds=None) -> int:
        """Resolve the ``pipeline_depth`` knob, including ``"auto"``.

        ``"auto"`` probes ONE serial batch against the prepared pipeline
        (after an untimed warmup, so compilation is excluded) and derives
        the window from the measured compute:prep ratio — the same
        decomposition bench_breakdown's serial rows report.  The probe
        uses its own RNG stream, so the run it sizes is unaffected; the
        result is cached on the engine — EXCEPT a degenerate probe (a
        ~zero prep lap, e.g. a cache-hit-everything first batch), which
        resolves to serial depth 1 for this run but is NOT cached, so the
        next resolve (or a refresh window) re-derives from a real
        measurement."""
        if depth is None:
            depth = self.pipeline_depth
        if depth != "auto":
            return int(depth)
        if self._auto_depth is None:
            if self.pipeline is None:
                raise RuntimeError("call prepare() before resolving pipeline_depth='auto'")
            if seeds is None:
                seeds = self._batches(1)[0]
            sample_s, feature_s, compute_s = self._probe_stage_seconds(np.asarray(seeds))
            derived = auto_pipeline_depth(sample_s + feature_s, compute_s)
            if derived < 2:
                return 1  # degenerate probe: don't cache, re-derive next time
            self._auto_depth = derived
        return self._auto_depth

    def _probe_stage_seconds(self, seeds: np.ndarray) -> tuple[float, float, float]:
        """Fully synchronized per-stage seconds for one batch (best of 2)."""
        self.warmup(seeds)
        pipe = self.pipeline
        best = None
        for rep in range(2):
            key = jax.random.PRNGKey(self.seed + 1000 + rep)
            t0 = time.perf_counter()
            block = sample_blocks(key, pipe.caches.dgraph, jnp.asarray(seeds), self.fanouts)
            jax.block_until_ready(block.frontiers[-1])
            t1 = time.perf_counter()
            feats, _ = pipe.caches.store.gather(block.input_nodes)
            jax.block_until_ready(feats)
            t2 = time.perf_counter()
            out = gnn_models.forward(self.params, feats, model=self.model, fanouts=self.fanouts)
            jax.block_until_ready(out)
            t3 = time.perf_counter()
            lap = (t1 - t0, t2 - t1, t3 - t2)
            best = lap if best is None or sum(lap) < sum(best) else best
        return best

    def run(
        self,
        *,
        config: EngineConfig | None = None,
        max_batches: int | None = None,
        warmup: bool = True,
        pipeline_depth: int | None = None,
        collect_outputs: bool = False,
        batches: list[np.ndarray] | None = None,
        prefetch: bool | None = None,
        use_kernel: bool | None = None,
        gather_buffers: int | None = None,
        dedup: bool | None = None,
        refresh=None,
        tracer=None,
        metrics=None,
        injector=None,
        retry_policy=None,
        degraded_mode: bool = False,
    ):
        """Run inference over the dataset's test batches (or explicit seed
        ``batches``) and return the stage-time / hit-rate report.

        ``tracer``/``metrics`` are live observability handles
        (core/trace.py) — keyword-only and not part of ``EngineConfig``
        (which stays a frozen JSON-safe value object).  A
        :class:`~repro.core.trace.Tracer` records the run's timeline
        (slot-lane batch/stage spans, refresh epochs); a
        :class:`~repro.core.trace.MetricsRegistry` is folded with the
        run's aggregate outcomes and snapshotted onto ``report.metrics``.
        Both default to off with effectively zero cost, and neither
        perturbs outputs (bit-for-bit equivalence-tested).

        ``config`` is the one knob object (:class:`~repro.core.config.
        EngineConfig`): mode, executor window, the four gather knobs, the
        layer-wise chunk size and the refresh trigger.  The loose keyword
        forms below remain as a deprecated one-release shim — any passed
        value merges over ``config`` via :func:`~repro.core.config.
        coalesce`, bit-for-bit equivalent to passing the config directly
        (tests/test_config.py).  Unset knobs default from the prepared
        pipeline; outputs and hit accounting are identical under every
        knob combination (equivalence-tested), only where the miss bytes
        move (and therefore wall clock) changes.

        ``config.mode="layerwise"`` dispatches to the chunked full-graph
        executor (:func:`~repro.runtime.layerwise.run_layerwise`) —
        scoring EVERY node in node-range chunks, layer by layer, with the
        intermediate embeddings spilled host-side behind their own cache —
        and returns its :class:`~repro.runtime.layerwise.LayerwiseReport`
        instead (``batches``/``max_batches``/``refresh`` do not apply).

        ``batches`` overrides the dataset-derived schedule (and RAIN's
        ``batch_order``) — the serving layer and the equivalence tests use
        it to run an exact per-stream batch list.

        ``pipeline_depth`` additionally accepts ``"auto"`` (derive the
        window from a measured compute:prep probe, see
        :meth:`resolve_pipeline_depth`; in layer-wise mode ``"auto"``
        resolves to 2 — chunk prep is pure gather, one overlap slot hides
        it).  ``refresh`` takes a
        :class:`~repro.runtime.cache_refresh.RefreshConfig` (or set the
        config's ``refresh_mode`` fields): an interval mode re-allocates
        and delta re-fills the caches every N retired batches from live
        telemetry.  Outputs are bit-identical with refresh on or off
        (refreshes move bytes, not values); hit accounting then comes per
        epoch via ``report.epoch_hits``.  With BOTH ``"auto"`` depth and
        refresh enabled, each refresh re-derives the window from the
        refreshed stage laps and applies it to the live executor (the
        warmup-time probe only seeds the initial depth)."""
        if self.pipeline is None:
            raise RuntimeError("call prepare() first")
        pipe = self.pipeline
        cfg = coalesce(
            config,
            _context="GNNInferenceEngine.run",
            pipeline_depth=pipeline_depth,
            prefetch=prefetch,
            use_kernel=use_kernel,
            gather_buffers=gather_buffers,
            dedup=dedup,
        )
        if refresh is None:
            refresh = cfg.refresh_config()
        requested_depth = (
            self.pipeline_depth if cfg.pipeline_depth is None else cfg.pipeline_depth
        )
        if cfg.mode == "layerwise":
            from repro.runtime.layerwise import run_layerwise

            depth = 2 if requested_depth == "auto" else int(requested_depth)
            report = run_layerwise(
                self.dataset,
                pipe,
                self.params,
                model=self.model,
                config=cfg.resolved(pipe, pipeline_depth=depth),
                tracer=tracer,
                metrics=metrics,
            )
            self.last_outputs = [report.outputs]
            return report
        tracer = resolve_tracer(tracer)
        if batches is None:
            batches = self._batches(max_batches)
        depth = self.resolve_pipeline_depth(
            requested_depth, seeds=batches[0] if batches else None
        )
        if warmup:
            self.warmup(
                batches[0],
                prefetch=cfg.prefetch,
                use_kernel=cfg.use_kernel,
                gather_buffers=cfg.gather_buffers,
                dedup=cfg.dedup,
            )

        # All cross-batch state (RNG stream, RAIN's reuse map, counters)
        # lives in the StreamRuntime; stage methods run in batch order at
        # any depth, preserving the serial key sequence and reuse ordering.
        rt = StreamRuntime(
            pipe,
            self.params,
            model=self.model,
            fanouts=self.fanouts,
            num_nodes=self.dataset.num_nodes,
            key=jax.random.PRNGKey(self.seed + 1),
            collect_outputs=collect_outputs,
            prefetch=cfg.prefetch,
            use_kernel=cfg.use_kernel,
            gather_buffers=cfg.gather_buffers,
            dedup=cfg.dedup,
            injector=injector,
            retry_policy=retry_policy,
            degraded_mode=degraded_mode,
        )
        rt.tracer = tracer
        clock = StageClock(overlap=depth > 1)
        manager = None
        if refresh is not None and refresh.enabled:
            from repro.runtime.cache_refresh import CacheRefreshManager

            manager = CacheRefreshManager(
                pipe,
                self.dataset,
                fanouts=self.fanouts,
                batch_size=self.batch_size,
                config=refresh,
            )
            manager.register_clock(clock, key=0)
            manager.tracer = tracer
            manager.injector = injector
            rt.telemetry = manager.telemetry_for(0)
            if warmup:
                # Refresh-aware warmup: a growing delta re-fill would
                # otherwise compile its first post-growth gather inside
                # the serve loop.
                self.warmup_refresh_growth(
                    batches[0], use_kernel=use_kernel,
                    gather_buffers=gather_buffers, dedup=dedup,
                )
        auto_depth = requested_depth == "auto" and manager is not None

        def on_retire(ctx):
            # Retire runs between batch dispatches, so an interval refresh
            # lands here: in-flight batches keep the old epoch's arrays,
            # the next dispatch reads the new epoch.
            rt.record(ctx)
            if manager is not None:
                event = manager.note_retired()
                if event is not None and auto_depth and manager.suggested_depth:
                    # Refresh-aware "auto": size the window from the
                    # refreshed stage laps instead of the warmup probe.
                    # The executor re-reads ``depth`` between batches, so
                    # the change applies at the next dispatch; depth never
                    # drops below 2, keeping the clock's overlap semantics.
                    executor.depth = manager.suggested_depth

        executor = PipelinedExecutor(
            stream_stages(lambda c: rt, prefetch=rt.prefetch),
            depth=depth,
            clock=clock,
            on_retire=on_retire,
            tracer=tracer,
        )
        executor.run(batches)
        self.last_outputs = rt.outputs

        # The config echoed by the report is the RESOLVED one — every knob
        # read back off the runtime that executed (rt.dedup already folds
        # in RAIN's reuse exclusion), so the echo cannot drift.
        resolved_cfg = cfg.resolved(pipe, pipeline_depth=depth).replace(
            prefetch=rt.prefetch,
            use_kernel=rt.use_kernel,
            gather_buffers=rt.gather_buffers,
            dedup=rt.dedup,
        )
        report = InferenceReport(
            policy=pipe.name,
            num_batches=len(batches),
            sample_seconds=clock.total("sample"),
            feature_seconds=clock.total("feature"),
            compute_seconds=clock.total("compute"),
            prep_seconds=pipe.prep_seconds,
            adj_hits=rt.adj_hits,
            adj_lookups=rt.adj_lookups,
            feat_hits=rt.feat_hits,
            feat_lookups=rt.feat_lookups,
            feat_row_bytes=self.dataset.feature_nbytes_per_row(),
            pipeline_depth=depth,
            prefetch=rt.prefetch,
            prefetch_seconds=clock.total("prefetch"),
            prefetched_rows=rt.prefetched_rows,
            dedup=rt.dedup,
            unique_rows=rt.unique_rows,
            gathered_rows=rt.gathered_rows,
            refresh_events=list(manager.events) if manager is not None else [],
            epoch_hits=rt.epoch_hit_rates() if manager is not None else None,
            config=resolved_cfg,
        )
        if metrics is not None:
            metrics.counter("batches_total", policy=pipe.name).inc(report.num_batches)
            metrics.gauge("feat_hit_rate", policy=pipe.name).set(report.feat_hit_rate)
            metrics.gauge("adj_hit_rate", policy=pipe.name).set(report.adj_hit_rate)
            for name in ("sample", "prefetch", "feature", "compute"):
                metrics.gauge("stage_seconds", policy=pipe.name, stage=name).set(
                    clock.total(name)
                )
            if report.epoch_hits:
                for epoch, rates in report.epoch_hits.items():
                    metrics.gauge("feat_hit_rate", policy=pipe.name, epoch=epoch).set(
                        rates["feat_hit_rate"]
                    )
            report.metrics = metrics.snapshot()
        return report
