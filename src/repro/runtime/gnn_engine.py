"""End-to-end sampled GNN inference engine (the system Fig. 5 describes).

Pipeline per mini-batch: sample blocks (adjacency cache aware) → gather
input-frontier features (feature cache aware; RAIN reuses the previous
batch instead) → run the GNN.  The engine times each stage exactly the way
the paper decomposes Fig. 1/7, counts cache hits, and also reports a
*modeled* transfer time using bandwidth constants so the CPU-only container
can be projected onto the paper's PCIe/GPU (or a TPU host-HBM) topology.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import PreparedPipeline, prepare
from repro.graph.datasets import SyntheticGraphDataset
from repro.graph.sampling import sample_blocks
from repro.models import gnn as gnn_models

__all__ = ["GNNInferenceEngine", "InferenceReport"]

# Link speeds for the modeled-transfer projection (bytes/s).
PCIE4_BW = 25e9  # paper's RTX 4090 host link (the UVA miss path)
HBM_BW = 819e9  # TPU v5e HBM (the cache-hit path)


@dataclasses.dataclass
class InferenceReport:
    policy: str
    num_batches: int
    sample_seconds: float
    feature_seconds: float
    compute_seconds: float
    prep_seconds: float
    adj_hits: int
    adj_lookups: int
    feat_hits: int
    feat_lookups: int
    feat_row_bytes: int

    @property
    def total_seconds(self) -> float:
        return self.sample_seconds + self.feature_seconds + self.compute_seconds

    @property
    def adj_hit_rate(self) -> float:
        return self.adj_hits / max(self.adj_lookups, 1)

    @property
    def feat_hit_rate(self) -> float:
        return self.feat_hits / max(self.feat_lookups, 1)

    def modeled_transfer_seconds(self, slow_bw: float = PCIE4_BW, fast_bw: float = HBM_BW) -> float:
        """Project byte movement onto a slow (miss) / fast (hit) link pair."""
        miss_bytes = (self.feat_lookups - self.feat_hits) * self.feat_row_bytes + (
            self.adj_lookups - self.adj_hits
        ) * 4
        hit_bytes = self.feat_hits * self.feat_row_bytes + self.adj_hits * 4
        return miss_bytes / slow_bw + hit_bytes / fast_bw

    def summary(self) -> dict:
        return {
            "policy": self.policy,
            "batches": self.num_batches,
            "sample_s": round(self.sample_seconds, 4),
            "feature_s": round(self.feature_seconds, 4),
            "compute_s": round(self.compute_seconds, 4),
            "total_s": round(self.total_seconds, 4),
            "prep_s": round(self.prep_seconds, 4),
            "adj_hit_rate": round(self.adj_hit_rate, 4),
            "feat_hit_rate": round(self.feat_hit_rate, 4),
            "modeled_transfer_s": round(self.modeled_transfer_seconds(), 6),
        }


class GNNInferenceEngine:
    def __init__(
        self,
        dataset: SyntheticGraphDataset,
        *,
        model: str = "graphsage",
        fanouts: tuple[int, ...] = (15, 10, 5),
        batch_size: int = 1024,
        seed: int = 0,
        params=None,
    ):
        self.dataset = dataset
        self.model = model
        self.fanouts = tuple(fanouts)
        self.batch_size = batch_size
        self.seed = seed
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else gnn_models.init_params(
            key, model, dataset.spec.feat_dim, dataset.spec.num_classes
        )
        self.pipeline: PreparedPipeline | None = None

    # ------------------------------------------------------------ prepare
    def prepare(self, policy: str, *, total_cache_bytes: int = 0, n_presample: int = 8):
        self.pipeline = prepare(
            policy,
            self.dataset,
            total_cache_bytes=total_cache_bytes,
            fanouts=self.fanouts,
            batch_size=self.batch_size,
            n_presample=n_presample,
            seed=self.seed,
        )
        return self.pipeline

    # ---------------------------------------------------------------- run
    def _batches(self, max_batches: int | None) -> list[np.ndarray]:
        test = self.dataset.test_idx
        nb = max(len(test) // self.batch_size, 1)
        need = nb * self.batch_size
        if len(test) < need:  # tiny datasets: cycle to fill one batch
            reps = -(-need // max(len(test), 1))
            test = np.tile(test, reps)
        arr = test[:need].reshape(nb, self.batch_size)
        order = (
            self.pipeline.batch_order
            if self.pipeline is not None and self.pipeline.batch_order is not None
            else np.arange(nb)
        )
        if max_batches is not None:
            order = order[:max_batches]
        return [arr[i] for i in order]

    def run(self, *, max_batches: int | None = None, warmup: bool = True) -> InferenceReport:
        if self.pipeline is None:
            raise RuntimeError("call prepare() first")
        pipe = self.pipeline
        dgraph, store = pipe.caches.dgraph, pipe.caches.store
        key = jax.random.PRNGKey(self.seed + 1)

        if warmup:
            # Trigger compilation outside the timed region (cache array
            # shapes differ per policy, so each policy compiles once).
            wseeds = jnp.asarray(self._batches(1)[0])
            wblock = sample_blocks(key, dgraph, wseeds, self.fanouts)
            wfeats, _ = store.gather(wblock.input_nodes)
            jax.block_until_ready(
                gnn_models.forward(self.params, wfeats, model=self.model, fanouts=self.fanouts)
            )

        t_sample = t_feature = t_compute = 0.0
        adj_hits = adj_total = feat_hits = feat_total = 0

        # RAIN cross-batch reuse state (host-side membership map).
        prev_map = np.full(self.dataset.num_nodes, -1, np.int64)
        prev_feats: jax.Array | None = None
        prev_nodes: np.ndarray | None = None

        batches = self._batches(max_batches)
        for seeds_np in batches:
            key, sub = jax.random.split(key)
            seeds = jnp.asarray(seeds_np)

            t0 = time.perf_counter()
            block = sample_blocks(sub, dgraph, seeds, self.fanouts)
            jax.block_until_ready(block.frontiers[-1])
            t_sample += time.perf_counter() - t0

            t0 = time.perf_counter()
            if pipe.reuse_prev_batch and prev_feats is not None:
                nodes = np.asarray(block.input_nodes)
                pos = prev_map[nodes]
                hit_np = pos >= 0
                reused = prev_feats[jnp.asarray(np.maximum(pos, 0))]
                fresh, _ = store.gather(block.input_nodes)
                feats = jnp.where(jnp.asarray(hit_np)[:, None], reused, fresh)
                hit = jnp.asarray(hit_np)
            else:
                feats, hit = store.gather(block.input_nodes)
            jax.block_until_ready(feats)
            t_feature += time.perf_counter() - t0

            t0 = time.perf_counter()
            logits = gnn_models.forward(
                self.params, feats, model=self.model, fanouts=self.fanouts
            )
            jax.block_until_ready(logits)
            t_compute += time.perf_counter() - t0

            bh, bt = block.adj_hit_stats()
            adj_hits += int(bh)
            adj_total += int(bt)
            feat_hits += int(jnp.sum(hit))
            feat_total += int(hit.shape[0])

            if pipe.reuse_prev_batch:
                if prev_nodes is not None:
                    prev_map[prev_nodes] = -1
                prev_nodes = np.asarray(block.input_nodes)
                prev_map[prev_nodes] = np.arange(len(prev_nodes))
                prev_feats = feats

        return InferenceReport(
            policy=pipe.name,
            num_batches=len(batches),
            sample_seconds=t_sample,
            feature_seconds=t_feature,
            compute_seconds=t_compute,
            prep_seconds=pipe.prep_seconds,
            adj_hits=adj_hits,
            adj_lookups=adj_total,
            feat_hits=feat_hits,
            feat_lookups=feat_total,
            feat_row_bytes=self.dataset.feature_nbytes_per_row(),
        )
