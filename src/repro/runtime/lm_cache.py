"""DCI's technique applied to LM serving — the cross-domain integration.

The paper's recipe is domain-agnostic (DESIGN.md §4):

  1. profile a small pre-serving workload sample, timing the two candidate
     stages (Eq. 1 inputs) and counting per-item visits;
  2. split one device-memory budget across two caches proportionally to the
     measured stage times (``core.allocation.allocate_capacity`` — the very
     same Eq. 1 implementation the GNN path uses);
  3. fill each cache with the sort-free above-mean heuristic.

For a transformer server the two gather-heavy stages are:

  * **embedding rows** (vocab up to 256k × d_model; token frequency is
    zipfian — the "node features" of this domain), and
  * **expert weights** (MoE: router selections are the "adjacency"
    workload; a decode batch touches a hot subset of experts).

``build_serving_caches`` profiles token/expert frequencies from a request
sample and returns resident hot sets + position maps with hit counters.
On TPU the hot tables are the HBM-resident working set and misses page
from host memory; here the hit/miss accounting and Eq. 1 split are exact,
byte movement is projected as in the GNN engine.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import CacheAllocation, allocate_capacity
from repro.graph.features import FeatureStore, build_feature_cache
from repro.models.lm.config import LMConfig

__all__ = ["ServingCaches", "profile_and_allocate", "build_serving_caches"]


@dataclasses.dataclass
class ServingCaches:
    allocation: CacheAllocation
    embed_cache: FeatureStore  # hot embedding rows (position-map + hot table)
    hot_experts: np.ndarray | None  # expert ids resident per the budget
    expert_bytes_each: int
    token_counts: np.ndarray
    expert_counts: np.ndarray | None

    def embed_hit_rate(self, tokens: np.ndarray) -> float:
        pos = np.asarray(self.embed_cache.position_map)[tokens.reshape(-1)]
        return float((pos >= 0).mean())

    def expert_hit_rate(self, expert_ids: np.ndarray) -> float:
        if self.hot_experts is None:
            return 0.0
        resident = np.zeros(int(self.expert_counts.shape[0]), bool)
        resident[self.hot_experts] = True
        return float(resident[expert_ids.reshape(-1)].mean())


def _expert_param_bytes(cfg: LMConfig) -> int:
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert  # we1/we2/we3
    return per_expert * 2 * cfg.n_layers // max(m.every, 1)  # bf16


def profile_and_allocate(
    cfg: LMConfig,
    params: dict,
    sample_tokens: np.ndarray,  # [n_req, seq] request sample (pre-serving)
    *,
    total_cache_bytes: int,
    seed: int = 0,
) -> tuple[CacheAllocation, np.ndarray, np.ndarray | None, list[float], list[float]]:
    """Stage timing + visit counting over the request sample (paper §IV-A/B).

    Stage A = embedding gather; stage B = expert selection + expert-weight
    touch (MoE) or KV staging (dense — then the split degenerates toward
    all-embedding, which is DCI's SCI special case).
    """
    embed = params["embed"]
    t_embed: list[float] = []
    t_expert: list[float] = []
    token_counts = np.zeros(cfg.vocab_padded, np.int64)
    expert_counts = (
        np.zeros(cfg.moe.n_experts, np.int64) if cfg.moe is not None else None
    )

    router = None
    if cfg.moe is not None:
        # first MoE block's router (any pattern position carrying "moe")
        for pos in range(cfg.pattern_period):
            if "moe" in params["blocks"][pos]:
                router = params["blocks"][pos]["moe"]["router"][0]  # repeat 0
                break

    for req in sample_tokens:
        ids = jnp.asarray(req)
        t0 = time.perf_counter()
        rows = embed[ids]
        jax.block_until_ready(rows)
        t_embed.append(time.perf_counter() - t0)
        np.add.at(token_counts, np.asarray(req), 1)

        if cfg.moe is not None and router is not None:
            t0 = time.perf_counter()
            logits = rows.astype(jnp.float32) @ router
            _, top = jax.lax.top_k(logits, cfg.moe.top_k)
            jax.block_until_ready(top)
            t_expert.append(time.perf_counter() - t0)
            np.add.at(expert_counts, np.asarray(top).reshape(-1), 1)
        else:
            t_expert.append(0.0)

    alloc = allocate_capacity(t_expert, t_embed, total_cache_bytes)
    # Eq.1 convention: "sample"-like stage (expert selection) ↔ adj budget.
    return alloc, token_counts, expert_counts, t_embed, t_expert


def build_serving_caches(
    cfg: LMConfig,
    params: dict,
    sample_tokens: np.ndarray,
    *,
    total_cache_bytes: int,
    seed: int = 0,
) -> ServingCaches:
    alloc, token_counts, expert_counts, _, _ = profile_and_allocate(
        cfg, params, sample_tokens, total_cache_bytes=total_cache_bytes, seed=seed
    )
    embed_np = np.asarray(params["embed"], np.float32)
    embed_cache = build_feature_cache(embed_np, token_counts, alloc.feat_bytes)

    hot_experts = None
    per_expert = 0
    if cfg.moe is not None and expert_counts is not None:
        per_expert = _expert_param_bytes(cfg) // cfg.moe.n_experts
        budget = max(alloc.adj_bytes // max(per_expert, 1), 0)
        mean = expert_counts.mean()
        hot = np.nonzero(expert_counts > mean)[0]
        if len(hot) > budget:
            hot = hot[np.argsort(-expert_counts[hot], kind="stable")[:budget]]
        elif len(hot) < budget:
            rest = np.nonzero(expert_counts <= mean)[0]
            rest = rest[np.argsort(-expert_counts[rest], kind="stable")]
            hot = np.concatenate([hot, rest[: budget - len(hot)]])
        hot_experts = np.sort(hot.astype(np.int32))

    return ServingCaches(
        allocation=alloc,
        embed_cache=embed_cache,
        hot_experts=hot_experts,
        expert_bytes_each=per_expert,
        token_counts=token_counts,
        expert_counts=expert_counts,
    )
