"""Multi-stream GNN serving over one shared DualCache.

DCI's premise is that a workload-aware dual cache amortizes redundant
loading across many inference requests — which only pays off when several
request *streams* actually share it.  This layer runs N independent batch
streams through ONE :class:`~repro.runtime.pipeline.PipelinedExecutor`
schedule against a single shared :class:`~repro.core.cache.DualCache`:

  - each stream owns a seed-batch queue, its own RNG stream and RAIN reuse
    state (a :class:`~repro.runtime.gnn_engine.StreamRuntime`), and its own
    overlap-aware :class:`~repro.utils.timing.StageClock`;
  - an admission policy interleaves the queues round-robin with a
    per-stream in-flight cap (backpressure), mirroring the slot design of
    :mod:`repro.runtime.serve_engine`: a saturated stream is skipped, not
    waited on, and admission never stalls batches already in flight;
  - per-stream hit/latency accounting plus shared aggregate accounting
    come out in a :class:`ServeReport`.

Because every stream's state is private to its ``StreamRuntime``, each
stream's outputs, RNG sequence, and hit counters are bit-identical to
running that stream's batches alone (tests/test_gnn_serve.py).  What
sharing buys is systemic: one presample + allocation + fill + XLA compile
amortized over all streams, and one budget-B cache serving everyone
instead of N private B/N caches — the axes
benchmarks/bench_multistream.py measures.

Online refresh (``refresh=RefreshConfig(...)``) closes the loop for
long-lived serving: retire-path telemetry feeds a
:class:`~repro.runtime.cache_refresh.CacheRefreshManager` that
periodically (and on stream join/leave — :meth:`MultiStreamServer.add_stream`
after serving has started, :meth:`MultiStreamServer.remove_stream`)
re-runs Eq. 1 on the measured serve-time stage ratio and swaps the shared
``DualCache`` to a new epoch as a delta re-fill.  Outputs stay
bit-identical (a refresh moves bytes, never values — the serial-
equivalence guarantee is unchanged); hit accounting is then reported per
epoch.  With refresh off the caches never mutate and the serve path is
bit-for-bit the pre-refresh system.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Sequence

import jax
import numpy as np

from repro.core.config import EngineConfig, ServeConfig, coalesce
from repro.core.faults import FaultInjector, FaultPlan, InjectedFault
from repro.core.retry import RetryExhausted, StageTimeout
from repro.core.trace import resolve_tracer
from repro.runtime.gnn_engine import (
    GNNInferenceEngine,
    PCIE4_BW,
    HBM_BW,
    StreamRuntime,
    modeled_transfer_seconds,
    stream_stages,
    summarize_epoch_counters,
)
from repro.runtime.pipeline import PipelinedExecutor
from repro.utils.timing import StageClock

__all__ = [
    "MultiStreamServer",
    "ServeReport",
    "StreamReport",
    "StreamState",
    "make_stream_batches",
]


def _latency_stats(latencies) -> tuple[float, float, float, float, float]:
    """(mean, max, p50, p95, p99) of a latency list — zeros when empty.

    Percentiles use numpy's default linear interpolation; with the small
    per-stream sample counts typical of a serve run the p99 of n < 100
    latencies interpolates toward the max, which is the conservative
    (tail-honest) direction for an SLO report."""
    if not latencies:
        return 0.0, 0.0, 0.0, 0.0, 0.0
    arr = np.asarray(latencies, np.float64)
    p50, p95, p99 = np.percentile(arr, [50, 95, 99])
    return float(arr.mean()), float(arr.max()), float(p50), float(p95), float(p99)


@dataclasses.dataclass
class StreamState:
    """One request stream: queue + per-stream runtime/clock/accounting."""

    stream_id: int
    seed: int
    runtime: StreamRuntime
    clock: StageClock
    queue: collections.deque  # of np.ndarray seed batches
    submitted: int = 0  # batches admitted into the pipeline so far
    retired: int = 0  # batches fully completed so far
    inflight: int = 0  # batches currently inside the executor window
    max_inflight_seen: int = 0
    seeds_served: int = 0
    latencies: list = dataclasses.field(default_factory=list)
    # Fault-tolerance accounting (zeros without an injector):
    batches_shed: int = 0  # dropped by the shed policy after retries exhausted
    batches_timed_out: int = 0  # shed batches whose terminal error was a timeout
    batches_retried: int = 0  # retired batches that needed >= 1 backoff retry
    batches_degraded: int = 0  # retired batches served cache-only (miss path down)
    _admit_times: dict = dataclasses.field(default_factory=dict)
    _flow_ids: dict = dataclasses.field(default_factory=dict)  # batch idx -> trace flow id


@dataclasses.dataclass
class StreamReport:
    stream_id: int
    seed: int
    num_batches: int
    num_seeds: int
    sample_seconds: float
    feature_seconds: float
    compute_seconds: float
    adj_hits: int
    adj_lookups: int
    feat_hits: int
    feat_lookups: int
    mean_latency_s: float
    max_latency_s: float
    prefetch_seconds: float = 0.0
    prefetched_rows: int = 0
    unique_rows: int = 0  # distinct input rows (dedup; 0 when off)
    gathered_rows: int = 0  # rows the feature stage actually gathered
    epoch_hits: dict | None = None  # per-cache-epoch rates (refresh on)
    # Latency distribution (admit→retire for queue-less serves; the
    # request front-end overwrites the samples with enqueue→retire):
    p50_latency_s: float = 0.0
    p95_latency_s: float = 0.0
    p99_latency_s: float = 0.0
    # Request-level accounting (request_queue front-end; zeros otherwise):
    requests_shed: int = 0
    deadline_hits: int = 0
    deadline_total: int = 0
    # Fault-tolerance accounting (core/faults.py; zeros without an injector):
    requests_timed_out: int = 0
    requests_retried: int = 0
    requests_degraded: int = 0
    stage_retries: int = 0  # individual backoff retries across all sites

    @property
    def adj_hit_rate(self) -> float:
        return self.adj_hits / max(self.adj_lookups, 1)

    @property
    def feat_hit_rate(self) -> float:
        return self.feat_hits / max(self.feat_lookups, 1)

    def summary(self) -> dict:
        out = {
            "stream": self.stream_id,
            "batches": self.num_batches,
            "adj_hit_rate": round(self.adj_hit_rate, 4),
            "feat_hit_rate": round(self.feat_hit_rate, 4),
            "mean_latency_s": round(self.mean_latency_s, 4),
            "max_latency_s": round(self.max_latency_s, 4),
            "p50_latency_s": round(self.p50_latency_s, 4),
            "p99_latency_s": round(self.p99_latency_s, 4),
        }
        if self.requests_shed:
            out["requests_shed"] = self.requests_shed
        if self.requests_timed_out:
            out["requests_timed_out"] = self.requests_timed_out
        if self.requests_retried:
            out["requests_retried"] = self.requests_retried
        if self.requests_degraded:
            out["requests_degraded"] = self.requests_degraded
        if self.deadline_total:
            out["deadline_hits"] = self.deadline_hits
            out["deadline_total"] = self.deadline_total
        if self.epoch_hits is not None:
            out["per_epoch"] = self.epoch_hits
        return out


@dataclasses.dataclass
class ServeReport:
    """Aggregate + per-stream outcome of one multi-stream serve run.

    Aggregate hit counters are sums over the per-stream reports (asserted
    in tests); ``wall_seconds`` is the serve loop's wall clock (warmup and
    preparation excluded — those are the *amortized* costs the benchmark
    accounts separately)."""

    policy: str
    num_streams: int
    depth: int
    max_inflight_per_stream: int
    wall_seconds: float
    feat_row_bytes: int
    streams: list[StreamReport]
    prefetch: bool = False
    dedup: bool = False
    # Online-refresh accounting (refresh off → empty/None, summary as before):
    refresh_events: list = dataclasses.field(default_factory=list)
    epochs: dict | None = None  # aggregate per-epoch hit rates across streams
    # Global latency distribution over every stream's samples pooled:
    p50_latency_s: float = 0.0
    p95_latency_s: float = 0.0
    p99_latency_s: float = 0.0
    # Request-level accounting (request_queue front-end; None/zeros otherwise):
    admission: str | None = None
    requests_shed: int = 0
    deadline_hits: int = 0
    deadline_total: int = 0
    # Fault-tolerance accounting (None/zeros without an injector):
    requests_timed_out: int = 0
    requests_retried: int = 0
    requests_degraded: int = 0
    unserved: int = 0  # requests/batches still queued when the loop ended
    fault_policy: str = "fail"
    faults: dict | None = None  # FaultInjector.counts() at report time
    error: str | None = None  # terminal error repr (run(raise_on_error=False))
    failovers: list = dataclasses.field(default_factory=list)  # shard-loss log
    # Sharded serving (runtime/sharded_serve.py): per-shard hit/byte/
    # allocation accounting; single-device runs leave the defaults.
    num_shards: int = 1
    shards: list | None = None
    # The RESOLVED ServeConfig the serve loop actually ran with (knobs and
    # caps read back off the live server at report time, so the echo
    # reflects e.g. a refresh-resized auto window, never the request).
    config: ServeConfig | None = None
    # MetricsRegistry.snapshot() taken at report time when the server was
    # given a registry (``--metrics``); None otherwise.
    metrics: dict | None = None

    @property
    def total_batches(self) -> int:
        return sum(s.num_batches for s in self.streams)

    @property
    def total_seeds(self) -> int:
        return sum(s.num_seeds for s in self.streams)

    @property
    def adj_hits(self) -> int:
        return sum(s.adj_hits for s in self.streams)

    @property
    def adj_lookups(self) -> int:
        return sum(s.adj_lookups for s in self.streams)

    @property
    def feat_hits(self) -> int:
        return sum(s.feat_hits for s in self.streams)

    @property
    def feat_lookups(self) -> int:
        return sum(s.feat_lookups for s in self.streams)

    @property
    def unique_rows(self) -> int:
        return sum(s.unique_rows for s in self.streams)

    @property
    def gathered_rows(self) -> int:
        return sum(s.gathered_rows for s in self.streams)

    @property
    def duplication_factor(self) -> float:
        """Aggregate input-frontier duplication removed by dedup (1.0 off)."""
        if not self.unique_rows:
            return 1.0
        return self.feat_lookups / self.unique_rows

    @property
    def adj_hit_rate(self) -> float:
        return self.adj_hits / max(self.adj_lookups, 1)

    @property
    def feat_hit_rate(self) -> float:
        return self.feat_hits / max(self.feat_lookups, 1)

    @property
    def throughput_seeds_per_s(self) -> float:
        return self.total_seeds / max(self.wall_seconds, 1e-12)

    @property
    def deadline_hit_rate(self) -> float:
        """Fraction of deadline-carrying requests retired on time (shed
        and late requests both count as misses); 1.0 when no request
        carried a deadline.  Timed-out requests are excluded from the
        denominator — they are reported separately as
        ``requests_timed_out``, not silently folded into SLO misses."""
        if not self.deadline_total:
            return 1.0
        return self.deadline_hits / self.deadline_total

    @property
    def availability(self) -> float:
        """Fraction of *offered* work that completed (degraded service
        counts as available — the request was answered, and marked).
        Offered = completed + shed + still-queued-at-exit; a fail-fast
        run that dies early therefore scores near zero, which is exactly
        the contrast bench_faults gates degraded mode against."""
        completed = self.total_batches
        offered = completed + self.requests_shed + self.unserved
        if not offered:
            return 1.0
        return completed / offered

    def modeled_transfer_seconds(self, slow_bw: float = PCIE4_BW, fast_bw: float = HBM_BW) -> float:
        """Project aggregate byte movement onto a slow-miss / fast-hit link
        pair (the model shared with
        :class:`~repro.runtime.gnn_engine.InferenceReport`)."""
        return modeled_transfer_seconds(
            feat_lookups=self.feat_lookups,
            feat_hits=self.feat_hits,
            adj_lookups=self.adj_lookups,
            adj_hits=self.adj_hits,
            feat_row_bytes=self.feat_row_bytes,
            slow_bw=slow_bw,
            fast_bw=fast_bw,
        )

    def summary(self) -> dict:
        out = {
            "policy": self.policy,
            "streams": self.num_streams,
            "depth": self.depth,
            "prefetch": self.prefetch,
            "dedup": self.dedup,
            "batches": self.total_batches,
            "wall_s": round(self.wall_seconds, 4),
            "throughput_seeds_per_s": round(self.throughput_seeds_per_s, 1),
            "adj_hit_rate": round(self.adj_hit_rate, 4),
            "feat_hit_rate": round(self.feat_hit_rate, 4),
            "modeled_transfer_s": round(self.modeled_transfer_seconds(), 6),
            "p50_latency_s": round(self.p50_latency_s, 4),
            "p99_latency_s": round(self.p99_latency_s, 4),
            "per_stream": [s.summary() for s in self.streams],
        }
        if self.config is not None:
            out["config"] = self.config.to_dict()
        if self.admission is not None:
            out["admission"] = self.admission
            out["requests_shed"] = self.requests_shed
            if self.deadline_total:
                out["deadline_hit_rate"] = round(self.deadline_hit_rate, 4)
        if self.faults is not None:
            out["fault_policy"] = self.fault_policy
            out["faults"] = self.faults
            out["availability"] = round(self.availability, 4)
            out["requests_timed_out"] = self.requests_timed_out
            out["requests_retried"] = self.requests_retried
            out["requests_degraded"] = self.requests_degraded
            out["requests_shed"] = self.requests_shed
            out["unserved"] = self.unserved
        if self.failovers:
            out["failovers"] = self.failovers
        if self.error is not None:
            out["error"] = self.error
        if self.dedup:
            out["unique_rows"] = self.unique_rows
            out["gathered_rows"] = self.gathered_rows
            out["duplication_factor"] = round(self.duplication_factor, 2)
        if self.epochs is not None:
            # With refresh on, the lifetime aggregate above hides the
            # post-refresh recovery — the per-epoch split is the headline.
            out["per_epoch"] = self.epochs
            out["refresh_events"] = [e.summary() for e in self.refresh_events]
        if self.shards is not None:
            out["num_shards"] = self.num_shards
            out["per_shard"] = self.shards
        if self.metrics is not None:
            out["metrics"] = self.metrics
        return out


class MultiStreamServer:
    """Serve N seed-batch streams through one pipelined executor + caches.

    Built on a *prepared* :class:`~repro.runtime.gnn_engine.GNNInferenceEngine`
    (its ``pipeline`` holds the shared DualCache and the policy metadata;
    its params are the shared model weights).

    ``depth`` is the executor window (1 = serial, >1 keeps that many
    batches in flight across streams).  ``max_inflight_per_stream`` is the
    backpressure cap: round-robin admission skips a stream that already
    occupies that many window slots, so one deep queue cannot monopolize
    the pipeline.  When every stream with pending work is at its cap the
    least-loaded one is admitted anyway — admission must make progress
    (retires only happen after the next dispatch), so the cap bounds
    *relative* occupancy rather than deadlocking the window.

    ``prefetch`` (default: the prepared pipeline's knob) inserts the
    miss-row staging stage into the shared schedule.  Per-stream prefetch
    respects the same backpressure cap automatically: a stream's staged
    buffers live in its admitted batches' contexts and are released at
    retire, so a stream can never hold more than its in-flight cap's
    worth of prefetched buffers — admission (and with it the next
    ``device_put``) is what the cap throttles.
    """

    def __init__(
        self,
        engine: GNNInferenceEngine,
        *,
        config: ServeConfig | None = None,
        depth: int | str | None = None,
        max_inflight_per_stream: int | None = None,
        prefetch: bool | None = None,
        use_kernel: bool | None = None,
        gather_buffers: int | None = None,
        dedup: bool | None = None,
        refresh=None,
        tracer=None,
        metrics=None,
        injector=None,
    ):
        if engine.pipeline is None:
            raise RuntimeError("prepare() the engine before constructing the server")
        # Live observability handles (core/trace.py) — keyword-only and
        # deliberately NOT part of ServeConfig, which stays a frozen,
        # JSON-round-trippable value object.
        self.tracer = resolve_tracer(tracer)
        self.metrics = metrics
        # ``config`` is the one knob object (ServeConfig wrapping an
        # EngineConfig); the loose keywords remain as a deprecated
        # one-release shim — any passed value merges over the config
        # (coalesce), bit-for-bit equivalent to passing it directly.
        cfg = coalesce(
            config,
            ServeConfig,
            _context=type(self).__name__,
            max_inflight=max_inflight_per_stream,
        )
        if any(v is not None for v in (depth, prefetch, use_kernel, gather_buffers, dedup)):
            cfg = cfg.replace(
                engine=coalesce(
                    cfg.engine,
                    EngineConfig,
                    _context=type(self).__name__,
                    pipeline_depth=depth,
                    prefetch=prefetch,
                    use_kernel=use_kernel,
                    gather_buffers=gather_buffers,
                    dedup=dedup,
                )
            )
        self.config = cfg
        # Fault-tolerance wiring (core/faults.py, core/retry.py).  The
        # injector is a live handle like tracer/metrics — pass one in, or
        # point ``cfg.faults`` at a FaultPlan JSON.  With neither, every
        # guard below is a single ``is None`` test and the serve path is
        # bit-for-bit the pre-fault-subsystem one.
        if injector is None and cfg.faults is not None:
            injector = FaultInjector(FaultPlan.load(cfg.faults))
        if injector is not None and not injector.tracer.enabled:
            injector.tracer = self.tracer
        self.injector = injector
        self.retry_policy = cfg.retry_policy()
        self.degraded_mode = cfg.degraded_mode
        self.fault_policy = cfg.fault_policy
        self._last_error: str | None = None
        depth = 2 if cfg.engine.pipeline_depth is None else cfg.engine.pipeline_depth
        self._auto_depth = depth == "auto"
        if depth == "auto":
            depth = engine.resolve_pipeline_depth("auto")
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.engine = engine
        self.depth = depth
        pipe = engine.pipeline
        if refresh is None:
            refresh = cfg.engine.refresh_config()
        self.refresh_manager = None
        if refresh is not None and refresh.enabled:
            from repro.runtime.cache_refresh import CacheRefreshManager

            self.refresh_manager = CacheRefreshManager(
                pipe,
                engine.dataset,
                fanouts=engine.fanouts,
                batch_size=engine.batch_size,
                config=refresh,
            )
            # Weighted telemetry merges (stream_weighting != "none") ask the
            # server for each stream's live pressure at refresh time.
            self.refresh_manager.set_weight_fn(self._stream_weight)
            self.refresh_manager.tracer = self.tracer
            self.refresh_manager.injector = self.injector
        self._started = False  # join/leave events fire only once serving began
        self._executor = None  # live executor during run() (auto-depth hook)
        self._serve_t0 = None  # perf_counter at serve start (arrival clock origin)
        eng_cfg = cfg.engine
        self.prefetch = pipe.prefetch if eng_cfg.prefetch is None else eng_cfg.prefetch
        self.use_kernel = pipe.use_kernel if eng_cfg.use_kernel is None else eng_cfg.use_kernel
        self.gather_buffers = (
            pipe.gather_buffers if eng_cfg.gather_buffers is None else eng_cfg.gather_buffers
        )
        self.dedup = (
            pipe.dedup if eng_cfg.dedup is None else eng_cfg.dedup
        ) and not pipe.reuse_prev_batch
        # Remember whether the cap was explicit: a defaulted cap follows
        # the window when refresh-aware auto depth resizes it mid-run (a
        # deeper window is useless if admission still stops at the old
        # depth), an explicit cap is the caller's backpressure contract
        # and stays put.
        self._explicit_inflight_cap = cfg.max_inflight is not None
        self.max_inflight = cfg.max_inflight if cfg.max_inflight is not None else depth
        if self.max_inflight < 1:
            raise ValueError("max_inflight_per_stream must be >= 1")
        self.streams: list[StreamState] = []
        self.admission_log: list[tuple[int, int]] = []  # (stream_id, per-stream batch idx)
        self._rr = 0  # round-robin cursor

    # ------------------------------------------------------------- intake
    def add_stream(
        self,
        batches: Sequence[np.ndarray],
        *,
        seed: int | None = None,
        collect_outputs: bool = False,
    ) -> StreamState:
        """Register a stream with its full seed-batch queue.

        ``seed`` fixes the stream's RNG: the stream's results are
        bit-identical to ``GNNInferenceEngine(seed=seed, ...)`` running the
        same ``batches`` alone against the same prepared pipeline.

        With online refresh enabled, a stream added AFTER serving has
        started is a serve-time join: the refresh manager presamples the
        new seed, re-merges it into the workload history, and (in event
        modes) applies an incremental refresh so the shared cache serves
        the new union workload.  Existing streams observe only the epoch
        bump — their outputs stay serial-equivalent."""
        sid = len(self.streams)
        if seed is None:
            seed = self.engine.seed + sid
        runtime = self._make_runtime(sid, seed, collect_outputs=collect_outputs)
        runtime.tracer = self.tracer
        state = StreamState(
            stream_id=sid,
            seed=seed,
            runtime=runtime,
            clock=StageClock(overlap=self.depth > 1),
            queue=collections.deque(np.asarray(b) for b in batches),
        )
        self.streams.append(state)
        if self.refresh_manager is not None:
            # Under weighting="none" telemetry_for returns the shared sink
            # (the pre-weighting path, byte-identical); otherwise each
            # stream records into its own sink so refresh can weight them.
            runtime.telemetry = self.refresh_manager.telemetry_for(sid)
            self.refresh_manager.register_clock(state.clock, key=sid)
            if self._started:
                self.refresh_manager.on_stream_join(seed)
        return state

    def _make_runtime(self, sid: int, seed: int, *, collect_outputs: bool) -> StreamRuntime:
        """Construct one stream's :class:`StreamRuntime`.  The sharded
        server overrides this to hand out shard-aware runtimes; RNG,
        knobs, and accounting are resolved identically either way."""
        del sid
        return StreamRuntime(
            self.engine.pipeline,
            self.engine.params,
            model=self.engine.model,
            fanouts=self.engine.fanouts,
            num_nodes=self.engine.dataset.num_nodes,
            key=jax.random.PRNGKey(seed + 1),
            collect_outputs=collect_outputs,
            prefetch=self.prefetch,
            use_kernel=self.use_kernel,
            gather_buffers=self.gather_buffers,
            dedup=self.dedup,
            injector=self.injector,
            retry_policy=self.retry_policy,
            degraded_mode=self.degraded_mode,
        )

    def remove_stream(self, stream_id: int) -> StreamState:
        """Serve-time leave: drop the stream's remaining queue (batches
        already in flight still retire normally) and, with refresh
        enabled, re-merge the workload without it and refresh the shared
        cache incrementally."""
        state = self.streams[stream_id]
        state.queue.clear()
        if self.refresh_manager is not None and self._started:
            self.refresh_manager.on_stream_leave(state.seed)
        return state

    # ---------------------------------------------------------- admission
    def _next_stream(self, eligible: Sequence[StreamState]) -> StreamState:
        """Round-robin over ``eligible`` streams, honoring the in-flight
        cap; falls back to the least-loaded eligible stream when everyone
        is saturated (see class docstring).

        ``eligible`` is whichever subset has admissible work right now —
        the queue-backed base server passes every stream with a non-empty
        queue; the request front-end passes streams whose head request has
        *arrived*.  Cursor mechanics are identical either way, so with all
        streams always eligible this reproduces the pre-request-queue
        admission log bit-for-bit."""
        n = len(self.streams)
        keys = {s.stream_id for s in eligible}
        for off in range(n):
            s = self.streams[(self._rr + off) % n]
            if s.stream_id in keys and s.inflight < self.max_inflight:
                self._rr = (s.stream_id + 1) % n
                return s
        s = min(eligible, key=lambda s: (s.inflight, (s.stream_id - self._rr) % n))
        self._rr = (s.stream_id + 1) % n
        return s

    def _admission(self):
        """Lazy (stream, payload) generator for the executor: pulled exactly
        when a window slot opens, so the in-flight counts it reads are live."""
        while True:
            pending = [s for s in self.streams if s.queue]
            if not pending:
                return
            s = self._next_stream(pending)
            payload = s.queue.popleft()
            self.admission_log.append((s.stream_id, s.submitted))
            s._admit_times[s.submitted] = time.perf_counter()
            s.submitted += 1
            s.inflight += 1
            s.max_inflight_seen = max(s.max_inflight_seen, s.inflight)
            if self.tracer.enabled:
                self._trace_admit(s, batch=s.submitted - 1)
            yield (s, payload)

    # ---------------------------------------------------------- tracing
    def _enqueue_ts_us(self, s: StreamState, batch: int) -> float:
        """Tracer timestamp at which batch ``batch`` of stream ``s`` was
        enqueued.  The queue-backed server's batches all exist at serve
        start; the request front-end overrides this with the request's
        arrival clock."""
        del s, batch
        return self.tracer.ts_from(self._serve_t0) if self._serve_t0 is not None else 0.0

    def _trace_admit(self, s: StreamState, *, batch: int) -> None:
        """Request-lifecycle tracing at admission: a ``queued`` span
        (enqueue → admit) on the stream's request lane, the start of the
        batch's flow (linked through the executor's batch span to the
        ``service`` span at retire), and queue-depth/inflight counters."""
        tr = self.tracer
        now = tr.now_us()
        lane = f"req:s{s.stream_id}"
        enq = min(self._enqueue_ts_us(s, batch), now)
        tr.complete("queued", lane=lane, ts_us=enq, dur_us=now - enq, args={"batch": batch})
        fid = tr.next_flow_id()
        s._flow_ids[batch] = fid
        # Anchored mid-span so Perfetto binds the arrow to the queued slice.
        tr.flow_start(fid, "req", lane=lane, ts_us=(enq + now) / 2)
        tr.counter(
            "queue_depth", {f"s{st.stream_id}": float(len(st.queue)) for st in self.streams}
        )
        tr.counter("inflight", {"batches": float(sum(st.inflight for st in self.streams))})

    def _trace_retire(self, ctx, s: StreamState, admit_t: float, now_t: float) -> None:
        """The retire half of the lifecycle: a ``service`` span (admit →
        retire), a flow step through the executor batch span the request
        actually rode in (its window slot), and the flow end."""
        tr = self.tracer
        lane = f"req:s{s.stream_id}"
        admit_us, now_us = tr.ts_from(admit_t), tr.ts_from(now_t)
        tr.complete(
            "service",
            lane=lane,
            ts_us=admit_us,
            dur_us=now_us - admit_us,
            args={"batch": s.retired, "epoch": ctx.epoch},
        )
        fid = s._flow_ids.pop(s.retired, None)
        if fid is not None:
            tr.flow_step(fid, "req", lane=f"slot {ctx.slot}", ts_us=ctx.trace_t0 + 1.0)
            tr.flow_end(fid, "req", lane=lane, ts_us=(admit_us + now_us) / 2)
        tr.counter("inflight", {"batches": float(sum(st.inflight for st in self.streams))})

    def _on_retire(self, ctx) -> None:
        s: StreamState = ctx.stream
        s.runtime.record(ctx)
        if ctx.outputs.get("_retried"):
            s.batches_retried += 1
            if self.metrics is not None:
                self.metrics.counter("requests_retried_total", stream=s.stream_id).inc()
        if ctx.outputs.get("_degraded"):
            s.batches_degraded += 1
            if self.metrics is not None:
                self.metrics.counter("requests_degraded_total", stream=s.stream_id).inc()
        now_t = time.perf_counter()
        admit_t = s._admit_times.pop(s.retired)
        latency = now_t - admit_t
        s.latencies.append(latency)
        n_seeds = int(np.asarray(ctx.payload).shape[0])
        s.seeds_served += n_seeds
        s.inflight -= 1
        if self.tracer.enabled:
            self._trace_retire(ctx, s, admit_t, now_t)
        if self.metrics is not None:
            self.metrics.histogram("request_latency_ms", stream=s.stream_id).observe(
                latency * 1e3
            )
            self.metrics.counter("batches_retired_total", stream=s.stream_id).inc()
            self.metrics.counter("seeds_served_total", stream=s.stream_id).inc(n_seeds)
        s.retired += 1
        if self.refresh_manager is not None:
            # Retire runs between dispatches, so an interval refresh lands
            # here — in-flight batches keep the old epoch's arrays.
            event = self.refresh_manager.note_retired()
            if event is not None:
                self._apply_refresh_event(event)

    # ------------------------------------------------------ fault shedding
    @staticmethod
    def _fault_root(err: BaseException) -> BaseException:
        """The underlying fault behind a retry-exhausted wrapper."""
        return err.last if isinstance(err, RetryExhausted) else err

    def _on_batch_error(self, ctx, err: BaseException) -> bool:
        """Executor hook under ``fault_policy="shed"``: drop JUST the
        failing batch (after its retries exhausted) and keep serving.

        Only fault-subsystem errors are shed — injected faults, retry
        exhaustion, and stage timeouts; anything else is a real bug and
        propagates.  The dying batch is always the most recently admitted
        (stages dispatch synchronously at admission), so its per-stream
        index is ``submitted - 1``; rolling ``submitted`` back keeps the
        retire-side ``_admit_times.pop(retired)`` bookkeeping contiguous,
        and a batch is counted shed XOR completed, never both."""
        if not isinstance(err, (InjectedFault, RetryExhausted, StageTimeout)):
            return False
        s: StreamState = ctx.stream
        root = self._fault_root(err)
        idx = s.submitted - 1
        self._shed_inflight(s, idx, root)
        if self.tracer.enabled:
            self.tracer.complete(
                "shed",
                lane="faults",
                ts_us=self.tracer.now_us(),
                dur_us=0.0,
                args={
                    "stream": s.stream_id,
                    "batch": idx,
                    "error": type(root).__name__,
                    "site": getattr(root, "site", None),
                },
            )
        return True

    def _shed_inflight(self, s: StreamState, idx: int, root: BaseException) -> None:
        """Undo batch ``idx``'s admission-side bookkeeping and count it
        shed.  The request front-end extends this to mark the riding
        request shed/timed-out as well."""
        s._admit_times.pop(idx, None)
        s._flow_ids.pop(idx, None)
        s.submitted -= 1
        s.inflight -= 1
        s.batches_shed += 1
        if isinstance(root, StageTimeout):
            s.batches_timed_out += 1
            if self.metrics is not None:
                self.metrics.counter("requests_timed_out_total", stream=s.stream_id).inc()
        if self.metrics is not None:
            self.metrics.counter("requests_shed_total", stream=s.stream_id).inc()

    def _note_failed_admission(self, err: BaseException) -> None:
        """After a terminal executor error: the failing batch was admitted
        but never retired (the drain covered only the others) — roll its
        bookkeeping back so shed XOR completed still holds in the partial
        report."""
        root = self._fault_root(err)
        for s in self.streams:
            while s.inflight > 0 and s.submitted > s.retired:
                self._shed_inflight(s, s.submitted - 1, root)

    def _apply_refresh_event(self, event) -> None:
        """React to a refresh that just fired on the retire path.  The
        base server resizes the auto-depth window; the sharded server
        additionally repartitions its per-shard stores to the new epoch."""
        if (
            self._auto_depth
            and self._executor is not None
            and self.refresh_manager.suggested_depth
        ):
            # Refresh-aware "auto": resize the live window from the
            # refreshed stage laps; applies at the next admission.
            self._executor.depth = self.refresh_manager.suggested_depth
            self.depth = self.refresh_manager.suggested_depth
            if not self._explicit_inflight_cap:
                self.max_inflight = self.depth

    # ----------------------------------------------------------------- run
    def _warmup_seeds(self) -> np.ndarray | None:
        """Seed batch to compile against before the timed loop — the first
        queued batch (the request front-end overrides this to peek at its
        arrival-sorted request queues).  None → nothing queued, skip."""
        for s in self.streams:
            if s.queue:
                return s.queue[0]
        return None

    def _stream_weight(self, key) -> float:
        """Live pressure of stream ``key`` for weighted telemetry merges:
        1 (base) + queued batches + in-flight batches.  The request
        front-end extends this with SLO pressure."""
        s = self.streams[key]
        return 1.0 + len(s.queue) + s.inflight

    def run(self, *, warmup: bool = True, raise_on_error: bool = True) -> ServeReport:
        """Serve every queued batch and return the :class:`ServeReport`.

        ``raise_on_error=False`` converts a terminal fault-subsystem error
        (injected fault / retry exhaustion / stage timeout escaping the
        executor under ``fault_policy != "shed"``) into a PARTIAL report:
        in-flight batches drain with full accounting, the error lands on
        ``report.error``, and unserved batches count against
        ``report.availability`` — the fail-fast arm of bench_faults.
        Real bugs always propagate."""
        if not self.streams:
            raise RuntimeError("add_stream() at least one stream before run()")
        self._started = True
        if warmup:
            seeds = self._warmup_seeds()
            if seeds is not None:
                self.engine.warmup(
                    seeds,
                    prefetch=self.prefetch,
                    use_kernel=self.use_kernel,
                    gather_buffers=self.gather_buffers,
                    dedup=self.dedup,
                )
                if self.refresh_manager is not None:
                    # Pre-compile the post-growth gather program too, so a
                    # mid-serve refresh that doubles the hot table doesn't
                    # pay XLA compile time on the serve path.
                    self.engine.warmup_refresh_growth(
                        seeds,
                        use_kernel=self.use_kernel,
                        gather_buffers=self.gather_buffers,
                        dedup=self.dedup,
                    )
        executor = PipelinedExecutor(
            stream_stages(lambda c: c.stream.runtime, prefetch=self.prefetch),
            depth=self.depth,
            clock_for=lambda c: c.stream.clock,
            on_retire=self._on_retire,
            on_batch_error=self._on_batch_error if self.fault_policy == "shed" else None,
            tracer=self.tracer,
        )
        self._executor = executor
        self._last_error = None
        self._serve_t0 = t0 = time.perf_counter()
        if self.tracer.enabled:
            self.tracer.instant(
                "serve-start", lane="serve", args={"streams": len(self.streams)}
            )
        try:
            executor.run_tagged(self._admission())
        except (InjectedFault, RetryExhausted, StageTimeout) as err:
            # The executor already drained in-flight batches (accounting
            # ran); the failing batch itself never retired — undo its
            # admission-side bookkeeping so shed XOR completed holds.
            self._note_failed_admission(err)
            if raise_on_error:
                raise
            self._last_error = repr(err)
        wall = time.perf_counter() - t0
        self._executor = None
        report = self._serve_report(wall)
        if self.metrics is not None:
            self._record_metrics(report)
            report.metrics = self.metrics.snapshot()
        return report

    def _record_metrics(self, report: ServeReport) -> None:
        """Fold the run's aggregate outcomes into the metrics registry —
        the labelled-gauge view of what the report holds as dataclasses
        (``feat_hit_rate{stream=...,epoch=...}`` et al.)."""
        m = self.metrics
        m.gauge("throughput_seeds_per_s").set(report.throughput_seeds_per_s)
        for sr in report.streams:
            m.gauge("feat_hit_rate", stream=sr.stream_id).set(sr.feat_hit_rate)
            m.gauge("adj_hit_rate", stream=sr.stream_id).set(sr.adj_hit_rate)
            if sr.requests_shed:
                m.counter("requests_shed_total", stream=sr.stream_id).inc(sr.requests_shed)
            if sr.epoch_hits:
                for epoch, rates in sr.epoch_hits.items():
                    m.gauge("feat_hit_rate", stream=sr.stream_id, epoch=epoch).set(
                        rates["feat_hit_rate"]
                    )
        for ev in report.refresh_events:
            m.counter("refresh_epochs_total", reason=ev.reason).inc()

    def _resolved_config(self) -> ServeConfig:
        """The ServeConfig the serve loop ACTUALLY ran with, read back off
        the live server at report time — after auto-depth resolution (and
        any refresh-driven resize), knob fallbacks to the prepared
        pipeline, and the in-flight cap's follow-the-window default."""
        return self.config.replace(
            max_inflight=self.max_inflight,
            engine=self.config.engine.replace(
                pipeline_depth=self.depth,
                prefetch=self.prefetch,
                use_kernel=self.use_kernel,
                gather_buffers=self.gather_buffers,
                dedup=self.dedup,
            ),
        )

    def _serve_report(self, wall: float) -> ServeReport:
        pooled: list[float] = []
        for s in self.streams:
            pooled.extend(s.latencies)
        _, _, p50, p95, p99 = _latency_stats(pooled)
        stream_reports = [self._stream_report(s) for s in self.streams]
        return ServeReport(
            policy=self.engine.pipeline.name,
            num_streams=len(self.streams),
            depth=self.depth,
            max_inflight_per_stream=self.max_inflight,
            wall_seconds=wall,
            feat_row_bytes=self.engine.dataset.feature_nbytes_per_row(),
            streams=stream_reports,
            prefetch=self.prefetch,
            dedup=self.dedup,
            refresh_events=(
                list(self.refresh_manager.events) if self.refresh_manager is not None else []
            ),
            epochs=self._aggregate_epochs() if self.refresh_manager is not None else None,
            p50_latency_s=p50,
            p95_latency_s=p95,
            p99_latency_s=p99,
            config=self._resolved_config(),
            requests_shed=sum(r.requests_shed for r in stream_reports),
            requests_timed_out=sum(r.requests_timed_out for r in stream_reports),
            requests_retried=sum(r.requests_retried for r in stream_reports),
            requests_degraded=sum(r.requests_degraded for r in stream_reports),
            unserved=self._unserved(),
            fault_policy=self.fault_policy,
            faults=self.injector.counts() if self.injector is not None else None,
            error=self._last_error,
        )

    def _unserved(self) -> int:
        """Work still queued when the serve loop ended (terminal error or
        shed-everything storms leave a non-empty tail); the availability
        denominator counts it as offered-but-not-served."""
        return sum(len(s.queue) for s in self.streams)

    def _aggregate_epochs(self) -> dict[int, dict]:
        """Sum per-epoch counters across streams — the shared cache's view."""
        totals: dict[int, list[int]] = {}
        for s in self.streams:
            for epoch, c in s.runtime.epoch_counters.items():
                agg = totals.setdefault(epoch, [0, 0, 0, 0, 0])
                for i, v in enumerate(c):
                    agg[i] += v
        return summarize_epoch_counters(totals)

    def _stream_report(self, s: StreamState) -> StreamReport:
        rt = s.runtime
        mean, mx, p50, p95, p99 = _latency_stats(s.latencies)
        return StreamReport(
            stream_id=s.stream_id,
            seed=s.seed,
            num_batches=s.retired,
            num_seeds=s.seeds_served,
            sample_seconds=s.clock.total("sample"),
            feature_seconds=s.clock.total("feature"),
            compute_seconds=s.clock.total("compute"),
            adj_hits=rt.adj_hits,
            adj_lookups=rt.adj_lookups,
            feat_hits=rt.feat_hits,
            feat_lookups=rt.feat_lookups,
            mean_latency_s=mean,
            max_latency_s=mx,
            p50_latency_s=p50,
            p95_latency_s=p95,
            p99_latency_s=p99,
            prefetch_seconds=s.clock.total("prefetch"),
            prefetched_rows=rt.prefetched_rows,
            unique_rows=rt.unique_rows,
            gathered_rows=rt.gathered_rows,
            epoch_hits=rt.epoch_hit_rates() if self.refresh_manager is not None else None,
            requests_shed=s.batches_shed,
            requests_timed_out=s.batches_timed_out,
            requests_retried=s.batches_retried,
            requests_degraded=s.batches_degraded,
            stage_retries=rt.stage_retries,
        )


def make_stream_batches(
    dataset,
    *,
    num_streams: int,
    batches_per_stream: int,
    batch_size: int,
    seed: int = 0,
) -> list[list[np.ndarray]]:
    """Per-stream seed-batch queues over the dataset's test nodes.

    Each stream draws its batches from its own shuffled permutation of the
    test set (rng ``seed + stream_id``) — independent request streams over
    the same graph, with the overlapping hot set that makes a *shared*
    cache worth more than N private ones."""
    out: list[list[np.ndarray]] = []
    need = batches_per_stream * batch_size
    for sid in range(num_streams):
        rng = np.random.default_rng(seed + sid)
        ids = rng.permutation(dataset.test_idx)
        if len(ids) < need:  # tiny datasets: cycle to fill the queue
            ids = np.tile(ids, -(-need // max(len(ids), 1)))
        out.append(list(ids[:need].reshape(batches_per_stream, batch_size)))
    return out
