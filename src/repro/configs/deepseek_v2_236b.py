"""DeepSeek-V2 236B [arXiv:2405.04434].

60 layers, d_model=5120, 128 heads with MLA (kv_lora=512, rope 64,
nope 128, v 128), MoE: 160 routed experts top-6 + 2 shared,
d_ff_expert=1536, vocab=102400.
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig, MLAConfig, MoEConfig

CONFIG = LMConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,  # nope(128) + rope(64); bookkeeping only under MLA
    d_ff=1536,
    vocab=102400,
    attn_kind="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_ff_expert=1536,
        n_shared=2,
        d_ff_shared=3072,
    ),
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=48,
    d_ff=128,
    vocab=512,
    mla=MLAConfig(
        kv_lora_rank=32, q_lora_rank=48, rope_head_dim=16, nope_head_dim=32, v_head_dim=32
    ),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, n_shared=1, d_ff_shared=128),
)
