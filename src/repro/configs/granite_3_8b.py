"""IBM Granite-3 8B [hf:ibm-granite/granite-3.0-2b-base family].

40 layers, d_model=4096, 32 heads (GQA kv=8), d_ff=12800, vocab=49155.
Dense full attention; long_500k uses the sliding-window carve-in.
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    arch_id="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab=49155,
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
)
