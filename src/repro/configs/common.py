"""Shared helpers for architecture configs + the assigned input shapes."""

from __future__ import annotations

import dataclasses

from repro.models.lm.config import LMConfig

__all__ = ["INPUT_SHAPES", "InputShape"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned input shapes.
INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: LMConfig, **overrides) -> LMConfig:
    """Build the smoke-test variant: same family, toy dims."""
    return dataclasses.replace(cfg, **overrides)
