"""Gemma 2B [arXiv:2403.08295].

18 layers, d_model=2048, 8 heads with MQA (kv=1), head_dim=256,
GeGLU d_ff=16384, vocab=256000.
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    arch_id="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    activation="geglu",
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab=512,
)
