"""Yi-6B [arXiv:2403.04652].

Llama-architecture GQA: 32 layers, d_model=4096, 32 heads (kv=4),
d_ff=11008, vocab=64000, rope theta 5e6.
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    arch_id="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab=64000,
    rope_theta=5_000_000.0,
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
)
