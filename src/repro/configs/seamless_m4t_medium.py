"""SeamlessM4T-medium transformer backbone [arXiv:2308.11596].

Encoder-decoder, 12+12 layers, d_model=1024, 16 heads (kv=16 -> MHA),
d_ff=4096, vocab=256206.  The audio frontend (mel + conv) is a stub:
input_specs supplies precomputed frame embeddings (DESIGN.md carve-out).
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    arch_id="seamless-m4t-medium",
    family="encdec-audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    encoder_layers=12,
    input_mode="embeds",  # encoder side consumes frame embeddings
    activation="gelu",
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    encoder_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab=512,
)
