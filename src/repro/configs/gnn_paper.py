"""The paper's own model/dataset configurations (Table II/III)."""

GNN_MODELS = {
    "graphsage": {"layers": 3, "agg": "sum", "hidden": 128},
    "gcn": {"layers": 3, "agg": "avg", "hidden": 128},
}

FANOUTS = {"small": (2, 2, 2), "medium": (8, 4, 2), "large": (15, 10, 5)}
BATCH_SIZES = (256, 1024, 4096)
