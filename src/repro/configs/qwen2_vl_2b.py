"""Qwen2-VL 2B [arXiv:2409.12191].

28 layers, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936,
M-RoPE (temporal/height/width rotary sections).  The ViT frontend is a
stub: input_specs supplies patch+text embeddings (DESIGN.md carve-out);
decode is plain text decoding.
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    input_mode="embeds",
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    mrope_sections=(4, 6, 6),
)
