"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``."""

from __future__ import annotations

import importlib

from repro.configs.common import INPUT_SHAPES, InputShape
from repro.models.lm.config import LMConfig

_MODULES = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "rwkv6-3b": "rwkv6_3b",
    "granite-3-8b": "granite_3_8b",
    "gemma2-27b": "gemma2_27b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "gemma-2b": "gemma_2b",
    "yi-6b": "yi_6b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> LMConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> LMConfig:
    return _module(arch_id).SMOKE


__all__ = ["ARCH_IDS", "INPUT_SHAPES", "InputShape", "get_config", "get_smoke"]
