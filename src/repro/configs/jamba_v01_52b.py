"""Jamba v0.1 (52B) [arXiv:2403.19887].

32 layers, period-8 blocks with attention:mamba = 1:7 (attention at
position 4 of each block), MoE (16 experts top-2) on every other layer,
d_model=4096, 32 heads (GQA kv=8), dense d_ff=14336, vocab=65536.
No RoPE (Mamba layers carry position).  Hybrid: long_500k runs with the
attention layers ring-buffered, Mamba state O(1).
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig, MoEConfig

CONFIG = LMConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    block_pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba"),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, every=2),
    rope_kind="none",
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    block_pattern=("mamba", "attn"),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=256, every=2),
)
