"""Phi-3.5-MoE-instruct (42B total / 6.6B active)
[hf:microsoft/Phi-3.5-MoE-instruct].

32 layers, d_model=4096, 32 heads (GQA kv=8), 16 experts top-2 with
d_ff_expert=6400, vocab=32064.
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig, MoEConfig

CONFIG = LMConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab=32064,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=256),
)
