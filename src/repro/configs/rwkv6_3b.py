"""RWKV-6 "Finch" 3B [arXiv:2404.05892].

32 layers, d_model=2560 (40 heads x 64), attention-free with
data-dependent decay; channel-mix d_ff=8960; vocab=65536.
Natively O(1)-state: runs long_500k without any carve-in.
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    block_pattern=("rwkv",),
    rope_kind="none",
    rwkv_head_dim=64,
    long_context_window=None,  # attention-free: no window needed
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=128,
    n_heads=2,
    n_kv_heads=2,
    head_dim=64,
    d_ff=256,
    vocab=512,
)
