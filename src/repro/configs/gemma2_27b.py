"""Gemma-2 27B [arXiv:2408.00118].

46 layers alternating local(4096-window)/global attention, d_model=4608,
32 heads (GQA kv=16), d_ff=36864, vocab=256000, GeGLU, logit softcaps
(attn 50, final 30).
"""

from repro.configs.common import reduced
from repro.models.lm.config import LMConfig

CONFIG = LMConfig(
    arch_id="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    block_pattern=("local", "attn"),  # alternating local/global
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    activation="geglu",
)

SMOKE = reduced(
    CONFIG,
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    window=16,
)
