"""GraphSAGE and GCN models (paper Table III: 3 layers, hidden 128, FC apply).

Pure-JAX functional models: ``init_params`` builds a parameter pytree,
``forward`` consumes input-frontier features plus the block structure
(static fan-outs) and produces per-seed logits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.gnn.layers import gcn_layer, sage_layer

__all__ = ["init_params", "forward", "forward_layer", "MODELS"]

MODELS = ("graphsage", "gcn")


def init_params(
    key: jax.Array,
    model: str,
    in_dim: int,
    num_classes: int,
    hidden: int = 128,
    n_layers: int = 3,
) -> list[dict]:
    if model not in MODELS:
        raise ValueError(f"unknown GNN model {model!r}")
    dims = [in_dim] + [hidden] * (n_layers - 1) + [num_classes]
    params = []
    for i in range(n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        scale = 1.0 / jnp.sqrt(dims[i])
        layer = {
            "w_self": jax.random.normal(k1, (dims[i], dims[i + 1]), jnp.float32) * scale,
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        }
        if model == "graphsage":
            layer["w_nbr"] = jax.random.normal(k2, (dims[i], dims[i + 1]), jnp.float32) * scale
        params.append(layer)
    return params


@functools.partial(jax.jit, static_argnames=("model", "fanouts"))
def forward(
    params: list[dict],
    input_feats: jax.Array,
    *,
    model: str,
    fanouts: tuple[int, ...],
    frontier_sizes: tuple[int, ...] | None = None,
    inverse_index: jax.Array | None = None,
) -> jax.Array:
    """Run the GNN over one sampled block.

    ``input_feats`` covers the deepest frontier (``block.input_nodes``).
    Frontier sizes are implied by ``fanouts`` and the seed count, which we
    recover from the feature row count (all shapes are static under jit).

    ``inverse_index`` switches to the unique-frontier form: ``input_feats``
    then holds one row per DISTINCT input node (a deduped gather, possibly
    pow2-padded — the pad rows are never referenced) and ``inverse_index``
    maps every frontier position to its unique row.  The per-frontier
    ``[self | neighbors]`` layout is reconstructed by one gather,
    ``input_feats[inverse_index]`` — each reconstructed row is the same
    bits the duplicate-carrying gather would have produced, so everything
    downstream (and therefore the logits) is bit-identical to the
    ``inverse_index=None`` path.
    """
    rev = tuple(reversed(fanouts))  # expansion order used by sample_blocks
    # Recover seed count: |frontier_L| = B * Π(1 + f)
    mult = 1
    for f in rev:
        mult *= 1 + f
    if inverse_index is not None:
        input_feats = input_feats[inverse_index.astype(jnp.int32)]
    num_seeds = input_feats.shape[0] // mult

    # Frontier sizes from seeds outward.
    sizes = [num_seeds]
    for f in rev:
        sizes.append(sizes[-1] * (1 + f))

    layer_fn = sage_layer if model == "graphsage" else gcn_layer
    h = input_feats
    n_layers = len(fanouts)
    # Walk from the deepest frontier inward; model layer 0 consumes raw feats.
    for li, l in enumerate(range(n_layers - 1, -1, -1)):
        h = layer_fn(params[li], h, sizes[l], rev[l])
        if li < n_layers - 1:
            h = jax.nn.relu(h)
    return h  # [num_seeds, num_classes]


@functools.partial(jax.jit, static_argnames=("model", "num_dst", "relu"))
def forward_layer(
    layer_params: dict,
    self_feats: jax.Array,
    nbr_feats: jax.Array,
    segment_ids: jax.Array,
    degrees: jax.Array,
    *,
    model: str,
    num_dst: int,
    relu: bool = False,
) -> jax.Array:
    """One GNN layer over EXACT neighbor aggregates — the layer-wise mode's
    per-layer split of :func:`forward`.

    Where :func:`forward` consumes a sampled ``[self | neighbors]`` frontier
    (dense ``fanout`` draws per node), this consumes one node-range chunk's
    full in-neighborhoods in CSC order: ``self_feats[num_dst, F]`` are the
    chunk nodes' own rows, ``nbr_feats[E_pad, F]`` the rows of every
    in-edge's source (pow2-padded; pad rows carry ``segment_ids ==
    num_dst`` and land in a dropped extra segment), ``segment_ids`` each
    edge row's destination within the chunk, and ``degrees[num_dst]`` the
    true in-degrees.  Aggregation is a single ``segment_sum`` — the
    ragged-neighborhood analogue of the sampled reshape+reduce.

    With every degree equal to the layer's fanout and sampling enumerating
    deterministically (``sample_neighbors(full_neighborhood=True)``), the
    aggregate equals the sampled sum exactly, so an L-layer chain of these
    is fp-identical to :func:`forward` on regular graphs
    (tests/test_layerwise.py).  Zero-degree nodes aggregate nothing
    (``agg = 0``) — the sampled path's self-loop stand-in has no
    full-neighborhood analogue.

    ``relu`` applies the inter-layer activation (every layer but the last),
    so the chunk executor never re-reads the output just to activate it.
    """
    agg = jax.ops.segment_sum(nbr_feats, segment_ids, num_segments=num_dst + 1)[:num_dst]
    if model == "graphsage":
        h = (
            self_feats @ layer_params["w_self"]
            + agg @ layer_params["w_nbr"]
            + layer_params["b"]
        )
    else:  # gcn: mean over {self} ∪ in-neighbors, single FC
        h = ((self_feats + agg) / (degrees[:, None] + 1.0)) @ layer_params["w_self"]
        h = h + layer_params["b"]
    return jax.nn.relu(h) if relu else h
