from repro.models.gnn.models import MODELS, forward, init_params

__all__ = ["MODELS", "forward", "init_params"]
