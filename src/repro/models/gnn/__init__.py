from repro.models.gnn.models import MODELS, forward, forward_layer, init_params

__all__ = ["MODELS", "forward", "forward_layer", "init_params"]
