"""GraphSAGE / GCN layers over padded sampled blocks (paper Table III).

A block layer's input is a feature matrix over frontier ``l+1`` with the
``[self | neighbors]`` layout produced by ``sample_blocks``; the layer
reduces it to features over frontier ``l``.  With-replacement fan-out
sampling makes neighborhoods dense ``(S, fanout, F)`` tensors, so
aggregation is a plain reshape + reduction — MXU-friendly, no ragged ops.
"""

from __future__ import annotations

import jax

__all__ = ["sage_layer", "gcn_layer", "split_frontier"]


def split_frontier(h: jax.Array, num_dst: int, fanout: int) -> tuple[jax.Array, jax.Array]:
    """Split ``[self | neighbors]`` features: ``(dst[S,F], nbrs[S,fanout,F])``."""
    self_part = h[:num_dst]
    nbr_part = h[num_dst:].reshape(num_dst, fanout, h.shape[-1])
    return self_part, nbr_part


def sage_layer(params: dict, h: jax.Array, num_dst: int, fanout: int) -> jax.Array:
    """GraphSAGE: sum-aggregate neighbors, separate self/neighbor FCs."""
    self_h, nbr_h = split_frontier(h, num_dst, fanout)
    agg = nbr_h.sum(axis=1)
    return self_h @ params["w_self"] + agg @ params["w_nbr"] + params["b"]


def gcn_layer(params: dict, h: jax.Array, num_dst: int, fanout: int) -> jax.Array:
    """GCN: mean over {self} ∪ neighbors, single FC."""
    self_h, nbr_h = split_frontier(h, num_dst, fanout)
    agg = (self_h + nbr_h.sum(axis=1)) / (fanout + 1)
    return agg @ params["w_self"] + params["b"]
