"""Mamba (selective SSM) mixer — Jamba's 7-of-8 layers.

Prefill runs the selective scan as a sequential ``lax.scan`` over time
(the per-step state is tiny; the 32k-step loop lowers to one HLO while
loop, which is what the dry-run compiles).  Decode is the O(1) single-step
recurrence with a (conv window, SSM state) cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.config import LMConfig

__all__ = ["init_mamba_params", "mamba_prefill", "mamba_decode", "init_mamba_cache"]


def _init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)


def _dims(cfg: LMConfig):
    d_inner = cfg.mamba_expand * cfg.d_model
    dt_rank = -(-cfg.d_model // 16)
    return d_inner, dt_rank, cfg.mamba_d_state, cfg.mamba_d_conv


def init_mamba_params(key: jax.Array, cfg: LMConfig, dtype) -> dict:
    d = cfg.d_model
    d_inner, dt_rank, d_state, d_conv = _dims(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, d_state))
    return {
        "in_proj": _init(ks[0], (d, 2 * d_inner), dtype),
        "conv_w": _init(ks[1], (d_conv, d_inner), dtype, fan_in=d_conv),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": _init(ks[2], (d_inner, dt_rank + 2 * d_state), dtype),
        "dt_proj": _init(ks[3], (dt_rank, d_inner), dtype),
        "dt_bias": jnp.zeros((d_inner,), jnp.float32),
        "a_log": jnp.log(a),  # fp32 continuous-time decay
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": _init(ks[4], (d_inner, d), dtype),
    }


def _ssm_inputs(params, u):
    """u: [..., d_inner] -> (dt, bmat, cmat) with fp32 dt."""
    d_inner = u.shape[-1]
    proj = u @ params["x_proj"]
    dt_rank = params["dt_proj"].shape[0]
    d_state = (proj.shape[-1] - dt_rank) // 2
    dt = jax.nn.softplus(
        (proj[..., :dt_rank] @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"]
    )  # [..., d_inner]
    bmat = proj[..., dt_rank : dt_rank + d_state].astype(jnp.float32)
    cmat = proj[..., dt_rank + d_state :].astype(jnp.float32)
    del d_inner
    return dt, bmat, cmat


def _step(params, h, u_t, dt_t, b_t, c_t):
    """One SSM step. h: [B, d_inner, d_state]."""
    a = -jnp.exp(params["a_log"])  # [d_inner, d_state]
    da = jnp.exp(dt_t[..., None] * a)  # [B, d_inner, d_state]
    h = da * h + (dt_t * u_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, c_t) + params["d_skip"] * u_t.astype(jnp.float32)
    return h, y


def _conv_full(params, x):
    """Depthwise causal conv along time. x: [B, S, d_inner]."""
    d_conv = params["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * params["conv_w"][i] for i in range(d_conv)
    )
    return out + params["conv_b"]


def mamba_prefill(params: dict, x: jax.Array, cfg: LMConfig) -> tuple[jax.Array, dict]:
    b, s, _ = x.shape
    d_inner, _, d_state, d_conv = _dims(cfg)
    xz = x @ params["in_proj"]
    xin, z = xz[..., :d_inner], xz[..., d_inner:]
    u = jax.nn.silu(_conv_full(params, xin))  # [B, S, d_inner]
    dt, bmat, cmat = _ssm_inputs(params, u)

    def body(h, t_in):
        u_t, dt_t, b_t, c_t = t_in
        h, y = _step(params, h, u_t, dt_t, b_t, c_t)
        return h, y

    h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
    hT, ys = jax.lax.scan(
        body,
        h0,
        (u.transpose(1, 0, 2), dt.transpose(1, 0, 2), bmat.transpose(1, 0, 2), cmat.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2).astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    cache = {
        "conv": xin[:, -(d_conv - 1) :, :],  # raw inputs for the conv window
        "ssm": hT,
    }
    return out, cache


def init_mamba_cache(cfg: LMConfig, batch: int, dtype) -> dict:
    d_inner, _, d_state, d_conv = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


def mamba_decode(params: dict, x: jax.Array, cache: dict, cfg: LMConfig) -> tuple[jax.Array, dict]:
    """x: [B, 1, d]."""
    d_inner, _, _, d_conv = _dims(cfg)
    xz = x[:, 0, :] @ params["in_proj"]
    xin, z = xz[..., :d_inner], xz[..., d_inner:]
    win = jnp.concatenate([cache["conv"], xin[:, None, :]], axis=1)  # [B, d_conv, d_inner]
    u = jax.nn.silu(jnp.einsum("bcd,cd->bd", win, params["conv_w"]) + params["conv_b"])
    dt, bmat, cmat = _ssm_inputs(params, u)
    h, y = _step(params, cache["ssm"], u, dt, bmat, cmat)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"conv": win[:, 1:, :], "ssm": h}
