"""Top-k MoE with sort-based dispatch (expert-parallel over the model axis).

The dense-compute formulation MaxText-style: assignments are sorted by
expert, each expert processes a static-capacity buffer ``[E, C, d]``, and
results scatter back weighted by the router gate.  FLOPs scale with
``E · C ≈ T · top_k · capacity_factor`` — the *active* compute — not with
the full expert count, so cost_analysis reflects real MoE arithmetic.
Experts are sharded on the ``model`` axis; the dispatch/combine scatters
become the all-to-alls visible in the roofline collective term.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.lm.config import LMConfig
from repro.utils.jax_compat import shard_map_compat

__all__ = [
    "init_moe_params",
    "moe_ffn",
    "init_dense_ffn",
    "dense_ffn",
    "moe_capacity",
    "set_shard_map_context",
]

# (mesh, data_axes, model_axis) — when set (by the launcher), moe_ffn runs
# the explicit shard_map dispatch instead of relying on GSPMD propagation.
# GSPMD cannot partition the data-dependent dispatch/combine scatters and
# falls back to replicating [T·k, d]-sized buffers (the "involuntary full
# rematerialization" warnings; see EXPERIMENTS.md §Perf iteration 1).
_SHARD_MAP_CTX: tuple | None = None


def set_shard_map_context(mesh=None, data_axes: tuple = (), model_axis: str = "model") -> None:
    """Enable (or with mesh=None disable) expert-parallel shard_map MoE."""
    global _SHARD_MAP_CTX
    _SHARD_MAP_CTX = None if mesh is None else (mesh, tuple(data_axes), model_axis)


def _init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[-2]
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)


# ------------------------------------------------------------- dense FFN


def init_dense_ffn(key: jax.Array, d: int, ff: int, activation: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w1": _init(ks[0], (d, ff), dtype), "w2": _init(ks[1], (ff, d), dtype)}
    if activation in ("silu", "geglu"):
        p["w3"] = _init(ks[2], (d, ff), dtype)  # gate
    return p


def _act(h, activation):
    if activation == "silu":
        return jax.nn.silu(h)
    if activation == "geglu":
        return jax.nn.gelu(h)
    if activation == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(activation)


def dense_ffn(params: dict, x: jax.Array, activation: str) -> jax.Array:
    from repro.models.lm.tp import maybe_row_parallel

    h = x @ params["w1"]
    if "w3" in params:
        h = _act(h, activation) * (x @ params["w3"])
    else:
        h = _act(h, activation)
    return maybe_row_parallel(h, params["w2"])


# -------------------------------------------------------------------- MoE


def moe_capacity(num_tokens: int, cfg: LMConfig) -> int:
    m = cfg.moe
    c = int(num_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor 8


def init_moe_params(key: jax.Array, cfg: LMConfig, dtype) -> dict:
    m = cfg.moe
    d, ff = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, m.n_experts), jnp.float32, fan_in=d),
        "we1": _init(ks[1], (m.n_experts, d, ff), dtype, fan_in=d),
        "we2": _init(ks[2], (m.n_experts, ff, d), dtype, fan_in=ff),
        "we3": _init(ks[3], (m.n_experts, d, ff), dtype, fan_in=d),
    }
    if m.n_shared > 0:
        ff_sh = m.d_ff_shared or m.n_shared * ff
        p["shared"] = init_dense_ffn(ks[4], d, ff_sh, cfg.activation, dtype)
    return p


def moe_ffn(params: dict, x: jax.Array, cfg: LMConfig) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,d], aux load-balance loss scalar)."""
    if _SHARD_MAP_CTX is not None:
        return _moe_ffn_shard_map(params, x, cfg, *_SHARD_MAP_CTX)
    return _moe_ffn_gspmd(params, x, cfg)


def _moe_ffn_gspmd(params: dict, x: jax.Array, cfg: LMConfig) -> tuple[jax.Array, jax.Array]:
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.n_experts
    cap = moe_capacity(t, cfg)

    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32)) @ params["router"]  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style): E * Σ_e f_e · p_e
    pe = probs.mean(0)
    fe = jnp.zeros(e, jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(fe * pe)

    # ---- sort-based dispatch ------------------------------------------
    flat_e = expert_idx.reshape(-1)  # [T*k]
    sort_idx = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[sort_idx]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = jnp.arange(t * k) - starts[sorted_e]
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, e * cap)  # overflow bin
    token_of = sort_idx // k

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(xf[token_of])
    buf = buf[:-1].reshape(e, cap, d)

    # ---- expert compute (grouped einsum; E sharded on 'model') --------
    h = jnp.einsum("ecd,edf->ecf", buf, params["we1"])
    g = jnp.einsum("ecd,edf->ecf", buf, params["we3"])
    h = _act(h, cfg.activation) * g
    y = jnp.einsum("ecf,efd->ecd", h, params["we2"])  # [E, C, d]

    # ---- combine -------------------------------------------------------
    yf = y.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], yf[jnp.minimum(dest, e * cap - 1)], 0.0)
    w = gate_vals.reshape(-1)[sort_idx][:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[token_of].add(gathered * w)

    if "shared" in params:
        out = out + dense_ffn(params["shared"], xf, cfg.activation)
    return out.reshape(b, s, d), aux


# --------------------------------------------- explicit expert parallelism


def _moe_ffn_shard_map(
    params: dict, x: jax.Array, cfg: LMConfig, mesh, data_axes: tuple, model_axis: str
) -> tuple[jax.Array, jax.Array]:
    """Megatron-style MoE: tokens sharded on data axes, experts on 'model'.

    Every device owns its expert block AND its token block, so dispatch and
    combine are purely local scatters; the only cross-device traffic is ONE
    bf16 psum of the [T_local, d] output over the model axis (which also
    folds in the tensor-parallel shared-expert partial) — versus GSPMD's
    replicated [T·k, d] buffers.  Batch=1 shapes pass ``data_axes=()``
    (tokens replicated over data, still expert-parallel over model).
    """
    m = cfg.moe
    k = m.top_k
    dspec = P(*( (data_axes if data_axes else None), None, None ))

    has_shared = "shared" in params
    shared_specs = {}
    if has_shared:
        shared_specs = {
            "w1": P(None, model_axis),
            "w2": P(model_axis, None),
        }
        if "w3" in params["shared"]:
            shared_specs["w3"] = P(None, model_axis)
    param_specs = {
        "router": P(None, None),
        "we1": P(model_axis, None, None),
        "we2": P(model_axis, None, None),
        "we3": P(model_axis, None, None),
    }
    if has_shared:
        param_specs["shared"] = shared_specs

    def local_fn(params_l, x_l):
        b_l, s, d = x_l.shape
        t = b_l * s
        cap = moe_capacity(t, cfg)
        xf = x_l.reshape(t, d)
        logits = xf.astype(jnp.float32) @ params_l["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        e_loc = params_l["we1"].shape[0]
        e_start = jax.lax.axis_index(model_axis) * e_loc
        flat_e = expert_idx.reshape(-1)
        local_e = jnp.where(
            (flat_e >= e_start) & (flat_e < e_start + e_loc), flat_e - e_start, e_loc
        )
        sort_idx = jnp.argsort(local_e)
        sorted_e = local_e[sort_idx]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e_loc))
        rank = jnp.arange(t * k) - starts[jnp.minimum(sorted_e, e_loc - 1)]
        keep = (sorted_e < e_loc) & (rank < cap)
        dest = jnp.where(keep, sorted_e * cap + rank, e_loc * cap)
        token_of = sort_idx // k

        buf = jnp.zeros((e_loc * cap + 1, d), x_l.dtype).at[dest].set(xf[token_of])
        buf = buf[:-1].reshape(e_loc, cap, d)
        h = jnp.einsum("ecd,edf->ecf", buf, params_l["we1"])
        g = jnp.einsum("ecd,edf->ecf", buf, params_l["we3"])
        y = jnp.einsum("ecf,efd->ecd", _act(h, cfg.activation) * g, params_l["we2"])
        yf = y.reshape(e_loc * cap, d)
        gathered = jnp.where(keep[:, None], yf[jnp.minimum(dest, e_loc * cap - 1)], 0.0)
        w = gate_vals.reshape(-1)[sort_idx][:, None].astype(x_l.dtype)
        out = jnp.zeros((t, d), x_l.dtype).at[token_of].add(gathered * w)

        if has_shared:
            sp = params_l["shared"]
            hs = xf @ sp["w1"]
            if "w3" in sp:
                hs = _act(hs, cfg.activation) * (xf @ sp["w3"])
            else:
                hs = _act(hs, cfg.activation)
            out = out + hs @ sp["w2"]  # partial over the sharded ff dim

        out = jax.lax.psum(out, model_axis)

        pe = probs.mean(0)
        fe = jnp.zeros(m.n_experts, jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (t * k)
        aux = m.n_experts * jnp.sum(fe * pe)
        if data_axes:
            aux = jax.lax.pmean(aux, data_axes)
        return out.reshape(b_l, s, d), aux

    fn = shard_map_compat(
        local_fn,
        mesh=mesh,
        in_specs=(param_specs, dspec),
        out_specs=(dspec, P()),
        check_vma=False,
    )
    return fn(
        {kk: params[kk] for kk in param_specs},
        x,
    )
