"""Unified transformer-zoo configuration for the assigned architectures.

One ``LMConfig`` drives every architecture: the layer stack is a repeated
``block_pattern`` (period P, repeated R = n_layers / P times) whose entries
name a mixer kind — ``attn`` (full causal), ``local`` (sliding window),
``mamba``, ``rwkv`` — so homogeneous super-blocks can be ``lax.scan``-ned
(DESIGN.md §5).  MoE/MLA/rope/softcap options are orthogonal knobs.
"""

from __future__ import annotations

import dataclasses

__all__ = ["MoEConfig", "MLAConfig", "LMConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0  # total intermediate width of the shared path
    every: int = 1  # MoE on every ``every``-th layer within the pattern period
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec-audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    block_pattern: tuple[str, ...] = ("attn",)
    moe: MoEConfig | None = None
    attn_kind: str = "gqa"  # gqa | mla
    mla: MLAConfig | None = None
    window: int | None = None  # sliding window for "local" layers
    attn_softcap: float | None = None
    final_softcap: float | None = None
    activation: str = "silu"  # silu | geglu | gelu
    rope_kind: str = "default"  # default | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w rotary halves
    # Encoder-decoder (SeamlessM4T): encoder layer count; 0 = decoder-only.
    encoder_layers: int = 0
    # Input modality: "tokens" (ids) or "embeds" (stub frontend supplies
    # frame/patch embeddings directly — the audio/VLM carve-out).
    input_mode: str = "tokens"
    tie_embeddings: bool = True
    # SSM dims
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    rwkv_head_dim: int = 64
    # long_500k dense carve-in: ring-buffer window used when decoding past
    # this many positions (None = arch is natively sub-quadratic or full).
    long_context_window: int | None = 8192
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.n_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.arch_id}: n_layers={self.n_layers} not divisible by "
                f"pattern period {len(self.block_pattern)}"
            )
        if self.attn_kind == "mla" and self.mla is None:
            raise ValueError("attn_kind='mla' requires an MLAConfig")

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to 256 so the vocab dim shards evenly."""
        return -(-self.vocab // 256) * 256

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_repeats(self) -> int:
        return self.n_layers // self.pattern_period

    def layer_kinds(self) -> tuple[str, ...]:
        return tuple(self.block_pattern[i % self.pattern_period] for i in range(self.n_layers))

    def is_moe_position(self, pos: int) -> bool:
        """Is pattern position ``pos`` an MoE FFN (vs dense FFN)?"""
        if self.moe is None:
            return False
        return (pos % self.moe.every) == (self.moe.every - 1) if self.moe.every > 1 else True

    def uses_attention(self) -> bool:
        return any(k in ("attn", "local") for k in self.block_pattern)
