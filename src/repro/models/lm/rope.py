"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the rotary half-dim into (temporal, height, width) sections,
each rotated by its own position stream; text tokens carry identical
(t, h, w) positions, which reduces exactly to standard RoPE.  Positions:
``[..., S]`` for default, ``[..., S, 3]`` for mrope.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["apply_rope", "rope_angles"]


def rope_angles(
    positions: jax.Array,  # [B, S] or [B, S, 3]
    head_dim: int,
    theta: float,
    kind: str,
    mrope_sections: tuple[int, int, int],
) -> tuple[jax.Array, jax.Array]:
    """Return (cos, sin) of shape [B, S, head_dim // 2] (fp32)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)  # [half]
    if kind == "default":
        ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,half]
    elif kind == "mrope":
        if positions.ndim < 2 or positions.shape[-1] != 3:
            raise ValueError("mrope needs positions [..., S, 3]")
        secs = mrope_sections
        if sum(secs) != half:
            raise ValueError(f"mrope sections {secs} must sum to half dim {half}")
        parts = []
        start = 0
        for axis, width in enumerate(secs):
            f = freqs[start : start + width]
            parts.append(positions[..., axis][..., None].astype(jnp.float32) * f)
            start += width
        ang = jnp.concatenate(parts, axis=-1)  # [B,S,half]
    else:
        raise ValueError(f"unknown rope kind {kind!r}")
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate ``x: [B, S, H, D]`` with angles ``[B, S, D//2]``."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
