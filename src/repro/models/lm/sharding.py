"""Sharding rules: parameter / batch / cache PartitionSpecs (DESIGN.md §5).

Classification is by leaf name (params are named for their parallelism
style): column-parallel weights shard the output feature dim on ``model``,
row-parallel shard the input dim, MoE expert tensors shard the expert dim
(expert parallelism), embeddings shard the vocab dim.  Leading stacking
dims (the scan repeat axis, the MoE expert axis where explicit) are padded
with ``None``.

Batch dims shard over the data axes (``("pod","data")`` multi-pod); KV
cache *sequence* dims shard over ``model`` — uniform and always divisible,
unlike kv-head counts (kv=1..16 across the zoo).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "batch_specs", "cache_specs", "to_shardings", "data_axes"]

# output-feature (last dim) on model
_COL = {
    "wq", "wk", "wv", "w_uq", "w_uk", "w_uv", "w1", "w3", "in_proj", "dt_proj",
    "w_r", "w_k", "w_v", "w_g", "w_in", "w_lora_b", "lm_head", "conv_w", "u_bonus",
}
# input-feature (second-to-last dim) on model
_ROW = {"wo", "w_o", "w2", "out_proj", "x_proj", "w_out"}
# expert-parallel: (E, d, ff) etc, expert dim on model
_EXPERT = {"we1", "we2", "we3"}
# 1-D vectors over a model-sharded feature dim
_VEC = {"conv_b", "dt_bias", "d_skip"}
_REPL = {"router", "mu", "w0", "w_lora_a", "w_dq", "w_dkv", "w_kr", "b"}


def _names(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(p.key)
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
    return out


def _pad(nd: int, tail: tuple) -> P:
    return P(*([None] * (nd - len(tail)) + list(tail)))


def _param_leaf_spec(path, leaf) -> P:
    names = _names(path)
    name = names[-1] if names else ""
    nd = leaf.ndim
    if name == "embed":
        return P("model", None)
    if name == "scale":
        if len(names) >= 2 and names[-2] == "ln_x":
            return _pad(nd, ("model",))
        return P(*([None] * nd))
    if name in _EXPERT:
        return _pad(nd, ("model", None, None))
    if name in _COL:
        return _pad(nd, (None, "model"))
    if name in _ROW:
        return _pad(nd, ("model", None))
    if name in _VEC:
        return _pad(nd, ("model",))
    if name == "a_log":
        return _pad(nd, ("model", None))
    if name in _REPL or nd == 0:
        return P(*([None] * nd))
    # default: replicate (norm scales, biases, anything unclassified)
    return P(*([None] * nd))


def param_specs(params) -> dict:
    return jax.tree_util.tree_map_with_path(_param_leaf_spec, params)


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_specs(batch, batch_axes: tuple):
    ba = tuple(batch_axes)
    first = ba if ba else None

    def leaf(path, x):
        return P(*([first] + [None] * (x.ndim - 1)))

    return jax.tree_util.tree_map_with_path(leaf, batch)


def _cache_leaf_spec(path, leaf, batch_axes: tuple) -> P:
    names = _names(path)
    name = names[-1] if names else ""
    nd = leaf.ndim
    ba = tuple(batch_axes) if batch_axes else None
    # All cache leaves are stacked over repeats: leading R dim, then batch.
    if name in ("k", "v"):  # (R, B, S, Hkv, Dh)
        return P(None, ba, "model", None, None)
    if name in ("c_kv", "k_rope"):  # (R, B, S, r)
        return P(None, ba, "model", None)
    if name == "conv":  # (R, B, d_conv-1, d_inner)
        return P(None, ba, None, "model")
    if name == "ssm":  # (R, B, d_inner, d_state)
        return P(None, ba, "model", None)
    if name == "state":  # (R, B, H, Dk, Dv) -> shard Dk
        return P(None, ba, None, "model", None)
    if name == "shift":  # (R, B, d)
        return P(None, ba, None)
    return P(*([None] * nd))


def cache_specs(caches, batch_axes: tuple):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _cache_leaf_spec(p, l, batch_axes), caches
    )


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
