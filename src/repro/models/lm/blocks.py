"""Block assembly: norm → mixer → residual → norm → FFN/MoE → residual.

A block's *kind* (one entry of ``cfg.block_pattern``) picks the mixer:
``attn`` (full causal GQA/MLA), ``local`` (sliding window), ``mamba``,
``rwkv`` (whose channel-mix replaces the FFN).  MoE replaces the dense FFN
at positions where ``cfg.is_moe_position`` holds.  Decoder blocks of an
encoder-decoder additionally carry cross-attention after self-attention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm import attention as attn
from repro.models.lm import mamba as mamba_mod
from repro.models.lm import rwkv6 as rwkv_mod
from repro.models.lm.config import LMConfig
from repro.models.lm.moe import dense_ffn, init_dense_ffn, init_moe_params, moe_ffn
from repro.models.lm.norms import init_rms_norm, rms_norm

__all__ = ["init_block_params", "block_prefill", "block_decode", "window_for", "init_block_cache"]


def window_for(kind: str, cfg: LMConfig, long_mode: bool) -> int | None:
    if kind == "local":
        return cfg.window
    if kind == "attn" and long_mode:
        return cfg.long_context_window  # dense long-context carve-in
    return None


def init_block_params(key: jax.Array, cfg: LMConfig, pos: int, dtype, *, cross: bool = False) -> dict:
    kind = cfg.block_pattern[pos]
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": init_rms_norm(cfg.d_model)}
    if kind in ("attn", "local"):
        if cfg.attn_kind == "mla":
            p["mla"] = attn.init_mla_params(ks[0], cfg, dtype)
        else:
            p["attn"] = attn.init_gqa_params(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = mamba_mod.init_mamba_params(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["tm"] = rwkv_mod.init_rwkv_params(ks[0], cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")

    if cross:
        p["ln_cross"] = init_rms_norm(cfg.d_model)
        p["cross"] = attn.init_cross_params(ks[2], cfg, dtype)

    p["ln2"] = init_rms_norm(cfg.d_model)
    if kind == "rwkv":
        p["cm"] = rwkv_mod.init_rwkv_cm_params(ks[1], cfg, dtype)
    elif cfg.is_moe_position(pos):
        p["moe"] = init_moe_params(ks[1], cfg, dtype)
    else:
        p["ffn"] = init_dense_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def init_block_cache(
    cfg: LMConfig,
    pos: int,
    batch: int,
    cache_size: int,
    dtype,
    *,
    long_mode: bool,
    enc_len: int | None = None,
):
    """Abstract-friendly cache allocator for one pattern position.

    ``enc_len`` adds the cross-attention KV (encoder-decoder decode).
    """
    base = _init_self_cache(cfg, pos, batch, cache_size, dtype, long_mode=long_mode)
    if enc_len is not None:
        return {
            "self": base,
            "cross_kv": {
                "k": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            },
        }
    return base


def _init_self_cache(cfg: LMConfig, pos: int, batch: int, cache_size: int, dtype, *, long_mode: bool):
    kind = cfg.block_pattern[pos]
    if kind in ("attn", "local"):
        w = window_for(kind, cfg, long_mode)
        sc = min(cache_size, w) if w is not None else cache_size
        if cfg.attn_kind == "mla":
            m = cfg.mla
            return {
                "c_kv": jnp.zeros((batch, sc, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, sc, m.rope_head_dim), dtype),
            }
        return {
            "k": jnp.zeros((batch, sc, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, sc, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    if kind == "mamba":
        return mamba_mod.init_mamba_cache(cfg, batch, dtype)
    if kind == "rwkv":
        return {
            "tm": rwkv_mod.init_rwkv_cache(cfg, batch),
            "cm": {"shift": jnp.zeros((batch, cfg.d_model), jnp.float32)},
        }
    raise ValueError(kind)


def _ring_from_full(full: jax.Array, cache_size: int) -> jax.Array:
    """Convert full-sequence KV [B, S, ...] to a ring cache of ``cache_size``."""
    s = full.shape[1]
    if s <= cache_size:
        pad = [(0, 0)] * full.ndim
        pad[1] = (0, cache_size - s)
        return jnp.pad(full, pad)
    win = full[:, -cache_size:]
    return jnp.roll(win, shift=(s - cache_size) % cache_size, axis=1)


def block_prefill(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: LMConfig,
    pos: int,
    *,
    causal: bool = True,
    enc_out: jax.Array | None = None,
    long_mode: bool = False,
    cache_size: int | None = None,
):
    """Returns (x, cache, aux_loss).  ``cache_size`` trims KV to a ring."""
    kind = cfg.block_pattern[pos]
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(params["ln1"], x)
    if kind in ("attn", "local"):
        w = window_for(kind, cfg, long_mode)
        if cfg.attn_kind == "mla":
            out, cache = attn.mla_prefill(params["mla"], h, positions, cfg, window=w, causal=causal)
        else:
            out, cache = attn.gqa_prefill(params["attn"], h, positions, cfg, window=w, causal=causal)
        if cache_size is not None:
            sc = min(cache_size, w) if w is not None else cache_size
            cache = jax.tree.map(lambda a: _ring_from_full(a, sc), cache)
    elif kind == "mamba":
        out, cache = mamba_mod.mamba_prefill(params["mamba"], h, cfg)
    else:  # rwkv
        from repro.models.lm.tp import rwkv_chunked

        if rwkv_chunked():
            out, cache = rwkv_mod.rwkv_time_mix_prefill_chunked(params["tm"], h, cfg)
        else:
            out, cache = rwkv_mod.rwkv_time_mix_prefill(params["tm"], h, cfg)
    from repro.models.lm.tp import maybe_barrier

    x = x + maybe_barrier(out)

    if "cross" in params:
        hc = rms_norm(params["ln_cross"], x)
        cross_kv = attn.encode_cross_kv(params["cross"], enc_out, cfg)
        x = x + attn.cross_attention(params["cross"], hc, cross_kv, cfg)
        cache = {"self": cache, "cross_kv": cross_kv}

    h2 = rms_norm(params["ln2"], x)
    if kind == "rwkv":
        out2, cm_cache = rwkv_mod.rwkv_channel_mix_prefill(params["cm"], h2, cfg)
        cache = {"tm": cache, "cm": cm_cache}
    elif "moe" in params:
        out2, aux = moe_ffn(params["moe"], h2, cfg)
        cm_cache = None
    else:
        out2 = dense_ffn(params["ffn"], h2, cfg.activation)
        cm_cache = None
    del cm_cache
    return x + maybe_barrier(out2), cache, aux


def block_decode(
    params: dict,
    x: jax.Array,  # [B, 1, d]
    cache,
    cache_len: jax.Array,
    cfg: LMConfig,
    pos: int,
    *,
    long_mode: bool = False,
    mla_absorb: bool = False,
):
    kind = cfg.block_pattern[pos]
    h = rms_norm(params["ln1"], x)
    self_cache = cache["self"] if "cross" in params else (cache["tm"] if kind == "rwkv" else cache)
    if kind in ("attn", "local"):
        w = window_for(kind, cfg, long_mode)
        if cfg.attn_kind == "mla":
            out, new_self = attn.mla_decode(
                params["mla"], h, self_cache, cache_len, cfg, window=w, absorb=mla_absorb
            )
        else:
            out, new_self = attn.gqa_decode(params["attn"], h, self_cache, cache_len, cfg, window=w)
    elif kind == "mamba":
        out, new_self = mamba_mod.mamba_decode(params["mamba"], h, self_cache, cfg)
    else:
        out, new_self = rwkv_mod.rwkv_time_mix_decode(params["tm"], h, self_cache, cfg)
    x = x + out

    if "cross" in params:
        hc = rms_norm(params["ln_cross"], x)
        x = x + attn.cross_attention(params["cross"], hc, cache["cross_kv"], cfg)

    h2 = rms_norm(params["ln2"], x)
    if kind == "rwkv":
        out2, new_cm = rwkv_mod.rwkv_channel_mix_decode(params["cm"], h2, cache["cm"], cfg)
        new_cache = {"tm": new_self, "cm": new_cm}
    else:
        if "moe" in params:
            out2, _ = moe_ffn(params["moe"], h2, cfg)
        else:
            out2 = dense_ffn(params["ffn"], h2, cfg.activation)
        new_cache = (
            {"self": new_self, "cross_kv": cache["cross_kv"]} if "cross" in params else new_self
        )
    return x + out2, new_cache
