"""Top-level LM: init / train_loss / prefill / decode_step.

The layer stack is `lax.scan`-ned over ``n_repeats`` of the pattern period
(DESIGN.md §5): parameters (and KV caches) are stacked pytrees with a
leading repeat axis, one tuple entry per pattern position.  Encoder-decoder
configs (SeamlessM4T) run an encoder stack first; decoder blocks carry
cross-attention whose KV is cached at prefill.

Loss is computed in sequence chunks so the full [B, S, vocab] logits tensor
never materializes (vocab reaches 256k).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.lm.blocks import block_decode, block_prefill, init_block_params
from repro.models.lm.config import LMConfig
from repro.models.lm.norms import init_rms_norm, rms_norm

__all__ = [
    "init_params",
    "abstract_params",
    "train_loss",
    "prefill",
    "decode_step",
    "default_positions",
    "encoder_config",
]

AUX_WEIGHT = 0.01


def encoder_config(cfg: LMConfig) -> LMConfig:
    """The encoder stack of an enc-dec config: plain dense attention blocks."""
    return dataclasses.replace(
        cfg,
        block_pattern=("attn",),
        moe=None,
        n_layers=cfg.encoder_layers,
        attn_kind="gqa",
        mla=None,
    )


def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


def _stack_blocks(key: jax.Array, cfg: LMConfig, *, cross: bool) -> tuple:
    dtype = _dtype(cfg)
    out = []
    for pos in range(cfg.pattern_period):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, cfg.n_repeats)
        out.append(
            jax.vmap(lambda k, p=pos: init_block_params(k, cfg, p, dtype, cross=cross))(keys)
        )
    return tuple(out)


def init_params(key: jax.Array, cfg: LMConfig) -> dict:
    dtype = _dtype(cfg)
    k_embed, k_blocks, k_enc, k_head = jax.random.split(key, 4)
    params: dict = {
        "embed": (
            jax.random.normal(k_embed, (cfg.vocab_padded, cfg.d_model), jnp.float32) * 0.02
        ).astype(dtype),
        "blocks": _stack_blocks(k_blocks, cfg, cross=cfg.encoder_layers > 0),
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab_padded), jnp.float32) * 0.02
        ).astype(dtype)
    if cfg.encoder_layers > 0:
        ecfg = encoder_config(cfg)
        params["encoder"] = {
            "blocks": _stack_blocks(k_enc, ecfg, cross=False),
            "final_norm": init_rms_norm(cfg.d_model),
        }
    return params


def abstract_params(cfg: LMConfig, seed: int = 0):
    """ShapeDtypeStruct pytree — the dry-run's zero-allocation param tree."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(seed), cfg))


def default_positions(cfg: LMConfig, batch: int, seq: int) -> jax.Array:
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos


# ------------------------------------------------------------- stack runs


def _run_prefill_stack(
    blocks: tuple,
    x: jax.Array,
    positions: jax.Array,
    cfg: LMConfig,
    *,
    causal: bool,
    enc_out: jax.Array | None,
    long_mode: bool,
    cache_size: int | None,
    collect: bool,
    remat: bool,
):
    period = cfg.pattern_period

    def body(hx, slices):
        caches = []
        aux = jnp.zeros((), jnp.float32)
        for pos in range(period):
            hx, cache, a = block_prefill(
                slices[pos],
                hx,
                positions,
                cfg,
                pos,
                causal=causal,
                enc_out=enc_out,
                long_mode=long_mode,
                cache_size=cache_size,
            )
            caches.append(cache)
            aux = aux + a
        return hx, (tuple(caches) if collect else None, aux)

    if remat:
        from repro.models.lm.tp import remat_policy

        pol = remat_policy()
        body_fn = jax.checkpoint(body, policy=pol) if pol else jax.checkpoint(body)
    else:
        body_fn = body
    x, (caches, auxs) = jax.lax.scan(body_fn, x, blocks)
    return x, caches, auxs.sum()


def _embed_in(params, cfg: LMConfig, batch: dict) -> jax.Array:
    if "embeds" in batch:
        return batch["embeds"].astype(_dtype(cfg))
    return params["embed"][batch["tokens"]]


def _logits(params, cfg: LMConfig, x: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    if cfg.vocab_padded != cfg.vocab:
        # Padded vocab rows must never win softmax / argmax.
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


def _run_encoder(params, cfg: LMConfig, src_embeds: jax.Array):
    ecfg = encoder_config(cfg)
    pos = default_positions(ecfg, src_embeds.shape[0], src_embeds.shape[1])
    enc_x, _, _ = _run_prefill_stack(
        params["encoder"]["blocks"],
        src_embeds.astype(_dtype(cfg)),
        pos,
        ecfg,
        causal=False,
        enc_out=None,
        long_mode=False,
        cache_size=None,
        collect=False,
        remat=True,
    )
    return rms_norm(params["encoder"]["final_norm"], enc_x)


# ------------------------------------------------------------- train loss


def train_loss(params: dict, batch: dict, cfg: LMConfig) -> jax.Array:
    """Mean next-token CE (+ MoE aux).  Labels −100 are ignored."""
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = _run_encoder(params, cfg, batch["src_embeds"])

    x = _embed_in(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, b, s)

    x, _, aux = _run_prefill_stack(
        params["blocks"],
        x,
        positions,
        cfg,
        causal=True,
        enc_out=enc_out,
        long_mode=False,
        cache_size=None,
        collect=False,
        remat=True,
    )
    x = rms_norm(params["final_norm"], x)

    labels = batch["labels"]
    chunk = 512 if s % 512 == 0 else s
    xc = x.reshape(b, s // chunk, chunk, cfg.d_model).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, s // chunk, chunk).transpose(1, 0, 2)

    def ce_body(carry, inp):
        xch, lch = inp
        logits = _logits(params, cfg, xch)
        logp = jax.nn.log_softmax(logits, axis=-1)
        valid = lch >= 0
        ll = jnp.take_along_axis(logp, jnp.maximum(lch, 0)[..., None], axis=-1)[..., 0]
        return (carry[0] + jnp.sum(ll * valid), carry[1] + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(ce_body, (jnp.zeros(()), jnp.zeros(())), (xc, lc))
    ce = -tot / jnp.maximum(cnt, 1.0)
    return ce + AUX_WEIGHT * aux


# ---------------------------------------------------------------- serving


def prefill(
    params: dict,
    batch: dict,
    cfg: LMConfig,
    *,
    cache_size: int | None = None,
    long_mode: bool = False,
) -> tuple[jax.Array, tuple]:
    """Process the prompt; returns (last-token logits [B, V], caches)."""
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = _run_encoder(params, cfg, batch["src_embeds"])
    x = _embed_in(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, b, s)
    x, caches, _ = _run_prefill_stack(
        params["blocks"],
        x,
        positions,
        cfg,
        causal=True,
        enc_out=enc_out,
        long_mode=long_mode,
        cache_size=cache_size if cache_size is not None else s,
        collect=True,
        remat=False,
    )
    x = rms_norm(params["final_norm"], x[:, -1:, :])
    return _logits(params, cfg, x)[:, 0, :], caches


def decode_step(
    params: dict,
    tokens: jax.Array,  # [B, 1] int32
    caches: tuple,
    cache_len: jax.Array,  # scalar int32: logical position being written
    cfg: LMConfig,
    *,
    long_mode: bool = False,
    mla_absorb: bool = False,
) -> tuple[jax.Array, tuple]:
    """One-token decode against the KV/state caches."""
    x = params["embed"][tokens]
    period = cfg.pattern_period

    def body(hx, slices):
        bslices, cslices = slices
        new_caches = []
        for pos in range(period):
            hx, nc = block_decode(
                bslices[pos],
                hx,
                cslices[pos],
                cache_len,
                cfg,
                pos,
                long_mode=long_mode,
                mla_absorb=mla_absorb,
            )
            new_caches.append(nc)
        return hx, tuple(new_caches)

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    x = rms_norm(params["final_norm"], x)
    return _logits(params, cfg, x)[:, 0, :], new_caches
