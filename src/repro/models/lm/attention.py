"""Attention mixers: GQA/MQA (with sliding window + softcap) and MLA.

Two entry modes per mixer:

* ``prefill`` — full-sequence causal attention.  Scores are computed in
  *q-chunks* under ``lax.scan`` so the peak live buffer is
  ``[B, H, chunk, S]`` instead of ``[B, H, S, S]`` — at 32k context the
  unchunked form would not fit any real device, and the dry-run's
  memory_analysis would (rightly) explode.  Returns the populated KV cache.
* ``decode`` — one new token against a KV cache, functional cache update
  at position ``cache_len`` (ring-buffer semantics when the cache is
  shorter than the logical position — the long_500k dense carve-in).

MLA (DeepSeek-V2) caches the *compressed* (c_kv, k_rope) pair.  The
baseline decode expands k/v from c_kv per step; ``absorb=True`` switches to
the matrix-absorbed decode (q projected into the compressed space) — a
beyond-paper §Perf option that shrinks decode FLOPs and live memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.config import LMConfig
from repro.models.lm.rope import apply_rope, rope_angles
from repro.models.lm.tp import maybe_row_parallel

__all__ = [
    "init_gqa_params",
    "gqa_prefill",
    "gqa_decode",
    "init_mla_params",
    "mla_prefill",
    "mla_decode",
    "init_cross_params",
    "cross_attention",
]

NEG_INF = -1e30


def _init(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# =============================================================== GQA / MQA


def init_gqa_params(key: jax.Array, cfg: LMConfig, dtype) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _init(ks[0], (d, h * dh), dtype),
        "wk": _init(ks[1], (d, hkv * dh), dtype),
        "wv": _init(ks[2], (d, hkv * dh), dtype),
        "wo": _init(ks[3], (h * dh, d), dtype),
    }


def _qkv(params, x, cfg: LMConfig):
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _softcap(s, cap):
    return s if cap is None else cap * jnp.tanh(s / cap)


def _chunked_scores_softmax(q, k, v, *, q_offset, kv_valid_len, window, softcap, causal, n_rep):
    """Causal/windowed attention with q chunked over a lax.scan.

    q: [B, S, H, D]; k/v: [B, Sk, Hkv, D].  Returns [B, S, H, D].
    ``n_rep`` = H // Hkv (GQA repetition, via reshape-grouped einsum so the
    kv tensors are never materially repeated).
    """
    b, s, h, dh = q.shape
    dv = v.shape[-1]  # may differ from dh (MLA: qk dim != v dim)
    sk = k.shape[1]
    hkv = k.shape[2]
    chunk = 512 if s % 512 == 0 else s
    n_chunks = s // chunk
    qg = q.reshape(b, n_chunks, chunk, hkv, n_rep, dh).transpose(1, 0, 2, 3, 4, 5)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    ki = jnp.arange(sk)

    def body(carry, qc_and_idx):
        qc, ci = qc_and_idx  # qc: [B, chunk, Hkv, rep, D]
        s_scores = jnp.einsum("bqkrd,bskd->bkrqs", qc.astype(jnp.float32), k.astype(jnp.float32))
        s_scores = _softcap(s_scores * scale, softcap)
        qi = q_offset + ci * chunk + jnp.arange(chunk)
        mask = ki[None, :] < kv_valid_len
        if causal:
            mask = mask & (qi[:, None] >= ki[None, :])
        if window is not None:
            mask = mask & (qi[:, None] - ki[None, :] < window)
        s_scores = jnp.where(mask[None, None, None], s_scores, NEG_INF)
        p = jax.nn.softmax(s_scores, axis=-1)
        out = jnp.einsum("bkrqs,bskd->bqkrd", p, v.astype(jnp.float32))
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(body, None, (qg, jnp.arange(n_chunks)))
    # outs: [n_chunks, B, chunk, Hkv, rep, Dv] -> [B, S, H, Dv]
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dv)


def gqa_prefill(
    params: dict,
    x: jax.Array,  # [B, S, d]
    positions: jax.Array,  # [B, S] (or [B, S, 3] for mrope)
    cfg: LMConfig,
    *,
    window: int | None,
    causal: bool = True,
) -> tuple[jax.Array, dict]:
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, cfg)
    if cfg.rope_kind != "none":
        cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta, cfg.rope_kind, cfg.mrope_sections)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    out = _chunked_scores_softmax(
        q,
        k,
        v,
        q_offset=0,
        kv_valid_len=s,
        window=window,
        softcap=cfg.attn_softcap,
        causal=causal,
        n_rep=cfg.n_heads // cfg.n_kv_heads,
    )
    out = maybe_row_parallel(out.reshape(b, s, cfg.n_heads * cfg.head_dim), params["wo"])
    return out, {"k": k, "v": v}


def _per_batch(cache_len: jax.Array, b: int) -> jax.Array:
    """Broadcast a scalar or [B] cache_len to [B] (per-slot serving)."""
    cache_len = jnp.asarray(cache_len, jnp.int32)
    if cache_len.ndim == 0:
        return jnp.broadcast_to(cache_len, (b,))
    return cache_len


def _ring_write(buf: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Write ``new[:, 0]`` at per-batch ring slots. buf: [B, Sc, ...]."""
    b = buf.shape[0]
    return buf.at[jnp.arange(b), slot].set(new[:, 0])


def _ring_mask(cache_len_b: jax.Array, sc: int, window: int | None) -> jax.Array:
    """[B, Sc] validity mask.  Slot ki holds logical position p(ki) = the
    largest p <= cache_len with p % sc == ki (ring semantics)."""
    ki = jnp.arange(sc)[None, :]
    cl = cache_len_b[:, None]
    logical = cl - jnp.mod(cl - ki, sc)
    mask = (logical >= 0) & (logical <= cl)
    if window is not None:
        mask &= cl - logical < window
    return mask


def gqa_decode(
    params: dict,
    x: jax.Array,  # [B, 1, d]
    cache: dict,  # {"k": [B, Sc, Hkv, D], "v": ...}
    cache_len: jax.Array,  # int32 scalar or [B]: logical position per slot
    cfg: LMConfig,
    *,
    window: int | None,
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    sc = cache["k"].shape[1]
    cl = _per_batch(cache_len, b)
    q, k, v = _qkv(params, x, cfg)
    pos = cl[:, None]
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(pos[..., None], (b, 1, 3))
    if cfg.rope_kind != "none":
        cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta, cfg.rope_kind, cfg.mrope_sections)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    slot = jnp.mod(cl, sc)  # ring buffer when logical pos >= capacity
    new_k = _ring_write(cache["k"], k, slot)
    new_v = _ring_write(cache["v"], v, slot)

    n_rep = cfg.n_heads // cfg.n_kv_heads
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.head_dim, jnp.float32))
    qg = q.reshape(b, 1, cfg.n_kv_heads, n_rep, cfg.head_dim)
    s_scores = jnp.einsum(
        "bqkrd,bskd->bkrqs", qg.astype(jnp.float32), new_k.astype(jnp.float32)
    )
    s_scores = _softcap(s_scores * scale, cfg.attn_softcap)
    mask = _ring_mask(cl, sc, window)  # [B, Sc]
    s_scores = jnp.where(mask[:, None, None, None, :], s_scores, NEG_INF)
    p = jax.nn.softmax(s_scores, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", p, new_v.astype(jnp.float32)).astype(x.dtype)
    out = maybe_row_parallel(out.reshape(b, 1, cfg.n_heads * cfg.head_dim), params["wo"])
    return out, {"k": new_k, "v": new_v}


# ===================================================================== MLA


def init_mla_params(key: jax.Array, cfg: LMConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "w_dq": _init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), jnp.float32)},
        "w_uq": _init(ks[1], (m.q_lora_rank, h * qd), dtype),
        "w_dkv": _init(ks[2], (d, m.kv_lora_rank), dtype),
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), jnp.float32)},
        "w_kr": _init(ks[3], (d, m.rope_head_dim), dtype),
        "w_uk": _init(ks[4], (m.kv_lora_rank, h * m.nope_head_dim), dtype),
        "w_uv": _init(ks[5], (m.kv_lora_rank, h * m.v_head_dim), dtype),
        "wo": _init(ks[6], (h * m.v_head_dim, d), dtype),
    }


def _mla_q(params, x, positions, cfg):
    from repro.models.lm.norms import rms_norm

    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rms_norm(params["q_norm"], x @ params["w_dq"])
    q = (cq @ params["w_uq"]).reshape(b, s, h, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    cos, sin = rope_angles(positions, m.rope_head_dim, cfg.rope_theta, "default", cfg.mrope_sections)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_compress(params, x, positions, cfg):
    from repro.models.lm.norms import rms_norm

    m = cfg.mla
    c_kv = rms_norm(params["kv_norm"], x @ params["w_dkv"])  # [B,S,R]
    k_rope = (x @ params["w_kr"])[:, :, None, :]  # [B,S,1,Dr] (shared head)
    cos, sin = rope_angles(positions, m.rope_head_dim, cfg.rope_theta, "default", cfg.mrope_sections)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0, :]  # [B,S,Dr]
    return c_kv, k_rope


def mla_prefill(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: LMConfig,
    *,
    window: int | None,
    causal: bool = True,
) -> tuple[jax.Array, dict]:
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(params, x, positions, cfg)
    c_kv, k_rope = _mla_compress(params, x, positions, cfg)
    k_nope = (c_kv @ params["w_uk"]).reshape(b, s, h, m.nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(b, s, h, m.v_head_dim)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.rope_head_dim))], axis=-1)
    out = _chunked_scores_softmax(
        q, k, v, q_offset=0, kv_valid_len=s, window=window, softcap=cfg.attn_softcap,
        causal=causal, n_rep=1,
    )
    out = maybe_row_parallel(out.reshape(b, s, h * m.v_head_dim), params["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(
    params: dict,
    x: jax.Array,  # [B, 1, d]
    cache: dict,  # {"c_kv": [B, Sc, R], "k_rope": [B, Sc, Dr]}
    cache_len: jax.Array,
    cfg: LMConfig,
    *,
    window: int | None,
    absorb: bool = False,
) -> tuple[jax.Array, dict]:
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    sc = cache["c_kv"].shape[1]
    cl = _per_batch(cache_len, b)
    pos = cl[:, None]
    q_nope, q_rope = _mla_q(params, x, pos, cfg)  # [B,1,H,*]
    c_new, kr_new = _mla_compress(params, x, pos, cfg)
    slot = jnp.mod(cl, sc)
    c_kv = _ring_write(cache["c_kv"], c_new, slot)
    k_rope = _ring_write(cache["k_rope"], kr_new, slot)

    mask = _ring_mask(cl, sc, window)  # [B, Sc]
    scale = 1.0 / jnp.sqrt(jnp.asarray(m.nope_head_dim + m.rope_head_dim, jnp.float32))

    if absorb:
        # Absorbed decode: fold W_uk into the query and W_uv into the output
        # so attention runs in the compressed space — no per-step k/v
        # expansion, cache reads are O(Sc · (R + Dr)).
        w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
        q_c = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))
        s_scores = jnp.einsum("bqhr,bsr->bhqs", q_c, c_kv.astype(jnp.float32))
        s_scores += jnp.einsum(
            "bqhe,bse->bhqs", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
        )
        s_scores = _softcap(s_scores * scale, cfg.attn_softcap)
        s_scores = jnp.where(mask[:, None, None, :], s_scores, NEG_INF)
        p = jax.nn.softmax(s_scores, axis=-1)
        ctx = jnp.einsum("bhqs,bsr->bqhr", p, c_kv.astype(jnp.float32))  # [B,1,H,R]
        w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv.astype(jnp.float32)).astype(x.dtype)
    else:
        # Baseline decode: expand k/v from the compressed cache every step.
        k_nope = (c_kv @ params["w_uk"]).reshape(b, sc, h, m.nope_head_dim)
        v = (c_kv @ params["w_uv"]).reshape(b, sc, h, m.v_head_dim)
        s_scores = jnp.einsum(
            "bqhn,bshn->bhqs", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32)
        )
        s_scores += jnp.einsum(
            "bqhe,bse->bhqs", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
        )
        s_scores = _softcap(s_scores * scale, cfg.attn_softcap)
        s_scores = jnp.where(mask[:, None, None, :], s_scores, NEG_INF)
        p = jax.nn.softmax(s_scores, axis=-1)
        out = jnp.einsum("bhqs,bshv->bqhv", p, v.astype(jnp.float32)).astype(x.dtype)

    out = maybe_row_parallel(out.reshape(b, 1, h * m.v_head_dim), params["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope}


# ======================================================== cross-attention


def init_cross_params(key: jax.Array, cfg: LMConfig, dtype) -> dict:
    return init_gqa_params(key, cfg, dtype)


def cross_attention(
    params: dict,
    x: jax.Array,  # [B, Sq, d] decoder states
    enc_kv: dict,  # {"k": [B, Se, Hkv, D], "v": ...} precomputed encoder KV
    cfg: LMConfig,
) -> jax.Array:
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    out = _chunked_scores_softmax(
        q,
        enc_kv["k"],
        enc_kv["v"],
        q_offset=0,
        kv_valid_len=enc_kv["k"].shape[1],
        window=None,
        softcap=None,
        causal=False,
        n_rep=cfg.n_heads // cfg.n_kv_heads,
    )
    return maybe_row_parallel(out.reshape(b, s, cfg.n_heads * cfg.head_dim), params["wo"])


def encode_cross_kv(params: dict, enc_out: jax.Array, cfg: LMConfig) -> dict:
    b, se, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ params["wv"]).reshape(b, se, cfg.n_kv_heads, cfg.head_dim)
    return {"k": k, "v": v}
