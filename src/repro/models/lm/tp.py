"""Explicit tensor-parallel row-parallel matmul (§Perf iteration).

GSPMD handles the col-parallel → row-parallel matmul pair correctly but
sinks the fp32 upcast of the downstream RMSNorm *before* the psum, so the
per-layer [B, S, d] activation all-reduce moves fp32 bytes (2× what it
needs to).  ``maybe_row_parallel`` routes the row-parallel matmul through a
shard_map whose psum is explicitly bf16.  Enabled by the launcher via
``set_tp_context`` (variant ``tp_shardmap``); off by default so the
baseline stays paper-naive.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

__all__ = [
    "set_tp_context",
    "maybe_row_parallel",
    "set_bf16_barrier",
    "maybe_barrier",
    "set_remat_policy",
    "remat_policy",
    "set_rwkv_chunked",
    "rwkv_chunked",
]

_TP_CTX: tuple | None = None  # (mesh, model_axis)
_BF16_BARRIER = False
_REMAT_POLICY: str | None = None


def set_remat_policy(name: str | None) -> None:
    """§Perf variant ``remat_dots``: make matmul outputs saveable under the
    layer-scan checkpoint so the backward pass re-reads instead of
    re-computing them — trades activation memory for HBM traffic/FLOPs."""
    global _REMAT_POLICY
    _REMAT_POLICY = name


def remat_policy():
    if _REMAT_POLICY == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


_RWKV_CHUNKED = False


def set_rwkv_chunked(on: bool) -> None:
    """§Perf variant ``rwkv_chunked``: chunked (flash-linear-attention
    style) WKV6 prefill instead of the per-token sequential scan."""
    global _RWKV_CHUNKED
    _RWKV_CHUNKED = bool(on)


def rwkv_chunked() -> bool:
    return _RWKV_CHUNKED


def set_bf16_barrier(on: bool) -> None:
    """§Perf variant ``bf16_psum``: place an optimization barrier between the
    row-parallel matmul output and the residual/norm consumer so XLA cannot
    sink the norm's fp32 upcast below the TP all-reduce (which would double
    its bytes).  The barrier pins the psum to the matmul's bf16 dtype."""
    global _BF16_BARRIER
    _BF16_BARRIER = bool(on)


def maybe_barrier(x: jax.Array) -> jax.Array:
    if _BF16_BARRIER:
        return jax.lax.optimization_barrier(x)
    return x


def set_tp_context(mesh=None, model_axis: str = "model") -> None:
    global _TP_CTX
    _TP_CTX = None if mesh is None else (mesh, model_axis)


def maybe_row_parallel(h: jax.Array, w: jax.Array) -> jax.Array:
    """``h @ w`` with w row-parallel on the model axis when TP is enabled.

    h: [..., F] activations whose last dim is model-sharded (produced by a
    col-parallel matmul); w: [F, D].  The psum runs in h.dtype (bf16).
    """
    if _TP_CTX is None:
        return h @ w
    mesh, model_axis = _TP_CTX
    if w.shape[0] % mesh.shape[model_axis] != 0:
        return h @ w  # not evenly shardable; leave to GSPMD

    h_spec = P(*([None] * (h.ndim - 1) + [model_axis]))
    w_spec = P(model_axis, None)
    out_spec = P(*([None] * h.ndim))

    def local_fn(h_l, w_l):
        return jax.lax.psum(h_l @ w_l, model_axis)

    # Manual only over the model axis: batch/data sharding of ``h`` stays
    # under GSPMD's control (partial-auto shard_map).
    return jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(h_spec, w_spec),
        out_specs=out_spec,
        axis_names={model_axis},
        check_vma=False,
    )(h, w)
