"""RMSNorm (the zoo's universal norm; fp32 accumulation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "init_rms_norm"]


def init_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)
