"""RWKV6 "Finch" mixer: attention-free, data-dependent per-channel decay.

Time-mix (the WKV6 recurrence) replaces attention; channel-mix (squared
ReLU with token shift) replaces the FFN.  Prefill runs the recurrence as a
sequential ``lax.scan`` (state per head is Dk×Dv); decode is the O(1)
single-step update.  The data-dependent decay ``w_t = exp(-exp(w0 +
tanh(x·A)·B))`` is the Finch signature (arXiv:2404.05892) — decay LoRA on
the shifted input.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.config import LMConfig

__all__ = [
    "init_rwkv_params",
    "rwkv_time_mix_prefill",
    "rwkv_time_mix_decode",
    "init_rwkv_cm_params",
    "rwkv_channel_mix_prefill",
    "rwkv_channel_mix_decode",
    "init_rwkv_cache",
]

DECAY_LORA = 64


def _init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)


def _heads(cfg: LMConfig):
    dh = cfg.rwkv_head_dim
    assert cfg.d_model % dh == 0
    return cfg.d_model // dh, dh


def init_rwkv_params(key: jax.Array, cfg: LMConfig, dtype) -> dict:
    d = cfg.d_model
    h, dh = _heads(cfg)
    ks = jax.random.split(key, 9)
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),  # shift-mix for r,k,v,w,g
        "w_r": _init(ks[0], (d, d), dtype),
        "w_k": _init(ks[1], (d, d), dtype),
        "w_v": _init(ks[2], (d, d), dtype),
        "w_g": _init(ks[3], (d, d), dtype),
        "w_o": _init(ks[4], (d, d), dtype),
        "w0": jnp.full((d,), -1.0, jnp.float32),  # base decay
        "w_lora_a": _init(ks[5], (d, DECAY_LORA), jnp.float32),
        "w_lora_b": _init(ks[6], (DECAY_LORA, d), jnp.float32) * 0.1,
        "u_bonus": jnp.zeros((h, dh), jnp.float32),
        "ln_x": {"scale": jnp.ones((d,), jnp.float32)},  # per-head group norm
    }


def _mix(x, x_prev, mu_row):
    return x + mu_row * (x_prev - x)


def _rkvwg(params, x, x_prev, cfg):
    """x, x_prev: [B, d] -> per-head r,k,v [B,H,Dh], decay w [B,H,Dh], gate g [B,d]."""
    h, dh = _heads(cfg)
    mu = params["mu"]
    xr = _mix(x, x_prev, mu[0])
    xk = _mix(x, x_prev, mu[1])
    xv = _mix(x, x_prev, mu[2])
    xw = _mix(x, x_prev, mu[3])
    xg = _mix(x, x_prev, mu[4])
    b = x.shape[0]
    r = (xr.astype(params["w_r"].dtype) @ params["w_r"]).reshape(b, h, dh)
    k = (xk.astype(params["w_k"].dtype) @ params["w_k"]).reshape(b, h, dh)
    v = (xv.astype(params["w_v"].dtype) @ params["w_v"]).reshape(b, h, dh)
    g = jax.nn.silu(xg.astype(params["w_g"].dtype) @ params["w_g"])  # [B, d]
    logw = params["w0"] + jnp.tanh(xw.astype(jnp.float32) @ params["w_lora_a"]) @ params["w_lora_b"]
    w = jnp.exp(-jnp.exp(logw)).reshape(b, h, dh)  # data-dependent decay in (0,1)
    return r, k, v, w, g


def _wkv_step(state, r, k, v, w, u):
    """state: [B,H,Dk,Dv]; r,k,v,w: [B,H,Dh]; u: [H,Dh]."""
    a = k[..., :, None] * v[..., None, :]  # [B,H,Dk,Dv]
    out = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32), state + u[..., None] * a)
    state = w[..., :, None] * state + a
    return state, out


def _group_norm(params, x, h, dh):
    """Per-head layer norm of the wkv output. x: [B, H, Dv] -> [B, d]."""
    mean = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return (y.reshape(x.shape[0], h * dh) * params["ln_x"]["scale"]).astype(jnp.float32)


def rwkv_time_mix_prefill(params: dict, x: jax.Array, cfg: LMConfig) -> tuple[jax.Array, dict]:
    b, s, d = x.shape
    h, dh = _heads(cfg)
    x32 = x.astype(jnp.float32)
    x_prev_seq = jnp.concatenate([jnp.zeros((b, 1, d), jnp.float32), x32[:, :-1]], axis=1)
    r, k, v, w, g = jax.vmap(
        lambda xt, xp: _rkvwg(params, xt, xp, cfg), in_axes=1, out_axes=1
    )(x32, x_prev_seq)

    def body(state, t_in):
        rt, kt, vt, wt = t_in
        state, out = _wkv_step(state, rt, kt, vt.astype(jnp.float32), wt, params["u_bonus"])
        return state, out

    s0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    sT, outs = jax.lax.scan(
        body,
        s0,
        (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3), v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3)),
    )
    outs = outs.transpose(1, 0, 2, 3)  # [B,S,H,Dv]
    y = jax.vmap(lambda o: _group_norm(params, o, h, dh), in_axes=1, out_axes=1)(outs)
    y = (y * g.astype(jnp.float32)).astype(x.dtype) @ params["w_o"]
    cache = {"state": sT, "shift": x32[:, -1, :]}
    return y, cache


def init_rwkv_cache(cfg: LMConfig, batch: int) -> dict:
    h, dh = _heads(cfg)
    return {
        "state": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "shift": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


def rwkv_time_mix_decode(params: dict, x: jax.Array, cache: dict, cfg: LMConfig):
    """x: [B, 1, d]."""
    h, dh = _heads(cfg)
    xt = x[:, 0, :].astype(jnp.float32)
    r, k, v, w, g = _rkvwg(params, xt, cache["shift"], cfg)
    state, out = _wkv_step(cache["state"], r, k, v.astype(jnp.float32), w, params["u_bonus"])
    y = _group_norm(params, out, h, dh)
    y = ((y * g.astype(jnp.float32)).astype(x.dtype) @ params["w_o"])[:, None, :]
    return y, {"state": state, "shift": xt}


# ------------------------------------------------------------ channel mix


def init_rwkv_cm_params(key: jax.Array, cfg: LMConfig, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), jnp.float32),  # shift-mix for k, r
        "w_in": _init(ks[0], (d, ff), dtype),
        "w_out": _init(ks[1], (ff, d), dtype),
        "w_gate": _init(ks[2], (d, d), dtype),
    }


def _cm(params, x, x_prev):
    xk = _mix(x, x_prev, params["mu"][0])
    xr = _mix(x, x_prev, params["mu"][1])
    k = jnp.square(jax.nn.relu(xk.astype(params["w_in"].dtype) @ params["w_in"]))
    kv = k @ params["w_out"]
    return jax.nn.sigmoid(xr.astype(params["w_gate"].dtype) @ params["w_gate"]) * kv


def rwkv_channel_mix_prefill(params: dict, x: jax.Array, cfg: LMConfig):
    b, s, d = x.shape
    x32 = x.astype(jnp.float32)
    x_prev = jnp.concatenate([jnp.zeros((b, 1, d), jnp.float32), x32[:, :-1]], axis=1)
    y = jax.vmap(lambda xt, xp: _cm(params, xt, xp), in_axes=1, out_axes=1)(x32, x_prev)
    return y.astype(x.dtype), {"shift": x32[:, -1, :]}


def rwkv_channel_mix_decode(params: dict, x: jax.Array, cache: dict, cfg: LMConfig):
    xt = x[:, 0, :].astype(jnp.float32)
    y = _cm(params, xt, cache["shift"])
    return y.astype(x.dtype)[:, None, :], {"shift": xt}


# --------------------------------------------------- chunked prefill (TPU)


def rwkv_time_mix_prefill_chunked(
    params: dict, x: jax.Array, cfg: LMConfig, chunk: int = 64
) -> tuple[jax.Array, dict]:
    """Chunked WKV6: flash-linear-attention style (TPU-native adaptation).

    The sequential per-token scan is latency-bound on real hardware (32k
    tiny VPU steps); this version processes ``chunk`` tokens per step with
    MXU matmuls.  Within a chunk, decays are applied in log space as
    pairwise differences ``cum_i − cum_{j+1} ≤ 0`` (always non-positive ⇒
    exp ≤ 1, numerically safe); across chunks a [Dk, Dv] state carries.

    Mathematically identical to ``rwkv_time_mix_prefill`` (tests assert
    allclose); exposed via the ``rwkv_chunked`` §Perf variant.
    """
    b, s, d = x.shape
    h, dh = _heads(cfg)
    pad = (-s) % chunk
    x32 = x.astype(jnp.float32)
    x_prev_seq = jnp.concatenate([jnp.zeros((b, 1, d), jnp.float32), x32[:, :-1]], axis=1)
    r, k, v, w, g = jax.vmap(
        lambda xt, xp: _rkvwg(params, xt, xp, cfg), in_axes=1, out_axes=1
    )(x32, x_prev_seq)
    # recompute log-decay directly (w = exp(-exp(logw)) -> lw = -exp(logw))
    mu = params["mu"]
    xw = jax.vmap(lambda xt, xp: _mix(xt, xp, mu[3]), in_axes=1, out_axes=1)(x32, x_prev_seq)
    logw = params["w0"] + jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    lw = -jnp.exp(logw).reshape(b, s, h, dh)  # [B,S,H,D], <= 0

    if pad:
        zpad = lambda a, fill=0.0: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=fill)
        r, k, v, w, lw = zpad(r), zpad(k), zpad(v), zpad(w), zpad(lw)
    sp = s + pad
    nc = sp // chunk

    def reshape_c(a):
        return a.reshape(b, nc, chunk, h, dh).transpose(1, 0, 3, 2, 4)  # [nc,B,H,C,D]

    r_c, k_c, v_c, lw_c = map(reshape_c, (r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), lw))

    u = params["u_bonus"]  # [H, D]

    def body(state, inp):
        rc, kc, vc, lwc = inp  # [B,H,C,D]
        cum = jnp.cumsum(lwc, axis=2) - lwc  # exclusive prefix: cum_i
        cum_end = cum[:, :, -1:, :] + lwc[:, :, -1:, :]  # full-chunk sum
        # inter-chunk: out_i += (r_i ⊙ exp(cum_i)) · S0
        r_dec = rc * jnp.exp(cum)
        out = jnp.einsum("bhcd,bhde->bhce", r_dec, state)
        # intra-chunk: A[i,j] = Σ_d r_i k_j exp(cum_i - cum_j - lw_j), j<i
        expo = cum[:, :, :, None, :] - (cum + lwc)[:, :, None, :, :]  # [B,H,C,C,D]
        idx = jnp.arange(chunk)
        tri = (idx[:, None] > idx[None, :])[None, None, :, :, None]
        a_mat = jnp.einsum(
            "bhcd,bhed,bhced->bhce", rc, kc, jnp.where(tri, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
        )
        diag = jnp.einsum("bhcd,bhcd->bhc", rc, u[None, :, None, :] * kc)
        a_mat = a_mat + jnp.eye(chunk)[None, None] * diag[:, :, :, None]
        out = out + jnp.einsum("bhce,bhed->bhcd", a_mat, vc)
        # state update: S' = S ⊙ exp(cum_end) + Σ_j exp(cum_end - cum_{j+1}) k_j ⊗ v_j
        k_dec = kc * jnp.exp(cum_end - (cum + lwc))
        state = state * jnp.exp(cum_end).transpose(0, 1, 3, 2) + jnp.einsum(
            "bhcd,bhce->bhde", k_dec, vc
        )
        return state, out

    s0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    sT, outs = jax.lax.scan(body, s0, (r_c, k_c, v_c, lw_c))
    outs = outs.transpose(1, 0, 3, 2, 4).reshape(b, sp, h, dh)[:, :s]  # [B,S,H,D]
    y = jax.vmap(lambda o: _group_norm(params, o, h, dh), in_axes=1, out_axes=1)(outs)
    y = (y * g.astype(jnp.float32)).astype(x.dtype) @ params["w_o"]
    cache = {"state": sT, "shift": x32[:, -1, :]}
    return y, cache
