"""AdamW with fp32 moments over (possibly bf16) parameters + cosine schedule."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_adamw", "adamw_update", "cosine_schedule"]


def init_adamw(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_schedule(step, *, base_lr=3e-4, warmup=100, total=10_000, min_frac=0.1):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * jnp.where(step < warmup, warm, cos)


def adamw_update(
    params,
    grads,
    state: dict,
    *,
    lr=None,
    base_lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    step = state["step"] + 1
    lr_t = cosine_schedule(step, base_lr=base_lr) if lr is None else lr
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(field):
        def f(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * g32 * g32
            if field == "m":
                return m_new
            if field == "v":
                return v_new
            update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
            p32 = p.astype(jnp.float32)
            return (p32 - lr_t * (update + weight_decay * p32)).astype(p.dtype)

        return f

    args = (params, grads, state["m"], state["v"])
    new_params = jax.tree.map(upd("p"), *args)
    new_m = jax.tree.map(upd("m"), *args)
    new_v = jax.tree.map(upd("v"), *args)
    return new_params, {"m": new_m, "v": new_v, "step": step}
