"""Property tests for the serving ring-buffer mask (per-slot cache_len)."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.lm.attention import _ring_mask


@settings(max_examples=60, deadline=None)
@given(
    sc=st.integers(1, 64),
    window=st.one_of(st.none(), st.integers(1, 80)),
    lens=st.lists(st.integers(0, 200), min_size=1, max_size=4),
)
def test_ring_mask_counts(sc, window, lens):
    """Each row must expose exactly min(cache_len+1, sc, window) positions:
    the logical prefix, capped by ring capacity and attention window."""
    cl = jnp.asarray(lens, jnp.int32)
    mask = np.asarray(_ring_mask(cl, sc, window))
    for i, l in enumerate(lens):
        expect = min(l + 1, sc, window if window is not None else l + 1)
        assert mask[i].sum() == expect, (l, sc, window, mask[i].sum())
        # the current token's slot is always visible
        assert mask[i, l % sc]
