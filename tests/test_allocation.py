"""Eq. 1 capacity allocation + workload-aware budget."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.allocation import (
    CacheAllocation,
    allocate_capacity,
    available_budget,
    reallocate_capacity,
    shard_allocations,
)


def test_eq1_proportional_split():
    a = allocate_capacity([1.0, 1.0], [3.0, 3.0], 1000)
    assert a.adj_bytes == 250  # 2 / (2+6)
    assert a.feat_bytes == 750
    assert a.adj_bytes + a.feat_bytes == 1000


def test_eq1_zero_times_splits_evenly():
    a = allocate_capacity([0.0], [0.0], 100)
    assert a.adj_bytes == 50


def test_eq1_rejects_mismatched_lists():
    with pytest.raises(ValueError):
        allocate_capacity([1.0], [1.0, 2.0], 10)
    with pytest.raises(ValueError):
        allocate_capacity([], [], 10)


def test_available_budget_reserve():
    assert available_budget(24 << 30, 2 << 30, reserve_bytes=1 << 30) == 21 << 30
    assert available_budget(1 << 30, 2 << 30) == 0  # never negative


@settings(max_examples=50, deadline=None)
@given(
    ts=st.lists(st.floats(0, 10, allow_nan=False), min_size=1, max_size=16),
    tf=st.lists(st.floats(0, 10, allow_nan=False), min_size=1, max_size=16),
    total=st.integers(0, 1 << 32),
)
def test_eq1_properties(ts, tf, total):
    n = min(len(ts), len(tf))
    ts, tf = ts[:n], tf[:n]
    a = allocate_capacity(ts, tf, total)
    # partition of the budget, both non-negative
    assert a.adj_bytes >= 0 and a.feat_bytes >= 0
    assert a.adj_bytes + a.feat_bytes == total
    # split fraction matches Eq. 1 within integer rounding
    denom = sum(ts) + sum(tf)
    if denom > 0 and total > 0:
        assert abs(a.adj_bytes / total - sum(ts) / denom) <= 1.0 / total + 1e-9


def test_saturation_spill():
    """Eq.1 share beyond a cache's useful size spills to the other
    (beyond-paper refinement; see EXPERIMENTS.md)."""
    # sampling dominates -> Eq.1 gives adj 80%; adj only needs 100 bytes
    a = allocate_capacity([8.0], [2.0], 1000, adj_need_bytes=100, feat_need_bytes=10_000)
    assert a.adj_bytes == 100
    assert a.feat_bytes == 900
    # both saturate when the budget covers everything
    b = allocate_capacity([1.0], [1.0], 10_000, adj_need_bytes=100, feat_need_bytes=200)
    assert b.adj_bytes == 100 and b.feat_bytes == 200


def test_spill_zero_total_budget():
    """A zero budget is legal (no memory left after the workload): both
    sides get nothing, whatever the needs and ratio say."""
    a = allocate_capacity([5.0], [1.0], 0, adj_need_bytes=100, feat_need_bytes=100)
    assert a.adj_bytes == 0 and a.feat_bytes == 0 and a.total_bytes == 0
    b = allocate_capacity([5.0], [1.0], 0)  # and with no needs given
    assert b.adj_bytes == 0 and b.feat_bytes == 0


def test_spill_both_needs_saturated():
    """Budget exceeding adj_need + feat_need saturates BOTH caches and
    leaves the remainder unallocated (Fig. 9: all strategies coincide
    once everything fits)."""
    a = allocate_capacity([1.0], [3.0], 1_000_000, adj_need_bytes=300, feat_need_bytes=500)
    assert a.adj_bytes == 300 and a.feat_bytes == 500
    # the extreme ratios saturate identically
    b = allocate_capacity([1.0], [0.0], 1_000_000, adj_need_bytes=300, feat_need_bytes=500)
    c = allocate_capacity([0.0], [1.0], 1_000_000, adj_need_bytes=300, feat_need_bytes=500)
    assert (b.adj_bytes, b.feat_bytes) == (c.adj_bytes, c.feat_bytes) == (300, 500)


def test_feat_spill_with_unbounded_adj():
    """feat_need spill with adj_need=None: the feature excess must flow to
    the adjacency cache UNCAPPED (no adj_need to clamp it)."""
    # feature dominates -> Eq.1 gives feat 900; feat only holds 100 bytes
    a = allocate_capacity([1.0], [9.0], 1000, feat_need_bytes=100)
    assert a.feat_bytes == 100
    assert a.adj_bytes == 900  # 100 base + 800 spill, no cap
    assert a.adj_bytes + a.feat_bytes == 1000


def test_reallocate_keeps_total_and_follows_new_ratio():
    """Serve-time Eq. 1 re-run: same budget, new measured ratio."""
    base = allocate_capacity([1.0], [1.0], 1000)
    again = reallocate_capacity(base, [3.0], [1.0], adj_need_bytes=10_000)
    assert isinstance(again, CacheAllocation)
    assert again.total_bytes == base.total_bytes == 1000
    assert again.adj_bytes == 750 and again.feat_bytes == 250


# --------------------------------------------------- allocation invariants


@settings(max_examples=100, deadline=None)
@given(
    ts=st.lists(st.floats(0, 10, allow_nan=False), min_size=1, max_size=8),
    tf=st.lists(st.floats(0, 10, allow_nan=False), min_size=1, max_size=8),
    total=st.integers(0, 1 << 40),
    adj_need=st.one_of(st.none(), st.integers(0, 1 << 40)),
    feat_need=st.one_of(st.none(), st.integers(0, 1 << 40)),
)
def test_allocation_never_exceeds_budget(ts, tf, total, adj_need, feat_need):
    """Invariant: whatever the needs, adj + feat never exceeds the budget
    and neither side goes negative."""
    n = min(len(ts), len(tf))
    a = allocate_capacity(
        ts[:n], tf[:n], total, adj_need_bytes=adj_need, feat_need_bytes=feat_need
    )
    assert a.adj_bytes >= 0 and a.feat_bytes >= 0
    assert a.adj_bytes + a.feat_bytes <= total
    assert a.total_bytes == total
    if adj_need is not None:
        assert a.adj_bytes <= adj_need
    if feat_need is not None:
        assert a.feat_bytes <= feat_need
    assert 0.0 <= a.sample_fraction <= 1.0


@settings(max_examples=100, deadline=None)
@given(
    ts=st.lists(st.floats(0, 10, allow_nan=False), min_size=1, max_size=8),
    tf=st.lists(st.floats(0, 10, allow_nan=False), min_size=1, max_size=8),
    total=st.integers(0, 1 << 40),
    adj_need=st.integers(0, 1 << 40),
    feat_need=st.integers(0, 1 << 40),
)
def test_spill_conserves_budget_with_both_needs(ts, tf, total, adj_need, feat_need):
    """With both *_need_bytes given, spill is conservative: the split uses
    exactly min(total, adj_need + feat_need) bytes — nothing is lost to
    rounding and nothing is invented."""
    n = min(len(ts), len(tf))
    a = allocate_capacity(
        ts[:n], tf[:n], total, adj_need_bytes=adj_need, feat_need_bytes=feat_need
    )
    assert a.adj_bytes + a.feat_bytes == min(total, adj_need + feat_need)


@settings(max_examples=100, deadline=None)
@given(
    mem=st.integers(0, 1 << 42),
    peak=st.integers(0, 1 << 42),
    reserve=st.integers(0, 1 << 42),
)
def test_available_budget_clamps_at_zero(mem, peak, reserve):
    b = available_budget(mem, peak, reserve_bytes=reserve)
    assert b >= 0
    assert b == max(mem - peak - reserve, 0)


# ------------------------------------------------- per-shard Eq. 1 split


def test_shard_allocations_partition_the_budget_and_keep_the_fraction():
    base = allocate_capacity([1.0], [3.0], 1001)
    allocs = shard_allocations(
        base, [3.0, 1.0, 0.0, 2.0], sample_times=[1.0], feature_times=[3.0]
    )
    assert len(allocs) == 4
    # budgets follow the weights (last shard takes the rounding remainder)
    assert [a.total_bytes for a in allocs][:3] == [500, 166, 0]
    assert sum(a.total_bytes for a in allocs) == base.total_bytes
    # Eq. 1 is scale-invariant: every non-empty shard's split fraction
    # equals the global one
    for a in allocs:
        if a.total_bytes:
            assert a.sample_fraction == pytest.approx(base.sample_fraction)
    assert sum(a.adj_bytes for a in allocs) <= base.total_bytes


def test_shard_allocations_zero_weights_fall_back_to_uniform():
    base = allocate_capacity([1.0], [1.0], 100)
    allocs = shard_allocations(base, [0.0, 0.0], sample_times=[1.0], feature_times=[1.0])
    assert [a.total_bytes for a in allocs] == [50, 50]
    # negative weights clamp to zero rather than stealing budget
    allocs = shard_allocations(base, [-5.0, 1.0], sample_times=[1.0], feature_times=[1.0])
    assert [a.total_bytes for a in allocs] == [0, 100]
    with pytest.raises(ValueError):
        shard_allocations(base, [], sample_times=[1.0], feature_times=[1.0])


def test_shard_allocations_respect_scaled_needs():
    # a shard whose share of the adjacency need is tiny spills the excess
    # to its feature side, exactly as the global allocator would
    base = allocate_capacity([9.0], [1.0], 1000, adj_need_bytes=100)
    allocs = shard_allocations(
        base,
        [1.0, 1.0],
        sample_times=[9.0],
        feature_times=[1.0],
        adj_need_bytes=100,
    )
    for a in allocs:
        assert a.adj_bytes <= 50  # capped at the shard's share of the need
        assert a.adj_bytes + a.feat_bytes == a.total_bytes
