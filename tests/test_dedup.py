"""Unique-frontier dedup invariants: reconstruction, equivalence, accounting.

The dedup path's contract is exact: ``unique_ids[inverse]`` reconstructs
every frontier bit-for-bit, and flipping ``dedup`` (alone or with the
prefetch / kernel / refresh knobs) never changes model outputs or hit
accounting — only how many rows the feature stage moves.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.graph.sampling import (
    dedup_frontier,
    device_graph,
    pow2_bucket,
    sample_blocks,
)
from repro.runtime.gnn_engine import GNNInferenceEngine

FANOUTS = (3, 2)
BATCH = 64
KW = dict(total_cache_bytes=200_000, n_presample=2)


# -------------------------------------------------------------- primitives


@settings(max_examples=50, deadline=None)
@given(ids=st.lists(st.integers(0, 40), min_size=1, max_size=120))
def test_dedup_frontier_reconstructs_exactly(ids):
    frontier = jnp.asarray(np.asarray(ids, np.int32))
    dd = dedup_frontier(frontier)
    nu = int(dd.num_unique)
    unique = np.asarray(dd.unique_ids)
    inverse = np.asarray(dd.inverse)
    # live prefix is the sorted distinct ids; without an explicit pad id
    # the tail repeats the max id
    np.testing.assert_array_equal(unique[:nu], np.unique(ids))
    assert (unique[nu:] == unique[nu - 1]).all()
    # inverse points into the live prefix and reconstructs every position
    assert inverse.min() >= 0 and inverse.max() < nu
    np.testing.assert_array_equal(unique[inverse], np.asarray(ids))


@settings(max_examples=25, deadline=None)
@given(ids=st.lists(st.integers(0, 40), min_size=1, max_size=120))
def test_dedup_frontier_pad_id_fills_tail_only(ids):
    """An explicit pad id replaces ONLY the tail — live prefix and inverse
    are bit-identical to the unpadded call; pad_id=-1 falls back to the
    max-id fill (the cacheless-policy path)."""
    frontier = jnp.asarray(np.asarray(ids, np.int32))
    plain = dedup_frontier(frontier)
    padded = dedup_frontier(frontier, 40)
    nu = int(plain.num_unique)
    assert nu == int(padded.num_unique)
    np.testing.assert_array_equal(
        np.asarray(padded.unique_ids)[:nu], np.asarray(plain.unique_ids)[:nu]
    )
    np.testing.assert_array_equal(np.asarray(padded.inverse), np.asarray(plain.inverse))
    assert (np.asarray(padded.unique_ids)[nu:] == 40).all()
    fallback = dedup_frontier(frontier, -1)
    np.testing.assert_array_equal(
        np.asarray(fallback.unique_ids), np.asarray(plain.unique_ids)
    )


def test_warmup_pad_id_never_stages_duplicate_miss(small_dataset):
    """The dedup-pad bugfix: padding the unique-id tail with the repeated
    MAX id let warmup stage that id's host row once per pad slot when the
    max id was a cache miss.  With ``pad_id=store.pad_node_id()`` the tail
    holds a known-CACHED id, so no padded slot can ever enter the staged
    miss set — with or without the live-prefix hint."""
    eng = GNNInferenceEngine(small_dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare("dci", **KW)
    store = eng.pipeline.caches.store
    pos = store.position_np()
    pad = store.pad_node_id()
    assert pad >= 0 and pos[pad] >= 0  # the pad id is a cached row
    cached = np.nonzero(pos >= 0)[0]
    uncached = np.nonzero(pos < 0)[0]
    assert uncached.size, "config must leave some rows uncached"
    big_miss = int(uncached[-1])
    base = np.concatenate([cached[:4], [big_miss]]).astype(np.int32)
    assert big_miss > base[:4].max()  # the duplicated miss IS the max id
    ids = np.tile(base, 4)[:16]  # 5 distinct ids -> pow2 bucket 8, tail 3
    dd = dedup_frontier(jnp.asarray(ids), store.pad_node_id())
    nu = int(dd.num_unique)
    assert nu == 5
    bucket = pow2_bucket(nu, ids.size)
    gather_ids = np.asarray(dd.unique_ids)[:bucket]
    # every padded tail slot holds the cached pad id — a guaranteed hit
    np.testing.assert_array_equal(gather_ids[nu:], pad)
    assert (pos[gather_ids[nu:]] >= 0).all()
    pf = store.prefetch_misses(gather_ids, num_live=nu)
    assert pf.idx is not None  # the pack path, not the all-miss fast path
    staged_pos = np.asarray(pf.idx)[: pf.num_miss]
    staged_ids = gather_ids[staged_pos]
    # staged set == the DISTINCT live misses: no pad slot, no duplicates
    assert (staged_pos < nu).all()
    assert pf.num_miss == int((pos[gather_ids[:nu]] < 0).sum())
    assert len(set(staged_ids.tolist())) == pf.num_miss
    assert big_miss in staged_ids.tolist() and pad not in staged_ids.tolist()
    # belt and suspenders: even WITHOUT the live-prefix hint the cached
    # pad tail stages nothing extra (the old max-id padding did)
    pf2 = store.prefetch_misses(gather_ids)
    assert pf2.num_miss == pf.num_miss


def test_pow2_bucket_covers_and_caps():
    assert pow2_bucket(0, 64) == 1
    assert pow2_bucket(1, 64) == 1
    assert pow2_bucket(3, 64) == 4
    assert pow2_bucket(4, 64) == 4
    assert pow2_bucket(33, 64) == 64
    assert pow2_bucket(100, 64) == 64  # capped at the frontier size


def test_sample_blocks_dedup_matches_plain_sampling(small_dataset):
    """dedup=True must not disturb sampling itself: same frontiers, hits,
    and edge slots as the plain call under the same key."""
    g = device_graph(small_dataset.graph)
    seeds = jnp.asarray(small_dataset.test_idx[:BATCH].astype(np.int32))
    key = jax.random.PRNGKey(7)
    plain = sample_blocks(key, g, seeds, FANOUTS)
    dedup = sample_blocks(key, g, seeds, FANOUTS, dedup=True)
    assert plain.dedup is None and dedup.dedup is not None
    for a, b in zip(plain.frontiers, dedup.frontiers):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(plain.edge_slots, dedup.edge_slots):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    dd = dedup.dedup
    np.testing.assert_array_equal(
        np.asarray(dd.unique_ids)[np.asarray(dd.inverse)],
        np.asarray(dedup.input_nodes),
    )
    assert int(dd.num_unique) == np.unique(np.asarray(dedup.input_nodes)).size


def test_forward_inverse_index_bit_identical(small_dataset):
    """forward(unique, inverse_index) == forward(unique[inverse]) — the
    reconstruction gather commutes with nothing, it IS the first op."""
    from repro.models import gnn as gnn_models

    g = device_graph(small_dataset.graph)
    seeds = jnp.asarray(small_dataset.test_idx[:BATCH].astype(np.int32))
    block = sample_blocks(jax.random.PRNGKey(3), g, seeds, FANOUTS, dedup=True)
    params = gnn_models.init_params(
        jax.random.PRNGKey(0), "graphsage", small_dataset.spec.feat_dim,
        small_dataset.spec.num_classes,
    )
    feats = jnp.asarray(small_dataset.features)
    unique_feats = feats[block.dedup.unique_ids]
    out_inverse = gnn_models.forward(
        params, unique_feats, model="graphsage", fanouts=FANOUTS,
        inverse_index=block.dedup.inverse,
    )
    out_plain = gnn_models.forward(
        params, unique_feats[block.dedup.inverse], model="graphsage", fanouts=FANOUTS
    )
    np.testing.assert_array_equal(np.asarray(out_inverse), np.asarray(out_plain))


# ------------------------------------------------------------- equivalence


def _paired_engines(dataset, policy):
    serial = GNNInferenceEngine(dataset, fanouts=FANOUTS, batch_size=BATCH)
    serial.prepare(policy, **KW)
    other = GNNInferenceEngine(
        dataset, fanouts=FANOUTS, batch_size=BATCH, params=serial.params
    )
    other.pipeline = serial.pipeline
    return serial, other


@pytest.mark.parametrize("policy", ["dci", "dgl"])
@pytest.mark.parametrize(
    "depth,prefetch,use_kernel",
    [(1, False, False), (3, True, False), (2, True, True)],
)
def test_dedup_equivalence(small_dataset, policy, depth, prefetch, use_kernel):
    """dedup=True is bit-identical to the plain serial run — outputs, adj
    and feature hit accounting — for every knob combination, while moving
    strictly fewer feature rows; with prefetch it stages only unique
    misses."""
    from repro.runtime.cache_refresh import RefreshConfig

    serial, piped = _paired_engines(small_dataset, policy)
    r1 = serial.run(max_batches=4, pipeline_depth=1, collect_outputs=True)
    o1 = serial.last_outputs
    r2 = piped.run(
        max_batches=4,
        pipeline_depth=depth,
        collect_outputs=True,
        prefetch=prefetch,
        use_kernel=use_kernel,
        dedup=True,
        refresh=RefreshConfig(mode="off"),
    )
    o2 = piped.last_outputs
    assert r2.dedup
    assert (r1.adj_hits, r1.adj_lookups) == (r2.adj_hits, r2.adj_lookups)
    assert (r1.feat_hits, r1.feat_lookups) == (r2.feat_hits, r2.feat_lookups)
    assert 0 < r2.unique_rows < r2.feat_lookups
    assert r2.duplication_factor > 1.0
    # pow2 padding bounds the gathered rows at 2x the distinct rows
    assert r2.unique_rows <= r2.gathered_rows <= 2 * r2.unique_rows
    if prefetch:
        assert r2.prefetched_rows <= r1.feat_lookups - r1.feat_hits
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(a, b)


def test_dedup_equivalence_single_batch(small_dataset):
    """Per-batch (not just cumulative) hit accounting is dedup-invariant:
    a one-batch run pins the first batch's counters exactly."""
    serial, piped = _paired_engines(small_dataset, "dci")
    r1 = serial.run(max_batches=1, pipeline_depth=1)
    r2 = piped.run(max_batches=1, pipeline_depth=1, dedup=True)
    assert (r1.feat_hits, r1.feat_lookups) == (r2.feat_hits, r2.feat_lookups)
    assert (r1.adj_hits, r1.adj_lookups) == (r2.adj_hits, r2.adj_lookups)


def test_dedup_with_refresh_outputs_identical(small_dataset):
    """dedup composes with online refresh: outputs stay bit-identical to
    the refresh-free serial run (a refresh moves bytes, never values)."""
    from repro.runtime.cache_refresh import RefreshConfig

    serial, piped = _paired_engines(small_dataset, "dci")
    r1 = serial.run(max_batches=6, pipeline_depth=1, collect_outputs=True)
    o1 = serial.last_outputs
    r2 = piped.run(
        max_batches=6,
        pipeline_depth=2,
        collect_outputs=True,
        dedup=True,
        refresh=RefreshConfig(mode="interval", interval_batches=2),
    )
    assert piped.pipeline.caches.epoch >= 1 and len(r2.refresh_events) >= 1
    assert r1.num_batches == r2.num_batches
    for a, b in zip(o1, piped.last_outputs):
        np.testing.assert_array_equal(a, b)


def test_dedup_rain_falls_back_to_reuse(small_dataset):
    """RAIN's cross-batch reuse map is per-visit — dedup resolves off, the
    run behaves exactly like the plain RAIN path."""
    serial, piped = _paired_engines(small_dataset, "rain")
    r1 = serial.run(max_batches=4, pipeline_depth=1, collect_outputs=True)
    o1 = serial.last_outputs
    r2 = piped.run(max_batches=4, pipeline_depth=2, dedup=True, collect_outputs=True)
    assert not r2.dedup  # resolved off against reuse_prev_batch
    assert r2.unique_rows == 0
    assert (r1.feat_hits, r1.feat_lookups) == (r2.feat_hits, r2.feat_lookups)
    for a, b in zip(o1, piped.last_outputs):
        np.testing.assert_array_equal(a, b)


def test_telemetry_multiplicities_bit_identical():
    """Unique+multiplicity recording produces the same counters as the
    per-visit form — the dedup telemetry contract."""
    from repro.core.telemetry import WorkloadTelemetry

    rng = np.random.default_rng(0)
    nodes = rng.integers(0, 12, 40)
    posmap = rng.integers(-1, 3, 12)
    hit = posmap[nodes] >= 0
    slots = [rng.integers(0, 8, (5, 3))]

    per_visit = WorkloadTelemetry(num_nodes=12, num_edges=8)
    per_visit.observe_batch(nodes, hit, slots)

    unique, inverse = np.unique(nodes, return_inverse=True)
    mult = np.bincount(inverse, minlength=unique.size)
    deduped = WorkloadTelemetry(num_nodes=12, num_edges=8)
    deduped.observe_batch(
        unique, posmap[unique] >= 0, slots, multiplicities=mult
    )

    np.testing.assert_array_equal(per_visit.node_counts, deduped.node_counts)
    np.testing.assert_array_equal(per_visit.node_miss_counts, deduped.node_miss_counts)
    np.testing.assert_array_equal(per_visit.edge_counts, deduped.edge_counts)
    assert per_visit.feat_lookups == deduped.feat_lookups == 40
    assert per_visit.feat_misses == deduped.feat_misses
    assert per_visit.miss_rate == deduped.miss_rate


def test_shard_local_pad_never_stages_cross_shard_row(small_dataset):
    """The sharded twin of the dedup-pad bugfix: the global pad id lives
    on ONE shard, so re-using it for every shard's bucket tail would make
    the other shards stage a cross-shard (guaranteed-miss) row per pad
    slot during warmup.  ``ShardedFeatureStore.partition`` pads each
    segment with that shard's LOCAL cached pad id instead — every pad
    slot is an in-shard local-cache hit, and no shard ever stages a pad
    row."""
    from repro.graph.shard import ShardedFeatureStore, make_shard_plan

    eng = GNNInferenceEngine(small_dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare("dci", **KW)
    store = eng.pipeline.caches.store
    ss = ShardedFeatureStore.partition_store(
        store, make_shard_plan(store.num_nodes, 4)
    )
    # a frontier spanning all shards, global-pow2-padded as warmup sees it
    rng = np.random.default_rng(11)
    uids = np.unique(rng.integers(0, store.num_nodes, size=50)).astype(np.int64)
    nu = uids.size
    bucket = pow2_bucket(nu)
    padded = np.full(bucket, int(store.pad_node_id()), np.int64)
    padded[:nu] = uids
    part = ss.partition(padded, num_live=nu)
    plan = ss.plan
    for s, buf in enumerate(part.seg_ids):
        if buf is None:
            continue
        lo, hi = plan.bounds(s)
        local = ss.shards[s]
        pos = local.position_np()
        n, live = part.seg_len[s], part.seg_live[s]
        # bucket tail pads are the shard's OWN pad id...
        local_pad = local.pad_node_id()
        expected_pad = local_pad if local_pad >= 0 else 0
        assert (buf[n:] == expected_pad).all()
        # ...always in-shard, and a local-cache hit wherever the shard
        # caches anything at all
        assert (buf >= 0).all() and (buf < hi - lo).all()
        if (pos >= 0).any():
            assert pos[expected_pad] >= 0
        # staging respects the live window: pads and the global pad-id
        # tail stage nothing, and every staged row is an in-shard miss
        pf = local.prefetch_misses(buf, num_live=live)
        assert pf.num_miss == int((pos[buf[:live]] < 0).sum())
        if pf.idx is not None:
            staged_pos = np.asarray(pf.idx)[: pf.num_miss]
            assert (staged_pos < live).all()
    # the per-shard live windows tile the live prefix exactly: the global
    # pad tail (positions nu..bucket) is dead on every shard
    assert sum(part.seg_live) == nu
    assert sum(part.seg_len) == bucket
