"""Shard round-trip properties (graph/shard.py).

The exchange protocol's contract is exact: partition → per-shard gather →
exchange-back → inverse-permute returns the SAME bits as a single-device
``FeatureStore.gather`` over the same ids — for arbitrary frontiers
(duplicates, empty shards, every id on one shard), any shard count, with
or without staged prefetch packs.  Per-visit hit accounting by owning
shard sums to the single-device counters exactly.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.graph.features import build_feature_cache, plain_feature_store
from repro.graph.sampling import pow2_bucket
from repro.graph.shard import (
    ShardedFeatureStore,
    make_shard_plan,
    partition_feature_store,
)

N, F = 50, 8


def _store(n=N, f=F, cached_frac=0.5, seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((n, f)).astype(np.float32)
    counts = rng.integers(0, 10, size=n).astype(np.float64)
    budget = int(cached_frac * n) * f * feats.dtype.itemsize
    return build_feature_cache(feats, counts, budget)


def _sharded(store, k):
    return ShardedFeatureStore.partition_store(store, make_shard_plan(store.num_nodes, k))


# ------------------------------------------------------------------- plan


def test_plan_balanced_and_boundary_mapping():
    plan = make_shard_plan(10, 3)
    assert plan.shard_sizes().tolist() == [4, 3, 3]
    assert plan.row_starts.tolist() == [0, 4, 7, 10]
    # boundary ids belong to the shard whose range STARTS there
    assert plan.shard_of(np.array([0, 3, 4, 6, 7, 9])).tolist() == [0, 0, 1, 1, 2, 2]
    with pytest.raises(ValueError):
        make_shard_plan(10, 0)


def test_plan_more_shards_than_nodes_leaves_empty_shards():
    plan = make_shard_plan(3, 5)
    assert plan.num_shards == 5
    assert plan.shard_sizes().sum() == 3
    # ids never land on an empty shard
    asgn = plan.shard_of(np.arange(3))
    assert all(plan.shard_sizes()[s] > 0 for s in asgn)


def test_partition_store_slices_and_reslots():
    store = _store()
    plan = make_shard_plan(N, 4)
    shards = partition_feature_store(store, plan)
    host = store.host_np()
    pos = store.position_np()
    for s, fs in enumerate(shards):
        lo, hi = plan.bounds(s)
        np.testing.assert_array_equal(fs.host_np(), host[lo:hi])
        # same cached-row membership, local slot ids re-packed ascending
        local_cached = np.nonzero(fs.position_np() >= 0)[0]
        np.testing.assert_array_equal(local_cached, np.nonzero(pos[lo:hi] >= 0)[0])
        # hot rows are bit-copies of the host rows they cache
        hot = np.asarray(fs.hot_table)
        for li in local_cached:
            np.testing.assert_array_equal(hot[fs.position_np()[li]], host[lo + li])
    assert sum((fs.position_np() >= 0).sum() for fs in shards) == store.num_cached


# ------------------------------------------------------- round-trip (unit)


@pytest.mark.parametrize("k", [1, 2, 3, 4, 7])
@pytest.mark.parametrize("cached_frac", [0.0, 0.5, 1.0])
def test_gather_matches_single_device(k, cached_frac):
    store = _store(cached_frac=cached_frac) if cached_frac else plain_feature_store(
        _store().host_np()
    )
    ss = _sharded(store, k)
    rng = np.random.default_rng(3)
    ids = rng.integers(0, N, size=37).astype(np.int64)  # duplicates, unsorted
    part = ss.partition(ids)
    feats, hit = ss.gather(part)
    ref_f, ref_h = store.gather(np.asarray(ids))
    np.testing.assert_array_equal(np.asarray(feats), np.asarray(ref_f))
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(ref_h))


def test_all_ids_on_one_shard_and_sorted_identity():
    store = _store()
    ss = _sharded(store, 4)
    lo, hi = ss.plan.bounds(2)
    ids = np.arange(lo, hi, dtype=np.int64)  # sorted, single owner
    part = ss.partition(ids)
    assert part.inv is None  # stable shard-sort degenerates to identity
    assert [b is not None for b in part.seg_ids] == [False, False, True, False]
    feats, hit = ss.gather(part)
    ref_f, ref_h = store.gather(ids)
    np.testing.assert_array_equal(np.asarray(feats), np.asarray(ref_f))
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(ref_h))


def test_prefetch_counts_and_gather_match_single_device():
    store = _store()
    ss = _sharded(store, 3)
    rng = np.random.default_rng(5)
    ids = np.unique(rng.integers(0, N, size=40)).astype(np.int64)
    nu = ids.size
    bucket = pow2_bucket(nu)
    padded = np.full(bucket, int(store.pad_node_id()), np.int64)
    padded[:nu] = ids
    part = ss.partition(padded, num_live=nu)
    staged = ss.prefetch(part)
    ref_staged = store.prefetch_misses(padded, num_live=nu)
    assert staged.num_miss == ref_staged.num_miss
    feats, hit = ss.gather(part, prefetched=staged)
    ref_f, ref_h = store.gather(padded, prefetched=ref_staged)
    np.testing.assert_array_equal(np.asarray(feats), np.asarray(ref_f))
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(ref_h))


def test_seg_live_windows_cover_exactly_the_live_prefix():
    store = _store()
    ss = _sharded(store, 4)
    ids = np.array([3, 17, 44, 9, 28, 46, 1, 30], np.int64)
    for num_live in range(len(ids) + 1):
        part = ss.partition(ids, num_live=num_live)
        assert sum(part.seg_live) == num_live
        # live members per shard == owning-shard histogram of the prefix
        live_asgn = ss.plan.shard_of(ids[:num_live])
        counts = np.bincount(live_asgn, minlength=4)
        for s in range(4):
            assert part.seg_live[s] == counts[s]


# ------------------------------------------------------ properties (given)


@settings(max_examples=25, deadline=None)
@given(
    ids=st.lists(st.integers(min_value=0, max_value=N - 1), min_size=1, max_size=60),
    k=st.integers(min_value=1, max_value=8),
    cached_frac=st.sampled_from([0.0, 0.3, 1.0]),
)
def test_property_round_trip_bitwise(ids, k, cached_frac):
    store = _store(cached_frac=max(cached_frac, 0.02)) if cached_frac else (
        plain_feature_store(_store().host_np())
    )
    ss = _sharded(store, k)
    ids = np.asarray(ids, np.int64)
    part = ss.partition(ids)
    # structural invariants: order is a permutation, segments partition it,
    # every local id is in its shard's range (pads included)
    assert np.array_equal(np.sort(part.order), np.arange(ids.size))
    assert sum(part.seg_len) == ids.size
    for s, buf in enumerate(part.seg_ids):
        lo, hi = ss.plan.bounds(s)
        if buf is None:
            assert part.seg_len[s] == 0
            continue
        assert len(buf) == pow2_bucket(part.seg_len[s])
        assert (buf >= 0).all() and (buf < hi - lo).all()
    feats, hit = ss.gather(part)
    ref_f, ref_h = store.gather(ids)
    np.testing.assert_array_equal(np.asarray(feats), np.asarray(ref_f))
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(ref_h))


@settings(max_examples=25, deadline=None)
@given(
    ids=st.lists(st.integers(min_value=0, max_value=N - 1), min_size=1, max_size=60),
    k=st.integers(min_value=1, max_value=6),
)
def test_property_per_visit_hits_sum_across_shards(ids, k):
    """The serving path's per-shard accounting (unique ids weighted by
    visit multiplicity, binned by owning shard) sums to the single-device
    per-visit counters exactly."""
    store = _store()
    ss = _sharded(store, k)
    ids = np.asarray(ids, np.int64)
    uids, inverse = np.unique(ids, return_inverse=True)
    part = ss.partition(uids)
    _, hit_u = ss.gather(part)
    hit_u = np.asarray(hit_u).astype(bool)
    mult = np.bincount(inverse, minlength=uids.size).astype(np.int64)
    asgn = ss.plan.shard_of(uids)
    lookups = np.zeros(k, np.int64)
    hits = np.zeros(k, np.int64)
    np.add.at(lookups, asgn, mult)
    np.add.at(hits, asgn[hit_u], mult[hit_u])
    # single-device reference over the raw (duplicate-carrying) frontier
    _, ref_hit = store.gather(ids)
    ref_hit = np.asarray(ref_hit).astype(bool)
    assert lookups.sum() == ids.size
    assert hits.sum() == int(ref_hit.sum())
