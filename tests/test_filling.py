"""Feature-cache filling (§IV-B): sort-free above-mean selection."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.graph.features import build_feature_cache, plain_feature_store


def test_above_mean_nodes_cached_first(rng):
    feats = rng.standard_normal((100, 8)).astype(np.float32)
    counts = np.zeros(100, np.int64)
    counts[:10] = 100  # hot
    counts[10:20] = 1  # lukewarm
    row = 8 * 4
    store = build_feature_cache(feats, counts, capacity_bytes=row * 10)
    pos = np.asarray(store.position_map)
    assert (pos[:10] >= 0).all()  # all above-mean nodes in
    assert (pos[20:] < 0).all()


def test_top_up_below_mean(rng):
    feats = rng.standard_normal((50, 4)).astype(np.float32)
    counts = np.zeros(50, np.int64)
    counts[0] = 10
    counts[1:6] = 1  # below mean after the spike? mean = 15/50 = 0.3 -> above
    store = build_feature_cache(feats, counts, capacity_bytes=4 * 4 * 20)
    pos = np.asarray(store.position_map)
    # visited nodes preferred over never-visited when topping up
    assert (pos[:6] >= 0).all()
    assert int((pos >= 0).sum()) == 20


def test_capacity_zero(rng):
    feats = rng.standard_normal((10, 4)).astype(np.float32)
    store = build_feature_cache(feats, np.ones(10, np.int64), capacity_bytes=0)
    assert store.num_cached == 0
    out, hit = store.gather(np.arange(10, dtype=np.int32))
    assert not np.asarray(hit).any()
    np.testing.assert_allclose(np.asarray(out), feats)


def test_gather_correct_on_hits_and_misses(rng):
    feats = rng.standard_normal((30, 6)).astype(np.float32)
    counts = rng.integers(0, 5, 30).astype(np.int64)
    store = build_feature_cache(feats, counts, capacity_bytes=6 * 4 * 7)
    idx = rng.integers(0, 30, 64).astype(np.int32)
    out, hit = store.gather(idx)
    np.testing.assert_allclose(np.asarray(out), feats[idx], rtol=1e-6)
    # hit mask matches the position map
    pos = np.asarray(store.position_map)
    np.testing.assert_array_equal(np.asarray(hit), pos[idx] >= 0)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 60),
    f=st.integers(1, 12),
    budget_rows=st.integers(0, 70),
    seed=st.integers(0, 999),
)
def test_feature_cache_properties(n, f, budget_rows, seed):
    rng = np.random.default_rng(seed)
    feats = rng.standard_normal((n, f)).astype(np.float32)
    counts = rng.integers(0, 10, n).astype(np.int64)
    store = build_feature_cache(feats, counts, capacity_bytes=budget_rows * f * 4)
    cached = store.num_cached
    assert cached <= min(budget_rows, n)
    if budget_rows >= n:
        assert cached == n  # everything fits
    # gather always reconstructs the exact features
    idx = rng.integers(0, n, 20).astype(np.int32)
    out, _ = store.gather(idx)
    np.testing.assert_allclose(np.asarray(out), feats[idx], rtol=1e-6)


def test_plain_store_never_hits(rng):
    feats = rng.standard_normal((5, 3)).astype(np.float32)
    store = plain_feature_store(feats)
    _, hit = store.gather(np.arange(5, dtype=np.int32))
    assert not np.asarray(hit).any()
