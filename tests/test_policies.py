"""All cache policies produce runnable pipelines with sane stats."""

import numpy as np
import pytest

from repro.core.policies import POLICIES, prepare
from repro.runtime.gnn_engine import GNNInferenceEngine

KW = dict(total_cache_bytes=200_000, fanouts=(3, 2), batch_size=64, n_presample=2)


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_policy_end_to_end(small_dataset, policy):
    eng = GNNInferenceEngine(small_dataset, fanouts=(3, 2), batch_size=64)
    eng.prepare(policy, total_cache_bytes=200_000, n_presample=2)
    rep = eng.run(max_batches=3)
    assert rep.num_batches == 3
    assert 0 <= rep.adj_hit_rate <= 1
    assert 0 <= rep.feat_hit_rate <= 1
    assert rep.total_seconds > 0
    if policy == "dgl":
        assert rep.adj_hit_rate == 0 or rep.adj_hit_rate < 0.2  # only self-loops
        assert rep.feat_hit_rate == 0
    if policy in ("dci", "ducati"):
        assert rep.adj_hit_rate > 0
    if policy in ("dci", "sci", "ducati"):
        assert rep.feat_hit_rate > 0


def test_dci_allocation_follows_eq1(small_dataset):
    pipe = prepare("dci", small_dataset, **KW)
    a = pipe.caches.allocation
    assert a.adj_bytes + a.feat_bytes == KW["total_cache_bytes"]
    assert 0.0 <= a.sample_fraction <= 1.0


def test_sci_all_budget_to_features(small_dataset):
    pipe = prepare("sci", small_dataset, **KW)
    a = pipe.caches.allocation
    assert a.adj_bytes == 0
    assert a.feat_bytes == KW["total_cache_bytes"]
    assert pipe.caches.adj_cached_elements == 0


def test_rain_batch_order_is_permutation(small_dataset):
    pipe = prepare("rain", small_dataset, batch_size=64)
    nb = max(len(small_dataset.test_idx) // 64, 1)
    order = np.sort(pipe.batch_order)
    np.testing.assert_array_equal(order, np.arange(nb))
    assert pipe.reuse_prev_batch


def test_ducati_prep_slower_than_dci(small_dataset, jit_warm):
    # The shared jit_warm fixture has already compiled the presample/fill
    # programs, so both prepares below measure steady-state prep cost.
    t_dci = prepare("dci", small_dataset, **KW).prep_seconds
    t_duc = prepare("ducati", small_dataset, **KW).prep_seconds
    # DUCATI gathers 4x the statistics + global sorts + curve fits.
    assert t_duc > t_dci


def test_unknown_policy_raises(small_dataset):
    with pytest.raises(KeyError):
        prepare("nope", small_dataset, **KW)
