"""Tracing & metrics layer (core/trace.py + the runtime wiring).

The load-bearing guarantees:

  * schema — every emitted event passes :func:`validate_trace` (Chrome
    trace-event fields present, X spans carry a non-negative ``dur``,
    every flow id pairs exactly one start with one end), so Perfetto /
    chrome://tracing always load the export;
  * agreement — per-stage span totals agree with the StageClock's stage
    seconds on a serial run (the span wraps the clock's lap, so span
    time is a tight upper bound);
  * overlap — the slot-lane model makes pipeline overlap a property of
    the trace: exactly 0.0 at depth 1, > 0 at depth > 1;
  * non-interference — tracing (and metrics) on vs off is bit-for-bit
    identical in outputs and hit accounting across the dedup x prefetch
    x refresh grid, and the NullTracer path allocates no events.
"""

import json
import pathlib
import subprocess
import sys
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import EngineConfig
from repro.core.trace import (
    NULL_TRACER,
    MetricsRegistry,
    NullTracer,
    Tracer,
    resolve_tracer,
    summarize_trace,
    validate_trace,
)
from repro.runtime.cache_refresh import RefreshConfig
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches
from repro.runtime.request_queue import Request, RequestQueueServer
from repro.runtime.sharded_serve import ShardedServer
from repro.utils.timing import Stopwatch

FANOUTS = (3, 2)
BATCH = 64
KW = dict(total_cache_bytes=200_000, n_presample=2)
STREAM_SEEDS = [100, 101, 102]


def _engine(dataset, *, streams=False):
    eng = GNNInferenceEngine(dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare("dci", stream_seeds=STREAM_SEEDS if streams else None, **KW)
    return eng


def _queues(dataset, n=2, batches=3):
    return make_stream_batches(
        dataset, num_streams=n, batches_per_stream=batches, batch_size=BATCH, seed=7
    )


def _serve(dataset, tracer=None, metrics=None, *, depth=2, refresh=None, server_cls=MultiStreamServer, **kw):
    eng = _engine(dataset, streams=True)
    srv = server_cls(eng, depth=depth, refresh=refresh, tracer=tracer, metrics=metrics, **kw)
    queues = _queues(dataset)
    states = [
        srv.add_stream(q, seed=STREAM_SEEDS[i], collect_outputs=True)
        for i, q in enumerate(queues)
    ]
    rep = srv.run()
    outs = [[np.asarray(o) for o in s.runtime.outputs] for s in states]
    return rep, outs


# ------------------------------------------------------------ tracer unit


def test_tracer_schema_and_lanes():
    tr = Tracer()
    with tr.span("a", lane="slot 0", args={"batch": 0}):
        with tr.span("b", lane="slot 1"):
            pass
    tr.instant("tick", lane="slot 0")
    tr.counter("depth", {"q": 3.0})
    fid = tr.next_flow_id()
    tr.flow_start(fid, "req", lane="slot 0")
    tr.flow_end(fid, "req", lane="slot 1")
    assert validate_trace(tr.events) == []
    doc = tr.to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    # metadata first, then timestamp order
    phs = [e["ph"] for e in doc["traceEvents"]]
    assert phs[: phs.count("M")] == ["M"] * phs.count("M")
    # lanes are dense tids in creation order, counters on tid 0
    names = {e["args"]["name"] for e in tr.events if e.get("name") == "thread_name"}
    assert {"slot 0", "slot 1"} <= names
    assert all(e["tid"] == 0 for e in tr.events if e["ph"] == "C")
    # spans nest: "b" closed before "a", both non-negative
    spans = {e["name"]: e for e in tr.events if e["ph"] == "X"}
    assert spans["b"]["dur"] >= 0 and spans["a"]["dur"] >= spans["b"]["dur"]


def test_validate_trace_catches_violations():
    bad = [
        {"ph": "X", "ts": 0.0, "pid": 1, "tid": 1, "name": "no-dur"},
        {"ph": "s", "ts": 0.0, "pid": 1, "tid": 1, "name": "f", "cat": "flow", "id": 9},
        {"ph": "i", "ts": 0.0, "pid": 1, "tid": 1, "name": "scope", "s": "zzz"},
    ]
    errs = validate_trace(bad)
    assert any("dur" in e for e in errs)
    assert any("flow" in e for e in errs)  # id 9 has a start but no finish
    assert any("scope" in e or "s" in e for e in errs)


def test_summarize_overlap_on_synthetic_spans():
    tr = Tracer()
    tr.complete("batch", lane="slot 0", ts_us=0.0, dur_us=100.0)
    tr.complete("batch", lane="slot 1", ts_us=50.0, dur_us=100.0)
    s = summarize_trace(tr.events)
    # busy wall-clock union is [0, 150] us; 50 us of it has both lanes busy
    assert s["overlap_fraction"] == pytest.approx(50.0 / 150.0)
    assert s["lanes"]["slot 0"]["spans"] == 1
    serial = Tracer()
    serial.complete("batch", lane="slot 0", ts_us=0.0, dur_us=100.0)
    serial.complete("batch", lane="slot 0", ts_us=100.0, dur_us=100.0)
    assert summarize_trace(serial.events)["overlap_fraction"] == 0.0


def test_null_tracer_is_free_and_shared():
    assert resolve_tracer(None) is NULL_TRACER
    tr = Tracer()
    assert resolve_tracer(tr) is tr
    null = resolve_tracer(None)
    assert isinstance(null, NullTracer) and not null.enabled
    with null.span("x", lane="anything", args={"k": 1}):
        pass
    null.instant("i")
    null.counter("c", {"v": 1.0})
    null.complete("x", lane="l", ts_us=0.0, dur_us=1.0)
    null.flow_start(null.next_flow_id(), "f", lane="l")
    assert tuple(null.events) == ()


# ------------------------------------------------------------ metrics unit


def test_metrics_registry_kinds_and_labels():
    m = MetricsRegistry()
    m.counter("reqs", stream=0).inc()
    m.counter("reqs", stream=0).inc(2)
    m.counter("reqs", stream=1).inc()
    m.gauge("rate", policy="dci").set(0.5)
    h = m.histogram("lat_ms")
    for v in (1.0, 3.0, 200.0, 900.0):
        h.observe(v)
    snap = m.snapshot()
    assert snap["counters"]['reqs{stream="0"}'] == 3.0
    assert snap["counters"]['reqs{stream="1"}'] == 1.0
    assert snap["gauges"]['rate{policy="dci"}'] == 0.5
    hs = snap["histograms"]["lat_ms"]
    assert hs["count"] == 4 and hs["min"] == 1.0 and hs["max"] == 900.0
    assert hs["p50"] <= hs["p95"] <= hs["p99"] <= 900.0
    # one name = one kind
    with pytest.raises(ValueError):
        m.gauge("reqs")
    with pytest.raises(ValueError):
        m.counter("reqs").inc(-1.0)
    # same labels in any kwarg order resolve to the same series
    assert m.counter("pair", a=1, b=2) is m.counter("pair", b=2, a=1)
    assert json.loads(m.to_json()) == m.snapshot()


def test_metrics_prometheus_text():
    m = MetricsRegistry()
    m.counter("served_total", stream=0).inc(5)
    m.gauge("hit_rate").set(0.25)
    m.histogram("lat_ms", buckets=(1.0, 10.0)).observe(2.0)
    text = m.to_prometheus()
    assert "# TYPE served_total counter" in text
    assert 'served_total{stream="0"} 5' in text
    assert "hit_rate 0.25" in text
    assert 'lat_ms_bucket{le="10"} 1' in text or 'lat_ms_bucket{le="10.0"} 1' in text
    assert 'lat_ms_bucket{le="+Inf"} 1' in text
    assert "lat_ms_count 1" in text


# ----------------------------------------------------------- stopwatch fix


def test_stopwatch_track_callable_sync():
    sw = Stopwatch()
    order = []

    def sync():
        order.append("sync")
        return jnp.arange(4)

    with sw.track("step", sync=sync):
        order.append("body")
    assert order == ["body", "sync"]
    assert sw.total("step") > 0.0
    # a failing body must not evaluate the sync callable
    with pytest.raises(RuntimeError):
        with sw.track("boom", sync=lambda: order.append("late")):
            raise RuntimeError("x")
    assert "late" not in order


# --------------------------------------------------- engine / serve wiring


def test_engine_serial_spans_agree_with_stage_clock(small_dataset, jit_warm):
    eng = _engine(small_dataset)
    tr = Tracer()
    rep = eng.run(max_batches=3, pipeline_depth=1, tracer=tr)
    assert validate_trace(tr.events) == []
    s = summarize_trace(tr.events)
    # serial: one slot lane, zero overlap
    assert s["overlap_fraction"] == 0.0
    assert [n for n in s["lanes"] if n.startswith("slot")] == ["slot 0"]
    clock_s = {
        "sample": rep.sample_seconds,
        "feature": rep.feature_seconds,
        "compute": rep.compute_seconds,
    }
    for stage, total in clock_s.items():
        span_s = s["stages"][stage]["total_ms"] / 1e3
        # the span wraps the clock lap (plus ~us of tracer overhead)
        assert span_s >= total * 0.98
        assert span_s <= total + 0.05 * max(total, 1.0)


def test_serve_trace_flows_overlap_and_refresh(small_dataset, jit_warm):
    tr = Tracer()
    metrics = MetricsRegistry()
    rep, _ = _serve(
        small_dataset,
        tr,
        metrics,
        depth=2,
        refresh=RefreshConfig(mode="interval", interval_batches=3),
    )
    assert validate_trace(tr.events) == []
    s = summarize_trace(tr.events)
    assert s["overlap_fraction"] > 0.0
    # one complete enqueue->retire flow per retired batch
    retired = sum(st.num_batches for st in rep.streams)
    assert s["n_flows"] == retired
    names = {e.get("name") for e in tr.events if e["ph"] == "X"}
    assert {"queued", "service", "batch", "refresh"} <= names
    assert "epoch" in {e.get("name") for e in tr.events if e["ph"] == "i"}
    assert {"queue_depth", "inflight", "allocation_bytes"} <= set(s["counters"])
    # metrics landed in the report snapshot
    assert rep.metrics
    lat = [v for k, v in rep.metrics["histograms"].items() if k.startswith("request_latency_ms")]
    assert sum(h["count"] for h in lat) == retired
    assert "metrics" in rep.summary()


def test_request_queue_trace_uses_arrival_clock(small_dataset, jit_warm):
    eng = _engine(small_dataset, streams=True)
    tr = Tracer()
    rq = RequestQueueServer(eng, depth=2, admission="round-robin", tracer=tr)
    queues = _queues(small_dataset)
    for sid, q in enumerate(queues):
        reqs = [
            Request(request_id=i, stream_id=sid, seeds=b, arrival_s=0.0, deadline_s=None)
            for i, b in enumerate(q)
        ]
        rq.add_request_stream(reqs, seed=STREAM_SEEDS[sid])
    rep = rq.run()
    assert validate_trace(tr.events) == []
    s = summarize_trace(tr.events)
    assert s["n_flows"] == sum(st.num_batches for st in rep.streams)
    queued = [e for e in tr.events if e["ph"] == "X" and e["name"] == "queued"]
    assert queued and all(e["dur"] >= 0 for e in queued)


def test_sharded_serve_emits_exchange_spans(small_dataset, jit_warm):
    tr = Tracer()
    _serve(small_dataset, tr, depth=2, server_cls=ShardedServer, num_shards=2)
    assert validate_trace(tr.events) == []
    exch = [e for e in tr.events if e["ph"] == "X" and e["name"] == "exchange"]
    assert exch
    lanes = {e["tid"] for e in exch}
    assert len(lanes) == 2  # one lane per shard


def test_layerwise_trace_layer_spans(small_dataset, jit_warm):
    eng = _engine(small_dataset)
    tr = Tracer()
    metrics = MetricsRegistry()
    rep = eng.run(
        config=EngineConfig(mode="layerwise", chunk_size=4096),
        tracer=tr,
        metrics=metrics,
    )
    assert validate_trace(tr.events) == []
    layer_spans = [
        e for e in tr.events if e["ph"] == "X" and str(e["name"]).startswith("layer ")
    ]
    assert len(layer_spans) == rep.num_layers  # one span per model layer
    assert rep.metrics is not None
    assert any(k.startswith("chunks_total") for k in rep.metrics["counters"])


# ------------------------------------------------- bit-for-bit equivalence


@pytest.mark.parametrize("dedup", [False, True])
@pytest.mark.parametrize("prefetch", [False, True])
@pytest.mark.parametrize("refresh_on", [False, True])
def test_tracing_is_bit_for_bit_invisible(small_dataset, jit_warm, dedup, prefetch, refresh_on):
    """Outputs (and, with immutable caches, hit counters) are identical
    with tracing+metrics on vs off across the knob grid."""
    eng = _engine(small_dataset)
    refresh = RefreshConfig(mode="interval", interval_batches=2) if refresh_on else None
    kw = dict(
        max_batches=4,
        pipeline_depth=2,
        dedup=dedup,
        prefetch=prefetch,
        refresh=refresh,
        collect_outputs=True,
    )
    r_off = eng.run(**kw)
    out_off = [np.asarray(o) for o in eng.last_outputs]
    tr = Tracer()
    r_on = eng.run(**kw, tracer=tr, metrics=MetricsRegistry())
    out_on = [np.asarray(o) for o in eng.last_outputs]
    assert len(out_off) == len(out_on)
    for a, b in zip(out_off, out_on):
        np.testing.assert_array_equal(a, b)
    if not refresh_on:
        # immutable caches: the accounting must match bit-for-bit too
        assert (r_off.feat_hits, r_off.feat_lookups) == (r_on.feat_hits, r_on.feat_lookups)
        assert (r_off.adj_hits, r_off.adj_lookups) == (r_on.adj_hits, r_on.adj_lookups)
        assert r_off.gathered_rows == r_on.gathered_rows
    assert validate_trace(tr.events) == []
    assert r_on.metrics is not None and r_off.metrics is None


# ------------------------------------------------------------- CLI summary


def test_trace_summary_cli_gates(small_dataset, jit_warm, tmp_path):
    tr = Tracer()
    _serve(small_dataset, tr, depth=2)
    path = tmp_path / "trace.json"
    tr.export(str(path))
    repo = str(pathlib.Path(__file__).resolve().parents[1])
    cmd = [sys.executable, "scripts/trace_summary.py", str(path)]
    ok = subprocess.run(
        cmd + ["--strict", "--min-overlap", "0.0", "--require-flows"],
        capture_output=True,
        text=True,
        cwd=repo,
    )
    assert ok.returncode == 0, ok.stderr
    assert "overlap fraction" in ok.stdout
    bad = subprocess.run(
        cmd + ["--require-span", "no-such-span"],
        capture_output=True,
        text=True,
        cwd=repo,
    )
    assert bad.returncode == 1
    assert "no-such-span" in bad.stderr


def test_tracer_timestamps_are_relative_and_monotonic():
    tr = Tracer()
    t0 = tr.now_us()
    time.sleep(0.001)
    t1 = tr.now_us()
    assert 0.0 <= t0 < t1
    # ts_from maps a perf_counter stamp into the same clock
    assert tr.ts_from(time.perf_counter()) >= t1
