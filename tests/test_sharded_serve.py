"""Sharded serving equivalence (runtime/sharded_serve.py).

The contract: a :class:`ShardedServer` at ANY mesh size is bit-for-bit
the single-device :class:`MultiStreamServer` over the same prepared
engine — logits, per-stream hit accounting, gathered/prefetched row
counts, refresh events — across the knob grid (dedup × prefetch ×
refresh), and its per-shard counters sum to the global ones exactly.

Equivalence runs share ONE prepared engine: Eq. 1's allocation depends on
measured wall-clock stage times, so two separately-prepared engines hold
different caches and their hit counters are not comparable (the logits
still would be — they are cache-independent — but the accounting is the
point here).  With refresh off the caches are immutable, so sequential
reuse is sound; the refresh test pins the re-allocation to the identity
and restores the initial membership between runs (a refresh at the same
counts and budget re-selects the from-scratch fill — the invariant
tests/test_cache_refresh.py establishes).

The co-resident layout (4 shards, 1 device) runs everywhere; real mesh
placement rides the session ``cpu_mesh`` fixture (4 virtual CPU devices
via ``XLA_FLAGS`` — skipped inline, exercised by
tests/test_mesh_respawn.py and the tier1-mesh CI job).
"""

import numpy as np
import pytest

import jax

from repro.launch.mesh import SERVE_AXIS, make_serving_mesh, serving_devices
from repro.runtime.cache_refresh import RefreshConfig
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches
from repro.runtime.sharded_serve import ShardedServer

FANOUTS = (3, 2)
BATCH = 64
KW = dict(total_cache_bytes=200_000, n_presample=2)
STREAM_SEEDS = [100, 101, 102]

COUNTERS = (
    "adj_hits",
    "adj_lookups",
    "feat_hits",
    "feat_lookups",
    "num_batches",
    "num_seeds",
    "prefetched_rows",
    "unique_rows",
    "gathered_rows",
)


def _shared_engine(dataset, **kw):
    eng = GNNInferenceEngine(dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare("dci", stream_seeds=STREAM_SEEDS, **{**KW, **kw})
    return eng


def _queues(dataset, n=3, batches=3):
    return make_stream_batches(
        dataset, num_streams=n, batches_per_stream=batches, batch_size=BATCH, seed=7
    )


def _serve(server_cls, eng, queues, *, refresh=None, **kw):
    srv = server_cls(eng, refresh=refresh, **kw)
    for sid, q in enumerate(queues):
        srv.add_stream(q, seed=STREAM_SEEDS[sid], collect_outputs=True)
    rep = srv.run()
    outs = [[np.asarray(o) for o in s.runtime.outputs] for s in srv.streams]
    return srv, rep, outs


def _assert_equivalent(rb, ob, rs, os_):
    for sb, ss in zip(rb.streams, rs.streams):
        for k in COUNTERS:
            assert getattr(sb, k) == getattr(ss, k), k
    for a_list, b_list in zip(ob, os_):
        assert len(a_list) == len(b_list)
        for a, b in zip(a_list, b_list):
            np.testing.assert_array_equal(a, b)


def _assert_shard_sums(rb, rs):
    per = rs.shards
    assert rs.num_shards == len(per)
    assert sum(p["feat_hits"] for p in per) == rb.feat_hits
    assert sum(p["feat_lookups"] for p in per) == rb.feat_lookups
    assert sum(p["adj_hits"] for p in per) == rb.adj_hits
    assert sum(p["adj_lookups"] for p in per) == rb.adj_lookups


# -------------------------------------------------------- degenerate mesh


def test_mesh_size_1_is_bit_for_bit_the_base_server(small_dataset):
    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    _, rb, ob = _serve(MultiStreamServer, eng, queues, dedup=True)
    srv, rs, os_ = _serve(ShardedServer, eng, queues, dedup=True, num_shards=1)
    assert rs.num_shards == 1 and len(rs.shards) == 1
    _assert_equivalent(rb, ob, rs, os_)
    # one shard holds the whole table: per-shard == global, verbatim
    only = rs.shards[0]
    assert only["feat_hits"] == rb.feat_hits
    assert only["feat_lookups"] == rb.feat_lookups
    assert only["rows_cached"] == eng.pipeline.caches.store.num_cached
    assert srv.sharded.plan.row_starts.tolist() == [0, small_dataset.num_nodes]


# ------------------------------------------------------------- knob grid


@pytest.mark.parametrize("dedup", [False, True])
@pytest.mark.parametrize("prefetch", [False, True])
def test_sharded_equivalence_knob_grid(small_dataset, dedup, prefetch):
    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    _, rb, ob = _serve(MultiStreamServer, eng, queues, dedup=dedup, prefetch=prefetch)
    _, rs, os_ = _serve(
        ShardedServer, eng, queues, dedup=dedup, prefetch=prefetch, num_shards=4
    )
    _assert_equivalent(rb, ob, rs, os_)
    _assert_shard_sums(rb, rs)
    assert rs.summary()["num_shards"] == 4
    assert len(rs.summary()["per_shard"]) == 4


def test_sharded_refresh_equivalence(small_dataset, monkeypatch):
    """With the Eq. 1 re-allocation pinned (refresh timing inputs are
    wall-clock and would differ run to run), a refreshing sharded serve is
    bit-for-bit the refreshing base serve: same events, same epoch-
    versioned hit accounting, and the shards repartition on each epoch."""
    import repro.runtime.cache_refresh as cr

    monkeypatch.setattr(cr, "reallocate_capacity", lambda alloc, *a, **k: alloc)
    eng = _shared_engine(small_dataset)
    stats = eng.pipeline.presample
    init_alloc = eng.pipeline.caches.allocation
    queues = _queues(small_dataset)
    refresh = RefreshConfig(mode="interval", interval_batches=2)
    _, rb, ob = _serve(MultiStreamServer, eng, queues, dedup=True, refresh=refresh)
    assert len(rb.refresh_events) > 0
    # restore the initial membership (refresh at the presample counts and
    # initial allocation == the from-scratch fill) so the sharded run
    # starts from the same cache state the base run did
    eng.pipeline.caches.refresh(
        allocation=init_alloc,
        node_counts=stats.node_counts,
        edge_counts=stats.edge_counts,
    )
    srv, rs, os_ = _serve(
        ShardedServer, eng, queues, dedup=True, refresh=refresh, num_shards=4
    )
    _assert_equivalent(rb, ob, rs, os_)
    _assert_shard_sums(rb, rs)
    assert len(rs.refresh_events) == len(rb.refresh_events)
    # every refresh epoch repartitioned the shards, and the per-shard rows
    # always re-tile the base fill exactly
    assert len(srv.repartition_log) == len(rs.refresh_events)
    for entry in srv.repartition_log:
        assert entry["reason"] == "interval"
        assert sum(entry["rows_after"]) == eng.pipeline.caches.store.num_cached


# -------------------------------------------------- per-shard allocation


def test_per_shard_allocations_partition_the_global_one(small_dataset):
    eng = _shared_engine(small_dataset)
    srv = ShardedServer(eng, num_shards=4)
    allocs = srv.shard_allocations
    base = eng.pipeline.caches.allocation
    assert len(allocs) == 4
    assert sum(a.total_bytes for a in allocs) == base.total_bytes
    for a in allocs:
        # Eq. 1 is scale-invariant: every shard's adj:feat split equals
        # the global split — the coordinated-partition property that lets
        # the globally-ranked fill shard by id range without moving rows
        assert a.sample_fraction == pytest.approx(base.sample_fraction, abs=1e-9)


def test_shard_weights_follow_presample_traffic(small_dataset):
    eng = _shared_engine(small_dataset)
    srv = ShardedServer(eng, num_shards=4)
    counts = np.asarray(eng.pipeline.presample.node_counts, np.float64)
    plan = srv.sharded.plan
    weights = np.array(
        [counts[lo:hi].sum() for lo, hi in map(plan.bounds, range(4))]
    )
    totals = np.array([a.total_bytes for a in srv.shard_allocations], np.float64)
    # budgets proportional to each range's share of the presampled visits
    # (up to integer rounding; the last shard absorbs the remainder)
    expect = weights / weights.sum() * eng.pipeline.caches.allocation.total_bytes
    assert np.all(np.abs(totals - expect) <= len(totals) + 1)


# --------------------------------------------------------- mesh placement


def test_serving_mesh_clamps_to_available_devices():
    mesh = make_serving_mesh(64)
    devs = serving_devices(mesh)
    assert 1 <= len(devs) <= len(jax.devices())
    assert mesh.axis_names == (SERVE_AXIS,)
    with pytest.raises(ValueError):
        make_serving_mesh(0)


def test_mesh_placement_four_devices(cpu_mesh, small_dataset):
    """On a real 4-device mesh the shards commit to distinct devices and
    the serve stays bit-for-bit the single-device run."""
    devs = serving_devices(cpu_mesh)
    assert len(devs) == 4 and len(set(devs)) == 4
    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    _, rb, ob = _serve(MultiStreamServer, eng, queues, dedup=True)
    srv, rs, os_ = _serve(
        ShardedServer, eng, queues, dedup=True, mesh=cpu_mesh, num_shards=4
    )
    # distributed, not co-resident: every shard's tables live on its device
    assert srv.sharded.devices is not None
    for s, fs in enumerate(srv.sharded.store.shards):
        (dev,) = fs.hot_table.devices()
        assert dev == devs[s]
    assert srv.sharded.store.assemble_device is not None
    _assert_equivalent(rb, ob, rs, os_)
    _assert_shard_sums(rb, rs)


def test_mesh_placement_prefetch_and_refresh(cpu_mesh, small_dataset, monkeypatch):
    import repro.runtime.cache_refresh as cr

    monkeypatch.setattr(cr, "reallocate_capacity", lambda alloc, *a, **k: alloc)
    eng = _shared_engine(small_dataset)
    stats = eng.pipeline.presample
    init_alloc = eng.pipeline.caches.allocation
    queues = _queues(small_dataset)
    refresh = RefreshConfig(mode="interval", interval_batches=2)
    _, rb, ob = _serve(
        MultiStreamServer, eng, queues, dedup=True, prefetch=True, refresh=refresh
    )
    eng.pipeline.caches.refresh(
        allocation=init_alloc,
        node_counts=stats.node_counts,
        edge_counts=stats.edge_counts,
    )
    srv, rs, os_ = _serve(
        ShardedServer,
        eng,
        queues,
        dedup=True,
        prefetch=True,
        refresh=refresh,
        mesh=cpu_mesh,
        num_shards=4,
    )
    assert srv.sharded.devices is not None  # genuinely distributed
    _assert_equivalent(rb, ob, rs, os_)
    _assert_shard_sums(rb, rs)
