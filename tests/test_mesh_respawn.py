"""CPU-mesh respawn: run the mesh-placement tests on 4 virtual devices.

``XLA_FLAGS=--xla_force_host_platform_device_count=N`` must be set before
jax initializes, and the inline test process already holds a 1-device
jax — so the distributed-placement tests (the ``cpu_mesh`` fixture)
skip inline and this module respawns pytest over the mesh suites with
the flag exported.  When the inline process already sees >= 4 devices
(the tier1-mesh CI job, or a developer exporting the flag) the respawn
would duplicate work, so it skips itself — exactly one process runs the
placement tests either way.
"""

import os
import subprocess
import sys

import pytest

import jax

MESH_SUITES = ["tests/test_sharded_serve.py", "tests/test_shard.py"]


def test_mesh_suite_on_four_virtual_devices():
    if len(jax.devices()) >= 4:
        pytest.skip("already multi-device: the mesh tests run inline here")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()
    env.setdefault("PYTHONPATH", "src")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", *MESH_SUITES],
        capture_output=True,
        text=True,
        timeout=1200,
        cwd=root,
        env=env,
    )
    assert proc.returncode == 0, (proc.stdout + proc.stderr)[-4000:]
    # the placement tests must have RUN there, not skipped: the respawned
    # report may skip only the hypothesis-optional properties
    assert "passed" in proc.stdout
