"""MoE dispatch correctness: sort-based dispatch == per-token reference,
and the expert-parallel shard_map path == the GSPMD path on a 1x1 mesh."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.lm import moe as moe_mod
from repro.models.lm.config import LMConfig, MoEConfig


def tiny_cfg(n_experts=4, top_k=2, cf=8.0) -> LMConfig:
    return dataclasses.replace(
        get_smoke("phi3.5-moe-42b-a6.6b"),
        dtype="float32",
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=64, capacity_factor=cf),
        d_model=32,
    )


def reference_moe(params, x, cfg):
    """Per-token loop over experts — the unambiguous oracle (no capacity)."""
    m = cfg.moe
    b, s, d = x.shape
    xf = np.asarray(x, np.float64).reshape(-1, d)
    router = np.asarray(params["router"], np.float64)
    logits = xf @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xf)
    we1 = np.asarray(params["we1"], np.float64)
    we2 = np.asarray(params["we2"], np.float64)
    we3 = np.asarray(params["we3"], np.float64)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[: m.top_k]
        gates = probs[t][top]
        gates = gates / gates.sum()
        for g, e in zip(gates, top):
            h = xf[t] @ we1[e]
            gate = xf[t] @ we3[e]
            act = h / (1 + np.exp(-h))  # silu
            out[t] += g * ((act * gate) @ we2[e])
    return out.reshape(b, s, d)


def test_sort_dispatch_matches_reference():
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = moe_mod.init_moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    got, aux = moe_mod.moe_ffn(params, x, cfg)
    want = reference_moe(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0  # load-balance loss is positive


def test_capacity_drops_only_overflow():
    """With capacity_factor ~1, some assignments drop; output stays finite
    and is a partial sum of the reference terms."""
    cfg = tiny_cfg(cf=0.5)
    params = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    got, _ = moe_mod.moe_ffn(params, x, cfg)
    assert np.isfinite(np.asarray(got)).all()


@pytest.mark.parametrize("n_shared", [0, 1])
def test_shard_map_path_matches_gspmd_on_unit_mesh(n_shared):
    cfg = tiny_cfg()
    if n_shared:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_shared=1, d_ff_shared=64)
        )
    params = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    base, aux_base = moe_mod.moe_ffn(params, x, cfg)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    moe_mod.set_shard_map_context(mesh, ("data",), "model")
    try:
        got, aux_got = moe_mod.moe_ffn(params, x, cfg)
    finally:
        moe_mod.set_shard_map_context(None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_got), float(aux_base), rtol=1e-5)


from _hypothesis_compat import given, settings, st


@settings(max_examples=15, deadline=None)
@given(
    n_experts=st.sampled_from([2, 4, 8]),
    top_k=st.integers(1, 2),
    t=st.integers(1, 6),
    seed=st.integers(0, 100),
)
def test_moe_token_conservation_property(n_experts, top_k, t, seed):
    """With ample capacity, every (token, expert) assignment contributes:
    output == reference for arbitrary tiny configs."""
    top_k = min(top_k, n_experts)
    cfg = tiny_cfg(n_experts=n_experts, top_k=top_k, cf=16.0)
    params = moe_mod.init_moe_params(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, t, cfg.d_model), jnp.float32)
    got, _ = moe_mod.moe_ffn(params, x, cfg)
    want = reference_moe(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-4, atol=5e-4)
