"""Request-level serving front-end (runtime/request_queue.py).

The load-bearing guarantees:

  * round-robin + zero arrival offsets is bit-for-bit the queue-backed
    ``MultiStreamServer`` — same admission log, same outputs, same hit
    counters (the front-end only re-sources *what* is admitted);
  * admission policies are pure orderings with the documented properties
    (EDF by deadline, deadline-free last, deterministic tie-breaks);
  * SLO admission sheds exactly the arrived-and-blown requests, and every
    request is accounted for: completed + shed == submitted trace.
"""

import numpy as np
import pytest

from repro.core.policies import (
    ADMISSION_POLICIES,
    AdmissionPolicy,
    EDFAdmission,
    RoundRobinAdmission,
    SLOAdmission,
)
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches
from repro.runtime.request_queue import (
    Request,
    RequestQueueServer,
    burst_trace,
    flash_crowd_trace,
    poisson_trace,
    uniform_seed_batches,
)

FANOUTS = (3, 2)
BATCH = 64
KW = dict(total_cache_bytes=200_000, n_presample=2)
STREAM_SEEDS = [100, 101, 102]


def _shared_engine(dataset, policy="dci"):
    eng = GNNInferenceEngine(dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare(policy, stream_seeds=STREAM_SEEDS, **KW)
    return eng


def _queues(dataset, n=3, batches=3):
    return make_stream_batches(
        dataset, num_streams=n, batches_per_stream=batches, batch_size=BATCH, seed=7
    )


def _as_requests(queue, sid, *, arrivals=None, deadlines=None):
    n = len(queue)
    arrivals = arrivals if arrivals is not None else [0.0] * n
    deadlines = deadlines if deadlines is not None else [None] * n
    return [
        Request(request_id=i, stream_id=sid, seeds=b, arrival_s=a, deadline_s=d)
        for i, (b, a, d) in enumerate(zip(queue, arrivals, deadlines))
    ]


# --------------------------------------------------- policy ordering (pure)


class _Req:
    def __init__(self, arrival, deadline, deferred=False):
        self.arrival_s = arrival
        self.deadline_s = deadline
        self.deferred = deferred

    @property
    def admission_deadline_s(self):
        return None if self.deferred else self.deadline_s


def test_edf_orders_by_deadline_then_arrival_then_key():
    p = EDFAdmission()
    cands = [
        (0, _Req(0.0, 9.0)),
        (1, _Req(0.0, 1.0)),
        (2, _Req(0.5, 1.0)),  # same deadline as 1, later arrival
        (3, _Req(0.0, None)),  # deadline-free sorts last
    ]
    assert [k for k, _ in p.order(cands, now=0.0)] == [1, 2, 0, 3]
    # permutation-invariant (total, deterministic order)
    assert [k for k, _ in p.order(list(reversed(cands)), now=0.0)] == [1, 2, 0, 3]


def test_edf_deferred_request_sorts_deadline_free():
    p = EDFAdmission()
    cands = [(0, _Req(0.0, 1.0, deferred=True)), (1, _Req(0.0, 50.0))]
    # 0's deadline is blown-and-deferred: despite the earlier nominal
    # deadline it must sort after every deadline-carrying request
    assert [k for k, _ in p.order(cands, now=0.0)] == [1, 0]


def test_fifo_orders_by_arrival_and_round_robin_defers():
    fifo = AdmissionPolicy()
    cands = [(0, _Req(2.0, None)), (1, _Req(1.0, None))]
    assert [k for k, _ in fifo.order(cands, now=0.0)] == [1, 0]
    assert RoundRobinAdmission().order(cands, now=0.0) is None


def test_admission_policy_registry_and_validation():
    assert set(ADMISSION_POLICIES) == {"round-robin", "edf", "slo"}
    assert SLOAdmission().blown == "shed" and SLOAdmission().sheds
    assert SLOAdmission("defer").blown == "defer"
    with pytest.raises(ValueError):
        SLOAdmission("drop-everything")


# ------------------------------------------------------- bit-for-bit baseline


def test_round_robin_requests_match_queue_server_exactly(small_dataset):
    """Zero arrival offsets + round-robin admission reproduces the
    queue-backed server bit-for-bit: admission log, per-stream outputs,
    and hit counters."""
    engine = _shared_engine(small_dataset)
    queues = _queues(small_dataset)

    base = MultiStreamServer(engine, depth=2)
    base_states = [
        base.add_stream(q, seed=STREAM_SEEDS[i], collect_outputs=True)
        for i, q in enumerate(queues)
    ]
    base_rep = base.run()

    rq = RequestQueueServer(engine, depth=2, admission="round-robin")
    rq_states = [
        rq.add_request_stream(
            _as_requests(q, i), seed=STREAM_SEEDS[i], collect_outputs=True
        )
        for i, q in enumerate(queues)
    ]
    rq_rep = rq.run()

    assert rq.admission_log == base.admission_log
    assert rq_rep.admission == "round-robin"
    assert (rq_rep.feat_hits, rq_rep.feat_lookups) == (base_rep.feat_hits, base_rep.feat_lookups)
    assert (rq_rep.adj_hits, rq_rep.adj_lookups) == (base_rep.adj_hits, base_rep.adj_lookups)
    for bs, rs in zip(base_states, rq_states):
        assert len(bs.runtime.outputs) == len(rs.runtime.outputs)
        for a, b in zip(bs.runtime.outputs, rs.runtime.outputs):
            np.testing.assert_array_equal(a, b)
    # every request retired with stamps and a deadline-free accounting row
    for s in rq.streams:
        assert not s.requests and len(s.completed) == 3
        assert all(r.retired_s is not None and r.latency_s >= 0 for r in s.completed)
    assert rq_rep.requests_shed == 0 and rq_rep.deadline_total == 0
    assert rq_rep.deadline_hit_rate == 1.0  # vacuous: no deadlines
    assert rq_rep.p99_latency_s >= rq_rep.p50_latency_s > 0


def test_edf_admission_drains_earliest_deadlines_first(small_dataset):
    """All work at t=0 with distinct deadlines: the admission order must
    be exactly the global deadline order, regardless of stream."""
    engine = _shared_engine(small_dataset)
    queues = _queues(small_dataset, n=2, batches=2)
    # stream 0 deadlines (10, 30), stream 1 deadlines (20, 5):
    # EDF order: (1,0 dl=5)? no — per-stream queues are arrival-ordered and
    # only HEADS compete, so stream 1's dl=20 head shields its dl=5 request.
    # Use per-stream non-increasing urgency to make the global order clean:
    traces = [
        _as_requests(queues[0], 0, deadlines=[10.0, 30.0]),
        _as_requests(queues[1], 1, deadlines=[5.0, 20.0]),
    ]
    rq = RequestQueueServer(engine, depth=1, admission="edf")
    for i, t in enumerate(traces):
        rq.add_request_stream(t, seed=STREAM_SEEDS[i])
    rep = rq.run()
    assert rq.admission_log == [(1, 0), (0, 0), (1, 1), (0, 1)]
    assert rep.admission == "edf"
    assert rep.total_batches == 4


def test_slo_admission_sheds_blown_requests(small_dataset):
    """A deadline already expired at arrival (deadline < arrival) must be
    shed before ever running; live-deadline requests still complete, and
    completed + shed covers the whole trace."""
    engine = _shared_engine(small_dataset)
    (queue,) = _queues(small_dataset, n=1, batches=4)
    reqs = _as_requests(
        queue, 0, deadlines=[-1.0, 3600.0, -1.0, 3600.0]  # 2 pre-blown, 2 generous
    )
    rq = RequestQueueServer(engine, depth=1, admission="slo")
    rq.add_request_stream(reqs, seed=STREAM_SEEDS[0])
    rep = rq.run()
    s = rq.streams[0]
    assert len(s.shed_requests) == 2 and all(r.shed for r in s.shed_requests)
    assert all(r.deadline_met is False for r in s.shed_requests)
    assert len(s.completed) == 2 and all(r.deadline_met for r in s.completed)
    assert rep.requests_shed == 2 and rq.total_shed == 2
    assert rep.total_batches == 2  # shed requests never entered the pipeline
    assert (rep.deadline_hits, rep.deadline_total) == (2, 4)
    assert rep.deadline_hit_rate == 0.5
    sr = rep.streams[0]
    assert sr.requests_shed == 2 and sr.summary()["requests_shed"] == 2


def test_slo_defer_runs_blown_requests_last(small_dataset):
    """blown="defer": expired requests keep their slot but run after every
    request that can still make its deadline."""
    engine = _shared_engine(small_dataset)
    queues = _queues(small_dataset, n=2, batches=2)
    traces = [
        _as_requests(queues[0], 0, deadlines=[-1.0, -1.0]),  # both blown
        _as_requests(queues[1], 1, deadlines=[3600.0, 3600.0]),
    ]
    rq = RequestQueueServer(engine, depth=1, admission=SLOAdmission("defer"))
    for i, t in enumerate(traces):
        rq.add_request_stream(t, seed=STREAM_SEEDS[i])
    rep = rq.run()
    assert rq.total_shed == 0 and rep.total_batches == 4  # nothing dropped
    assert rq.admission_log == [(1, 0), (1, 1), (0, 0), (0, 1)]
    assert all(r.deferred for r in rq.streams[0].completed)
    assert (rep.deadline_hits, rep.deadline_total) == (2, 4)


def test_future_arrivals_wait_and_latency_counts_queueing(small_dataset):
    """A request cannot be admitted before its arrival time, and its
    reported latency is enqueue→retire (admitted_s >= arrival_s)."""
    engine = _shared_engine(small_dataset)
    (queue,) = _queues(small_dataset, n=1, batches=2)
    reqs = _as_requests(queue, 0, arrivals=[0.0, 0.25])
    rq = RequestQueueServer(engine, depth=1, admission="round-robin")
    rq.add_request_stream(reqs, seed=STREAM_SEEDS[0])
    rq.run()
    (s,) = rq.streams
    assert [r.request_id for r in s.completed] == [0, 1]
    late = s.completed[1]
    assert late.admitted_s >= late.arrival_s
    assert late.latency_s == pytest.approx(late.retired_s - late.arrival_s)


def test_request_server_rejects_unknown_policy(small_dataset):
    engine = _shared_engine(small_dataset)
    with pytest.raises(ValueError):
        RequestQueueServer(engine, admission="lifo")
    with pytest.raises(TypeError):
        RequestQueueServer(engine, admission=42)


# ------------------------------------------------------------ trace builders


def test_poisson_trace_shapes_and_determinism(small_dataset):
    t1 = poisson_trace(
        small_dataset,
        num_streams=2,
        requests_per_stream=4,
        batch_size=16,
        mean_interarrival_s=0.01,
        slo_s=0.5,
        seed=3,
    )
    t2 = poisson_trace(
        small_dataset,
        num_streams=2,
        requests_per_stream=4,
        batch_size=16,
        mean_interarrival_s=0.01,
        slo_s=0.5,
        seed=3,
    )
    assert len(t1) == 2 and all(len(s) == 4 for s in t1)
    for s1, s2 in zip(t1, t2):
        for a, b in zip(s1, s2):
            assert a.arrival_s == b.arrival_s
            np.testing.assert_array_equal(a.seeds, b.seeds)
    for stream in t1:
        arr = [r.arrival_s for r in stream]
        assert arr == sorted(arr) and arr[0] > 0
        assert all(r.deadline_s == pytest.approx(r.arrival_s + 0.5) for r in stream)
        assert all(r.seeds.shape == (16,) for r in stream)


def test_burst_trace_structure(small_dataset):
    burst, steady = burst_trace(
        small_dataset,
        burst_requests=5,
        steady_requests=8,
        batch_size=16,
        service_estimate_s=0.02,
        slo_s=0.1,
        seed=0,
    )
    assert all(r.arrival_s == 0.0 and r.stream_id == 0 for r in burst)
    assert [r.arrival_s for r in steady] == pytest.approx(
        [i * 0.02 for i in range(8)]
    )
    # burst content is a flash crowd: every batch permutes one fixed pool
    pool = set(np.asarray(burst[0].seeds).tolist())
    assert all(set(np.asarray(r.seeds).tolist()) == pool for r in burst)
    # steady content matches the shared uniform generator
    expect = uniform_seed_batches(small_dataset, n_batches=8, batch_size=16, seed=1)
    for r, b in zip(steady, expect):
        np.testing.assert_array_equal(r.seeds, b)


def test_flash_crowd_trace_all_at_zero(small_dataset):
    trace = flash_crowd_trace(
        small_dataset, num_streams=3, requests_per_stream=2, batch_size=16, slo_s=0.05
    )
    assert len(trace) == 3
    assert all(r.arrival_s == 0.0 and r.deadline_s == 0.05 for s in trace for r in s)


# --------------------------------------------------- fault-tolerant accounting


def test_timed_out_requests_shed_once_and_excluded_from_slo(small_dataset):
    """Shed/defer bookkeeping under retry: a request whose attempts all
    overrun the per-attempt budget is shed exactly once (never also
    completed), marked timed-out, and EXCLUDED from the deadline-hit
    denominator — a timeout is an availability event, not an SLO miss."""
    from repro.core.config import EngineConfig, ServeConfig
    from repro.core.faults import FaultInjector, FaultPlan, FaultRule

    engine = _shared_engine(small_dataset)
    (queue,) = _queues(small_dataset, n=1, batches=4)
    reqs = _as_requests(queue, 0, deadlines=[3600.0] * 4)
    # Two injected 50 ms delays against a 5 ms per-attempt budget and a
    # 2-attempt retry: ONE request exhausts on timeouts and sheds; the
    # delay cap is then spent, so every other request completes in time.
    plan = FaultPlan(
        rules=(
            FaultRule(
                "host_fetch", kind="delay", latency_s=0.05, start_after=1, max_faults=2
            ),
        )
    )
    cfg = ServeConfig(
        engine=EngineConfig(pipeline_depth=2),
        fault_policy="shed",
        retry_attempts=2,
        retry_backoff_ms=0.01,
        retry_timeout_ms=5.0,
    )
    rq = RequestQueueServer(engine, config=cfg, injector=FaultInjector(plan))
    rq.add_request_stream(reqs, seed=STREAM_SEEDS[0])
    rep = rq.run()
    (s,) = rq.streams

    # shed XOR completed, exactly once each: ids partition the trace
    assert len(s.shed_requests) == 1 and len(s.completed) == 3
    done = {r.request_id for r in s.completed}
    shed = {r.request_id for r in s.shed_requests}
    assert done | shed == {0, 1, 2, 3} and not (done & shed)
    victim = s.shed_requests[0]
    assert victim.shed and victim.timed_out
    assert rep.requests_shed == 1 and rq.total_shed == 1
    assert rep.requests_timed_out == 1
    assert rep.unserved == 0

    # SLO accounting: the timed-out request is OUT of the denominator —
    # the three completed (deadline-met) requests give a 1.0 hit rate
    assert rep.deadline_total == 3 and rep.deadline_hits == 3
    assert rep.deadline_hit_rate == 1.0
    assert all(r.deadline_met for r in s.completed)
    assert rep.availability == pytest.approx(3 / 4)
    assert rep.fault_policy == "shed"


def test_request_retry_and_degraded_marking(small_dataset):
    """Recovered retries and degraded service are stamped onto the
    individual Request rows and summed on the report."""
    from repro.core.config import EngineConfig, ServeConfig
    from repro.core.faults import FaultInjector, FaultPlan, FaultRule

    engine = _shared_engine(small_dataset)
    (queue,) = _queues(small_dataset, n=1, batches=3)
    reqs = _as_requests(queue, 0)
    plan = FaultPlan(rules=(FaultRule("host_fetch", start_after=1, max_faults=1),))
    cfg = ServeConfig(
        engine=EngineConfig(pipeline_depth=2),
        fault_policy="retry",
        retry_attempts=3,
        retry_backoff_ms=0.01,
    )
    rq = RequestQueueServer(engine, config=cfg, injector=FaultInjector(plan))
    rq.add_request_stream(reqs, seed=STREAM_SEEDS[0])
    rep = rq.run()
    (s,) = rq.streams
    assert len(s.completed) == 3 and rep.requests_shed == 0
    retried = [r for r in s.completed if r.retries > 0]
    assert len(retried) == 1 and rep.requests_retried == 1
    assert all(not r.degraded for r in s.completed)
    assert rep.availability == 1.0
