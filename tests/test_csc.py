"""CSC storage + Algorithm 1 (two-level sort & adjacency-cache fill)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.graph.csc import BYTES_PER_ADJ_ELEMENT, CSCGraph, build_adj_cache, two_level_sort


def random_csc(rng, n=20, max_deg=6):
    deg = rng.integers(0, max_deg, n)
    col_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=col_ptr[1:])
    row = rng.integers(0, n, int(deg.sum())).astype(np.int32)
    return CSCGraph(col_ptr=col_ptr, row_index=row)


def test_csc_validation_rejects_bad_ptr():
    with pytest.raises(ValueError):
        CSCGraph(col_ptr=np.array([0, 2, 1]), row_index=np.zeros(2, np.int32))


def test_two_level_sort_orders_within_column(rng):
    g = random_csc(rng)
    counts = rng.integers(0, 100, g.num_edges).astype(np.int64)
    sorted_row, node_totals = two_level_sort(g, counts)
    # Per column: the multiset of neighbors is preserved and counts descend.
    count_of = {}
    for v in range(g.num_nodes):
        lo, hi = g.col_ptr[v], g.col_ptr[v + 1]
        assert sorted(sorted_row[lo:hi]) == sorted(g.row_index[lo:hi])
        assert node_totals[v] == counts[lo:hi].sum()
    del count_of


def test_two_level_sort_descending_counts(rng):
    g = random_csc(rng, n=30)
    counts = rng.integers(0, 50, g.num_edges).astype(np.int64)
    sorted_row, _ = two_level_sort(g, counts)
    # Re-derive each element's count by matching (greedy multiset check).
    for v in range(g.num_nodes):
        lo, hi = g.col_ptr[v], g.col_ptr[v + 1]
        seg = list(counts[lo:hi])
        got = []
        for u in sorted_row[lo:hi]:
            # pick the largest remaining count for this neighbor id
            cands = [
                (c, i)
                for i, (r, c) in enumerate(zip(g.row_index[lo:hi], counts[lo:hi]))
            ]
            del cands
        got = sorted(seg, reverse=True)
        # counts of the sorted segment must be the descending multiset
        assert got == sorted(seg, reverse=True)


def test_adj_cache_respects_capacity(rng):
    g = random_csc(rng, n=50, max_deg=10)
    counts = rng.integers(0, 100, g.num_edges).astype(np.int64)
    sorted_row, totals = two_level_sort(g, counts)
    cap = 40 * BYTES_PER_ADJ_ELEMENT
    cache = build_adj_cache(g, sorted_row, totals, cap)
    assert cache.nbytes() <= cap
    assert (cache.cached_len <= g.degrees()).all()
    # hottest fully-fitting node is cached first
    order = np.argsort(-totals, kind="stable")
    v0 = order[0]
    if g.degrees()[v0] <= 40:
        assert cache.cached_len[v0] == g.degrees()[v0]


def test_adj_cache_full_fit(rng):
    g = random_csc(rng, n=10, max_deg=4)
    counts = np.ones(g.num_edges, np.int64)
    sorted_row, totals = two_level_sort(g, counts)
    cache = build_adj_cache(g, sorted_row, totals, g.num_edges * BYTES_PER_ADJ_ELEMENT)
    assert cache.num_cached_elements == g.num_edges
    assert (cache.cached_len == g.degrees()).all()


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 40),
    max_deg=st.integers(1, 8),
    cap_elems=st.integers(0, 300),
    seed=st.integers(0, 10_000),
)
def test_adj_cache_properties(n, max_deg, cap_elems, seed):
    """Property: cache is a per-node prefix, within capacity, ptr consistent."""
    rng = np.random.default_rng(seed)
    g = random_csc(rng, n=n, max_deg=max_deg)
    counts = rng.integers(0, 20, g.num_edges).astype(np.int64)
    sorted_row, totals = two_level_sort(g, counts)
    cache = build_adj_cache(g, sorted_row, totals, cap_elems * BYTES_PER_ADJ_ELEMENT)
    assert cache.num_cached_elements <= cap_elems or (
        g.num_edges * BYTES_PER_ADJ_ELEMENT <= cap_elems * BYTES_PER_ADJ_ELEMENT
    )
    assert cache.cache_ptr[0] == 0
    assert (np.diff(cache.cache_ptr) == cache.cached_len).all()
    # each cached segment equals the sorted copy's prefix
    for v in range(g.num_nodes):
        k = cache.cached_len[v]
        if k:
            lo = g.col_ptr[v]
            np.testing.assert_array_equal(
                cache.cache_row_index[cache.cache_ptr[v] : cache.cache_ptr[v] + k],
                sorted_row[lo : lo + k],
            )
