import numpy as np
import pytest

from repro.graph.datasets import load_dataset


@pytest.fixture(scope="session")
def small_dataset():
    return load_dataset("ogbn-products", scale=0.002, seed=0)


@pytest.fixture(scope="session")
def tiny_dataset():
    return load_dataset("reddit", scale=0.001, seed=1)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
