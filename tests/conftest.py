import numpy as np
import pytest

from repro.graph.datasets import load_dataset


@pytest.fixture(scope="session")
def small_dataset():
    return load_dataset("ogbn-products", scale=0.002, seed=0)


@pytest.fixture(scope="session")
def jit_warm(small_dataset):
    """Compile the presample/sample/gather programs once per process.

    jit compilation is per-process and would otherwise be charged to
    whichever timing-sensitive test (prep-cost comparisons, stage-time
    assertions) happens to run first in a cold process.  Tests that
    compare wall clocks depend on this fixture instead of each warming
    inline."""
    from repro.core.policies import prepare

    prepare(
        "dci",
        small_dataset,
        total_cache_bytes=200_000,
        fanouts=(3, 2),
        batch_size=64,
        n_presample=2,
    )


@pytest.fixture(scope="session")
def tiny_dataset():
    return load_dataset("reddit", scale=0.001, seed=1)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def cpu_mesh():
    """A 4-device 1-D serving mesh, shared by every mesh-placement test.

    Real device placement needs >= 4 jax devices; on CPU that means the
    process started with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
    (set before jax initializes — tests/test_mesh_respawn.py respawns the
    suite that way when the inline process only sees one device, and the
    tier1-mesh CI job sets it in the job env).  Skips when the devices
    are not there, so the inline single-device run stays green."""
    import jax

    if len(jax.devices()) < 4:
        pytest.skip(
            "needs >= 4 jax devices "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=4)"
        )
    from repro.launch.mesh import make_serving_mesh

    return make_serving_mesh(4)
