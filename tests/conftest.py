import numpy as np
import pytest

from repro.graph.datasets import load_dataset


@pytest.fixture(scope="session")
def small_dataset():
    return load_dataset("ogbn-products", scale=0.002, seed=0)


@pytest.fixture(scope="session")
def jit_warm(small_dataset):
    """Compile the presample/sample/gather programs once per process.

    jit compilation is per-process and would otherwise be charged to
    whichever timing-sensitive test (prep-cost comparisons, stage-time
    assertions) happens to run first in a cold process.  Tests that
    compare wall clocks depend on this fixture instead of each warming
    inline."""
    from repro.core.policies import prepare

    prepare(
        "dci",
        small_dataset,
        total_cache_bytes=200_000,
        fanouts=(3, 2),
        batch_size=64,
        n_presample=2,
    )


@pytest.fixture(scope="session")
def tiny_dataset():
    return load_dataset("reddit", scale=0.001, seed=1)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
