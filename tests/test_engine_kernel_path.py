"""The Pallas cached_gather kernel is a drop-in for the store gather."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph.features import build_feature_cache, plain_feature_store


def test_store_gather_kernel_parity(small_dataset, rng):
    ds = small_dataset
    counts = rng.integers(0, 6, ds.num_nodes).astype(np.int64)
    store = build_feature_cache(ds.features, counts, capacity_bytes=200_000)
    idx = jnp.asarray(rng.integers(0, ds.num_nodes, 512), jnp.int32)
    ref, hit_ref = store.gather(idx)
    out, hit_k = store.gather(idx, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(hit_ref), np.asarray(hit_k))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_store_gather_prefetched_parity(small_dataset, rng, use_kernel):
    """Prefetched miss rows are a bit-exact stand-in for the host table —
    on the jnp path (scatter over the hot gather) and the kernel path
    (row-aligned miss source)."""
    ds = small_dataset
    counts = rng.integers(0, 6, ds.num_nodes).astype(np.int64)
    store = build_feature_cache(ds.features, counts, capacity_bytes=200_000)
    idx_np = rng.integers(0, ds.num_nodes, 512)
    idx = jnp.asarray(idx_np, jnp.int32)
    ref, hit_ref = store.gather(idx)
    staged = store.prefetch_misses(idx_np)
    out, hit = store.gather(idx, use_kernel=use_kernel, prefetched=staged)
    np.testing.assert_array_equal(np.asarray(hit_ref), np.asarray(hit))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_store_prefetch_all_miss_and_all_hit(small_dataset, rng):
    ds = small_dataset
    # all-miss: the no-cache store stages the whole row set (idx is None)
    plain = plain_feature_store(ds.features)
    idx_np = rng.integers(0, ds.num_nodes, 64)
    staged = plain.prefetch_misses(idx_np)
    assert staged.idx is None and staged.rows.shape == (64, plain.feat_dim)
    out, hit = plain.gather(jnp.asarray(idx_np, jnp.int32), prefetched=staged)
    np.testing.assert_array_equal(np.asarray(out), ds.features[idx_np])
    assert not bool(np.asarray(hit).any())
    # all-hit: a store caching everything stages an empty (padded) pack
    counts = np.ones(ds.num_nodes, np.int64)
    full = build_feature_cache(ds.features, counts, capacity_bytes=ds.features.nbytes)
    staged = full.prefetch_misses(idx_np)
    assert staged.idx is not None and int(np.asarray(staged.idx).min()) == 64  # all pads
    out, hit = full.gather(jnp.asarray(idx_np, jnp.int32), prefetched=staged)
    np.testing.assert_array_equal(np.asarray(out), ds.features[idx_np])
    assert bool(np.asarray(hit).all())
