"""The Pallas cached_gather kernel is a drop-in for the store gather."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.features import build_feature_cache


def test_store_gather_kernel_parity(small_dataset, rng):
    ds = small_dataset
    counts = rng.integers(0, 6, ds.num_nodes).astype(np.int64)
    store = build_feature_cache(ds.features, counts, capacity_bytes=200_000)
    idx = jnp.asarray(rng.integers(0, ds.num_nodes, 512), jnp.int32)
    ref, hit_ref = store.gather(idx)
    out, hit_k = store.gather(idx, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(hit_ref), np.asarray(hit_k))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
