"""Online cache-refresh subsystem (runtime/cache_refresh.py + friends).

Load-bearing guarantees:

  * a refresh NEVER changes values — sampled blocks, gathered rows, and
    logits are bit-identical with refresh on or off (the sort order and
    host tables are frozen; a refresh moves bytes, not results);
  * re-fills are deltas — kept feature rows stay in their device slots,
    unchanged adjacency segments are copied from the old cache, and the
    refreshed caches equal what a from-scratch fill at the same counts
    and budget would select;
  * epoch accounting — per-epoch hit counters partition the lifetime
    counters exactly;
  * serve-time join/leave triggers an incremental refresh and unchanged
    streams stay serial-equivalent.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.cache import DualCache
from repro.core.allocation import CacheAllocation
from repro.core.telemetry import WorkloadTelemetry, merge_windows
from repro.graph.csc import build_adj_cache, refresh_adj_cache, two_level_sort
from repro.graph.features import build_feature_cache, refresh_feature_cache, select_hot_rows
from repro.runtime.cache_refresh import CacheRefreshManager, RefreshConfig
from repro.runtime.gnn_engine import GNNInferenceEngine, auto_pipeline_depth
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches
from repro.utils.timing import StageClock

FANOUTS = (3, 2)
BATCH = 64
KW = dict(total_cache_bytes=200_000, n_presample=2)


def _engine(dataset, policy="dci", **kw):
    eng = GNNInferenceEngine(dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare(policy, **{**KW, **kw})
    return eng


# ------------------------------------------------------------------ telemetry


def test_telemetry_accumulates_and_windows():
    t = WorkloadTelemetry(num_nodes=10, num_edges=6)
    nodes = np.array([1, 2, 2, 5])
    hit = np.array([True, False, False, True])
    t.observe_batch(nodes, hit, [np.array([[0, 1]]), np.array([[5]])])
    assert t.batches == 1
    assert t.node_counts[2] == 2 and t.node_counts[1] == 1
    assert t.node_miss_counts[2] == 2 and t.node_miss_counts[1] == 0
    assert t.edge_counts[5] == 1 and t.edge_counts[0] == 1
    win = t.snapshot()
    assert win.feat_lookups == 4 and win.feat_misses == 2 and win.miss_rate == 0.5
    t.reset()
    assert t.batches == 0 and t.node_counts.sum() == 0
    # snapshot is a copy — later accumulation must not mutate it
    t.observe_batch(nodes, hit, [])
    assert win.node_counts[2] == 2


def test_telemetry_drops_out_of_bounds_edge_slots():
    """A zero-degree node at the CSC tail emits slot == num_edges; the
    presample path's JAX scatter drops it silently — telemetry must too,
    not crash the serve loop (np.add.at raises on OOB)."""
    t = WorkloadTelemetry(num_nodes=4, num_edges=4)
    t.observe_batch(np.array([0]), np.array([True]), [np.array([[3, 4]])])
    assert t.edge_counts[3] == 1 and t.edge_counts.sum() == 1


def test_telemetry_pull_times_uses_cursors():
    t = WorkloadTelemetry(num_nodes=4, num_edges=2)
    clock = StageClock(overlap=True)
    for _ in range(3):
        with clock.stage("sample"):
            pass
        with clock.stage("feature"):
            pass
    t.pull_times(clock)
    assert len(t.sample_times) == len(t.feature_times) == 3
    t.pull_times(clock)  # no new laps -> nothing double-counted
    assert len(t.sample_times) == 3
    with clock.stage("sample"):
        pass
    t.pull_times(clock)
    assert len(t.sample_times) == 4
    t.reset()  # window resets, cursors persist
    t.pull_times(clock)
    assert len(t.sample_times) == 0


# ------------------------------------------------------------- feature delta


def _counts(rng, n):
    return rng.integers(0, 50, n).astype(np.int64)


def test_feature_refresh_matches_fresh_build_selection(rng):
    feats = rng.standard_normal((200, 8)).astype(np.float32)
    store = build_feature_cache(feats, _counts(rng, 200), 40 * 32)
    new_counts = _counts(rng, 200)
    refreshed, stats = refresh_feature_cache(store, new_counts, 40 * 32)
    fresh = build_feature_cache(feats, new_counts, 40 * 32)
    old_pos = np.asarray(store.position_map)
    new_pos = np.asarray(refreshed.position_map)
    # identical hot SET to a from-scratch fill (slot layout may differ)
    np.testing.assert_array_equal(np.nonzero(new_pos >= 0)[0],
                                  np.nonzero(np.asarray(fresh.position_map) >= 0)[0])
    # kept rows stayed in their slots; every cached slot holds its row's bits
    kept = (old_pos >= 0) & (new_pos >= 0)
    np.testing.assert_array_equal(old_pos[kept], new_pos[kept])
    cached_nodes = np.nonzero(new_pos >= 0)[0]
    np.testing.assert_array_equal(
        np.asarray(refreshed.hot_table)[new_pos[cached_nodes]], feats[cached_nodes]
    )
    assert stats.rows_kept == int(kept.sum())
    assert stats.rows_inserted == int(((old_pos < 0) & (new_pos >= 0)).sum())
    assert stats.rows_evicted == int(((old_pos >= 0) & (new_pos < 0)).sum())
    # host mirror carried forward matches the device map
    np.testing.assert_array_equal(refreshed.position_np(), new_pos)


def test_feature_refresh_same_counts_is_noop(rng):
    feats = rng.standard_normal((100, 4)).astype(np.float32)
    counts = _counts(rng, 100)
    store = build_feature_cache(feats, counts, 20 * 16)
    refreshed, stats = refresh_feature_cache(store, counts, 20 * 16)
    assert not stats.changed
    assert refreshed.hot_table is store.hot_table  # no device writes at all
    assert refreshed.position_map is store.position_map


def test_feature_refresh_grow_and_shrink(rng):
    feats = rng.standard_normal((100, 4)).astype(np.float32)
    store = build_feature_cache(feats, _counts(rng, 100), 10 * 16)
    grown, stats = refresh_feature_cache(store, _counts(rng, 100), 40 * 16)
    assert grown.num_cached == 40 and stats.budget_rows == 40
    assert stats.physical_rows >= 40
    # shrink: physical table is reused (no reshape), logical occupancy drops
    shrunk, sstats = refresh_feature_cache(grown, _counts(rng, 100), 5 * 16)
    assert shrunk.num_cached == 5
    assert shrunk.hot_table.shape[0] == grown.hot_table.shape[0]
    assert sstats.rows_evicted >= 35


def test_select_hot_rows_matches_build_semantics(rng):
    counts = _counts(rng, 64)
    hot = select_hot_rows(counts, 16)
    assert len(set(hot.tolist())) == 16
    # top above-mean nodes are always selected
    mean = counts.mean()
    above = np.nonzero(counts > mean)[0]
    top = above[np.argsort(-counts[above], kind="stable")[:16]]
    assert set(top.tolist()) <= set(hot.tolist())


# ----------------------------------------------------------- adjacency delta


def test_adj_refresh_prefix_invariant_and_delta(small_dataset, rng):
    g = small_dataset.graph
    ec0 = rng.integers(0, 9, g.num_edges).astype(np.int64)
    sorted_row, totals0 = two_level_sort(g, ec0)
    old = build_adj_cache(g, sorted_row, totals0, 4 * 1500)
    # updated counts re-rank the nodes; the sorted order stays frozen
    ec1 = rng.integers(0, 9, g.num_edges).astype(np.int64)
    _, totals1 = two_level_sort(g, ec1)
    new, stats = refresh_adj_cache(g, sorted_row, old, totals1, 4 * 1500)
    fresh = build_adj_cache(g, sorted_row, totals1, 4 * 1500)
    # the delta re-fill lands exactly where a fresh Alg. 1 fill would
    np.testing.assert_array_equal(new.cached_len, fresh.cached_len)
    np.testing.assert_array_equal(new.cache_ptr, fresh.cache_ptr)
    np.testing.assert_array_equal(new.cache_row_index, fresh.cache_row_index)
    assert new.num_cached_elements * 4 <= 4 * 1500
    assert stats.elements_kept + stats.elements_regathered == new.num_cached_elements
    changed = new.cached_len.astype(int) != old.cached_len.astype(int)
    assert stats.nodes_changed == int(changed.sum())


def test_adj_refresh_same_totals_is_noop(small_dataset, rng):
    g = small_dataset.graph
    ec = rng.integers(0, 9, g.num_edges).astype(np.int64)
    sorted_row, totals = two_level_sort(g, ec)
    old = build_adj_cache(g, sorted_row, totals, 4 * 1000)
    new, stats = refresh_adj_cache(g, sorted_row, old, totals, 4 * 1000)
    assert not stats.changed and stats.elements_regathered == 0
    np.testing.assert_array_equal(new.cache_row_index, old.cache_row_index)


# ------------------------------------------------------------ DualCache epochs


def test_dual_cache_refresh_bumps_epoch_and_applies_delta(small_dataset, rng):
    ds = small_dataset
    alloc = CacheAllocation(
        total_bytes=100_000, adj_bytes=50_000, feat_bytes=50_000, sample_fraction=0.5
    )
    dc = DualCache.build(
        ds,
        node_counts=rng.integers(0, 9, ds.num_nodes),
        edge_counts=rng.integers(0, 9, ds.graph.num_edges),
        allocation=alloc,
    )
    assert dc.epoch == 0 and dc.refreshable
    new_alloc = dataclasses.replace(alloc, adj_bytes=30_000, feat_bytes=70_000)
    delta = dc.refresh(
        allocation=new_alloc,
        node_counts=rng.integers(0, 9, ds.num_nodes),
        edge_counts=rng.integers(0, 9, ds.graph.num_edges),
    )
    assert dc.epoch == 1 and delta.epoch == 1
    assert dc.allocation is new_alloc
    assert dc.feat_cached_rows * ds.feature_nbytes_per_row() <= new_alloc.feat_bytes
    assert dc.adj_cached_elements * 4 <= new_alloc.adj_bytes
    # device adjacency array is padded (shape-stable across epochs); the
    # logical prefix is what the budget pays for
    assert dc.dgraph.cache_row_index.shape[0] >= dc.adj_cached_elements


def test_cacheless_dual_cache_rejects_refresh(small_dataset):
    dc = DualCache.none(small_dataset)
    assert not dc.refreshable
    with pytest.raises(ValueError):
        dc.refresh(
            allocation=CacheAllocation(
                total_bytes=0, adj_bytes=0, feat_bytes=0, sample_fraction=0.5
            ),
            node_counts=np.zeros(small_dataset.num_nodes),
            edge_counts=np.zeros(small_dataset.graph.num_edges),
        )


# -------------------------------------------------------------- config errors


def test_refresh_config_validation():
    with pytest.raises(ValueError):
        RefreshConfig(mode="sometimes")
    with pytest.raises(ValueError):
        RefreshConfig(mode="interval")  # needs interval_batches >= 1
    with pytest.raises(ValueError):
        RefreshConfig(mode="events", history_decay=1.5)
    with pytest.raises(ValueError):
        RefreshConfig(mode="events", max_split_step=0.0)
    assert not RefreshConfig().enabled
    assert RefreshConfig(mode="all", interval_batches=2).on_interval


def test_manager_rejects_disabled_config_and_cacheless_policy(small_dataset):
    eng = _engine(small_dataset)
    with pytest.raises(ValueError):
        CacheRefreshManager(
            eng.pipeline, small_dataset, fanouts=FANOUTS, batch_size=BATCH,
            config=RefreshConfig(),
        )
    dgl = _engine(small_dataset, policy="dgl")
    with pytest.raises(ValueError):
        CacheRefreshManager(
            dgl.pipeline, small_dataset, fanouts=FANOUTS, batch_size=BATCH,
            config=RefreshConfig(mode="events"),
        )


# ---------------------------------------------------------- engine refresh


def test_engine_refresh_outputs_bit_identical_and_epochs_partition(small_dataset):
    ref = _engine(small_dataset)
    r0 = ref.run(max_batches=6, pipeline_depth=1, collect_outputs=True)
    o0 = ref.last_outputs

    eng = GNNInferenceEngine(small_dataset, fanouts=FANOUTS, batch_size=BATCH,
                             params=ref.params)
    eng.pipeline = ref.pipeline  # same prepared pipeline, epoch 0
    r1 = eng.run(
        max_batches=6,
        pipeline_depth=2,
        collect_outputs=True,
        refresh=RefreshConfig(mode="interval", interval_batches=2),
    )
    assert eng.pipeline.caches.epoch >= 1
    assert len(r1.refresh_events) >= 1
    for e in r1.refresh_events:
        # every re-fill is a delta: no full rebuild — something stayed put
        assert e.delta.feat.rows_kept > 0 or e.delta.adj.elements_kept > 0
        assert e.pause_seconds >= 0
    # refresh moves bytes, never values
    for a, b in zip(o0, eng.last_outputs):
        np.testing.assert_array_equal(a, b)
    # per-epoch counters partition the lifetime counters exactly
    assert r1.epoch_hits is not None and len(r1.epoch_hits) >= 2
    assert sum(v["batches"] for v in r1.epoch_hits.values()) == r1.num_batches


def test_engine_refresh_off_is_default_path(small_dataset):
    eng = _engine(small_dataset)
    r_off = eng.run(max_batches=4, pipeline_depth=1, refresh=RefreshConfig(mode="off"))
    assert r_off.refresh_events == [] and r_off.epoch_hits is None
    assert eng.pipeline.caches.epoch == 0
    assert "refresh_events" not in r_off.summary()


# ----------------------------------------------------------- serve join/leave


def test_serve_join_leave_trigger_incremental_refresh(small_dataset):
    eng = _engine(small_dataset, n_presample=4, stream_seeds=[100, 101])
    queues = make_stream_batches(
        small_dataset, num_streams=3, batches_per_stream=3, batch_size=BATCH, seed=7
    )
    server = MultiStreamServer(eng, depth=2, refresh=RefreshConfig(mode="events"))
    s0 = server.add_stream(queues[0], seed=100, collect_outputs=True)
    s1 = server.add_stream(queues[1], seed=101, collect_outputs=True)
    server.run()
    assert eng.pipeline.caches.epoch == 0  # pre-run adds are not join events

    s2 = server.add_stream(queues[2], seed=102, collect_outputs=True)
    assert eng.pipeline.caches.epoch == 1  # serve-time join refreshed
    events = server.refresh_manager.events
    assert [e.reason for e in events] == ["stream-join"]
    assert events[0].delta.feat.rows_kept > 0 or events[0].delta.adj.elements_kept > 0
    server.run()

    server.remove_stream(s2.stream_id)
    assert eng.pipeline.caches.epoch == 2
    assert [e.reason for e in server.refresh_manager.events] == [
        "stream-join",
        "stream-leave",
    ]

    # unchanged streams: per-stream results stay serial-equivalent
    for state, queue, seed in ((s0, queues[0], 100), (s1, queues[1], 101)):
        ref = GNNInferenceEngine(
            small_dataset, fanouts=FANOUTS, batch_size=BATCH, seed=seed, params=eng.params
        )
        ref.pipeline = eng.pipeline
        ref.run(batches=list(queue), pipeline_depth=1, collect_outputs=True)
        assert len(ref.last_outputs) == len(state.runtime.outputs)
        for a, b in zip(ref.last_outputs, state.runtime.outputs):
            np.testing.assert_array_equal(a, b)


def test_serve_interval_refresh_reports_per_epoch(small_dataset):
    eng = _engine(small_dataset, stream_seeds=[100, 101])
    queues = make_stream_batches(
        small_dataset, num_streams=2, batches_per_stream=4, batch_size=BATCH, seed=3
    )
    server = MultiStreamServer(
        eng, depth=2, refresh=RefreshConfig(mode="interval", interval_batches=3)
    )
    for i, q in enumerate(queues):
        server.add_stream(q, seed=100 + i)
    rep = server.run()
    assert rep.epochs is not None and len(rep.refresh_events) >= 1
    # aggregate per-epoch batches partition the total
    assert sum(v["batches"] for v in rep.epochs.values()) == rep.total_batches
    # per-stream epoch splits sum to the aggregate
    for epoch, agg in rep.epochs.items():
        per_stream = sum(
            s.epoch_hits[epoch]["batches"] for s in rep.streams
            if s.epoch_hits and epoch in s.epoch_hits
        )
        assert per_stream == agg["batches"]
    assert "per_epoch" in rep.summary()


def test_serve_refresh_off_report_unchanged(small_dataset):
    eng = _engine(small_dataset)
    (queue,) = make_stream_batches(
        small_dataset, num_streams=1, batches_per_stream=2, batch_size=BATCH, seed=3
    )
    server = MultiStreamServer(eng, depth=1)
    server.add_stream(queue, seed=100)
    rep = server.run()
    assert rep.epochs is None and rep.refresh_events == []
    assert "per_epoch" not in rep.summary()
    assert "per_epoch" not in rep.streams[0].summary()


# ------------------------------------------------------------ adaptive depth


def test_auto_pipeline_depth_heuristic():
    # A ~zero prep lap means the probe measured nothing overlappable —
    # depth 1 (serial), NOT prep/compute → 0 → "pin at 2" from noise.
    assert auto_pipeline_depth(0.0, 1.0) == 1
    assert auto_pipeline_depth(5e-7, 1.0) == 1  # below the degenerate-lap floor
    assert auto_pipeline_depth(1.0, 1.0) == 2
    assert auto_pipeline_depth(3.0, 1.0) == 4
    assert auto_pipeline_depth(100.0, 1.0) == 4  # saturates at max_depth
    assert auto_pipeline_depth(100.0, 1.0, max_depth=6) == 6
    # Degenerate COMPUTE probe with real prep: double-buffer, never a
    # divide-by-~0 ratio pinning the window at the cap.
    assert auto_pipeline_depth(1.0, 0.0) == 2
    assert auto_pipeline_depth(1.0, 1e-9) == 2


def test_engine_does_not_cache_degenerate_auto_probe(small_dataset, monkeypatch):
    """A zero-measured prep lap resolves to depth 1 for THIS run but is
    not cached — the next resolve re-probes and can recover a real
    window."""
    eng = _engine(small_dataset)
    monkeypatch.setattr(
        eng, "_probe_stage_seconds", lambda seeds: (0.0, 0.0, 1.0)
    )
    assert eng.resolve_pipeline_depth("auto") == 1
    monkeypatch.undo()
    depth = eng.resolve_pipeline_depth("auto")  # re-probed, now cached
    assert 2 <= depth <= 4
    assert eng.resolve_pipeline_depth("auto") == depth


def test_engine_resolves_auto_depth(small_dataset):
    eng = _engine(small_dataset)
    depth = eng.resolve_pipeline_depth("auto")
    assert isinstance(depth, int) and 2 <= depth <= 4
    assert eng.resolve_pipeline_depth("auto") == depth  # cached
    rep = eng.run(max_batches=2, pipeline_depth="auto")
    assert rep.pipeline_depth == depth
    # plain ints pass through untouched, without a probe
    assert eng.resolve_pipeline_depth(3) == 3


def test_run_with_empty_batch_list_still_returns(small_dataset):
    """An explicit empty batch list is a no-op run, not an IndexError from
    the depth-resolution probe's eager seeds lookup."""
    eng = _engine(small_dataset)
    rep = eng.run(batches=[], warmup=False, pipeline_depth=2)
    assert rep.num_batches == 0 and rep.feat_lookups == 0


def test_prepare_accepts_auto_depth(small_dataset):
    eng = GNNInferenceEngine(
        small_dataset, fanouts=FANOUTS, batch_size=BATCH, pipeline_depth="auto"
    )
    pipe = eng.prepare("dci", pipeline_depth="auto", **KW)
    assert pipe.presample is not None  # presampling ran (serially) fine


# ------------------------------------------------------------- threaded pack


def test_prefetch_pack_thread_bit_identical(small_dataset):
    eng = _engine(small_dataset)
    store = eng.pipeline.caches.store
    rng = np.random.default_rng(0)
    nodes = rng.integers(0, small_dataset.num_nodes, 257).astype(np.int32)
    a = store.prefetch_misses(nodes, pack_in_thread=True)
    b = store.prefetch_misses(nodes, pack_in_thread=False)
    np.testing.assert_array_equal(np.asarray(a.rows), np.asarray(b.rows))
    assert a.num_miss == b.num_miss
    if a.idx is not None:
        np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
        np.testing.assert_array_equal(np.asarray(a.pack_pos), np.asarray(b.pack_pos))


# --------------------------------------------------- SLO miss-rate trigger


def test_refresh_config_validates_miss_threshold():
    with pytest.raises(ValueError):
        RefreshConfig(mode="events", miss_threshold=0.0)
    with pytest.raises(ValueError):
        RefreshConfig(mode="events", miss_threshold=1.5)
    cfg = RefreshConfig(mode="events", miss_threshold=0.3)
    assert cfg.enabled and not cfg.on_interval


def test_miss_threshold_fires_before_interval(small_dataset):
    """A high-miss window must refresh on the SLO trigger without waiting
    out the interval (here: events mode, so no interval trigger at all)."""
    eng = _engine(small_dataset, total_cache_bytes=40_000)
    rep = eng.run(
        max_batches=4,
        pipeline_depth=1,
        refresh=RefreshConfig(mode="events", miss_threshold=0.05),
    )
    assert rep.refresh_events, "threshold never fired"
    assert all(e.reason == "miss-threshold" for e in rep.refresh_events)
    assert all(e.window_miss_rate >= 0.05 for e in rep.refresh_events)


def test_miss_threshold_composes_with_interval(small_dataset):
    """interval mode + threshold: the quality trigger may pre-empt the
    schedule, and the schedule still guarantees a refresh cadence."""
    eng = _engine(small_dataset, total_cache_bytes=40_000)
    rep = eng.run(
        max_batches=6,
        pipeline_depth=1,
        refresh=RefreshConfig(
            mode="interval", interval_batches=3, miss_threshold=0.05
        ),
    )
    reasons = {e.reason for e in rep.refresh_events}
    assert reasons <= {"miss-threshold", "interval"} and reasons


def test_low_threshold_never_fires_below_it(small_dataset):
    """A threshold above the actual miss rate must never fire — only the
    interval trigger remains."""
    eng = _engine(small_dataset)  # ample cache → low miss rate
    rep = eng.run(
        max_batches=6,
        pipeline_depth=1,
        refresh=RefreshConfig(mode="interval", interval_batches=3, miss_threshold=0.999),
    )
    assert all(e.reason == "interval" for e in rep.refresh_events)


# ------------------------------------------------ refresh-aware auto depth


def test_refresh_rederives_auto_depth(small_dataset):
    """With pipeline_depth='auto' and refresh enabled, each refresh derives
    a window from the measured serve-time prep:compute laps and applies it
    to the live executor; outputs stay bit-identical to serial."""
    eng = _engine(small_dataset)
    r1 = eng.run(max_batches=6, pipeline_depth=1, collect_outputs=True)
    o1 = eng.last_outputs
    eng2 = GNNInferenceEngine(
        small_dataset, fanouts=FANOUTS, batch_size=BATCH, params=eng.params
    )
    eng2.pipeline = eng.pipeline
    r2 = eng2.run(
        max_batches=6,
        pipeline_depth="auto",
        collect_outputs=True,
        refresh=RefreshConfig(mode="interval", interval_batches=2),
    )
    depths = [e.suggested_depth for e in r2.refresh_events]
    assert depths and all(d is None or 2 <= d <= 4 for d in depths)
    # telemetry recorded compute laps, so at least the LAST refresh (after
    # a full window of retired batches) must carry a derived depth
    assert any(d is not None for d in depths)
    for a, b in zip(o1, eng2.last_outputs):
        np.testing.assert_array_equal(a, b)


def test_serve_refresh_rederives_auto_depth(small_dataset):
    """The multi-stream server applies the re-derived window to its live
    executor (depth='auto' + interval refresh)."""
    eng = _engine(small_dataset, stream_seeds=[0, 1])
    server = MultiStreamServer(
        eng,
        depth="auto",
        refresh=RefreshConfig(mode="interval", interval_batches=3),
    )
    queues = make_stream_batches(
        small_dataset, num_streams=2, batches_per_stream=3, batch_size=BATCH, seed=0
    )
    for sid, q in enumerate(queues):
        server.add_stream(q, seed=sid)
    rep = server.run()
    events = server.refresh_manager.events
    assert events, "interval refresh never fired"
    derived = [e.suggested_depth for e in events if e.suggested_depth is not None]
    if derived:  # once compute laps exist, the server follows the new window
        assert rep.depth == derived[-1]
        # the defaulted backpressure cap follows the window — a deeper
        # window admission can actually fill (an explicit cap would stay)
        assert server.max_inflight == derived[-1]


# -------------------------------------------- weighted per-stream telemetry


def test_merge_windows_weights_counts_not_laps():
    a = WorkloadTelemetry(num_nodes=6, num_edges=4)
    b = WorkloadTelemetry(num_nodes=6, num_edges=4)
    a.observe_batch(np.array([0, 1]), np.array([True, False]), [np.array([[0]])])
    b.observe_batch(np.array([1, 2]), np.array([False, True]), [np.array([[1]])])
    a.sample_times.append(0.5)
    b.sample_times.append(0.25)
    merged = merge_windows([a.snapshot(), b.snapshot()], [1.0, 3.0])
    # counts weighted: node 1 visited once in each window -> 1*1 + 3*1
    assert merged.node_counts[1] == 4.0 and merged.node_counts[0] == 1.0
    assert merged.node_miss_counts[1] == 4.0
    assert merged.edge_counts[1] == 3.0
    # laps concatenated UNweighted, batches summed
    assert merged.sample_times == [0.5, 0.25] and merged.batches == 2
    # weights=None == all-ones == plain sum
    plain = merge_windows([a.snapshot(), b.snapshot()])
    np.testing.assert_array_equal(
        plain.node_counts, a.snapshot().node_counts + b.snapshot().node_counts
    )
    # negative weights clamp to zero (a merge can't subtract a stream)
    clamped = merge_windows([a.snapshot(), b.snapshot()], [1.0, -5.0])
    np.testing.assert_array_equal(clamped.node_counts, a.snapshot().node_counts)
    with pytest.raises(ValueError):
        merge_windows([])
    with pytest.raises(ValueError):
        merge_windows([a.snapshot()], [1.0, 2.0])


def test_refresh_config_validates_stream_weighting():
    with pytest.raises(ValueError):
        RefreshConfig(mode="interval", interval_batches=2, stream_weighting="bogus")
    cfg = RefreshConfig(mode="interval", interval_batches=2, stream_weighting="queue-depth")
    assert cfg.enabled


def test_manager_telemetry_for_routes_by_weighting(small_dataset):
    eng = _engine(small_dataset)
    shared = CacheRefreshManager(
        eng.pipeline, small_dataset, fanouts=FANOUTS, batch_size=BATCH,
        config=RefreshConfig(mode="interval", interval_batches=2),
    )
    assert shared.telemetry_for(0) is shared.telemetry  # "none": shared sink
    weighted = CacheRefreshManager(
        eng.pipeline, small_dataset, fanouts=FANOUTS, batch_size=BATCH,
        config=RefreshConfig(
            mode="interval", interval_batches=2, stream_weighting="queue-depth"
        ),
    )
    s0, s1 = weighted.telemetry_for(0), weighted.telemetry_for(1)
    assert s0 is not weighted.telemetry and s0 is not s1
    assert weighted.telemetry_for(0) is s0  # stable per key


def test_serve_weighted_telemetry_refreshes_and_stays_equivalent(small_dataset):
    """stream_weighting='queue-depth': per-stream sinks feed a weighted
    merge at each refresh; refreshes still fire, their windows still
    count every stream's batches, and outputs stay serial-equivalent
    (weights change the ranking, never values)."""
    eng = _engine(small_dataset, stream_seeds=[100, 101])
    queues = make_stream_batches(
        small_dataset, num_streams=2, batches_per_stream=4, batch_size=BATCH, seed=3
    )
    server = MultiStreamServer(
        eng,
        depth=2,
        refresh=RefreshConfig(
            mode="interval", interval_batches=3, stream_weighting="queue-depth"
        ),
    )
    states = [
        server.add_stream(q, seed=100 + i, collect_outputs=True)
        for i, q in enumerate(queues)
    ]
    rep = server.run()
    mgr = server.refresh_manager
    assert rep.refresh_events, "interval refresh never fired"
    assert set(mgr._stream_telemetry) == {0, 1}  # one sink per stream
    assert rep.refresh_events[0].window_batches >= 3  # both streams counted
    for i, q in enumerate(queues):
        ref = GNNInferenceEngine(
            small_dataset, fanouts=FANOUTS, batch_size=BATCH, seed=100 + i,
            params=eng.params,
        )
        ref.pipeline = eng.pipeline
        ref.run(batches=list(q), pipeline_depth=1, collect_outputs=True)
        for a, b in zip(ref.last_outputs, states[i].runtime.outputs):
            np.testing.assert_array_equal(a, b)


# ------------------------------------------- leave-path history invariants


def test_join_serve_leave_history_never_negative(small_dataset):
    """join → serve (decays the remnant in lockstep) → leave: the decayed
    subtraction must leave every history count >= 0 — float-rounding
    asymmetry between the summed history decay and the remnant's solo
    decay must be absorbed by the clamp, not leak anti-visits into the
    next Eq. 1 re-allocation."""
    eng = _engine(small_dataset, n_presample=4, stream_seeds=[100, 101])
    queues = make_stream_batches(
        small_dataset, num_streams=3, batches_per_stream=3, batch_size=BATCH, seed=7
    )
    server = MultiStreamServer(
        eng, depth=2, refresh=RefreshConfig(mode="all", interval_batches=2)
    )
    server.add_stream(queues[0], seed=100)
    server.add_stream(queues[1], seed=101)
    server.run()
    s2 = server.add_stream(queues[2], seed=102)  # join: refresh + remnant stored
    mgr = server.refresh_manager
    assert 102 in mgr._stream_stats
    server.run()  # interval refreshes decay history AND remnant in lockstep
    assert any(e.reason == "interval" for e in mgr.events)
    server.remove_stream(s2.stream_id)  # leave: subtract the decayed remnant
    assert 102 not in mgr._stream_stats
    assert (mgr._node_counts >= 0.0).all()
    assert (mgr._edge_counts >= 0.0).all()
    assert mgr._sample_s >= 0.0 and mgr._feature_s >= 0.0
    # and the post-leave history still supports a refresh
    event = mgr.refresh("manual")
    assert event.delta.epoch == eng.pipeline.caches.epoch


# ------------------------------------------------------------- mesh path


def test_telemetry_shard_slice_partitions_the_window():
    t = WorkloadTelemetry(num_nodes=10, num_edges=6)
    t.observe_batch(
        np.array([1, 2, 2, 7, 9]),
        np.array([True, False, False, True, False]),
        [np.array([[0, 1]]), np.array([[5]])],
    )
    win = t.snapshot()
    slices = [win.shard_slice(0, 4), win.shard_slice(4, 7), win.shard_slice(7, 10)]
    # node traffic partitions exactly across the ranges
    np.testing.assert_array_equal(
        np.concatenate([s.node_counts for s in slices]), win.node_counts
    )
    np.testing.assert_array_equal(
        np.concatenate([s.node_miss_counts for s in slices]), win.node_miss_counts
    )
    for s in slices:
        # adjacency is replicated per shard; stage laps are whole-pipeline
        # facts — both pass through unsliced
        np.testing.assert_array_equal(s.edge_counts, win.edge_counts)
        assert s.sample_times == win.sample_times
        assert s.batches == win.batches


def test_sharded_serve_refresh_outputs_bit_identical(small_dataset):
    """Refresh on the mesh path moves bytes, never values: the sharded
    server's epoch-versioned outputs are bit-identical with refresh on or
    off, and its per-epoch counters partition the lifetime counters —
    the single-device invariants, carried across the shard exchange."""
    from repro.runtime.sharded_serve import ShardedServer

    eng = _engine(small_dataset, stream_seeds=[100, 101])
    queues = make_stream_batches(
        small_dataset, num_streams=2, batches_per_stream=4, batch_size=BATCH, seed=7
    )

    off = ShardedServer(eng, num_shards=4, dedup=True)
    for sid, q in enumerate(queues):
        off.add_stream(q, seed=100 + sid, collect_outputs=True)
    r_off = off.run()
    assert r_off.refresh_events == []

    on = ShardedServer(
        eng,
        num_shards=4,
        dedup=True,
        refresh=RefreshConfig(mode="interval", interval_batches=2),
    )
    for sid, q in enumerate(queues):
        on.add_stream(q, seed=100 + sid, collect_outputs=True)
    r_on = on.run()
    assert len(r_on.refresh_events) >= 1
    assert eng.pipeline.caches.epoch >= 1
    # the shards repartitioned on every refresh epoch; the latest
    # repartition mirrors the base fill exactly (earlier epochs' row
    # totals tracked their OWN epoch's allocation)
    assert len(on.repartition_log) == len(r_on.refresh_events)
    assert sum(on.repartition_log[-1]["rows_after"]) == (
        eng.pipeline.caches.store.num_cached
    )
    assert [e["epoch"] for e in on.repartition_log] == [
        e.epoch for e in r_on.refresh_events
    ]
    for a, b in zip(off.streams, on.streams):
        assert len(a.runtime.outputs) == len(b.runtime.outputs)
        for x, y in zip(a.runtime.outputs, b.runtime.outputs):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert r_on.epochs is not None
    assert sum(v["batches"] for v in r_on.epochs.values()) == r_on.total_batches


def test_refresh_manager_shard_allocations_partition_the_global(small_dataset):
    """After serve-time refreshes, the manager's per-shard Eq. 1 on the
    decayed partitioned history sums to the global budget with every
    shard at the global split fraction."""
    from repro.graph.shard import make_shard_plan
    from repro.runtime.sharded_serve import ShardedServer

    eng = _engine(small_dataset, stream_seeds=[100, 101])
    queues = make_stream_batches(
        small_dataset, num_streams=2, batches_per_stream=4, batch_size=BATCH, seed=7
    )
    server = ShardedServer(
        eng,
        num_shards=4,
        refresh=RefreshConfig(mode="interval", interval_batches=2),
    )
    for sid, q in enumerate(queues):
        server.add_stream(q, seed=100 + sid)
    server.run()
    mgr = server.refresh_manager
    assert mgr.events, "serve must have refreshed"
    base = eng.pipeline.caches.allocation
    for k in (1, 3, 4):
        allocs = mgr.shard_allocations(make_shard_plan(small_dataset.num_nodes, k))
        assert len(allocs) == k
        assert sum(a.total_bytes for a in allocs) == base.total_bytes
        for a in allocs:
            if a.total_bytes:
                assert a.sample_fraction == pytest.approx(
                    base.sample_fraction, abs=1e-9
                )
    # the server recorded the same per-shard allocations at the last epoch
    assert sum(a.total_bytes for a in server.shard_allocations) == base.total_bytes
