"""Layer-wise full-graph inference (runtime/layerwise.py).

The load-bearing guarantees:

  * numerical equivalence — an L-layer layer-wise pass equals (a) a dense
    numpy reference on arbitrary small graphs and (b) a FULL-NEIGHBORHOOD
    sampled forward on regular graphs (degree == fanout, where the
    deterministic enumeration takes every neighbor exactly once), within
    fp tolerance (summation order differs: segment_sum vs reshape-reduce);
  * knob invariance — prefetch / kernel route / pipeline depth / chunk
    size never change the outputs, only where bytes move;
  * exact access counts — the layer-wise pattern is ``1 + out_degree``
    per node per layer, read straight off the CSC;
  * engine dispatch — ``EngineConfig(mode="layerwise")`` routes
    ``GNNInferenceEngine.run`` to the chunked executor and the report
    echoes the resolved config.
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.allocation import allocate_layerwise_capacity
from repro.core.config import EngineConfig
from repro.graph.csc import CSCGraph
from repro.graph.datasets import DatasetSpec, SyntheticGraphDataset
from repro.graph.sampling import sample_blocks
from repro.models import gnn as gnn_models
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.layerwise import (
    LayerwiseReport,
    layerwise_access_counts,
    plan_chunks,
)

TOL = dict(rtol=2e-4, atol=2e-5)


def _dataset_from_graph(graph: CSCGraph, feat_dim: int = 8, num_classes: int = 4, seed: int = 0):
    n = graph.num_nodes
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    spec = DatasetSpec("custom", n, graph.num_edges / max(n, 1), feat_dim, num_classes, (0.5, 0.2, 0.3))
    return SyntheticGraphDataset(
        spec=spec,
        graph=graph,
        features=rng.standard_normal((n, feat_dim)).astype(np.float32),
        labels=rng.integers(0, num_classes, n).astype(np.int32),
        train_idx=idx[: n // 2],
        val_idx=idx[n // 2 : (7 * n) // 10],
        test_idx=idx[(7 * n) // 10 :],
    )


def _regular_graph(n: int, d: int) -> CSCGraph:
    """Every node's in-neighbors are the next ``d`` nodes (mod n) — degree
    exactly ``d`` everywhere, so fanout ``d`` full-neighborhood sampling
    enumerates each in-edge exactly once."""
    col_ptr = np.arange(n + 1, dtype=np.int64) * d
    row_index = np.empty(n * d, np.int32)
    for v in range(n):
        row_index[v * d : (v + 1) * d] = [(v + k + 1) % n for k in range(d)]
    return CSCGraph(col_ptr=col_ptr, row_index=row_index)


def _ragged_graph() -> CSCGraph:
    """Small arbitrary graph with a zero-degree node and a multi-edge."""
    nbrs = [[1, 2], [0, 3, 4, 4], [], [2], [0, 1, 2, 3, 5], [4], [0]]
    col_ptr = np.cumsum([0] + [len(x) for x in nbrs]).astype(np.int64)
    row_index = np.concatenate([np.asarray(x, np.int32) for x in nbrs if x])
    return CSCGraph(col_ptr=col_ptr, row_index=row_index)


def _dense_reference(dataset, params, model: str) -> np.ndarray:
    """Straight numpy layer chain over full in-neighborhoods (agg = 0 for
    zero-degree nodes, matching forward_layer's segment_sum semantics)."""
    g = dataset.graph
    n = g.num_nodes
    deg = np.diff(g.col_ptr).astype(np.float64)
    h = dataset.features.astype(np.float64)
    for li, p in enumerate(params):
        agg = np.zeros_like(h)
        for v in range(n):
            e0, e1 = int(g.col_ptr[v]), int(g.col_ptr[v + 1])
            if e1 > e0:
                agg[v] = h[np.asarray(g.row_index[e0:e1])].sum(axis=0)
        if model == "graphsage":
            out = h @ np.asarray(p["w_self"], np.float64)
            out += agg @ np.asarray(p["w_nbr"], np.float64)
            out += np.asarray(p["b"], np.float64)
        else:
            out = ((h + agg) / (deg[:, None] + 1.0)) @ np.asarray(p["w_self"], np.float64)
            out += np.asarray(p["b"], np.float64)
        h = np.maximum(out, 0.0) if li < len(params) - 1 else out
    return h


def _params(dataset, model, n_layers, seed=0, hidden=6):
    import jax

    return gnn_models.init_params(
        jax.random.PRNGKey(seed),
        model,
        dataset.spec.feat_dim,
        dataset.spec.num_classes,
        hidden=hidden,
        n_layers=n_layers,
    )


def _layerwise_engine(dataset, *, model="graphsage", fanouts=(3, 3), cache_bytes=4096, seed=0):
    # Layer count must match the fanout depth: the sampled forward runs
    # len(fanouts) layers, the layer-wise executor len(params).
    eng = GNNInferenceEngine(
        dataset,
        model=model,
        fanouts=fanouts,
        batch_size=8,
        seed=seed,
        params=_params(dataset, model, len(fanouts), seed=seed),
    )
    eng.prepare("dci", total_cache_bytes=cache_bytes, n_presample=2)
    return eng


# ------------------------------------------------------------ access pattern


def test_access_counts_exact():
    g = _ragged_graph()
    counts = layerwise_access_counts(g)
    # 1 (chunk member) + out-degree (appearances as an in-edge source).
    out_deg = np.bincount(np.asarray(g.row_index), minlength=g.num_nodes)
    np.testing.assert_array_equal(counts, 1 + out_deg)
    assert counts.min() >= 1


@pytest.mark.parametrize("chunk_size", [3, 4, 7, 16])
def test_plan_chunks_geometry(chunk_size):
    g = _ragged_graph()
    plan = plan_chunks(g, chunk_size)
    assert sum(c.cnt for c in plan.chunks) == g.num_nodes
    assert sum(c.n_edges for c in plan.chunks) == g.num_edges
    for c in plan.chunks:
        bucket = c.base_ids.shape[0] - chunk_size
        assert bucket >= c.n_edges and bucket & (bucket - 1) == 0  # pow2
        # Live self block is the node range; live neighbor block is the
        # CSC slice; seg ids map each live edge into [0, cnt).
        np.testing.assert_array_equal(
            c.base_ids[: c.cnt], np.arange(c.lo, c.lo + c.cnt, dtype=np.int32)
        )
        np.testing.assert_array_equal(
            c.base_ids[chunk_size : chunk_size + c.n_edges],
            np.asarray(g.row_index[g.col_ptr[c.lo] : g.col_ptr[c.lo] + c.n_edges]),
        )
        seg = np.asarray(c.seg_ids)
        assert seg[: c.n_edges].max(initial=0) < c.cnt
        assert (seg[c.n_edges :] == chunk_size).all()  # pads → dropped segment
        assert int(np.asarray(c.live).sum()) == c.cnt + c.n_edges


# ------------------------------------------------------------- equivalence


@pytest.mark.parametrize("model", ["graphsage", "gcn"])
def test_matches_dense_reference(model):
    ds = _dataset_from_graph(_ragged_graph())
    eng = _layerwise_engine(ds, model=model, fanouts=(2, 2), cache_bytes=2048)
    rep = eng.run(config=EngineConfig(mode="layerwise", chunk_size=3))
    assert isinstance(rep, LayerwiseReport)
    ref = _dense_reference(ds, eng.params, model)
    np.testing.assert_allclose(rep.outputs, ref, **TOL)


@pytest.mark.parametrize("model", ["graphsage", "gcn"])
def test_matches_full_neighborhood_sampled_forward(model):
    """On a d-regular graph with fanout == d, the deterministic
    full-neighborhood enumeration takes every in-edge exactly once, so the
    sampled L-layer forward IS the full-graph computation — the layer-wise
    outputs must match it within summation-order tolerance."""
    d = 3
    ds = _dataset_from_graph(_regular_graph(24, d))
    fanouts = (d, d)
    eng = _layerwise_engine(ds, model=model, fanouts=fanouts, cache_bytes=4096)
    rep = eng.run(config=EngineConfig(mode="layerwise", chunk_size=8))

    dgraph = eng.pipeline.caches.dgraph
    store = eng.pipeline.caches.store
    import jax

    for lo in range(0, ds.num_nodes, 8):
        seeds = jnp.arange(lo, min(lo + 8, ds.num_nodes), dtype=jnp.int32)
        block = sample_blocks(
            jax.random.PRNGKey(0), dgraph, seeds, fanouts, full_neighborhood=True
        )
        feats, _ = store.gather(block.input_nodes)
        logits = gnn_models.forward(eng.params, feats, model=model, fanouts=fanouts)
        np.testing.assert_allclose(rep.outputs[np.asarray(seeds)], np.asarray(logits), **TOL)


def test_knob_and_chunk_invariance():
    """Prefetch staging, the kernel route, a deeper window, and a
    different chunk size never change the scores — only byte movement."""
    ds = _dataset_from_graph(_regular_graph(30, 4), feat_dim=8)
    eng = _layerwise_engine(ds, fanouts=(4, 4), cache_bytes=4096)
    base = eng.run(config=EngineConfig(mode="layerwise", chunk_size=8, pipeline_depth=1))
    for knobs in (
        dict(prefetch=True),
        dict(use_kernel=True),
        dict(prefetch=True, use_kernel=True),
        dict(pipeline_depth=3),
    ):
        rep = eng.run(config=EngineConfig(mode="layerwise", chunk_size=8, **knobs))
        np.testing.assert_array_equal(rep.outputs, base.outputs)
        assert (rep.feat_hits, rep.feat_lookups) == (base.feat_hits, base.feat_lookups)
        assert (rep.embed_hits, rep.embed_lookups) == (base.embed_hits, base.embed_lookups)
    other = eng.run(config=EngineConfig(mode="layerwise", chunk_size=13))
    np.testing.assert_allclose(other.outputs, base.outputs, **TOL)
    # Lookup totals are chunking-invariant: N + E per layer, exactly.
    assert other.feat_lookups == base.feat_lookups
    assert other.embed_lookups == base.embed_lookups


def test_cacheless_budget_still_runs():
    ds = _dataset_from_graph(_ragged_graph())
    eng = GNNInferenceEngine(
        ds, fanouts=(2, 2), batch_size=8, params=_params(ds, "graphsage", 2)
    )
    eng.prepare("dgl")  # no cache budget at all
    rep = eng.run(config=EngineConfig(mode="layerwise", chunk_size=4))
    assert rep.outputs.shape == (ds.num_nodes, ds.spec.num_classes)
    assert rep.allocation is None
    ref = _dense_reference(ds, eng.params, "graphsage")
    np.testing.assert_allclose(rep.outputs, ref, **TOL)


# ---------------------------------------------------------- engine surface


def test_engine_dispatch_and_report():
    ds = _dataset_from_graph(_regular_graph(24, 3))
    eng = _layerwise_engine(ds, fanouts=(3, 3))
    rep = eng.run(config=EngineConfig(mode="layerwise", chunk_size=8, pipeline_depth=2))
    assert isinstance(rep, LayerwiseReport)
    assert eng.last_outputs[0] is rep.outputs
    s = rep.summary()
    assert s["mode"] == "layerwise"
    assert s["chunks"] == rep.num_chunks == -(-ds.num_nodes // 8)
    assert s["pipeline_depth"] == 2
    # The echoed config is RESOLVED: every knob concrete.
    cfg = s["config"]
    assert cfg["mode"] == "layerwise" and cfg["chunk_size"] == 8
    assert all(cfg[k] is not None for k in ("prefetch", "use_kernel", "gather_buffers"))
    # Lookups are the exact access pattern: N + E per layer.
    n, e = ds.num_nodes, ds.graph.num_edges
    assert rep.feat_lookups == n + e
    assert rep.embed_lookups == (rep.num_layers - 1) * (n + e)
    assert rep.modeled_transfer_seconds() > 0


def test_layerwise_allocation_mapping():
    # Feature gathers measured 3x slower than embedding gathers → Eq. 1
    # gives the feature cache 75% of the budget.
    alloc = allocate_layerwise_capacity([0.03], [0.01], 1000)
    assert alloc.feat_bytes == 750 and alloc.embed_bytes == 250
    assert alloc.feat_fraction == pytest.approx(0.75)
    # Saturation spill: a feature share beyond its need flows to embeds.
    alloc = allocate_layerwise_capacity([0.03], [0.01], 1000, feat_need_bytes=500)
    assert alloc.feat_bytes == 500 and alloc.embed_bytes == 500
    assert dataclasses.asdict(alloc)  # frozen dataclass stays introspectable
