"""Typed config API (core/config.py): round-trips, validation, coalesce
shim semantics, and bit-for-bit equivalence of the ``config=`` path against
the deprecated loose-kwarg path.

The equivalence tests share ONE prepared pipeline between the legacy-kwarg
engine and the config engine (the tests/test_pipeline_executor.py pattern):
preparation measures stage wall times for the Eq. 1 split, so separately
prepared engines can land different cache contents — sharing the pipeline
is what makes "bit-for-bit" a meaningful claim about the call styles
rather than about cache luck.
"""

import argparse
import warnings

import numpy as np
import pytest

from repro.core.config import (
    DEFAULT_CHUNK_SIZE,
    INFERENCE_MODES,
    REFRESH_MODES,
    EngineConfig,
    ServeConfig,
    coalesce,
)
from repro.runtime import cache_refresh
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches

FANOUTS = (3, 2)
BATCH = 64
KW = dict(total_cache_bytes=200_000, n_presample=2)


def _paired_engines(dataset, policy="dci"):
    """Legacy-kwarg engine and config engine over the SAME prepared pipeline."""
    legacy = GNNInferenceEngine(dataset, fanouts=FANOUTS, batch_size=BATCH)
    legacy.prepare(policy, **KW)
    cfg_eng = GNNInferenceEngine(
        dataset, fanouts=FANOUTS, batch_size=BATCH, params=legacy.params
    )
    cfg_eng.pipeline = legacy.pipeline
    return legacy, cfg_eng


# --------------------------------------------------------------- round-trips


def test_refresh_modes_mirror_runtime():
    # core duplicates the runtime tuple to stay import-cycle-free; this is
    # the tripwire if either side ever grows a mode alone.
    assert REFRESH_MODES == tuple(cache_refresh.MODES)


def test_engine_config_roundtrip():
    cfg = EngineConfig(
        mode="layerwise",
        pipeline_depth=3,
        prefetch=True,
        use_kernel=False,
        gather_buffers=1,
        dedup=True,
        chunk_size=77,
        refresh_mode="interval",
        refresh_interval=3,
        refresh_miss_threshold=0.4,
    )
    assert EngineConfig.from_dict(cfg.to_dict()) == cfg
    # unknown keys are ignored (reports may grow fields the config lacks)
    assert EngineConfig.from_dict({**cfg.to_dict(), "junk": 1}) == cfg
    # defaults round-trip too (all-None knobs survive)
    assert EngineConfig.from_dict(EngineConfig().to_dict()) == EngineConfig()


def test_serve_config_roundtrip():
    cfg = ServeConfig(
        engine=EngineConfig(pipeline_depth="auto", dedup=True),
        max_inflight=3,
        admission="edf",
        slo_ms=25.0,
        arrival="poisson",
        mean_interarrival_ms=10.0,
        mesh=2,
    )
    back = ServeConfig.from_dict(cfg.to_dict())
    assert back == cfg
    assert isinstance(back.engine, EngineConfig)


def test_from_args_parity():
    # The exact namespace launch/infer_gnn.py hands over: every config
    # field must be pulled from its arg, none silently defaulted.
    ns = argparse.Namespace(
        mode="layerwise",
        pipeline_depth="auto",
        prefetch=True,
        use_kernel=True,
        gather_buffers=1,
        dedup=True,
        chunk_size=123,
        refresh_mode="interval",
        refresh_interval=5,
        refresh_miss_threshold=0.2,
        max_inflight=4,
        admission="slo",
        slo_ms=30.0,
        arrival="burst",
        mean_interarrival_ms=5.0,
        mesh=2,
    )
    cfg = ServeConfig.from_args(ns)
    assert cfg.engine == EngineConfig(
        mode="layerwise",
        pipeline_depth="auto",
        prefetch=True,
        use_kernel=True,
        gather_buffers=1,
        dedup=True,
        chunk_size=123,
        refresh_mode="interval",
        refresh_interval=5,
        refresh_miss_threshold=0.2,
    )
    assert (cfg.max_inflight, cfg.admission, cfg.slo_ms) == (4, "slo", 30.0)
    assert (cfg.arrival, cfg.mean_interarrival_ms, cfg.mesh) == ("burst", 5.0, 2)
    assert ServeConfig.from_dict(cfg.to_dict()) == cfg


@pytest.mark.parametrize(
    "kw",
    [
        dict(mode="bogus"),
        dict(refresh_mode="bogus"),
        dict(pipeline_depth=0),
        dict(gather_buffers=0),
        dict(chunk_size=0),
    ],
)
def test_engine_config_validation(kw):
    with pytest.raises(ValueError):
        EngineConfig(**kw)


@pytest.mark.parametrize(
    "kw",
    [dict(max_inflight=0), dict(mesh=-1), dict(arrival="sometimes")],
)
def test_serve_config_validation(kw):
    with pytest.raises(ValueError):
        ServeConfig(**kw)


def test_auto_depth_allowed():
    assert EngineConfig(pipeline_depth="auto").pipeline_depth == "auto"


def test_modes_are_the_documented_pair():
    assert INFERENCE_MODES == ("sampling", "layerwise")


def test_refresh_config_build():
    assert EngineConfig().refresh_config() is None
    built = EngineConfig(
        refresh_mode="interval", refresh_interval=4, refresh_miss_threshold=0.25
    ).refresh_config()
    assert built == cache_refresh.RefreshConfig(
        mode="interval", interval_batches=4, miss_threshold=0.25
    )
    assert built.enabled


def test_resolved_fills_every_none():
    class _Pipe:
        prefetch = True
        use_kernel = False
        gather_buffers = 1
        dedup = True

    r = EngineConfig().resolved(_Pipe(), pipeline_depth=2)
    assert r == EngineConfig(
        pipeline_depth=2,
        prefetch=True,
        use_kernel=False,
        gather_buffers=1,
        dedup=True,
        chunk_size=DEFAULT_CHUNK_SIZE,
    )
    # explicit knobs beat the pipeline defaults
    explicit = EngineConfig(prefetch=False, chunk_size=9).resolved(_Pipe(), pipeline_depth=1)
    assert (explicit.prefetch, explicit.chunk_size) == (False, 9)


# ----------------------------------------------------------------- coalesce


def test_coalesce_no_legacy_no_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert coalesce(None) == EngineConfig()
        cfg = EngineConfig(prefetch=True)
        # None legacy values mean "not specified" — ignored silently
        assert coalesce(cfg, prefetch=None, dedup=None) == cfg


def test_coalesce_merges_and_warns():
    with pytest.warns(DeprecationWarning, match="dedup, prefetch"):
        merged = coalesce(EngineConfig(use_kernel=True), prefetch=True, dedup=False)
    assert merged == EngineConfig(use_kernel=True, prefetch=True, dedup=False)


def test_coalesce_serve_level():
    with pytest.warns(DeprecationWarning, match="MultiStreamServer"):
        merged = coalesce(
            ServeConfig(), ServeConfig, _context="MultiStreamServer", max_inflight=3
        )
    assert merged == ServeConfig(max_inflight=3)


def test_coalesce_rejects_wrong_config_type():
    with pytest.raises(TypeError):
        coalesce(ServeConfig(), EngineConfig)
    with pytest.raises(TypeError):
        coalesce(EngineConfig(), ServeConfig)


# --------------------------------------------- shim bit-for-bit equivalence


@pytest.mark.parametrize(
    "dedup,prefetch,refresh_on",
    [
        (False, False, False),
        (True, False, False),
        (False, True, False),
        (True, True, False),
        (False, False, True),
        (True, True, True),
    ],
)
def test_run_shim_equivalence(small_dataset, dedup, prefetch, refresh_on):
    """engine.run(loose kwargs) ≡ engine.run(config=EngineConfig(...)) on a
    shared prepared pipeline, across the dedup × prefetch × refresh grid."""
    legacy_eng, cfg_eng = _paired_engines(small_dataset)
    legacy_refresh = (
        cache_refresh.RefreshConfig(mode="interval", interval_batches=2)
        if refresh_on
        else None
    )
    with pytest.warns(DeprecationWarning, match="GNNInferenceEngine.run"):
        r1 = legacy_eng.run(
            max_batches=4,
            pipeline_depth=2,
            dedup=dedup,
            prefetch=prefetch,
            refresh=legacy_refresh,
            collect_outputs=True,
        )
    o1 = legacy_eng.last_outputs
    refresh_fields = (
        dict(refresh_mode="interval", refresh_interval=2) if refresh_on else {}
    )
    cfg = EngineConfig(pipeline_depth=2, dedup=dedup, prefetch=prefetch, **refresh_fields)
    r2 = cfg_eng.run(max_batches=4, config=cfg, collect_outputs=True)
    o2 = cfg_eng.last_outputs

    assert r1.num_batches == r2.num_batches
    assert len(o1) == len(o2) == r1.num_batches
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(a, b)
    if refresh_on:
        # The first run's refresh re-fills the SHARED caches in place, so
        # hit counters are per-epoch quantities, not comparable across the
        # two runs — but the interval trigger itself is deterministic.
        assert len(r1.refresh_events) == len(r2.refresh_events) > 0
    else:
        assert (r1.feat_hits, r1.feat_lookups) == (r2.feat_hits, r2.feat_lookups)
        assert (r1.adj_hits, r1.adj_lookups) == (r2.adj_hits, r2.adj_lookups)
    # Both reports echo the same resolved knobs.  Refresh fields are
    # normalized out: the legacy path hands the runtime RefreshConfig
    # object straight to run(), so only the config path records the
    # trigger in the echo.
    norm = dict(refresh_mode="off", refresh_interval=8, refresh_miss_threshold=None)
    assert r1.config.replace(**norm) == r2.config.replace(**norm)
    assert r1.config.pipeline_depth == 2
    assert (r1.config.dedup, r1.config.prefetch) == (dedup, prefetch)


def test_serve_shim_equivalence(small_dataset):
    """MultiStreamServer(loose kwargs) ≡ MultiStreamServer(config=...) over
    one shared engine+pipeline: identical per-stream outputs, hit counters,
    and resolved-config echo."""
    eng = GNNInferenceEngine(small_dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare("dci", stream_seeds=[eng.seed, eng.seed + 1], **KW)
    queues = make_stream_batches(
        small_dataset, num_streams=2, batches_per_stream=3, batch_size=BATCH, seed=eng.seed
    )

    def _serve(server):
        states = [
            server.add_stream(q, seed=eng.seed + sid, collect_outputs=True)
            for sid, q in enumerate(queues)
        ]
        rep = server.run()
        return rep, [s.runtime.outputs for s in states]

    with pytest.warns(DeprecationWarning, match="MultiStreamServer"):
        legacy = MultiStreamServer(
            eng, depth=2, prefetch=True, dedup=True, max_inflight_per_stream=2
        )
    r1, outs1 = _serve(legacy)
    cfg_server = MultiStreamServer(
        eng,
        config=ServeConfig(
            engine=EngineConfig(pipeline_depth=2, prefetch=True, dedup=True),
            max_inflight=2,
        ),
    )
    r2, outs2 = _serve(cfg_server)

    for s1, s2 in zip(outs1, outs2):
        assert len(s1) == len(s2) == 3
        for a, b in zip(s1, s2):
            np.testing.assert_array_equal(a, b)
    assert (r1.feat_hits, r1.feat_lookups) == (r2.feat_hits, r2.feat_lookups)
    assert legacy._resolved_config() == cfg_server._resolved_config()
    assert r1.config == r2.config
    assert r1.config.max_inflight == 2
    assert r1.config.engine.pipeline_depth == 2
    # the echo lands in the JSON summary both ways
    assert r1.summary()["config"] == r2.summary()["config"]


def test_report_echoes_resolved_config(small_dataset):
    """Satellite fix: the report's knob echo is the RESOLVED config the run
    executed with, not the knobs the constructor happened to see."""
    eng = GNNInferenceEngine(small_dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare("dci", **KW)
    rep = eng.run(max_batches=2, config=EngineConfig(pipeline_depth=2, prefetch=True))
    echo = rep.summary()["config"]
    assert echo["pipeline_depth"] == 2
    assert echo["prefetch"] is True
    assert echo["mode"] == "sampling"
    # every inheritable knob is concrete in the echo — None never leaks
    for knob in ("prefetch", "use_kernel", "gather_buffers", "dedup", "chunk_size"):
        assert echo[knob] is not None
