"""Pipelined batch executor ≡ serial engine, for every policy (incl. RAIN).

The equivalence suite shares one prepared pipeline (identical caches /
batch order / params) between a serial (depth=1) and a pipelined (depth>1)
engine and asserts bit-identical logits, identical adjacency/feature hit
counts, and identical batch order.  Property tests cover the overlap-aware
StageClock invariants and InferenceReport stage-time consistency.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.policies import POLICIES
from repro.runtime.gnn_engine import GNNInferenceEngine, InferenceReport
from repro.runtime.pipeline import BatchContext, PipelinedExecutor, Stage
from repro.utils.timing import StageClock

FANOUTS = (3, 2)
BATCH = 64
KW = dict(total_cache_bytes=200_000, n_presample=2)


def _paired_engines(dataset, policy):
    """Two engines over the same params and the SAME prepared pipeline, so
    wall-clock-dependent preparation (Eq. 1 uses measured stage times)
    cannot diverge between the serial and pipelined runs."""
    serial = GNNInferenceEngine(dataset, fanouts=FANOUTS, batch_size=BATCH)
    serial.prepare(policy, **KW)
    piped = GNNInferenceEngine(
        dataset, fanouts=FANOUTS, batch_size=BATCH, params=serial.params
    )
    piped.pipeline = serial.pipeline
    return serial, piped


@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("depth", [2, 3])
def test_depth_equivalence(small_dataset, policy, depth):
    serial, piped = _paired_engines(small_dataset, policy)
    r1 = serial.run(max_batches=4, pipeline_depth=1, collect_outputs=True)
    o1 = serial.last_outputs
    r2 = piped.run(max_batches=4, pipeline_depth=depth, collect_outputs=True)
    o2 = piped.last_outputs

    assert r1.num_batches == r2.num_batches
    assert r2.pipeline_depth == depth
    # hit accounting identical (adjacency and feature, incl. RAIN reuse)
    assert (r1.adj_hits, r1.adj_lookups) == (r2.adj_hits, r2.adj_lookups)
    assert (r1.feat_hits, r1.feat_lookups) == (r2.feat_hits, r2.feat_lookups)
    # same batches, same order, bit-identical logits
    assert len(o1) == len(o2) == r1.num_batches
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("policy", ["dci", "dgl", "rain"])
@pytest.mark.parametrize(
    "depth,prefetch,use_kernel",
    [(1, True, False), (3, True, False), (2, True, True), (2, False, True)],
)
def test_knob_equivalence(small_dataset, policy, depth, prefetch, use_kernel):
    """The execution knobs (miss-path prefetch, Pallas kernel route, and
    an explicitly-disabled refresh config) never change outputs or hit
    accounting — only where the miss bytes move.  Every combination must
    match the plain serial run bit for bit."""
    from repro.runtime.cache_refresh import RefreshConfig

    serial, piped = _paired_engines(small_dataset, policy)
    r1 = serial.run(max_batches=4, pipeline_depth=1, collect_outputs=True)
    o1 = serial.last_outputs
    r2 = piped.run(
        max_batches=4,
        pipeline_depth=depth,
        collect_outputs=True,
        prefetch=prefetch,
        use_kernel=use_kernel,
        refresh=RefreshConfig(mode="off"),
    )
    o2 = piped.last_outputs
    assert r2.prefetch == prefetch
    assert (r1.adj_hits, r1.adj_lookups) == (r2.adj_hits, r2.adj_lookups)
    assert (r1.feat_hits, r1.feat_lookups) == (r2.feat_hits, r2.feat_lookups)
    # refresh off: no epochs, no events, no cache mutation — the report
    # (and therefore every baseline comparison over it) is unchanged
    assert r2.refresh_events == [] and r2.epoch_hits is None
    assert piped.pipeline.caches.epoch == 0
    if prefetch and policy != "rain":
        # every miss was staged ahead of its gather (RAIN reuses the
        # previous batch first, so its prefetch count is over-staged)
        assert r2.prefetched_rows == r2.feat_lookups - r2.feat_hits
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("depth,prefetch", [(1, False), (2, True)])
def test_knob_equivalence_refresh_on_outputs_identical(small_dataset, depth, prefetch):
    """Even with refresh ENABLED mid-run, outputs stay bit-identical to the
    serial refresh-free run — a refresh re-ranks the caches (bytes), never
    the values; only hit accounting may differ, reported per epoch."""
    from repro.runtime.cache_refresh import RefreshConfig

    serial, piped = _paired_engines(small_dataset, "dci")
    r1 = serial.run(max_batches=6, pipeline_depth=1, collect_outputs=True)
    o1 = serial.last_outputs
    r2 = piped.run(
        max_batches=6,
        pipeline_depth=depth,
        collect_outputs=True,
        prefetch=prefetch,
        refresh=RefreshConfig(mode="interval", interval_batches=2),
    )
    assert piped.pipeline.caches.epoch >= 1 and len(r2.refresh_events) >= 1
    assert r1.num_batches == r2.num_batches
    for a, b in zip(o1, piped.last_outputs):
        np.testing.assert_array_equal(a, b)
    if prefetch:
        # staged-row accounting still matches the (per-epoch) misses
        assert r2.prefetched_rows == r2.feat_lookups - r2.feat_hits


def test_prefetch_off_keeps_stage_list_and_report_defaults(small_dataset):
    """The depth=1, prefetch-off path is the pre-prefetch engine exactly:
    no prefetch stage runs, no prefetch seconds are booked, and the
    report's knob fields default off."""
    serial, _ = _paired_engines(small_dataset, "dci")
    rep = serial.run(max_batches=2, pipeline_depth=1)
    assert not rep.prefetch
    assert rep.prefetch_seconds == 0.0
    assert rep.prefetched_rows == 0


def test_rain_reuse_ordering_preserved(small_dataset):
    """RAIN's cross-batch reuse makes batch i+1's gather depend on batch i;
    the pipelined run must reproduce the serial hit sequence exactly."""
    serial, piped = _paired_engines(small_dataset, "rain")
    r1 = serial.run(max_batches=6, pipeline_depth=1)
    r2 = piped.run(max_batches=6, pipeline_depth=3)
    assert r1.feat_hits == r2.feat_hits
    assert r1.feat_hits > 0  # clustered order actually produces reuse


# ------------------------------------------------------------- executor unit


def _recording_stages(events):
    return [
        Stage("a", lambda c: events.append(("a", c.index)) or c.index * 10),
        Stage("b", lambda c: events.append(("b", c.index)) or c.outputs["a"] + 1),
    ]


def test_depth1_is_lockstep():
    events = []
    values = []
    ex = PipelinedExecutor(
        _recording_stages(events),
        depth=1,
        on_retire=lambda c: (events.append(("r", c.index)), values.append(c.outputs["b"])),
    )
    out = ex.run(range(3))
    assert events == [
        ("a", 0), ("b", 0), ("r", 0),
        ("a", 1), ("b", 1), ("r", 1),
        ("a", 2), ("b", 2), ("r", 2),
    ]
    assert values == [1, 11, 21]
    # retired contexts are returned emptied: extraction happens in on_retire,
    # so memory stays O(depth) on long runs
    assert all(c.outputs == {} for c in out)


def test_depth2_overlaps_one_batch():
    events = []
    ex = PipelinedExecutor(
        _recording_stages(events), depth=2, on_retire=lambda c: events.append(("r", c.index))
    )
    out = ex.run(range(3))
    # batch 0 retires only after batch 1 fully dispatched; drain retires 2.
    assert events == [
        ("a", 0), ("b", 0),
        ("a", 1), ("b", 1), ("r", 0),
        ("a", 2), ("b", 2), ("r", 1),
        ("r", 2),
    ]
    assert [c.index for c in out] == [0, 1, 2]  # retire order == batch order


def test_run_tagged_stamps_stream_and_routes_clocks():
    """Tagged runs: ctx.stream carries the tag through to retire, and with
    clock_for every batch's laps AND retire drains land on its own stream's
    clock — the per-stream accounting the serving layer builds on."""
    import jax.numpy as jnp

    class Tag:
        def __init__(self, name):
            self.name = name
            self.clock = StageClock(overlap=True)

    a, b = Tag("a"), Tag("b")
    seen = []
    ex = PipelinedExecutor(
        [Stage("s", lambda c: jnp.arange(8) + c.payload, lambda c: c.outputs["s"])],
        depth=2,
        clock_for=lambda c: c.stream.clock,
        on_retire=lambda c: seen.append((c.stream.name, c.payload)),
    )
    ex.run_tagged([(a, 0), (b, 1), (a, 2)])
    assert seen == [("a", 0), ("b", 1), ("a", 2)]
    assert len(a.clock.laps["s"]) == 2 and len(b.clock.laps["s"]) == 1
    # overlap mode: each stream's drains are attributed to its own clock
    assert a.clock.totals["s"] >= sum(a.clock.laps["s"])
    assert b.clock.totals["s"] >= sum(b.clock.laps["s"])


def test_drain_sentinel_flushes_window_without_admitting():
    """DRAIN retires everything in flight, admits nothing, and does not
    advance the batch index — the request-queue front-end's way to flush
    while waiting for arrivals."""
    from repro.runtime.pipeline import DRAIN

    events = []
    ex = PipelinedExecutor(
        _recording_stages(events), depth=3, on_retire=lambda c: events.append(("r", c.index))
    )
    out = ex.run_tagged([(None, 0), (None, 1), DRAIN, (None, 2)])
    # both in-flight batches retire at the sentinel; batch 2 keeps index 2
    assert events == [
        ("a", 0), ("b", 0),
        ("a", 1), ("b", 1),
        ("r", 0), ("r", 1),
        ("a", 2), ("b", 2),
        ("r", 2),
    ]
    assert [c.index for c in out] == [0, 1, 2]
    # DRAIN with an empty window is a no-op
    assert ex.run_tagged([DRAIN]) == []


def test_run_is_run_tagged_with_no_stream():
    done = []
    ex = PipelinedExecutor(
        [Stage("s", lambda c: c.payload)],
        depth=1,
        on_retire=lambda c: done.append(c.stream),
    )
    ex.run(range(3))
    assert done == [None, None, None]


def test_executor_rejects_bad_config():
    with pytest.raises(ValueError):
        PipelinedExecutor([Stage("a", lambda c: None)], depth=0)
    with pytest.raises(ValueError):
        PipelinedExecutor([], depth=1)
    with pytest.raises(ValueError):  # all-optional, all off
        PipelinedExecutor([None, None], depth=1)


def test_executor_drops_optional_stages():
    """None entries model optional stages (the prefetch hook off): the
    schedule must be identical to never listing them."""
    events = []
    ex = PipelinedExecutor(
        [None] + _recording_stages(events) + [None],
        depth=1,
        on_retire=lambda c: events.append(("r", c.index)),
    )
    assert [s.name for s in ex.stages] == ["a", "b"]
    ex.run(range(2))
    assert events == [("a", 0), ("b", 0), ("r", 0), ("a", 1), ("b", 1), ("r", 1)]


def test_batch_context_carries_payload():
    ctx = BatchContext(3, "payload")
    assert ctx.index == 3 and ctx.payload == "payload" and ctx.outputs == {}


def test_stage_error_drains_inflight_then_reraises():
    """A stage failure mid-window must not strand completed work: every
    in-flight batch retires (accounting runs, slots release) before the
    FIRST error re-raises, and the executor stays usable afterwards."""
    events = []

    def fn(c):
        if c.index == 2:
            raise RuntimeError("boom")
        events.append(("a", c.index))
        return c.index

    ex = PipelinedExecutor(
        [Stage("a", fn)], depth=3, on_retire=lambda c: events.append(("r", c.index))
    )
    with pytest.raises(RuntimeError, match="boom"):
        ex.run(range(5))
    # batches 0 and 1 were in flight when 2 died: both retired, in order
    assert events == [("a", 0), ("a", 1), ("r", 0), ("r", 1)]
    # all window slots were released: a fresh run reuses slots 0..depth-1
    events.clear()
    out = ex.run([10, 11])
    assert [c.index for c in out] == [0, 1]
    assert sorted({c.slot for c in out}) <= [0, 1, 2]


def test_retire_error_still_drains_remaining_window():
    """An exception thrown by on_retire itself (the drain path's own
    failure mode) also drains the rest of the window best-effort and the
    original error wins."""
    retired = []

    def on_retire(c):
        if c.index == 0:
            raise RuntimeError("retire-boom")
        retired.append(c.index)

    ex = PipelinedExecutor([Stage("a", lambda c: c.index)], depth=3, on_retire=on_retire)
    with pytest.raises(RuntimeError, match="retire-boom"):
        ex.run(range(3))
    assert retired == [1, 2]  # later batches still retired during the drain


def test_on_batch_error_drops_only_the_failing_batch():
    """The shed hook: a handled failure drops exactly that batch — its
    slot and index are reused, later batches keep contiguous indices, and
    unhandled errors still take the drain-and-raise path."""
    dropped, retired = [], []

    def fn(c):
        if c.payload == "bad":
            raise RuntimeError("poisoned")
        return c.payload

    ex = PipelinedExecutor(
        [Stage("a", fn)],
        depth=2,
        on_retire=lambda c: retired.append((c.index, c.outputs["a"])),
        on_batch_error=lambda c, e: dropped.append((c.index, str(e))) or True,
    )
    out = ex.run(["x", "bad", "y", "z"])
    assert dropped == [(1, "poisoned")]
    # the dropped batch's index was reused: retires are contiguous 0..2
    assert retired == [(0, "x"), (1, "y"), (2, "z")]
    assert [c.index for c in out] == [0, 1, 2]

    # a handler that declines (returns False) falls through to the drain
    ex2 = PipelinedExecutor(
        [Stage("a", fn)], depth=2, on_batch_error=lambda c, e: False
    )
    with pytest.raises(RuntimeError, match="poisoned"):
        ex2.run(["x", "bad"])


# ----------------------------------------------------- StageClock invariants


def _clock_invariants(clock: StageClock):
    for laps in clock.laps.values():
        assert all(dt >= 0 for dt in laps)
    for name, total in clock.totals.items():
        assert total >= 0
        assert total >= sum(clock.laps.get(name, [])) - 1e-9
    all_laps = sum(sum(v) for v in clock.laps.values())
    assert abs(sum(clock.totals.values()) - (all_laps + clock.drain_seconds)) < 1e-9


def test_stage_clock_serial_blocks_on_sync():
    import jax.numpy as jnp

    clock = StageClock(overlap=False)
    with clock.stage("s", sync=lambda: jnp.arange(8).sum()):
        pass
    assert clock.total("s") > 0
    assert len(clock.laps["s"]) == 1
    _clock_invariants(clock)


def test_stage_clock_overlap_drain_accounting():
    import jax.numpy as jnp

    clock = StageClock(overlap=True)
    for _ in range(3):
        with clock.stage("s"):
            v = jnp.arange(128) * 2
        clock.drain("s", v)
    assert len(clock.laps["s"]) == 3
    assert clock.drain_seconds >= 0
    _clock_invariants(clock)


@settings(max_examples=50, deadline=None)
@given(
    secs=st.lists(st.floats(0, 100, allow_nan=False), min_size=3, max_size=3),
    depth=st.integers(1, 8),
)
def test_report_stage_seconds_consistent(secs, depth):
    """InferenceReport: stage seconds non-negative, total == their sum at
    any pipeline depth (overlap changes attribution, not the identity)."""
    rep = InferenceReport(
        policy="dci",
        num_batches=4,
        sample_seconds=secs[0],
        feature_seconds=secs[1],
        compute_seconds=secs[2],
        prep_seconds=0.0,
        adj_hits=1,
        adj_lookups=2,
        feat_hits=1,
        feat_lookups=2,
        feat_row_bytes=4,
        pipeline_depth=depth,
    )
    assert rep.sample_seconds >= 0 and rep.feature_seconds >= 0 and rep.compute_seconds >= 0
    assert abs(rep.total_seconds - sum(secs)) < 1e-9
    assert rep.total_seconds >= max(secs) - 1e-9
    assert rep.summary()["pipeline_depth"] == depth


@settings(max_examples=30, deadline=None)
@given(
    plan=st.lists(
        st.tuples(st.sampled_from(["s", "f", "c"]), st.booleans()), min_size=1, max_size=24
    )
)
def test_stage_clock_invariants_random_schedule(plan):
    """Random interleavings of stage laps and drains keep the clock's
    accounting identities intact in overlap mode."""
    import jax.numpy as jnp

    clock = StageClock(overlap=True)
    for name, do_drain in plan:
        with clock.stage(name):
            v = jnp.ones(16)
        if do_drain:
            clock.drain(name, v)
    _clock_invariants(clock)
    for name, _ in plan:
        assert clock.total(name) > 0
