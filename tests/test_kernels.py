"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cached_gather.kernel import (
    cached_gather,
    cached_gather_blocks,
    cached_gather_select,
    default_interpret,
    dma_supported,
)
from repro.kernels.cached_gather.ref import cached_gather_ref
from repro.kernels.flash_attention.kernel import flash_attention_2d
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.seg_agg.kernel import seg_agg
from repro.kernels.seg_agg.ref import seg_agg_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("h,n,f,s", [(16, 100, 64, 32), (8, 50, 602, 7), (4, 256, 128, 200), (1, 10, 16, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cached_gather_matches_ref(h, n, f, s, dtype):
    hot = jnp.asarray(RNG.standard_normal((h, f)), dtype)
    host = jnp.asarray(RNG.standard_normal((n, f)), dtype)
    idx = jnp.asarray(RNG.integers(0, n, s), jnp.int32)
    pos = jnp.asarray(RNG.integers(-1, h, s), jnp.int32)
    out = cached_gather(hot, host, idx, pos)
    ref = cached_gather_ref(hot, host, idx, pos)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=1e-6
    )


def test_cached_gather_all_hits_and_all_misses():
    hot = jnp.asarray(RNG.standard_normal((4, 8)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((9, 8)), jnp.float32)
    idx = jnp.arange(4, dtype=jnp.int32)
    all_hit = cached_gather(hot, host, idx, jnp.arange(4, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(all_hit), np.asarray(hot[:4]))
    all_miss = cached_gather(hot, host, idx, jnp.full((4,), -1, jnp.int32))
    np.testing.assert_allclose(np.asarray(all_miss), np.asarray(host[:4]))


def test_cached_gather_empty_index_set():
    """S=0: no kernel launch, just the empty batch buffer."""
    hot = jnp.asarray(RNG.standard_normal((4, 96)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((9, 96)), jnp.float32)
    out = cached_gather(hot, host, jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32))
    assert out.shape == (0, 96) and out.dtype == host.dtype


@pytest.mark.parametrize("f", [96, 130, 250, 602])
def test_cached_gather_non_vreg_feature_dims(f):
    """Feature dims that are not multiples of the 128-lane VREG width:
    pad-and-slice must stay bit-exact for every source row."""
    hot = jnp.asarray(RNG.standard_normal((6, f)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((40, f)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 40, 17), jnp.int32)
    pos = jnp.asarray(RNG.integers(-1, 6, 17), jnp.int32)
    out = cached_gather(hot, host, idx, pos)
    ref = cached_gather_ref(hot, host, idx, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("gather_buffers", [1, 2, 3, 4])
def test_cached_gather_buffer_counts(gather_buffers):
    """1 slot = serial copies, 2 = double buffering, more = deeper rotation;
    the slot-reuse waits must keep every variant bit-exact."""
    hot = jnp.asarray(RNG.standard_normal((8, 160)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((64, 160)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 64, 33), jnp.int32)
    pos = jnp.asarray(RNG.integers(-1, 8, 33), jnp.int32)
    out = cached_gather(hot, host, idx, pos, gather_buffers=gather_buffers)
    ref = cached_gather_ref(hot, host, idx, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_cached_gather_rejects_bad_buffers():
    hot = jnp.zeros((1, 8), jnp.float32)
    host = jnp.zeros((2, 8), jnp.float32)
    idx = jnp.zeros((2,), jnp.int32)
    with pytest.raises(ValueError):
        cached_gather(hot, host, idx, idx, gather_buffers=0)


@pytest.mark.parametrize("h,n,f,s", [(16, 100, 64, 32), (8, 50, 602, 7), (4, 256, 128, 200)])
def test_cached_gather_blocks_matches_ref_random(h, n, f, s):
    """Arbitrary (unsorted, mixed-source) index sets: every block falls
    back to per-row copies and the output must still be bit-exact."""
    hot = jnp.asarray(RNG.standard_normal((h, f)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((n, f)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, n, s), jnp.int32)
    pos = jnp.asarray(RNG.integers(-1, h, s), jnp.int32)
    out = cached_gather_blocks(hot, host, idx, pos)
    ref = cached_gather_ref(hot, host, idx, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_cached_gather_blocks_contiguous_runs():
    """Sorted ids with id-ordered slots — the dedup frontier's shape: whole
    blocks collapse to single run DMAs on both the hit and miss source."""
    n, f = 64, 128
    host = jnp.asarray(RNG.standard_normal((n, f)), jnp.float32)
    ids = jnp.asarray(np.arange(10, 42, dtype=np.int32))
    all_hit = cached_gather_blocks(host, host, ids, ids)
    np.testing.assert_array_equal(np.asarray(all_hit), np.asarray(host)[10:42])
    all_miss = cached_gather_blocks(host, host, ids, jnp.full((32,), -1, jnp.int32))
    np.testing.assert_array_equal(np.asarray(all_miss), np.asarray(host)[10:42])


def test_cached_gather_blocks_singleton_runs():
    """Strided sorted ids: every run breaks after one row (mode-0 blocks
    throughout) — the worst case must still be exact."""
    n, f = 64, 96
    hot = jnp.asarray(RNG.standard_normal((8, f)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((n, f)), jnp.float32)
    idx = jnp.asarray(np.arange(0, 34, 2, dtype=np.int32))  # stride 2: no runs
    pos = jnp.asarray(RNG.integers(-1, 8, 17), jnp.int32)
    out = cached_gather_blocks(hot, host, idx, pos)
    ref = cached_gather_ref(hot, host, idx, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_cached_gather_blocks_empty_and_row_block_edges():
    """S=0 short-circuits; row_block=1 routes to the per-row kernel; a
    row_block larger than S pads to one block; non-128 feature dims keep
    the pad-and-slice bit-exact."""
    hot = jnp.asarray(RNG.standard_normal((4, 130)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((9, 130)), jnp.float32)
    empty = cached_gather_blocks(
        hot, host, jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32)
    )
    assert empty.shape == (0, 130)
    idx = jnp.asarray(RNG.integers(0, 9, 3), jnp.int32)
    pos = jnp.asarray([-1, 0, 2], jnp.int32)
    ref = cached_gather_ref(hot, host, idx, pos)
    for rb in (1, 4, 16):
        out = cached_gather_blocks(hot, host, idx, pos, row_block=rb)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    with pytest.raises(ValueError):
        cached_gather_blocks(hot, host, idx, pos, row_block=0)


@pytest.mark.parametrize("gather_buffers", [1, 2, 3])
def test_cached_gather_blocks_buffer_rotation(gather_buffers):
    hot = jnp.asarray(RNG.standard_normal((8, 160)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((64, 160)), jnp.float32)
    idx = jnp.asarray(np.sort(RNG.choice(64, 33, replace=False)).astype(np.int32))
    pos = jnp.asarray(RNG.integers(-1, 8, 33), jnp.int32)
    out = cached_gather_blocks(hot, host, idx, pos, gather_buffers=gather_buffers)
    ref = cached_gather_ref(hot, host, idx, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_cached_gather_select_fallback_matches_ref():
    """The select-based fallback (for JAX versions without interpret-mode
    DMA) must stay parity-tested alongside the double-buffered kernel."""
    hot = jnp.asarray(RNG.standard_normal((8, 160)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((30, 160)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 30, 11), jnp.int32)
    pos = jnp.asarray(RNG.integers(-1, 8, 11), jnp.int32)
    out = cached_gather_select(hot, host, idx, pos, interpret=True)
    ref = cached_gather_ref(hot, host, idx, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_interpret_default_resolves_by_backend():
    assert default_interpret() == (jax.default_backend() != "tpu")
    # On TPU the DMA path is always available; elsewhere the probe decides
    # (and on this container's JAX the interpret-mode DMA path exists).
    assert isinstance(dma_supported(), bool)


@pytest.mark.skipif(
    jax.default_backend() != "tpu", reason="compiled Pallas backend (TPU) not available"
)
def test_cached_gather_compiled_matches_interpret():
    """Where a compiled backend exists, compiled and interpret mode must
    agree bit-for-bit (same DMA schedule, same select)."""
    hot = jnp.asarray(RNG.standard_normal((8, 256)), jnp.float32)
    host = jnp.asarray(RNG.standard_normal((64, 256)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 64, 33), jnp.int32)
    pos = jnp.asarray(RNG.integers(-1, 8, 33), jnp.int32)
    compiled = cached_gather(hot, host, idx, pos, interpret=False)
    interpreted = cached_gather(hot, host, idx, pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(compiled), np.asarray(interpreted))


@pytest.mark.parametrize("s,fo,f", [(32, 5, 128), (7, 2, 602), (100, 15, 64), (1, 1, 1)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_seg_agg_matches_ref(s, fo, f, mode, dtype):
    x = jnp.asarray(RNG.standard_normal((s, fo, f)), dtype)
    out = seg_agg(x, mode=mode)
    ref = seg_agg_ref(x, mode=mode)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize(
    "sq,sk,d,causal,window,cap",
    [
        (128, 128, 64, True, None, None),
        (256, 256, 128, True, None, 50.0),
        (200, 200, 64, True, 64, None),
        (128, 128, 64, False, None, None),
        (96, 160, 64, False, None, None),
        (64, 64, 128, True, 16, 30.0),
    ],
)
def test_flash_attention_matches_ref(sq, sk, d, causal, window, cap):
    q = jnp.asarray(RNG.standard_normal((sq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((sk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((sk, d)), jnp.float32)
    out = flash_attention_2d(q, k, v, causal=causal, window=window, softcap=cap)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((128, 64)), jnp.bfloat16)
    out = flash_attention_2d(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=5e-2, atol=5e-2
    )


def test_multi_head_wrapper_gqa():
    from repro.kernels.flash_attention.ops import multi_head_attention

    b, hq, hkv, s, d = 2, 8, 2, 64, 32
    q = jnp.asarray(RNG.standard_normal((b, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    out_kernel = multi_head_attention(q, k, v, use_kernel=True)
    out_ref = multi_head_attention(q, k, v, use_kernel=False)
    assert out_kernel.shape == (b, hq, s, d)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_ref), rtol=3e-4, atol=3e-4)


def test_flash_attention_decode_shape():
    """Sq=1 against a long KV — the serving hot path through the kernel."""
    q = jnp.asarray(RNG.standard_normal((1, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1024, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1024, 64)), jnp.float32)
    # non-causal with window: the decode-style mask
    out = flash_attention_2d(q, k, v, causal=False, window=None)
    ref = attention_ref(q, k, v, causal=False, window=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)
