"""Chunked WKV6 (flash-linear-attention style) == sequential scan."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.lm import rwkv6 as R


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("rwkv6-3b"), dtype="float32")
    params = R.init_rwkv_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, params


@pytest.mark.parametrize("s", [1, 7, 32, 64, 130])
@pytest.mark.parametrize("chunk", [16, 32])
def test_chunked_matches_scan(setup, s, chunk):
    cfg, params = setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model), jnp.float32)
    y_scan, c_scan = R.rwkv_time_mix_prefill(params, x, cfg)
    y_chunk, c_chunk = R.rwkv_time_mix_prefill_chunked(params, x, cfg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_chunk), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(c_scan["state"]), np.asarray(c_chunk["state"]), rtol=2e-4, atol=2e-5
    )
    np.testing.assert_allclose(np.asarray(c_scan["shift"]), np.asarray(c_chunk["shift"]))


def test_chunked_then_decode_consistent(setup):
    """Chunked prefill's carried state must continue correctly in decode."""
    cfg, params = setup
    s = 33
    x = jax.random.normal(jax.random.PRNGKey(2), (2, s + 1, cfg.d_model), jnp.float32)
    y_full, _ = R.rwkv_time_mix_prefill(params, x, cfg)
    _, cache = R.rwkv_time_mix_prefill_chunked(params, x[:, :s], cfg, chunk=16)
    y_dec, _ = R.rwkv_time_mix_decode(params, x[:, s : s + 1], cache, cfg)
    np.testing.assert_allclose(
        np.asarray(y_full[:, -1:]), np.asarray(y_dec), rtol=2e-4, atol=2e-5
    )
