"""Regression guard: the dry-run (512 host devices) still lowers+compiles.

Runs in a subprocess because the dry-run must set XLA_FLAGS before jax
initializes (the test process already holds a 1-device jax).  One cheap
combo per kind + the §Perf variants keeps it fast (~1 min total).
"""

import subprocess
import sys

import pytest

CASES = [
    ("gemma-2b", "decode_32k", "baseline"),
    ("qwen2-vl-2b", "train_4k", "baseline"),
    ("phi3.5-moe-42b-a6.6b", "prefill_32k", "moe_shardmap"),
    ("deepseek-v2-236b", "decode_32k", "mla_absorb"),
    ("rwkv6-3b", "long_500k", "baseline"),
]


@pytest.mark.parametrize("arch,shape,variant", CASES)
def test_dryrun_compiles(arch, shape, variant):
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.dryrun",
            "--arch",
            arch,
            "--shape",
            shape,
            "--variant",
            variant,
        ],
        capture_output=True,
        text=True,
        timeout=420,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "1 combos compiled, 1 with analyses" in proc.stdout


def test_pod_scale_gnn_dryrun_compiles():
    """The beyond-paper pod-scale GNN inference dry-run (papers100M scale)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun_gnn", "--batch", "256"],
        capture_output=True,
        text=True,
        timeout=420,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "cold" in proc.stdout and "hot" in proc.stdout
