"""Dry-run spec consistency: the abstract caches used for decode lowering
must match (structure AND shapes) what prefill actually produces — this is
the test that keeps `launch/specs.py` honest as the model evolves."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.launch.specs import abstract_caches
from repro.models.lm import model as M

B, S = 2, 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_abstract_caches_match_prefill(arch):
    cfg = get_smoke(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = {}
    if cfg.encoder_layers > 0:
        batch["src_embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.float32)
        batch["tokens"] = jnp.zeros((B, S), jnp.int32)
    elif cfg.input_mode == "embeds":
        batch["embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jnp.zeros((B, S), jnp.int32)
    _, caches = jax.eval_shape(
        lambda p, b: M.prefill(p, b, cfg, cache_size=S), params, batch
    )

    # enc-dec abstract uses a fixed encoder length; align it for comparison
    import repro.launch.specs as specs_mod

    old = specs_mod.DECODE_ENC_LEN
    specs_mod.DECODE_ENC_LEN = S
    try:
        abstract = abstract_caches(cfg, B, S, long_mode=False)
    finally:
        specs_mod.DECODE_ENC_LEN = old

    assert jax.tree.structure(caches) == jax.tree.structure(abstract)
    for got, want in zip(jax.tree.leaves(caches), jax.tree.leaves(abstract)):
        assert got.shape == want.shape, (arch, got.shape, want.shape)
        assert got.dtype == want.dtype, (arch, got.dtype, want.dtype)


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma2-27b", "rwkv6-3b", "jamba-v0.1-52b"])
def test_decode_accepts_abstract_cache_shapes(arch):
    """decode_step must lower against exactly the abstract cache tree."""
    cfg = get_smoke(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    abstract = abstract_caches(cfg, B, S, long_mode=False)
    tokens = jnp.zeros((B, 1), jnp.int32)
    out = jax.eval_shape(
        lambda p, t, c: M.decode_step(p, t, c, jnp.int32(S - 1), cfg),
        params,
        tokens,
        abstract,
    )
    logits, new_caches = out
    assert logits.shape == (B, cfg.vocab_padded)
    assert jax.tree.structure(new_caches) == jax.tree.structure(abstract)
