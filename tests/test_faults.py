"""Fault injection and fault-tolerant serving (core/faults.py, core/retry.py).

The load-bearing guarantees:

  * determinism — a FaultPlan replays bit-for-bit: the same plan against
    the same call sequence triggers the same faults (and the retry
    backoff schedule is a pure function of (policy, key));
  * zero-diff when disabled — fault knobs on but no plan (or an empty
    plan) leave outputs, hit accounting, and RNG draws bit-identical to
    the pre-fault-subsystem serve, across the dedup × prefetch grid and
    the sharded server;
  * recovery semantics — retry recovers transient faults bit-identically,
    degraded mode keeps availability at 1.0 with per-request marking,
    shed drops exactly the failing request, fail-fast drains and records
    the error instead of dropping work silently;
  * transactional refresh — a refresh that dies mid-apply rolls back to
    the byte-identical old epoch and serving continues against it;
  * shard failover — a lost shard's id range is served from the host
    mirror bit-identically until rejoin, hit sums still tiling the
    global counters.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.config import EngineConfig, ServeConfig
from repro.core.faults import (
    SITES,
    FaultInjector,
    FaultPlan,
    FaultRule,
    InjectedFault,
)
from repro.core.retry import (
    RetryExhausted,
    RetryPolicy,
    StageTimeout,
    call_with_retry,
)
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches

FANOUTS = (3, 2)
BATCH = 64
KW = dict(total_cache_bytes=200_000, n_presample=2)
STREAM_SEEDS = [100, 101, 102]


def _shared_engine(dataset, policy="dci"):
    eng = GNNInferenceEngine(dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare(policy, stream_seeds=STREAM_SEEDS, **KW)
    return eng


def _queues(dataset, n=2, batches=3):
    return make_stream_batches(
        dataset, num_streams=n, batches_per_stream=batches, batch_size=BATCH, seed=7
    )


def _fast_retry(**kw):
    """A retry config whose sleeps are microscopic (tests never wait)."""
    base = dict(fault_policy="retry", retry_attempts=3, retry_backoff_ms=0.01)
    base.update(kw)
    return base


def _serve(engine, queues, *, cfg=None, injector=None, refresh=None, **run_kw):
    srv = MultiStreamServer(engine, config=cfg, injector=injector, refresh=refresh)
    for sid, q in enumerate(queues):
        srv.add_stream(q, seed=STREAM_SEEDS[sid], collect_outputs=True)
    rep = srv.run(**run_kw)
    outs = [[np.asarray(o) for o in s.runtime.outputs] for s in srv.streams]
    return srv, rep, outs


def _assert_same_serve(rep_a, outs_a, rep_b, outs_b):
    assert (rep_a.feat_hits, rep_a.feat_lookups) == (rep_b.feat_hits, rep_b.feat_lookups)
    assert (rep_a.adj_hits, rep_a.adj_lookups) == (rep_b.adj_hits, rep_b.adj_lookups)
    for a_list, b_list in zip(outs_a, outs_b):
        assert len(a_list) == len(b_list)
        for a, b in zip(a_list, b_list):
            np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------ plan (unit)


def test_plan_json_round_trip(tmp_path):
    plan = FaultPlan(
        seed=13,
        rules=(
            FaultRule("host_fetch", probability=0.25, start_after=4, max_faults=7),
            FaultRule("prefetch", kind="delay", latency_s=0.002, burst_period=8, burst_length=2),
            FaultRule("shard_exchange", shard=1, down_for=3),
        ),
    )
    assert FaultPlan.from_dict(plan.to_dict()) == plan
    path = tmp_path / "plan.json"
    plan.save(str(path))
    assert FaultPlan.load(str(path)) == plan
    assert plan.sites == ("host_fetch", "prefetch", "shard_exchange")
    assert plan.rule_for("host_fetch").max_faults == 7
    assert plan.rule_for("refresh_fill") is None


def test_plan_and_rule_validation():
    with pytest.raises(ValueError):
        FaultRule("not-a-site")
    with pytest.raises(ValueError):
        FaultRule("host_fetch", kind="explode")
    with pytest.raises(ValueError):
        FaultRule("host_fetch", probability=1.5)
    with pytest.raises(ValueError):
        FaultRule("host_fetch", burst_period=4)  # length missing
    with pytest.raises(ValueError):
        FaultRule("host_fetch", burst_period=2, burst_length=5)
    with pytest.raises(ValueError):  # duplicate site
        FaultPlan(rules=(FaultRule("host_fetch"), FaultRule("host_fetch")))
    with pytest.raises(ValueError):  # unknown JSON field
        FaultRule.from_dict({"site": "host_fetch", "blast_radius": 3})


def test_injector_schedule_is_deterministic_and_capped():
    plan = FaultPlan(
        seed=5,
        rules=(FaultRule("host_fetch", probability=0.4, start_after=3, max_faults=4),),
    )

    def fault_calls():
        inj = FaultInjector(plan)
        hits = []
        for call in range(60):
            try:
                inj.check("host_fetch")
            except InjectedFault as err:
                assert err.site == "host_fetch" and err.call == call
                hits.append(call)
        return hits, inj

    hits_a, inj = fault_calls()
    hits_b, _ = fault_calls()
    assert hits_a == hits_b  # pure function of (plan, call index)
    assert len(hits_a) == 4 and min(hits_a) >= 3  # armed after start_after, capped
    assert inj.counts() == {"host_fetch": {"calls": 60, "faults": 4}}
    assert inj.active("host_fetch") and not inj.active("adj_fetch")
    # unlisted sites count calls but never fault
    inj.check("adj_fetch")
    assert inj.counts()["adj_fetch"] == {"calls": 1, "faults": 0}
    with pytest.raises(ValueError):
        inj.check("not-a-site")


def test_injector_draws_do_not_depend_on_window_phase():
    """The k-th call's probability draw is consumed armed or not, so the
    fault decision at call k is invariant to start_after: a late-armed
    rule faults at exactly the early rule's post-arming fault calls."""

    def hits(start_after):
        plan = FaultPlan(
            seed=11, rules=(FaultRule("host_fetch", probability=0.3, start_after=start_after),)
        )
        inj = FaultInjector(plan)
        out = []
        for call in range(80):
            try:
                inj.check("host_fetch")
            except InjectedFault:
                out.append(call)
        return out

    early, late = hits(0), hits(25)
    assert late == [c for c in early if c >= 25]


def test_injector_burst_and_delay_kinds():
    sleeps = []
    plan = FaultPlan(
        rules=(
            FaultRule(
                "prefetch", kind="delay", latency_s=0.5, burst_period=4, burst_length=2
            ),
        )
    )
    inj = FaultInjector(plan, sleep=sleeps.append)
    for _ in range(8):
        inj.check("prefetch")  # delay kind never raises
    # armed calls are the first 2 of every 4-call window: 0,1,4,5
    assert sleeps == [0.5] * 4
    assert inj.delays["prefetch"] == 4
    assert inj.counts()["prefetch"] == {"calls": 8, "faults": 4}


# ------------------------------------------------------------ retry (unit)


def test_backoff_delays_deterministic_and_bounded():
    pol = RetryPolicy(max_attempts=5, backoff_s=1e-3, max_backoff_s=4e-3, jitter=0.5)
    d1 = pol.backoff_delays(("host_fetch", 3))
    d2 = pol.backoff_delays(("host_fetch", 3))
    assert d1 == d2 and len(d1) == 4
    assert all(0.0 <= d <= pol.max_backoff_s * (1 + pol.jitter) for d in d1)
    assert sum(d1) <= pol.total_backoff_bound()
    # distinct keys get distinct jitter schedules
    others = [pol.backoff_delays(("host_fetch", k)) for k in range(8)]
    assert any(d != d1 for d in others)


def test_call_with_retry_recovers_then_exhausts():
    pol = RetryPolicy(max_attempts=3, backoff_s=0.0, jitter=0.0)
    attempts, retries = [], []

    def flaky():
        attempts.append(1)
        if len(attempts) < 3:
            raise InjectedFault("host_fetch", len(attempts))
        return 42

    got = call_with_retry(
        flaky,
        policy=pol,
        retryable=(InjectedFault,),
        on_retry=lambda a, d, e: retries.append((a, type(e).__name__)),
        sleep=lambda _s: None,
    )
    assert got == 42 and len(attempts) == 3
    assert retries == [(1, "InjectedFault"), (2, "InjectedFault")]

    def always():
        raise InjectedFault("host_fetch", 0)

    with pytest.raises(RetryExhausted) as ei:
        call_with_retry(always, policy=pol, retryable=(InjectedFault,), sleep=lambda _s: None)
    assert ei.value.attempts == 3 and isinstance(ei.value.last, InjectedFault)


def test_call_with_retry_propagates_non_retryable_immediately():
    calls = []

    def bug():
        calls.append(1)
        raise ValueError("real bug, not a fault")

    with pytest.raises(ValueError):
        call_with_retry(
            bug,
            policy=RetryPolicy(max_attempts=4, backoff_s=0.0, jitter=0.0),
            retryable=(InjectedFault,),
            sleep=lambda _s: None,
        )
    assert len(calls) == 1  # no retry budget spent on real bugs


def test_per_attempt_timeout_discards_late_success():
    ticks = iter(range(100))
    pol = RetryPolicy(max_attempts=2, backoff_s=0.0, jitter=0.0, timeout_s=0.5)
    with pytest.raises(RetryExhausted) as ei:
        call_with_retry(
            lambda: "late",  # every attempt "succeeds" after 1 fake second
            policy=pol,
            retryable=(InjectedFault,),
            sleep=lambda _s: None,
            clock=lambda: float(next(ticks)),
        )
    assert isinstance(ei.value.last, StageTimeout)
    assert ei.value.last.timeout_s == 0.5
    # without a timeout the same thunk returns on attempt 1
    assert call_with_retry(lambda: "ok", policy=RetryPolicy(), sleep=lambda _s: None) == "ok"


# ----------------------------------------------------- properties (hypothesis)


@settings(max_examples=50, deadline=None)
@given(
    max_attempts=st.integers(1, 6),
    backoff_ms=st.floats(0.0, 10.0, allow_nan=False),
    multiplier=st.floats(1.0, 3.0, allow_nan=False),
    max_backoff_ms=st.floats(0.0, 20.0, allow_nan=False),
    jitter=st.floats(0.0, 1.0, allow_nan=False),
    seed=st.integers(0, 2**31),
    key=st.integers(0, 10_000),
)
def test_property_backoff_schedule_bounds(
    max_attempts, backoff_ms, multiplier, max_backoff_ms, jitter, seed, key
):
    """Every jittered schedule is deterministic per key, per-delay bounded
    by max_backoff * (1 + jitter), and summed below the closed-form bound."""
    pol = RetryPolicy(
        max_attempts=max_attempts,
        backoff_s=backoff_ms * 1e-3,
        backoff_multiplier=multiplier,
        max_backoff_s=max_backoff_ms * 1e-3,
        jitter=jitter,
        seed=seed,
    )
    delays = pol.backoff_delays(key)
    assert delays == pol.backoff_delays(key)
    assert len(delays) == max_attempts - 1
    cap = pol.max_backoff_s * (1.0 + jitter) + 1e-12
    assert all(0.0 <= d <= cap for d in delays)
    assert sum(delays) <= pol.total_backoff_bound() + 1e-12


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    probability=st.floats(0.0, 1.0, allow_nan=False),
    start_after=st.integers(0, 20),
    max_faults=st.one_of(st.none(), st.integers(0, 10)),
    calls=st.integers(0, 60),
    site=st.sampled_from(SITES),
)
def test_property_injector_replay_is_pure(
    seed, probability, start_after, max_faults, calls, site
):
    """Two injectors over the same plan agree on every fault decision, the
    faults respect the armed window, and the cap is never exceeded."""
    plan = FaultPlan(
        seed=seed,
        rules=(
            FaultRule(
                site, probability=probability, start_after=start_after, max_faults=max_faults
            ),
        ),
    )

    def run():
        inj = FaultInjector(plan)
        out = []
        for call in range(calls):
            try:
                inj.check(site)
            except InjectedFault:
                out.append(call)
        return out

    hits_a, hits_b = run(), run()
    assert hits_a == hits_b
    assert all(c >= start_after for c in hits_a)
    if max_faults is not None:
        assert len(hits_a) <= max_faults
    if probability == 1.0 and max_faults is None:
        assert hits_a == list(range(start_after, calls))


@settings(max_examples=5, deadline=None)
@given(failed_attempts=st.integers(1, 3))
def test_property_refresh_rollback_is_byte_identical(small_dataset, failed_attempts):
    """However many refresh attempts die mid-apply, the cache stays on the
    old epoch's exact objects (JAX arrays are immutable, so object
    identity IS byte identity) and a later clean refresh still lands."""
    eng = _shared_engine(small_dataset)
    caches = eng.pipeline.caches
    stats = eng.pipeline.presample
    before = (caches.dgraph, caches.store, caches.allocation, caches.epoch)
    plan = FaultPlan(rules=(FaultRule("refresh_fill", max_faults=failed_attempts),))
    inj = FaultInjector(plan)
    for _ in range(failed_attempts):
        with pytest.raises(InjectedFault):
            caches.refresh(
                allocation=caches.allocation,
                node_counts=stats.node_counts,
                edge_counts=stats.edge_counts,
                injector=inj,
            )
        assert (caches.dgraph, caches.store, caches.allocation, caches.epoch) == before
    # the injector's cap is spent: the next refresh commits
    delta = caches.refresh(
        allocation=caches.allocation,
        node_counts=stats.node_counts,
        edge_counts=stats.edge_counts,
        injector=inj,
    )
    assert caches.epoch == before[3] + 1 and delta.epoch == caches.epoch


# ------------------------------------------------- serving: zero-diff baseline


@pytest.mark.parametrize("dedup", [False, True])
@pytest.mark.parametrize("prefetch", [False, True])
def test_fault_knobs_without_faults_are_bit_identical(small_dataset, dedup, prefetch):
    """Retry policy armed, degraded mode on, an injector with an EMPTY
    plan installed — and the serve is still bit-for-bit the plain one:
    no RNG draws, no accounting drift, nothing on any knob combination."""
    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    engine_cfg = EngineConfig(pipeline_depth=2, dedup=dedup, prefetch=prefetch)
    _, rb, ob = _serve(eng, queues, cfg=ServeConfig(engine=engine_cfg))
    cfg = ServeConfig(
        engine=engine_cfg, **_fast_retry(degraded_mode=True, retry_timeout_ms=10_000.0)
    )
    srv, rf, of = _serve(eng, queues, cfg=cfg, injector=FaultInjector(FaultPlan()))
    _assert_same_serve(rb, ob, rf, of)
    assert rf.availability == 1.0 and rf.requests_retried == 0
    assert rf.requests_degraded == 0
    assert all(v["faults"] == 0 for v in rf.faults.values())  # calls charged, none fault
    assert srv.injector is not None and not srv.injector.enabled


# ---------------------------------------------------- serving: fault policies


def test_retry_recovers_transient_faults_bit_identically(small_dataset):
    """A bounded burst of miss-path faults under the retry policy: every
    batch completes and outputs + hit accounting equal the fault-free run
    (site ops are idempotent, so a retried gather is THE gather)."""
    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    cfg0 = ServeConfig(engine=EngineConfig(pipeline_depth=2))
    _, rb, ob = _serve(eng, queues, cfg=cfg0)
    plan = FaultPlan(
        seed=3,
        rules=(
            FaultRule("host_fetch", start_after=1, max_faults=2),
            FaultRule("adj_fetch", start_after=2, max_faults=1),
        ),
    )
    cfg = cfg0.replace(**_fast_retry())
    srv, rf, of = _serve(eng, queues, cfg=cfg, injector=FaultInjector(plan))
    _assert_same_serve(rb, ob, rf, of)
    assert rf.availability == 1.0 and rf.requests_shed == 0
    assert rf.requests_retried > 0
    assert rf.faults["host_fetch"]["faults"] == 2
    assert rf.faults["adj_fetch"]["faults"] == 1
    assert sum(s.runtime.stage_retries for s in srv.streams) >= 3
    assert rf.summary()["fault_policy"] == "retry"


def test_degraded_mode_serves_cache_only_when_miss_path_is_down(small_dataset):
    """host_fetch down for the whole run: with degraded mode the serve
    completes everything from cache-hit rows (miss rows zeroed), marks
    each affected request, and availability stays 1.0."""
    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    plan = FaultPlan(rules=(FaultRule("host_fetch"),))  # always down
    cfg = ServeConfig(
        engine=EngineConfig(pipeline_depth=2),
        **_fast_retry(retry_attempts=2, degraded_mode=True),
    )
    srv, rep, outs = _serve(eng, queues, cfg=cfg, injector=FaultInjector(plan))
    offered = sum(len(q) for q in _queues(small_dataset))
    assert rep.total_batches == offered and rep.availability == 1.0
    assert rep.requests_degraded == offered and rep.requests_shed == 0
    assert all(s.batches_degraded == len(outs[i]) for i, s in enumerate(srv.streams))
    assert sum(s.runtime.degraded_batches for s in srv.streams) == offered
    # hit accounting is untouched: degraded gathers count the same lookups
    assert rep.feat_lookups > 0 and rep.feat_hits > 0


def test_prefetch_faults_skip_staging_without_degrading(small_dataset):
    """A dead prefetch stage is invisible: staging is optional by design,
    so the serve falls back to gather-time fetches bit-identically and no
    request is marked degraded."""
    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    cfg0 = ServeConfig(engine=EngineConfig(pipeline_depth=2, prefetch=True))
    _, rb, ob = _serve(eng, queues, cfg=cfg0)
    plan = FaultPlan(rules=(FaultRule("prefetch"),))
    cfg = cfg0.replace(**_fast_retry(retry_attempts=2, degraded_mode=True))
    _, rf, of = _serve(eng, queues, cfg=cfg, injector=FaultInjector(plan))
    _assert_same_serve(rb, ob, rf, of)
    assert rf.requests_degraded == 0 and rf.availability == 1.0
    assert sum(s.prefetched_rows for s in rf.streams) == 0  # nothing was staged


def test_fail_fast_drains_and_records_the_error(small_dataset):
    """fault_policy="fail": the first unrecovered fault aborts the serve.
    raise_on_error=True surfaces it; raise_on_error=False records it on
    the report, and completed + unserved still covers the whole offer."""
    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    plan = FaultPlan(rules=(FaultRule("host_fetch", start_after=2),))
    cfg = ServeConfig(engine=EngineConfig(pipeline_depth=2))
    with pytest.raises(InjectedFault):
        _serve(eng, queues, cfg=cfg, injector=FaultInjector(plan))
    srv, rep, _ = _serve(
        eng, queues, cfg=cfg, injector=FaultInjector(plan), raise_on_error=False
    )
    offered = sum(len(q) for q in queues)
    assert rep.error is not None and "host_fetch" in rep.error
    assert rep.fault_policy == "fail"
    assert rep.total_batches + rep.unserved + rep.requests_shed == offered
    assert rep.availability < 1.0
    assert rep.summary()["error"] == rep.error


def test_shed_policy_sheds_exactly_the_failing_request(small_dataset):
    """fault_policy="shed": a request whose retries exhaust is dropped —
    exactly once, exactly that one — and the serve keeps going; every
    offered request is either completed or shed, never both or neither."""
    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset, n=2, batches=3)
    # 2 faults with a 2-attempt budget: one batch exhausts and sheds, the
    # cap is then spent so every later batch completes cleanly.
    plan = FaultPlan(rules=(FaultRule("host_fetch", start_after=1, max_faults=2),))
    cfg = ServeConfig(
        engine=EngineConfig(pipeline_depth=2),
        **_fast_retry(fault_policy="shed", retry_attempts=2),
    )
    srv, rep, outs = _serve(eng, queues, cfg=cfg, injector=FaultInjector(plan))
    offered = sum(len(q) for q in queues)
    assert rep.requests_shed == 1
    assert rep.total_batches == offered - 1
    assert rep.unserved == 0
    assert rep.total_batches + rep.requests_shed == offered  # shed XOR completed
    assert rep.availability == pytest.approx((offered - 1) / offered)
    assert sum(s.batches_shed for s in srv.streams) == 1
    assert sum(len(o) for o in outs) == offered - 1
    assert rep.summary()["requests_shed"] == 1


# ------------------------------------------------------------ refresh rollback


def test_refresh_manager_records_rollback_and_serving_continues(small_dataset):
    """A refresh_fill fault mid-serve rolls the epoch back and serving
    finishes on the stale epoch: availability 1.0, the failure recorded,
    and outputs bit-identical to the refresh-free serve (refreshes move
    bytes, never values — a rolled-back one moves nothing at all)."""
    from repro.runtime.cache_refresh import RefreshConfig

    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    cfg0 = ServeConfig(engine=EngineConfig(pipeline_depth=2))
    _, rb, ob = _serve(eng, queues, cfg=cfg0)
    plan = FaultPlan(rules=(FaultRule("refresh_fill", max_faults=1),))
    refresh = RefreshConfig(mode="interval", interval_batches=2)
    srv, rf, of = _serve(
        eng, queues, cfg=cfg0.replace(**_fast_retry()), injector=FaultInjector(plan), refresh=refresh
    )
    assert len(srv.refresh_manager.failures) == 1
    failure = srv.refresh_manager.failures[0]
    assert failure.epoch == 0 and "InjectedFault" in failure.error
    assert rf.availability == 1.0
    # later refreshes (cap spent) commit: the epoch moved past the rollback
    assert eng.pipeline.caches.epoch >= 1
    # outputs (not hit counters — committed refreshes re-rank the caches)
    # stay bit-identical to the refresh-free serve
    for a_list, b_list in zip(ob, of):
        for a, b in zip(a_list, b_list):
            np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------- shard failover


def test_shard_failover_serves_lost_range_from_host_and_rejoins(small_dataset):
    """Losing a shard mid-serve routes its id range to the host mirror —
    outputs and hit accounting stay bit-identical to the healthy sharded
    serve (the mirror holds the same rows), per-shard hits still tile the
    global counters, and the shard rejoins after its down_for window."""
    from repro.runtime.sharded_serve import ShardedServer

    eng = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    cfg = ServeConfig(engine=EngineConfig(pipeline_depth=2))

    def serve_sharded(injector):
        srv = ShardedServer(eng, config=cfg, num_shards=2, injector=injector)
        for sid, q in enumerate(queues):
            srv.add_stream(q, seed=STREAM_SEEDS[sid], collect_outputs=True)
        rep = srv.run()
        outs = [[np.asarray(o) for o in s.runtime.outputs] for s in srv.streams]
        return srv, rep, outs

    _, rb, ob = serve_sharded(None)
    plan = FaultPlan(
        rules=(FaultRule("shard_exchange", start_after=2, max_faults=1, shard=1, down_for=2),)
    )
    srv, rf, of = serve_sharded(FaultInjector(plan))
    _assert_same_serve(rb, ob, rf, of)
    assert rf.failovers == [{"shard": 1, "down_for": 2, "call": 2}]
    assert srv.sharded.down == {}  # rejoined before the serve ended
    assert [p.get("failed_over", False) for p in rf.shards] == [False, True]
    per = rf.shards
    assert sum(p["feat_hits"] for p in per) == rf.feat_hits
    assert sum(p["feat_lookups"] for p in per) == rf.feat_lookups
    assert rf.availability == 1.0


# ------------------------------------------------------- single-stream engine


def test_engine_run_accepts_live_fault_handles(small_dataset):
    """The single-stream path (infer_gnn's else-branch): injector +
    retry policy passed straight to engine.run, recovery bit-identical."""
    eng = _shared_engine(small_dataset)
    batches = _queues(small_dataset, n=1, batches=4)[0]
    rb = eng.run(batches=list(batches), pipeline_depth=1, collect_outputs=True)
    ob = [np.asarray(o) for o in eng.last_outputs]
    plan = FaultPlan(rules=(FaultRule("host_fetch", start_after=1, max_faults=2),))
    rf = eng.run(
        batches=list(batches),
        pipeline_depth=1,
        collect_outputs=True,
        injector=FaultInjector(plan),
        retry_policy=RetryPolicy(max_attempts=3, backoff_s=1e-5, jitter=0.0),
    )
    assert (rb.feat_hits, rb.feat_lookups) == (rf.feat_hits, rf.feat_lookups)
    assert (rb.adj_hits, rb.adj_lookups) == (rf.adj_hits, rf.adj_lookups)
    for a, b in zip(ob, eng.last_outputs):
        np.testing.assert_array_equal(a, b)
