"""Shared hypothesis import guard for the property-test modules.

``hypothesis`` is a dev-only dependency (requirements-dev.txt).  Test
modules import the property-testing API through this shim::

    from _hypothesis_compat import given, settings, st

When hypothesis is installed, these are the real thing.  When it is not,
``given`` turns each property test into a cleanly *skipped* test (instead
of the whole module erroring at collection), ``settings`` is a no-op
decorator, and ``st`` is a stub whose strategy constructors are inert —
plain tests in the same module keep running either way.

``require()`` is available for modules that are property-based end to end
and prefer one module-level skip.
"""

from __future__ import annotations

import pytest

__all__ = [
    "HAVE_HYPOTHESIS",
    "HealthCheck",
    "assume",
    "given",
    "require",
    "settings",
    "st",
]

SKIP_REASON = "hypothesis not installed (pip install -r requirements-dev.txt)"

try:
    from hypothesis import HealthCheck, assume, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised when dep absent
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs any strategy construction (st.lists(st.floats(...), ...))."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_a, **_k):
            return self

    st = _StrategyStub()
    HealthCheck = _StrategyStub()

    def assume(*_a, **_k):
        return True

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            # Zero-arg replacement: pytest must not see the property's
            # parameters, or it would demand fixtures for them.
            def skipper():
                pytest.skip(SKIP_REASON)

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco


def require(*, module_level: bool = True) -> None:
    """Skip the calling test module (or test) when hypothesis is absent."""
    if not HAVE_HYPOTHESIS:
        pytest.skip(SKIP_REASON, allow_module_level=module_level)
