"""Multi-stream serving over a shared DualCache (runtime/gnn_serve.py).

The load-bearing guarantees:

  * per-stream serial equivalence — N interleaved streams produce, per
    stream, bit-identical logits and hit counters to running that stream's
    batches alone through the single-stream engine (per-stream RNG, reuse
    state, and the immutability of the shared caches);
  * shared-cache accounting — the aggregate report is exactly the sum of
    the per-stream reports;
  * admission — round-robin over streams with work, per-stream in-flight
    cap (backpressure), and no starvation under uneven queues.
"""

import numpy as np
import pytest

from repro.core.presample import merge_stats, run_presampling
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches

FANOUTS = (3, 2)
BATCH = 64
KW = dict(total_cache_bytes=200_000, n_presample=2)
STREAM_SEEDS = [100, 101, 102]


def _shared_engine(dataset, policy="dci"):
    eng = GNNInferenceEngine(dataset, fanouts=FANOUTS, batch_size=BATCH)
    eng.prepare(policy, stream_seeds=STREAM_SEEDS, **KW)
    return eng


def _queues(dataset, n=3, batches=3):
    return make_stream_batches(
        dataset, num_streams=n, batches_per_stream=batches, batch_size=BATCH, seed=7
    )


def _reference_run(engine, queue, seed):
    """The stream's batches alone, serially, same params + shared pipeline."""
    ref = GNNInferenceEngine(
        engine.dataset, fanouts=FANOUTS, batch_size=BATCH, seed=seed, params=engine.params
    )
    ref.pipeline = engine.pipeline
    rep = ref.run(batches=list(queue), pipeline_depth=1, collect_outputs=True)
    return rep, ref.last_outputs


# --------------------------------------------------------------- equivalence


@pytest.mark.parametrize("policy", ["dci", "rain", "dgl"])
@pytest.mark.parametrize("depth", [1, 3])
def test_per_stream_serial_equivalence(small_dataset, policy, depth):
    """Interleaving N streams changes nothing a stream can observe — RAIN's
    cross-batch reuse included, because reuse state is per-stream."""
    engine = _shared_engine(small_dataset, policy)
    queues = _queues(small_dataset)
    server = MultiStreamServer(engine, depth=depth)
    states = [
        server.add_stream(q, seed=STREAM_SEEDS[i], collect_outputs=True)
        for i, q in enumerate(queues)
    ]
    report = server.run()
    assert report.num_streams == len(queues)
    for i, q in enumerate(queues):
        ref_rep, ref_out = _reference_run(engine, q, STREAM_SEEDS[i])
        rt = states[i].runtime
        assert (ref_rep.adj_hits, ref_rep.adj_lookups) == (rt.adj_hits, rt.adj_lookups)
        assert (ref_rep.feat_hits, ref_rep.feat_lookups) == (rt.feat_hits, rt.feat_lookups)
        assert len(ref_out) == len(rt.outputs) == len(q)
        for a, b in zip(ref_out, rt.outputs):
            np.testing.assert_array_equal(a, b)


def test_serve_prefetch_bit_identical_and_capped(small_dataset):
    """Prefetch on the shared schedule: outputs and hit accounting are
    bit-identical to the prefetch-off serve over the SAME prepared
    pipeline, prefetched rows equal the aggregate misses, and per-stream
    staging respects the backpressure cap (staged buffers only exist
    inside admitted in-flight batches)."""
    engine = _shared_engine(small_dataset)
    queues = _queues(small_dataset)

    def serve(prefetch):
        server = MultiStreamServer(
            engine, depth=2, max_inflight_per_stream=2, prefetch=prefetch
        )
        states = [
            server.add_stream(q, seed=STREAM_SEEDS[i], collect_outputs=True)
            for i, q in enumerate(queues)
        ]
        return server.run(), states

    rep_off, _ = serve(False)
    rep_on, states = serve(True)
    assert rep_on.prefetch and not rep_off.prefetch
    assert (rep_off.feat_hits, rep_off.feat_lookups) == (rep_on.feat_hits, rep_on.feat_lookups)
    assert (rep_off.adj_hits, rep_off.adj_lookups) == (rep_on.adj_hits, rep_on.adj_lookups)
    for s_off, s_on in zip(rep_off.streams, rep_on.streams):
        assert (s_off.feat_hits, s_off.adj_hits) == (s_on.feat_hits, s_on.adj_hits)
    total_prefetched = sum(s.prefetched_rows for s in rep_on.streams)
    assert total_prefetched == rep_on.feat_lookups - rep_on.feat_hits
    for st in states:
        assert st.max_inflight_seen <= 2  # staged buffers bounded by the cap
    # and stream outputs match a prefetch-off solo reference run exactly
    for i, q in enumerate(queues):
        _, ref_out = _reference_run(engine, q, STREAM_SEEDS[i])
        for a, b in zip(ref_out, states[i].runtime.outputs):
            np.testing.assert_array_equal(a, b)


def test_single_stream_server_matches_engine(small_dataset):
    engine = _shared_engine(small_dataset)
    (queue,) = _queues(small_dataset, n=1, batches=4)
    server = MultiStreamServer(engine, depth=1)
    server.add_stream(queue, seed=STREAM_SEEDS[0], collect_outputs=True)
    report = server.run()
    ref_rep, ref_out = _reference_run(engine, queue, STREAM_SEEDS[0])
    s = report.streams[0]
    assert (s.adj_hits, s.feat_hits) == (ref_rep.adj_hits, ref_rep.feat_hits)
    assert report.total_batches == ref_rep.num_batches
    for a, b in zip(ref_out, server.streams[0].runtime.outputs):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------- accounting


def test_aggregate_accounting_sums_streams(small_dataset):
    engine = _shared_engine(small_dataset)
    queues = _queues(small_dataset)
    server = MultiStreamServer(engine, depth=2)
    for i, q in enumerate(queues):
        server.add_stream(q, seed=STREAM_SEEDS[i])
    rep = server.run()
    assert rep.adj_hits == sum(s.adj_hits for s in rep.streams)
    assert rep.adj_lookups == sum(s.adj_lookups for s in rep.streams)
    assert rep.feat_hits == sum(s.feat_hits for s in rep.streams)
    assert rep.feat_lookups == sum(s.feat_lookups for s in rep.streams)
    assert rep.total_batches == sum(len(q) for q in queues)
    assert rep.total_seeds == rep.total_batches * BATCH
    assert 0 < rep.feat_hit_rate <= 1
    assert rep.throughput_seeds_per_s > 0
    assert rep.modeled_transfer_seconds() > 0
    summary = rep.summary()
    assert summary["streams"] == 3 and len(summary["per_stream"]) == 3


def test_per_stream_clocks_and_latencies(small_dataset):
    engine = _shared_engine(small_dataset)
    queues = _queues(small_dataset, batches=2)
    server = MultiStreamServer(engine, depth=2)
    for i, q in enumerate(queues):
        server.add_stream(q, seed=STREAM_SEEDS[i])
    rep = server.run()
    for s in rep.streams:
        assert s.num_batches == 2
        # every stage booked time on the STREAM's own clock
        assert s.sample_seconds > 0 and s.feature_seconds > 0 and s.compute_seconds > 0
        assert s.mean_latency_s > 0 and s.max_latency_s >= s.mean_latency_s


# ----------------------------------------------------------------- admission


def test_round_robin_admission_with_backpressure(small_dataset):
    """Uneven queues (6/2/1), cap 1: round-robin while everyone has work;
    short streams finish without ever waiting behind the deep queue; the
    lone remaining stream is allowed past its cap (documented fallback —
    admission must make progress) but only once others drained."""
    engine = _shared_engine(small_dataset)
    all_batches = _queues(small_dataset, n=1, batches=9)[0]
    queues = [all_batches[:6], all_batches[6:8], all_batches[8:9]]
    server = MultiStreamServer(engine, depth=2, max_inflight_per_stream=1)
    for i, q in enumerate(queues):
        server.add_stream(q, seed=STREAM_SEEDS[i])
    rep = server.run()
    assert server.admission_log == [
        (0, 0), (1, 0), (2, 0), (0, 1), (1, 1), (0, 2), (0, 3), (0, 4), (0, 5),
    ]
    # every stream fully served, in its own batch order
    assert [s.num_batches for s in rep.streams] == [6, 2, 1]
    # cap respected whenever another stream could be picked instead
    assert server.streams[1].max_inflight_seen == 1
    assert server.streams[2].max_inflight_seen == 1
    assert server.streams[0].max_inflight_seen == 2  # solo-tail fallback


def test_no_starvation_first_round_covers_every_stream(small_dataset):
    engine = _shared_engine(small_dataset)
    queues = _queues(small_dataset, n=3, batches=2)
    server = MultiStreamServer(engine, depth=3)
    for i, q in enumerate(queues):
        server.add_stream(q, seed=STREAM_SEEDS[i])
    server.run()
    first_round = {sid for sid, _ in server.admission_log[:3]}
    assert first_round == {0, 1, 2}


# ------------------------------------------------------------ shared presample


def test_merge_stats_sums_counts_and_concats_times(small_dataset):
    per_stream = [
        run_presampling(
            small_dataset, fanouts=FANOUTS, batch_size=BATCH, n_batches=1, seed=s
        )
        for s in STREAM_SEEDS
    ]
    merged = merge_stats(per_stream)
    np.testing.assert_array_equal(
        merged.node_counts, np.sum([s.node_counts for s in per_stream], axis=0)
    )
    np.testing.assert_array_equal(
        merged.edge_counts, np.sum([s.edge_counts for s in per_stream], axis=0)
    )
    assert merged.n_batches == 3
    assert len(merged.sample_times) == len(merged.feature_times) == 3
    assert merged.peak_workload_bytes == max(s.peak_workload_bytes for s in per_stream)
    with pytest.raises(ValueError):
        merge_stats([])


def test_shared_prepare_splits_presample_budget(small_dataset):
    eng = GNNInferenceEngine(small_dataset, fanouts=FANOUTS, batch_size=BATCH)
    pipe = eng.prepare(
        "dci", total_cache_bytes=200_000, n_presample=8, stream_seeds=STREAM_SEEDS
    )
    # total presample budget split across streams EXACTLY (8 = 3 + 3 + 2),
    # not multiplied by them and not truncated by integer division
    assert pipe.presample.n_batches == 8
    assert pipe.caches.allocation.total_bytes == 200_000


# -------------------------------------------------------------------- errors


def test_server_rejects_bad_config(small_dataset):
    engine = _shared_engine(small_dataset)
    with pytest.raises(ValueError):
        MultiStreamServer(engine, depth=0)
    with pytest.raises(ValueError):
        MultiStreamServer(engine, depth=2, max_inflight_per_stream=0)
    with pytest.raises(RuntimeError):
        MultiStreamServer(engine, depth=1).run()
    unprepared = GNNInferenceEngine(small_dataset, fanouts=FANOUTS, batch_size=BATCH)
    with pytest.raises(RuntimeError):
        MultiStreamServer(unprepared)


def test_make_stream_batches_shapes_and_determinism(small_dataset):
    q1 = make_stream_batches(
        small_dataset, num_streams=2, batches_per_stream=3, batch_size=32, seed=5
    )
    q2 = make_stream_batches(
        small_dataset, num_streams=2, batches_per_stream=3, batch_size=32, seed=5
    )
    assert len(q1) == 2 and all(len(q) == 3 for q in q1)
    assert all(b.shape == (32,) for q in q1 for b in q)
    for a, b in zip(q1[0], q2[0]):
        np.testing.assert_array_equal(a, b)
    # different streams draw different orderings of the same test set
    assert not all(np.array_equal(a, b) for a, b in zip(q1[0], q1[1]))
