"""Per-architecture smoke tests (brief requirement: reduced variant, one
forward/train step on CPU, shape + finiteness assertions) plus
prefill↔decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models.lm import model as M

B, S = 2, 32


def make_batch(cfg, key, s=S, labels=True):
    batch = {}
    if cfg.encoder_layers > 0:
        batch["src_embeds"] = jax.random.normal(key, (B, s, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(key, (B, s), 0, cfg.vocab)
    elif cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(key, (B, s, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (B, s), 0, cfg.vocab)
    if labels:
        batch["labels"] = jax.random.randint(key, (B, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced_constraints(arch):
    cfg = get_smoke(arch)
    assert cfg.n_layers <= 2 + cfg.pattern_period  # reduced depth
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    # same family as the full config
    assert cfg.family == get_config(arch).family
    assert cfg.block_pattern[0] in ("attn", "local", "mamba", "rwkv")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = make_batch(cfg, key)
    loss, grads = jax.value_and_grad(lambda p: M.train_loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    batch = make_batch(cfg, key, labels=False)
    logits, caches = M.prefill(params, batch, cfg, cache_size=S + 4)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits[:, : cfg.vocab])).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert int(tok.max()) < cfg.vocab  # padded vocab rows masked out
    logits2, caches2 = M.decode_step(params, tok, caches, jnp.int32(S), cfg)
    assert logits2.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2[:, : cfg.vocab])).all()
    # cache trees keep their structure
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize(
    "arch", ["granite-3-8b", "gemma2-27b", "deepseek-v2-236b", "jamba-v0.1-52b", "rwkv6-3b"]
)
def test_prefill_decode_consistency_fp32(arch):
    """prefill(N+1) last logits == prefill(N) + decode (exact in fp32)."""
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )  # avoid prefill-only capacity drops
    key = jax.random.PRNGKey(0)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    s = 17
    toks = jax.random.randint(key, (B, s + 1), 0, cfg.vocab)
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :s]}
    lf, _ = M.prefill(params, batch_full, cfg, cache_size=s + 8)
    _, caches = M.prefill(params, batch_pre, cfg, cache_size=s + 8)
    ld, _ = M.decode_step(params, toks[:, s : s + 1], caches, jnp.int32(s), cfg)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(ld), rtol=2e-4, atol=2e-4)


def test_mla_absorb_matches_naive():
    cfg = dataclasses.replace(get_smoke("deepseek-v2-236b"), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(key, (B, 9), 0, cfg.vocab)
    _, caches = M.prefill(params, {"tokens": toks}, cfg, cache_size=16)
    nxt = toks[:, :1]
    l_naive, _ = M.decode_step(params, nxt, caches, jnp.int32(9), cfg, mla_absorb=False)
    l_abs, _ = M.decode_step(params, nxt, caches, jnp.int32(9), cfg, mla_absorb=True)
    np.testing.assert_allclose(np.asarray(l_naive), np.asarray(l_abs), rtol=2e-3, atol=2e-3)


def test_ring_buffer_window_decode():
    """With a ring cache of size W, decoding past W stays finite and the
    window mask only sees the last W tokens."""
    cfg = dataclasses.replace(get_smoke("granite-3-8b"), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    w = 8
    toks = jax.random.randint(key, (B, 30), 0, cfg.vocab)
    _, caches = M.prefill(
        params, {"tokens": toks[:, :16]}, cfg, cache_size=w, long_mode=True
    )
    logits = None
    for t in range(16, 30):
        logits, caches = M.decode_step(
            params, toks[:, t : t + 1], caches, jnp.int32(t), cfg, long_mode=True
        )
    assert np.isfinite(np.asarray(logits[:, : cfg.vocab])).all()


def test_mrope_positions_change_logits():
    cfg = dataclasses.replace(get_smoke("qwen2-vl-2b"), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    emb = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    p1 = M.default_positions(cfg, B, S)
    # RoPE is relative: a uniform shift is a no-op.  Shift the "height"
    # stream of only the first half (a 2-D patch block) to change relative
    # geometry, as dynamic-resolution image grids do.
    p2 = p1.at[:, : S // 2, 1].add(7)
    l1, _ = M.prefill(params, {"embeds": emb, "positions": p1}, cfg, cache_size=S)
    l2, _ = M.prefill(params, {"embeds": emb, "positions": p2}, cfg, cache_size=S)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-5
    # ...and a uniform shift of every stream IS a no-op
    p3 = p1 + 11
    l3, _ = M.prefill(params, {"embeds": emb, "positions": p3}, cfg, cache_size=S)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l3), atol=1e-4)
