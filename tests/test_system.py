"""End-to-end behaviour tests for the DCI system (paper pipeline + LM side)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokens import TokenStream, batches
from repro.launch.steps import make_train_step
from repro.models.lm.model import init_params
from repro.optim.adamw import init_adamw
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.lm_cache import build_serving_caches


def test_dci_end_to_end_beats_dgl_on_modeled_transfer(small_dataset):
    reports = {}
    for policy in ("dgl", "dci"):
        eng = GNNInferenceEngine(small_dataset, fanouts=(4, 3, 2), batch_size=128)
        eng.prepare(policy, total_cache_bytes=1_000_000)
        reports[policy] = eng.run(max_batches=4)
    dgl, dci = reports["dgl"], reports["dci"]
    # hit accounting is exact; modeled transfer projects the PCIe/HBM gap
    assert dci.modeled_transfer_seconds() < dgl.modeled_transfer_seconds()
    assert dci.adj_hit_rate > 0 and dci.feat_hit_rate > 0
    assert dgl.feat_hit_rate == 0
    # stage decomposition is complete and sane
    assert dci.total_seconds > 0
    assert dci.feat_hits <= dci.feat_lookups
    assert dci.adj_hits <= dci.adj_lookups


def test_dci_allocation_reacts_to_workload(small_dataset):
    """Fat fan-outs make sampling relatively more expensive -> Eq.1 gives
    the adjacency cache a non-trivial share."""
    eng = GNNInferenceEngine(small_dataset, fanouts=(15, 10, 5), batch_size=128)
    pipe = eng.prepare("dci", total_cache_bytes=1_000_000)
    a = pipe.caches.allocation
    assert 0 < a.sample_fraction < 1
    assert a.adj_bytes > 0 and a.feat_bytes > 0


def test_lm_training_loss_decreases():
    import dataclasses

    from repro.configs import get_smoke

    cfg = dataclasses.replace(get_smoke("yi-6b"), vocab=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    step = jax.jit(make_train_step(cfg, base_lr=3e-3))
    stream = TokenStream(vocab=cfg.vocab, seed=0)
    losses = []
    for b in batches(stream, batch=4, seq=32, steps=30):
        params, opt, loss = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_serving_dual_cache_hits_on_zipfian_requests():
    from repro.configs import get_smoke

    cfg = get_smoke("phi3.5-moe-42b-a6.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    stream = TokenStream(vocab=cfg.vocab, seed=1)
    rng = np.random.default_rng(0)
    sample = stream.sample(rng, 8, 32)
    caches = build_serving_caches(cfg, params, sample, total_cache_bytes=100_000)
    a = caches.allocation
    assert a.adj_bytes + a.feat_bytes == 100_000
    live = stream.sample(rng, 4, 32)
    assert 0.0 <= caches.embed_hit_rate(live) <= 1.0
    # zipfian reuse: the hot-row cache must catch a meaningful share
    assert caches.embed_hit_rate(live) > 0.3


def test_gnn_inference_deterministic_given_pipeline(small_dataset):
    """Eq.1's split depends on measured wall time (by design), so determinism
    holds *given a prepared pipeline*: same caches + seed => same hits."""
    eng = GNNInferenceEngine(small_dataset, fanouts=(3, 2), batch_size=64, seed=7)
    eng.prepare("dci", total_cache_bytes=500_000)
    r1 = eng.run(max_batches=2)
    r2 = eng.run(max_batches=2)
    assert (r1.adj_hits, r1.feat_hits) == (r2.adj_hits, r2.feat_hits)


def test_full_budget_gives_full_hit_rates(small_dataset):
    """With a budget covering the whole dataset, both caches hit ~100%
    (paper: 'performance of both strategies is identical' past that point)."""
    ds = small_dataset
    budget = ds.features.nbytes + ds.graph.num_edges * 4 + 1024
    eng = GNNInferenceEngine(ds, fanouts=(4, 3, 2), batch_size=128)
    eng.prepare("dci", total_cache_bytes=budget)
    rep = eng.run(max_batches=4)
    assert rep.feat_hit_rate == 1.0
    assert rep.adj_hit_rate == 1.0


def test_sampler_is_uniform_over_neighbors(small_dataset):
    """Chi-square-style check: slots are drawn uniformly over each node's
    neighbor list (the property Eq.1's workload statistics rely on)."""
    import jax

    from repro.graph.sampling import device_graph, sample_neighbors

    ds = small_dataset
    deg = np.diff(ds.graph.col_ptr)
    v = int(np.argmax((deg >= 5) & (deg <= 20)))  # a mid-degree node
    d = int(deg[v])
    g = device_graph(ds.graph)
    seeds = jnp.full((256,), v, jnp.int32)
    counts = np.zeros(d, np.int64)
    for i in range(20):
        _, _, slots = sample_neighbors(jax.random.PRNGKey(i), g, seeds, 4)
        local = np.asarray(slots).reshape(-1) - int(ds.graph.col_ptr[v])
        np.add.at(counts, local, 1)
    n = counts.sum()
    expect = n / d
    chi2 = float(((counts - expect) ** 2 / expect).sum())
    # dof = d-1 <= 19; chi2 far below a catastrophic threshold
    assert chi2 < 4 * d, (chi2, d, counts)
