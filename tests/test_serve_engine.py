"""Batched serving correctness: slot-batched decoding with ragged request
lengths must produce exactly the tokens sequential per-request decoding
produces (fp32; greedy)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.lm.model import decode_step, init_params, prefill
from repro.runtime.serve_engine import BatchedServer


def sequential_generate(cfg, params, prompt, max_new, max_len):
    logits, caches = prefill(params, {"tokens": jnp.asarray(prompt[None, :])}, cfg, cache_size=max_len)
    toks = [int(jnp.argmax(logits[0, : cfg.vocab]))]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, caches = decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), caches, jnp.int32(pos), cfg
        )
        toks.append(int(jnp.argmax(logits[0, : cfg.vocab])))
        pos += 1
    return toks


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma2-27b"])
def test_batched_server_matches_sequential(arch):
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32) for n in (5, 9, 13, 7, 11)]
    max_new = 6
    max_len = 32

    server = BatchedServer(cfg, params, slots=2, max_len=max_len)
    for i, p in enumerate(prompts):
        server.submit(p, max_new, req_id=i)
    results = server.run()
    assert len(results) == len(prompts)

    for req, prompt in zip(results, prompts):
        want = sequential_generate(cfg, params, prompt, max_new, max_len)
        assert req.generated == want, (req.req_id, req.generated, want)


def test_server_rejects_embeds_arch():
    cfg = get_smoke("qwen2-vl-2b")
    with pytest.raises(ValueError):
        BatchedServer(cfg, params=None)
