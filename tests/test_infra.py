"""Infrastructure tests: optimizer, checkpoint, token pipeline, HLO analysis,
sharding specs (including divisibility on the production mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo
from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data.tokens import TokenStream, batches
from repro.models.lm.model import abstract_params
from repro.models.lm.sharding import param_specs
from repro.optim.adamw import adamw_update, cosine_schedule, init_adamw

# ----------------------------------------------------------------- optim


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_adamw(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = adamw_update(params, grads, state, lr=0.1, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert int(state["step"]) == 200


def test_adamw_preserves_dtypes():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = init_adamw(params)
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new_params, new_state = adamw_update(params, grads, state)
    assert new_params["w"].dtype == jnp.bfloat16
    assert new_state["m"]["w"].dtype == jnp.float32


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), base_lr=1.0, warmup=10, total=100)) for s in range(100)]
    assert lrs[0] < lrs[9]  # warmup rises
    assert lrs[20] > lrs[90]  # decays after


# ------------------------------------------------------------ checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16), "d": jnp.int32(7)},
        "blocks": (jnp.zeros((2, 2)), jnp.ones((3,))),
    }
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree)
    restored = load_checkpoint(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
    assert restored["b"]["c"].dtype == jnp.bfloat16


# ------------------------------------------------------------ token data


def test_token_stream_bounds_and_determinism():
    stream = TokenStream(vocab=128, seed=3)
    b1 = list(batches(stream, batch=2, seq=16, steps=3, seed=1))
    b2 = list(batches(stream, batch=2, seq=16, steps=3, seed=1))
    for x, y in zip(b1, b2):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    assert all((b["tokens"] >= 0).all() and (b["tokens"] < 128).all() for b in b1)
    assert b1[0]["tokens"].shape == (2, 16)
    # labels are the shifted stream
    np.testing.assert_array_equal(b1[0]["labels"][:, :-1], b1[0]["tokens"][:, 1:])


# ----------------------------------------------------------- HLO analysis


def test_hlo_flops_recovers_scan_trip_count():
    n, k, m, trips = 64, 32, 16, 10
    w = jnp.ones((k, m), jnp.float32)

    def f(x):
        def body(c, _):
            return c, x @ w

        _, ys = jax.lax.scan(body, 0, jnp.arange(trips))
        return ys.sum()

    x = jnp.ones((n, k), jnp.float32)
    hlo = jax.jit(f).lower(x).compile().as_text()
    s = analyze_hlo(hlo)
    expected = 2 * n * k * m * trips
    # XLA may hoist the loop-invariant matmul; accept either exact scan
    # accounting or the hoisted single execution.
    assert s.flops in (expected, expected / trips)
    assert s.unresolved_trip_counts == 0


def test_hlo_flops_counts_dependent_scan():
    n, trips = 32, 7
    w = jnp.eye(n, dtype=jnp.float32) * 0.5

    def f(x):
        def body(c, _):
            return c @ w, ()

        c, _ = jax.lax.scan(body, x, jnp.arange(trips))
        return c.sum()

    x = jnp.ones((n, n), jnp.float32)
    s = analyze_hlo(jax.jit(f).lower(x).compile().as_text())
    assert s.flops == 2 * n * n * n * trips


# --------------------------------------------------------- sharding specs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_structure_and_divisibility(arch):
    """Every sharded dim must divide by the model-axis size (16) — this is
    the static check that keeps new configs dry-run-compatible."""
    cfg = get_config(arch)
    params = abstract_params(cfg)
    specs = param_specs(params)
    leaves = jax.tree_util.tree_leaves_with_path(params)
    spec_leaves = {
        jax.tree_util.keystr(p): s
        for p, s in jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
    }
    for path, leaf in leaves:
        spec = spec_leaves[jax.tree_util.keystr(path)]
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if axis == "model":
                assert dim % 16 == 0, f"{jax.tree_util.keystr(path)}: {dim} % 16 != 0"
