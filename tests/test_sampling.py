"""Sampler invariants: validity, cache-hit equivalence, visit counting."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.allocation import CacheAllocation
from repro.core.cache import DualCache
from repro.graph.csc import build_adj_cache, two_level_sort
from repro.graph.sampling import count_visits, device_graph, sample_blocks, sample_neighbors


def neighbors_of(ds, v):
    lo, hi = ds.graph.col_ptr[v], ds.graph.col_ptr[v + 1]
    return set(ds.graph.row_index[lo:hi].tolist()) or {v}


def test_sampled_neighbors_are_real(small_dataset):
    ds = small_dataset
    g = device_graph(ds.graph)
    seeds = jnp.asarray(ds.test_idx[:32])
    nbr, hit, _ = sample_neighbors(jax.random.PRNGKey(0), g, seeds, 5)
    nbr = np.asarray(nbr)
    for i, v in enumerate(np.asarray(seeds)):
        allowed = neighbors_of(ds, int(v))
        assert set(nbr[i].tolist()) <= allowed


def test_cached_sampler_returns_real_neighbors(small_dataset):
    """With the adjacency cache active, samples must still be true neighbors."""
    ds = small_dataset
    seeds = jnp.asarray(ds.test_idx[:64])
    # visit counts from a real pre-sampling pass over the same seeds, so the
    # cache holds the edges these seeds actually touch
    plain = device_graph(ds.graph)
    _, _, slots = sample_neighbors(jax.random.PRNGKey(7), plain, seeds, 4)
    counts = np.zeros(ds.graph.num_edges, np.int64)
    np.add.at(counts, np.asarray(slots).reshape(-1), 1)
    sorted_row, totals = two_level_sort(ds.graph, counts)
    cache = build_adj_cache(ds.graph, sorted_row, totals, capacity_bytes=4 * 2000)
    g = device_graph(ds.graph, sorted_row_index=sorted_row, adj_cache=cache)
    nbr, hit, _ = sample_neighbors(jax.random.PRNGKey(1), g, seeds, 4)
    nbr, hit = np.asarray(nbr), np.asarray(hit)
    assert hit.any()  # cache actually used
    for i, v in enumerate(np.asarray(seeds)):
        assert set(nbr[i].tolist()) <= neighbors_of(ds, int(v))


def test_zero_degree_self_loop():
    import numpy as np

    from repro.graph.csc import CSCGraph

    g = CSCGraph(col_ptr=np.array([0, 0, 1]), row_index=np.array([0], np.int32))
    dg = device_graph(g)
    nbr, hit, _ = sample_neighbors(jax.random.PRNGKey(0), dg, jnp.array([0], jnp.int32), 3)
    assert (np.asarray(nbr) == 0).all()
    assert np.asarray(hit).all()  # self-loops need no host access


def test_block_frontier_sizes(small_dataset):
    g = device_graph(small_dataset.graph)
    seeds = jnp.asarray(small_dataset.test_idx[:16])
    b = sample_blocks(jax.random.PRNGKey(0), g, seeds, (4, 3, 2))
    sizes = [16]
    for f in (2, 3, 4):  # expansion uses reversed fanouts
        sizes.append(sizes[-1] * (1 + f))
    assert [fr.shape[0] for fr in b.frontiers] == sizes


def test_count_visits_totals(small_dataset):
    g = device_graph(small_dataset.graph)
    seeds = jnp.asarray(small_dataset.test_idx[:16])
    b = sample_blocks(jax.random.PRNGKey(0), g, seeds, (3, 2))
    node_counts, edge_counts = count_visits(
        small_dataset.num_nodes, small_dataset.graph.num_edges, [b]
    )
    assert node_counts.sum() == b.input_nodes.shape[0]
    # every edge count came from a sampled slot of a non-isolated seed
    assert edge_counts.sum() <= sum(s.size for s in b.edge_slots)


@settings(max_examples=10, deadline=None)
@given(fanout=st.integers(1, 6), n_seeds=st.integers(1, 32), seed=st.integers(0, 99))
def test_hit_rate_in_unit_interval(small_dataset, fanout, n_seeds, seed):
    ds = small_dataset
    counts = np.random.default_rng(seed).integers(0, 5, ds.graph.num_edges).astype(np.int64)
    sorted_row, totals = two_level_sort(ds.graph, counts)
    cache = build_adj_cache(ds.graph, sorted_row, totals, capacity_bytes=4 * 200)
    g = device_graph(ds.graph, sorted_row_index=sorted_row, adj_cache=cache)
    seeds = jnp.asarray(ds.test_idx[:n_seeds])
    _, hit, _ = sample_neighbors(jax.random.PRNGKey(seed), g, seeds, fanout)
    rate = float(jnp.mean(hit))
    assert 0.0 <= rate <= 1.0


def test_dual_cache_build(small_dataset):
    ds = small_dataset
    rng = np.random.default_rng(0)
    alloc = CacheAllocation(
        total_bytes=100_000, adj_bytes=50_000, feat_bytes=50_000, sample_fraction=0.5
    )
    dc = DualCache.build(
        ds,
        node_counts=rng.integers(0, 9, ds.num_nodes),
        edge_counts=rng.integers(0, 9, ds.graph.num_edges),
        allocation=alloc,
    )
    assert dc.adj_cached_elements * 4 <= alloc.adj_bytes
    assert dc.feat_cached_rows * ds.feature_nbytes_per_row() <= alloc.feat_bytes
