"""End-to-end training driver example: a ~40M-param Llama-family model for a
few hundred steps on the synthetic token stream (loss visibly decreases).

    PYTHONPATH=src python examples/train_lm.py            # ~40M params, 200 steps
    PYTHONPATH=src python examples/train_lm.py --big      # ~120M params (slower)

This wraps the production driver (repro.launch.train) with a custom
mid-size config — larger than the smoke configs, CPU-trainable.
"""

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenStream, batches
from repro.launch.steps import make_train_step
from repro.models.lm.model import init_params
from repro.optim.adamw import init_adamw

ap = argparse.ArgumentParser()
ap.add_argument("--big", action="store_true", help="~120M params instead of ~40M")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

base = get_config("yi-6b")  # llama-family architecture
cfg = dataclasses.replace(
    base,
    arch_id="yi-mini",
    n_layers=4 if not args.big else 8,
    d_model=256 if not args.big else 512,
    n_heads=4 if not args.big else 8,
    n_kv_heads=2,
    head_dim=64,
    d_ff=1024 if not args.big else 2048,
    vocab=8192,
)

params = init_params(jax.random.PRNGKey(0), cfg)
n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
print(f"training {cfg.arch_id}: {n_params/1e6:.1f}M params, {args.steps} steps")

opt_state = init_adamw(params)
step_fn = jax.jit(make_train_step(cfg, base_lr=1e-3))
stream = TokenStream(vocab=cfg.vocab, seed=0)

losses = []
t0 = time.perf_counter()
for i, b in enumerate(batches(stream, batch=8, seq=128, steps=args.steps)):
    params, opt_state, loss = step_fn(
        params, opt_state, {k: jnp.asarray(v) for k, v in b.items()}
    )
    losses.append(float(loss))
    if (i + 1) % 20 == 0:
        print(f"step {i+1:4d}  loss {np.mean(losses[-20:]):.4f}  "
              f"{(time.perf_counter()-t0)/(i+1):.2f}s/step")

first, last = np.mean(losses[:10]), np.mean(losses[-10:])
print(f"loss {first:.3f} -> {last:.3f} ({'OK: decreased' if last < first else 'WARN'})")
sys.exit(0 if last < first else 1)
