"""Scenario: continuous batching — ragged requests through shared slots.

Five requests with different prompt lengths and budgets stream through a
2-slot server; per-slot cache lengths let them decode in one jitted step.

    PYTHONPATH=src python examples/continuous_batching.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models.lm.model import init_params
from repro.runtime.serve_engine import BatchedServer

cfg = dataclasses.replace(get_smoke("granite-3-8b"), dtype="float32")
params = init_params(jax.random.PRNGKey(0), cfg)

rng = np.random.default_rng(0)
server = BatchedServer(cfg, params, slots=2, max_len=64)
for i, (plen, budget) in enumerate([(5, 8), (12, 4), (7, 10), (20, 6), (9, 5)]):
    server.submit(rng.integers(0, cfg.vocab, plen).astype(np.int32), budget, req_id=i)

results = server.run()
total = sum(len(r.generated) for r in results)
print(f"served {len(results)} requests / {total} tokens in {server.elapsed:.2f}s "
      f"({total/server.elapsed:.1f} tok/s) on 2 slots")
for r in results:
    print(f"  req {r.req_id}: prompt {len(r.prompt):2d} tokens -> generated {r.generated}")
