"""Train GraphSAGE on a synthetic Table-II graph, then run DCI inference.

Closes the loop the paper assumes: a *trained* model served through the
dual-cache inference system.  Labels here are a noisy function of a hidden
linear probe of the features, so accuracy above chance proves learning.

    PYTHONPATH=src python examples/train_gnn.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import load_dataset
from repro.graph.features import plain_feature_store
from repro.graph.sampling import device_graph, sample_blocks
from repro.models import gnn as gnn_models
from repro.optim.adamw import adamw_update, init_adamw
from repro.runtime.gnn_engine import GNNInferenceEngine

FANOUTS = (4, 3, 2)
BATCH = 256
STEPS = 120

ds = load_dataset("ogbn-products", scale=0.004, seed=0)
# learnable labels: hidden probe of the features
rng = np.random.default_rng(0)
probe = rng.standard_normal((ds.spec.feat_dim, ds.spec.num_classes)).astype(np.float32)
labels = (ds.features @ probe + 0.1 * rng.standard_normal((ds.num_nodes, ds.spec.num_classes))).argmax(1)
labels = labels.astype(np.int32)

g = device_graph(ds.graph)
store = plain_feature_store(ds.features)
params = gnn_models.init_params(
    jax.random.PRNGKey(0), "graphsage", ds.spec.feat_dim, ds.spec.num_classes
)
opt = init_adamw(params)


@jax.jit
def loss_fn(params, feats, seed_labels):
    logits = gnn_models.forward(params, feats, model="graphsage", fanouts=FANOUTS)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    return -jnp.take_along_axis(logp, seed_labels[:, None], -1).mean()


key = jax.random.PRNGKey(1)
train = ds.train_idx
t0 = time.perf_counter()
for step in range(STEPS):
    key, s1, s2 = jax.random.split(key, 3)
    seeds = jax.random.choice(s1, jnp.asarray(train), (BATCH,))
    block = sample_blocks(s2, g, seeds, FANOUTS)
    feats, _ = store.gather(block.input_nodes)
    loss, grads = jax.value_and_grad(loss_fn)(params, feats, jnp.asarray(labels)[seeds])
    params, opt = adamw_update(params, grads, opt, lr=3e-3, weight_decay=0.0)
    if (step + 1) % 15 == 0:
        print(f"step {step+1:3d} loss {float(loss):.4f} ({(time.perf_counter()-t0)/(step+1):.2f}s/step)")

# test accuracy through the DCI inference engine's sampler
key, s1, s2 = jax.random.split(key, 3)
test_seeds = jnp.asarray(ds.test_idx[:1024])
block = sample_blocks(s2, g, test_seeds, FANOUTS)
feats, _ = store.gather(block.input_nodes)
pred = gnn_models.forward(params, feats, model="graphsage", fanouts=FANOUTS).argmax(-1)
acc = float((pred == jnp.asarray(labels)[test_seeds]).mean())
print(f"test accuracy {acc:.3f} (chance ≈ {1/ds.spec.num_classes:.3f})")

# and serve the trained model with the dual cache
eng = GNNInferenceEngine(ds, model="graphsage", fanouts=FANOUTS, batch_size=512, params=params)
eng.prepare("dci", total_cache_bytes=2_000_000)
rep = eng.run(max_batches=6)
print("DCI serving:", rep.summary())
