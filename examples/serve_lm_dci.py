"""Scenario: batched LM serving with DCI's dual cache (embeddings + experts).

Runs the MoE smoke model: profiles a request sample, Eq.1-allocates the
budget between hot-embedding rows and hot-expert weights, then serves a
batch of requests and reports hit rates — the paper's workflow transplanted
to transformer serving (DESIGN.md §4).

    PYTHONPATH=src python examples/serve_lm_dci.py
"""

import subprocess
import sys

for arch, budget in (("phi3.5-moe-42b-a6.6b", 2.0), ("gemma-2b", 1.0)):
    print(f"=== {arch} (budget {budget} MB) ===")
    rc = subprocess.call(
        [
            sys.executable,
            "-m",
            "repro.launch.serve",
            "--arch",
            arch,
            "--smoke",
            "--requests",
            "8",
            "--prompt-len",
            "48",
            "--gen-len",
            "16",
            "--cache-mb",
            str(budget),
        ],
    )
    if rc != 0:
        sys.exit(rc)
print("done — see repro.launch.serve for the full driver.")
