"""Quickstart: DCI dual-cache GNN inference vs baselines in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.graph import load_dataset
from repro.runtime.gnn_engine import GNNInferenceEngine

# A scaled synthetic stand-in for Ogbn-products (Table II statistics).
dataset = load_dataset("ogbn-products", scale=0.004, seed=0)
print(f"graph: {dataset.num_nodes} nodes, {dataset.graph.num_edges} edges, "
      f"feat dim {dataset.spec.feat_dim}")

for policy in ("dgl", "sci", "dci"):
    engine = GNNInferenceEngine(
        dataset, model="graphsage", fanouts=(8, 4, 2), batch_size=512
    )
    # DCI: pre-sample 8 batches -> Eq.1 capacity split -> lightweight fill.
    engine.prepare(policy, total_cache_bytes=2_000_000)
    report = engine.run(max_batches=8)
    s = report.summary()
    print(
        f"{policy:4s} | total {s['total_s']:6.3f}s | prep {s['prep_s']:6.3f}s | "
        f"adj hit {s['adj_hit_rate']:.2f} | feat hit {s['feat_hit_rate']:.2f} | "
        f"modeled transfer {s['modeled_transfer_s']*1e3:7.3f}ms"
    )
