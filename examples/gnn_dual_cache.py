"""Scenario: how the Eq.1 split and hit rates react to the cache budget.

Sweeps the total cache budget and prints DCI's allocation decision plus the
resulting hit rates — the Fig. 9 experiment as a runnable script.  Each
budget also runs twice through the batch executor (serial pipeline_depth=1
vs double-buffered depth=2): hit rates are identical by construction, only
wall clock moves.

Part 2 serves FOUR request streams against one shared DualCache
(runtime/gnn_serve.py) and compares the shared budget-B cache with what
each stream would get from a private B/4 cache — the hit-rate uplift that
makes cache *sharing* the point of a dual-cache serving system.

    PYTHONPATH=src python examples/gnn_dual_cache.py
"""

from repro.core.config import EngineConfig, ServeConfig
from repro.graph import load_dataset
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches

dataset = load_dataset("ogbn-products", scale=0.004, seed=0)

print(
    f"{'budget':>12s} {'C_adj':>10s} {'C_feat':>10s} {'adj_hit':>8s} {'feat_hit':>9s} "
    f"{'serial_s':>9s} {'pipe_s':>8s}"
)
for budget in (250_000, 1_000_000, 4_000_000, 16_000_000):
    engine = GNNInferenceEngine(dataset, fanouts=(15, 10, 5), batch_size=256)
    pipe = engine.prepare("dci", total_cache_bytes=budget)
    rep = engine.run(max_batches=6, config=EngineConfig(pipeline_depth=1))
    rep_pipe = engine.run(max_batches=6, config=EngineConfig(pipeline_depth=2))
    a = pipe.caches.allocation
    print(
        f"{budget:12,d} {a.adj_bytes:10,d} {a.feat_bytes:10,d} "
        f"{rep.adj_hit_rate:8.3f} {rep.feat_hit_rate:9.3f} "
        f"{rep.total_seconds:9.4f} {rep_pipe.total_seconds:8.4f}"
    )
print("\nlarger budgets -> both caches saturate; the split follows the")
print("measured sample:feature time ratio (Eq. 1), not a fixed fraction.")
print("pipeline_depth=2 overlaps batch i+1's sample/gather with batch i's")
print("compute; outputs and hit rates match depth=1 exactly.")

# ---------------------------------------------------------------- part 2
# Four request streams, one shared cache vs four private quarter caches.
BUDGET, STREAMS, BATCHES = 2_000_000, 4, 4
queues = make_stream_batches(
    dataset, num_streams=STREAMS, batches_per_stream=BATCHES, batch_size=256, seed=0
)
stream_seeds = list(range(STREAMS))

shared = GNNInferenceEngine(dataset, fanouts=(15, 10, 5), batch_size=256)
shared.prepare("dci", total_cache_bytes=BUDGET, stream_seeds=stream_seeds)
server = MultiStreamServer(shared, config=ServeConfig(engine=EngineConfig(pipeline_depth=2)))
for sid, queue in enumerate(queues):
    server.add_stream(queue, seed=stream_seeds[sid])
rep = server.run()

private_hits = private_lookups = 0
for sid, queue in enumerate(queues):
    eng = GNNInferenceEngine(dataset, fanouts=(15, 10, 5), batch_size=256, seed=stream_seeds[sid])
    eng.prepare("dci", total_cache_bytes=BUDGET // STREAMS)
    r = eng.run(batches=queue, config=EngineConfig(pipeline_depth=1))
    private_hits, private_lookups = private_hits + r.feat_hits, private_lookups + r.feat_lookups

print(f"\n{STREAMS} streams x {BATCHES} batches, total budget {BUDGET:,d} B:")
print(f"  shared  cache (one {BUDGET:,d} B DualCache, one presample): "
      f"feat hit {rep.feat_hit_rate:.3f}, {rep.throughput_seeds_per_s:,.0f} seeds/s")
print(f"  private caches ({STREAMS} x {BUDGET // STREAMS:,d} B, {STREAMS} presamples): "
      f"feat hit {private_hits / max(private_lookups, 1):.3f}")
print("one shared budget-B cache beats N private B/N caches on hit rate, and")
print("its presample/allocation/fill/compile cost is paid once, not N times")
print("(benchmarks/bench_multistream.py quantifies the throughput uplift).")
