"""Scenario: how the Eq.1 split and hit rates react to the cache budget.

Sweeps the total cache budget and prints DCI's allocation decision plus the
resulting hit rates — the Fig. 9 experiment as a runnable script.  Each
budget also runs twice through the batch executor (serial pipeline_depth=1
vs double-buffered depth=2): hit rates are identical by construction, only
wall clock moves.

    PYTHONPATH=src python examples/gnn_dual_cache.py
"""

from repro.graph import load_dataset
from repro.runtime.gnn_engine import GNNInferenceEngine

dataset = load_dataset("ogbn-products", scale=0.004, seed=0)

print(
    f"{'budget':>12s} {'C_adj':>10s} {'C_feat':>10s} {'adj_hit':>8s} {'feat_hit':>9s} "
    f"{'serial_s':>9s} {'pipe_s':>8s}"
)
for budget in (250_000, 1_000_000, 4_000_000, 16_000_000):
    engine = GNNInferenceEngine(dataset, fanouts=(15, 10, 5), batch_size=256)
    pipe = engine.prepare("dci", total_cache_bytes=budget)
    rep = engine.run(max_batches=6, pipeline_depth=1)
    rep_pipe = engine.run(max_batches=6, pipeline_depth=2)
    a = pipe.caches.allocation
    print(
        f"{budget:12,d} {a.adj_bytes:10,d} {a.feat_bytes:10,d} "
        f"{rep.adj_hit_rate:8.3f} {rep.feat_hit_rate:9.3f} "
        f"{rep.total_seconds:9.4f} {rep_pipe.total_seconds:8.4f}"
    )
print("\nlarger budgets -> both caches saturate; the split follows the")
print("measured sample:feature time ratio (Eq. 1), not a fixed fraction.")
print("pipeline_depth=2 overlaps batch i+1's sample/gather with batch i's")
print("compute; outputs and hit rates match depth=1 exactly.")
