"""Multi-stream serving: N streams sharing one DualCache vs N private engines.

The experiment the serving layer (src/repro/runtime/gnn_serve.py) exists
for.  The same workload — N request streams of ``batches_per_stream``
batches each — is served two ways:

  * ``private-serial``: each stream gets its own engine with a private
    cache of budget B/N, prepared from its own presampling run
    (``n_presample`` batches per stream), then runs its queue serially
    (pipeline_depth=1).  Cold-start cost = N x (presample + allocate +
    fill + warmup) + the N runs, back to back.
  * ``shared-multistream``: ONE cache of budget B is prepared from the
    union workload (the same total presample budget split across stream
    seeds and merged), then all N streams interleave through one pipelined
    executor (round-robin + backpressure admission).
  * ``shared-multistream+prefetch``: the shared configuration with the
    miss-path prefetch stage — each admitted batch's missed host rows are
    staged onto the device during earlier batches' compute, per-stream
    staging bounded by the backpressure cap.  Hit accounting is identical
    to ``shared-multistream`` (checked), isolating the wall-clock effect.

Reported per configuration:

  * cold-start aggregate throughput (seeds/s over prepare + warmup + run)
    — the serving-system metric.  Sharing wins on it for the paper's own
    reason: preprocessing is a headline cost (Tables IV, Fig. 10), and the
    shared cache pays it once instead of N times;
  * steady-state serve wall (run only) — on this CPU container the
    pipeline depth only changes the sync pattern (all stages contend for
    the same cores), so this column is expected ~flat; on an accelerator
    the overlap shows up here;
  * aggregate feature/adjacency hit rates and the modeled PCIe/HBM
    transfer time: one budget-B cache serves every stream's hot set, so
    hit rates are >= the private-B/N ones.

Acceptance (checked in main, printed as PASS/FAIL):
  >= 1.2x cold-start aggregate throughput at 4 streams, and shared-cache
  hit rate >= the private single-stream hit rate.

Output: ``emit`` CSV rows (harness contract ``name,us_per_call,derived``)
plus ``--json`` rows with the schema documented in docs/BENCHMARKS.md.
"""

from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import CACHE_BYTES, emit, geomean, make_engine
from repro.core.config import EngineConfig, ServeConfig
from repro.runtime.cache_refresh import RefreshConfig
from repro.runtime.gnn_engine import GNNInferenceEngine
from repro.runtime.gnn_serve import MultiStreamServer, make_stream_batches
from repro.runtime.request_queue import (
    RequestQueueServer,
    burst_trace,
    flash_crowd_seed_batches,
    poisson_trace,
)

N_PRESAMPLE = 8  # per prepared cache (Fig. 11's stabilization point)


def _private_serial(dataset, queues, stream_seeds, *, model, fanouts, batch_size, cache_bytes):
    """N single-stream engines, each with a private cache of cache_bytes/N."""
    n = len(queues)
    wall0 = time.perf_counter()
    run_s = hits = lookups = ahits = alookups = modeled = 0.0
    seeds_served = 0
    for sid, queue in enumerate(queues):
        eng = GNNInferenceEngine(
            dataset, model=model, fanouts=fanouts, batch_size=batch_size, seed=stream_seeds[sid]
        )
        eng.prepare("dci", total_cache_bytes=cache_bytes // n, n_presample=N_PRESAMPLE)
        rep = eng.run(batches=queue, config=EngineConfig(pipeline_depth=1))
        run_s += rep.total_seconds
        hits, lookups = hits + rep.feat_hits, lookups + rep.feat_lookups
        ahits, alookups = ahits + rep.adj_hits, alookups + rep.adj_lookups
        modeled += rep.modeled_transfer_seconds()
        seeds_served += rep.num_batches * batch_size
    return {
        "mode": "private-serial",
        "cold_s": time.perf_counter() - wall0,
        "serve_s": run_s,
        "seeds": seeds_served,
        "feat_hit": hits / max(lookups, 1),
        "adj_hit": ahits / max(alookups, 1),
        "modeled_transfer_s": modeled,
    }


def _shared_multistream(
    dataset,
    queues,
    stream_seeds,
    *,
    model,
    fanouts,
    batch_size,
    cache_bytes,
    depth,
    refresh_interval=0,
):
    """One shared budget-B cache, one presample/compile, N interleaved streams.

    Returns TWO rows over the SAME prepared pipeline: without and with the
    miss-path prefetch stage.  Sharing one preparation is what makes the
    pair comparable — the Eq. 1 split depends on measured stage times, so
    re-preparing would change the cache itself; against one cache, hit
    accounting is bit-identical with prefetch on or off (checked) and the
    row pair isolates the wall-clock effect of moving the miss copies off
    the critical path.  Each row's cold start = the shared preparation +
    its own warmup/serve (both modes would pay that same preparation)."""
    wall0 = time.perf_counter()
    eng = GNNInferenceEngine(dataset, model=model, fanouts=fanouts, batch_size=batch_size)
    eng.prepare(
        "dci",
        total_cache_bytes=cache_bytes,
        n_presample=N_PRESAMPLE,
        stream_seeds=stream_seeds,
    )
    prep_s = time.perf_counter() - wall0
    rows = []
    # The refresh row (off unless --refresh-interval is set) runs LAST so
    # the prefetch-vs-plain pair still observes the untouched epoch-0
    # cache (a refresh mutates the shared DualCache in place).
    modes = [("shared-multistream", False, None), ("shared-multistream+prefetch", True, None)]
    if refresh_interval:
        modes.append(
            (
                "shared-multistream+refresh",
                False,
                RefreshConfig(mode="all", interval_batches=refresh_interval),
            )
        )
    for mode, prefetch, refresh in modes:
        t0 = time.perf_counter()
        server = MultiStreamServer(
            eng,
            config=ServeConfig(engine=EngineConfig(pipeline_depth=depth, prefetch=prefetch)),
            refresh=refresh,
        )
        for sid, queue in enumerate(queues):
            server.add_stream(queue, seed=stream_seeds[sid])
        rep = server.run()
        row = {
            "mode": mode,
            "cold_s": prep_s + (time.perf_counter() - t0),
            "serve_s": rep.wall_seconds,
            "seeds": rep.total_seeds,
            "feat_hit": rep.feat_hit_rate,
            "adj_hit": rep.adj_hit_rate,
            "modeled_transfer_s": rep.modeled_transfer_seconds(),
            "per_stream_feat_hit": [round(s.feat_hit_rate, 4) for s in rep.streams],
            "mean_latency_s": round(
                sum(s.mean_latency_s for s in rep.streams) / len(rep.streams), 5
            ),
            "p50_latency_s": round(rep.p50_latency_s, 5),
            "p99_latency_s": round(rep.p99_latency_s, 5),
            "prefetched_rows": sum(s.prefetched_rows for s in rep.streams),
        }
        if rep.epochs is not None:
            # With refresh on, per-epoch rates are the story — a lifetime
            # aggregate would average away exactly the adaptation.
            row["per_epoch"] = rep.epochs
            row["refresh_count"] = len(rep.refresh_events)
        rows.append(row)
    return rows


def run(
    dataset_name="ogbn-products",
    *,
    num_streams=4,
    batches_per_stream=8,
    batch_size=512,
    cache_bytes=CACHE_BYTES,
    depth=2,
    fanouts=(8, 4, 2),
    model="graphsage",
    refresh_interval=0,
):
    eng0 = make_engine(dataset_name, model=model, fanouts=fanouts, batch_size=batch_size)
    dataset = eng0.dataset
    stream_seeds = list(range(1, num_streams + 1))
    queues = make_stream_batches(
        dataset,
        num_streams=num_streams,
        batches_per_stream=batches_per_stream,
        batch_size=batch_size,
        seed=0,
    )
    # Untimed pre-warm of the programs BOTH sides share at these shapes
    # (sampler, forward, accounting) so neither timed window is charged for
    # them — otherwise whichever mode runs first pays the process-wide jit
    # compile and the uplift would partly be a compile-order artifact.  Each
    # side still pays its own cache-shape-specific gather compile inside its
    # cold window (hot tables of B/N vs B rows are different programs), which
    # is honest: private engines really do compile N distinct caches' worth.
    eng0.prepare("dgl")
    eng0.warmup(queues[0][0])
    kw = dict(model=model, fanouts=fanouts, batch_size=batch_size, cache_bytes=cache_bytes)
    private = _private_serial(dataset, queues, stream_seeds, **kw)
    shared_rows = _shared_multistream(
        dataset, queues, stream_seeds, depth=depth, refresh_interval=refresh_interval, **kw
    )
    shared, shared_pf = shared_rows[0], shared_rows[1]

    rows = []
    for r in (private, *shared_rows):
        r.update(
            dataset=dataset_name,
            streams=num_streams,
            batches_per_stream=batches_per_stream,
            batch_size=batch_size,
            cache_bytes=cache_bytes,
            depth=1 if r["mode"] == "private-serial" else depth,
            cold_throughput_seeds_per_s=r["seeds"] / max(r["cold_s"], 1e-9),
        )
        for k in ("cold_s", "serve_s", "modeled_transfer_s", "feat_hit", "adj_hit",
                  "cold_throughput_seeds_per_s"):
            r[k] = round(r[k], 5)
        rows.append(r)
        emit(
            f"multistream/{dataset_name}/{num_streams}streams/{r['mode']}",
            r["cold_s"] / max(num_streams * batches_per_stream, 1) * 1e6,
            f"cold_tput={r['cold_throughput_seeds_per_s']:.0f};"
            f"feat_hit={r['feat_hit']:.3f};serve_s={r['serve_s']:.3f}",
        )
    uplift = shared["cold_throughput_seeds_per_s"] / max(
        private["cold_throughput_seeds_per_s"], 1e-9
    )
    checks = {
        "throughput_uplift_vs_private": round(uplift, 3),
        "uplift_ge_1.2": bool(uplift >= 1.2),
        "shared_hit_ge_private": bool(shared["feat_hit"] >= private["feat_hit"] - 1e-9),
        # Prefetch must not change what the cache serves, only when the
        # miss bytes cross the link (bit-for-bit accounting guarantee).
        "prefetch_hits_identical": bool(
            abs(shared_pf["feat_hit"] - shared["feat_hit"]) < 1e-9
            and abs(shared_pf["adj_hit"] - shared["adj_hit"]) < 1e-9
        ),
        "prefetch_serve_ratio": round(
            shared["serve_s"] / max(shared_pf["serve_s"], 1e-9), 3
        ),
    }
    return rows, checks


def run_sharded(
    dataset_name="ogbn-products",
    *,
    num_shards=4,
    num_streams=4,
    batches_per_stream=8,
    batch_size=512,
    cache_bytes=CACHE_BYTES,
    depth=2,
    fanouts=(8, 4, 2),
    model="graphsage",
):
    """Sharded-scaling section: one ShardedServer vs the single-device server.

    ONE prepared engine serves both runs (refresh off keeps the caches
    frozen), so the comparison is exact: sharded serving is bit-for-bit
    the single-device run — same logits, same hit accounting — and the
    per-shard counters tile the global ones.  The scaling metric is
    MODELED: each shard drives its own HBM/PCIe link pair, so the mesh's
    projected transfer time is the max over shards, and

        modeled_scaling = global modeled transfer / max-over-shards modeled

    — machine-independent (a 1-core CI box cannot show wall-clock
    parallelism, and on it the wall ratio below is informational only).
    The dedup path feeds the exchange its sorted unique ids, giving the
    cached-working-set workload the acceptance gate specifies: >= 1.5x
    aggregate modeled throughput at 4 shards (run.py --check-against
    regression-gates the ratio and the equivalence booleans)."""
    from repro.runtime.sharded_serve import ShardedServer

    eng = make_engine(dataset_name, model=model, fanouts=fanouts, batch_size=batch_size)
    dataset = eng.dataset
    stream_seeds = list(range(1, num_streams + 1))
    queues = make_stream_batches(
        dataset,
        num_streams=num_streams,
        batches_per_stream=batches_per_stream,
        batch_size=batch_size,
        seed=0,
    )
    eng.prepare(
        "dci",
        total_cache_bytes=cache_bytes,
        n_presample=N_PRESAMPLE,
        stream_seeds=stream_seeds,
        dedup=True,
    )

    def serve(server_cls, **kw):
        t0 = time.perf_counter()
        server = server_cls(
            eng, config=ServeConfig(engine=EngineConfig(pipeline_depth=depth, dedup=True)), **kw
        )
        for sid, queue in enumerate(queues):
            server.add_stream(queue, seed=stream_seeds[sid])
        rep = server.run()
        return rep, time.perf_counter() - t0

    base_rep, base_wall = serve(MultiStreamServer)
    shard_rep, shard_wall = serve(ShardedServer, num_shards=num_shards)

    global_modeled = base_rep.modeled_transfer_seconds()
    per_shard = shard_rep.shards
    max_shard_modeled = max(p["modeled_transfer_s"] for p in per_shard)
    modeled_scaling = global_modeled / max(max_shard_modeled, 1e-12)
    hits_identical = bool(
        base_rep.feat_hits == shard_rep.feat_hits
        and base_rep.feat_lookups == shard_rep.feat_lookups
        and base_rep.adj_hits == shard_rep.adj_hits
        and base_rep.adj_lookups == shard_rep.adj_lookups
    )
    shard_sums_tile = bool(
        sum(p["feat_hits"] for p in per_shard) == base_rep.feat_hits
        and sum(p["feat_lookups"] for p in per_shard) == base_rep.feat_lookups
    )
    rows = []
    for mode, rep, wall in (
        ("single-device", base_rep, base_wall),
        (f"sharded-{num_shards}", shard_rep, shard_wall),
    ):
        row = {
            "mode": mode,
            "dataset": dataset_name,
            "streams": num_streams,
            "num_shards": rep.num_shards,
            "batches_per_stream": batches_per_stream,
            "batch_size": batch_size,
            "cache_bytes": cache_bytes,
            "serve_s": round(rep.wall_seconds, 5),
            "wall_s": round(wall, 5),
            "feat_hit": round(rep.feat_hit_rate, 5),
            "adj_hit": round(rep.adj_hit_rate, 5),
            "modeled_transfer_s": round(rep.modeled_transfer_seconds(), 7),
        }
        if rep.shards is not None:
            row["per_shard"] = [
                {
                    "shard": p["shard"],
                    "rows_cached": p["rows_cached"],
                    "feat_hits": p["feat_hits"],
                    "feat_lookups": p["feat_lookups"],
                    "modeled_transfer_s": round(p["modeled_transfer_s"], 7),
                }
                for p in rep.shards
            ]
            row["max_shard_modeled_s"] = round(max_shard_modeled, 7)
            row["modeled_scaling_vs_single"] = round(modeled_scaling, 3)
        rows.append(row)
        emit(
            f"multistream_sharded/{dataset_name}/{num_shards}shards/{mode}",
            rep.wall_seconds / max(num_streams * batches_per_stream, 1) * 1e6,
            f"feat_hit={row['feat_hit']:.3f};modeled_s={row['modeled_transfer_s']:.2e}",
        )
    checks = {
        "sharded_modeled_scaling": round(modeled_scaling, 3),
        "sharded_scaling_ge_1.5": bool(modeled_scaling >= 1.5),
        "sharded_hits_identical": hits_identical,
        "shard_sums_tile_global": shard_sums_tile,
        # informational on 1-core CI; real on a multi-device host
        "sharded_wall_ratio": round(base_rep.wall_seconds / max(shard_rep.wall_seconds, 1e-9), 3),
    }
    return rows, checks


def run_request_latency(
    dataset_name="ogbn-products",
    *,
    burst_requests=4,
    steady_requests=8,
    batch_size=128,
    cache_bytes=CACHE_BYTES,
    fanouts=(8, 4, 2),
    model="graphsage",
    seeds=(0, 1),
    slo_margin=4.0,
):
    """Per-request tail latency under arrival traces: EDF vs round-robin.

    One engine/cache pair (refresh off, so the caches stay frozen) serves
    every run at depth 1 — runs differ ONLY in arrival clock and admission
    order.  The headline is the burst trace: a flash crowd dumped at t=0
    colliding with a steady stream paced at the measured service time.
    Round-robin interleaves the two, so the burst's tail sits ~2x its
    solo drain time; EDF admits the earliest deadlines (the burst) first
    and roughly halves the burst p99.  The gate metric is the p99 RATIO
    rr/edf, geomean'd over trace seeds — a scheduling property, not a
    wall-clock one, so it is machine-independent (run.py gates on it).
    Informational extras: an SLO-shedding run on the same burst and a
    Poisson steady-traffic run.
    """
    eng = make_engine(dataset_name, model=model, fanouts=fanouts, batch_size=batch_size)
    dataset = eng.dataset
    eng.prepare("dci", total_cache_bytes=cache_bytes, n_presample=N_PRESAMPLE)
    probe = flash_crowd_seed_batches(
        dataset, n_batches=1, batch_size=batch_size, seed=seeds[0]
    )[0]
    eng.warmup(probe)
    # Per-batch service time at depth 1 = sample + gather + compute; the
    # steady stream paces itself (and deadlines scale) off this measurement.
    service_s = float(sum(eng._probe_stage_seconds(probe)))
    slo_s = slo_margin * service_s

    def serve(trace, admission):
        # Fresh Request objects per run (traces are mutated in place), one
        # fresh server per run; depth 1 so admission order IS service order.
        server = RequestQueueServer(
            eng, config=ServeConfig(engine=EngineConfig(pipeline_depth=1)), admission=admission
        )
        for sid, reqs in enumerate(trace):
            server.add_request_stream(reqs, seed=100 + sid)
        return server.run()

    def row(arrival, rep, seed, **extra):
        r = {
            "mode": f"request-{arrival}",
            "dataset": dataset_name,
            "admission": rep.admission,
            "trace_seed": seed,
            "requests": rep.total_batches,
            "requests_shed": rep.requests_shed,
            "deadline_hit_rate": round(rep.deadline_hit_rate, 3),
            "p50_latency_s": round(rep.p50_latency_s, 5),
            "p99_latency_s": round(rep.p99_latency_s, 5),
            "service_estimate_s": round(service_s, 5),
        }
        r.update(extra)
        emit(
            f"request_latency/{dataset_name}/{arrival}/{rep.admission}/seed{seed}",
            rep.p99_latency_s * 1e6,
            f"p50_s={rep.p50_latency_s:.4f};shed={rep.requests_shed};"
            f"deadline_hit={rep.deadline_hit_rate:.3f}",
        )
        return r

    # Throwaway serve: the first pass through the serve loop pays one-off
    # costs (executor threads, accounting jit) that would otherwise land in
    # whichever timed run goes first and skew its latency stamps.
    serve(
        burst_trace(
            dataset,
            burst_requests=1,
            steady_requests=1,
            batch_size=batch_size,
            service_estimate_s=service_s,
            seed=seeds[0],
        ),
        "round-robin",
    )

    rows = []
    rr_p99s, edf_p99s, ratios = [], [], []
    for seed in seeds:
        per_policy = {}
        # The SLO-shed run is informational; one seed's worth is enough.
        policies = ["round-robin", "edf"] + (["slo"] if seed == seeds[0] else [])
        for policy in policies:
            trace = burst_trace(
                dataset,
                burst_requests=burst_requests,
                steady_requests=steady_requests,
                batch_size=batch_size,
                service_estimate_s=service_s,
                slo_s=slo_s,
                seed=seed,
            )
            rep = serve(trace, policy)
            burst_p99 = rep.streams[0].p99_latency_s
            per_policy[policy] = burst_p99
            rows.append(row("burst", rep, seed, burst_p99_s=round(burst_p99, 5)))
        rr_p99s.append(per_policy["round-robin"])
        edf_p99s.append(per_policy["edf"])
        ratios.append(max(per_policy["round-robin"], 1e-9) / max(per_policy["edf"], 1e-9))
    trace = poisson_trace(
        dataset,
        num_streams=2,
        requests_per_stream=max(burst_requests, 2),
        batch_size=batch_size,
        mean_interarrival_s=service_s,
        slo_s=slo_s,
        seed=seeds[0],
    )
    rows.append(row("poisson", serve(trace, "round-robin"), seeds[0]))

    ratio = geomean(ratios)
    checks = {
        "latency_p99_rr_burst_s": round(geomean(rr_p99s), 5),
        "latency_p99_edf_burst_s": round(geomean(edf_p99s), 5),
        "edf_vs_rr_p99_ratio_burst": round(ratio, 3),
        "edf_beats_rr_p99_burst": bool(ratio >= 1.0),
    }
    return rows, checks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--batches-per-stream", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=512)
    ap.add_argument("--depth", type=int, default=2, help="shared run's pipeline depth")
    ap.add_argument("--cache-mb", type=float, default=CACHE_BYTES / 1e6)
    ap.add_argument(
        "--refresh-interval",
        type=int,
        default=0,
        help="add a shared-multistream+refresh row (online refresh every N "
        "retired batches) reporting per-epoch hit rates; 0 = off",
    )
    ap.add_argument("--json", default=None, help="also write rows+checks as JSON")
    ap.add_argument(
        "--request-latency",
        action="store_true",
        help="also run the request-level arrival-trace benchmark: per-request "
        "p50/p99 under burst and Poisson traces, EDF-vs-round-robin burst "
        "p99 ratio (the tail gate run.py checks), and an SLO shedding row",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny config for CI: 2 streams x 2 batches, no acceptance thresholds",
    )
    ap.add_argument(
        "--sharded",
        type=int,
        default=0,
        metavar="K",
        help="also run the sharded-scaling section: a K-shard ShardedServer "
        "vs the single-device server over one prepared engine — bit-for-bit "
        "hit accounting plus the modeled (max-over-shards) transfer-time "
        "scaling ratio the >=1.5x acceptance gate checks",
    )
    args = ap.parse_args()
    if args.smoke:
        rows, checks = run(
            num_streams=2, batches_per_stream=2, batch_size=128, depth=2
        )
    else:
        rows, checks = run(
            num_streams=args.streams,
            batches_per_stream=args.batches_per_stream,
            batch_size=args.batch_size,
            cache_bytes=int(args.cache_mb * 1e6),
            depth=args.depth,
            refresh_interval=args.refresh_interval,
        )
    for r in rows:
        print(r)
    status = "PASS" if (checks["uplift_ge_1.2"] and checks["shared_hit_ge_private"]) else "FAIL"
    print(f"checks ({'smoke: informational' if args.smoke else status}): {checks}")
    payload = {"rows": rows, "checks": checks}
    if args.sharded:
        sh_rows, sh_checks = run_sharded(
            num_shards=args.sharded,
            num_streams=2 if args.smoke else args.streams,
            batches_per_stream=2 if args.smoke else args.batches_per_stream,
            batch_size=128 if args.smoke else args.batch_size,
            cache_bytes=int(args.cache_mb * 1e6),
            depth=args.depth,
        )
        for r in sh_rows:
            print(r)
        sh_status = "PASS" if (
            sh_checks["sharded_scaling_ge_1.5"] and sh_checks["sharded_hits_identical"]
        ) else "FAIL"
        print(f"sharded checks ({sh_status}): {sh_checks}")
        payload["sharded"] = {"rows": sh_rows, "checks": sh_checks}
    if args.request_latency:
        rl_rows, rl_checks = run_request_latency(
            batch_size=min(args.batch_size, 128), cache_bytes=int(args.cache_mb * 1e6)
        )
        for r in rl_rows:
            print(r)
        rl_status = "PASS" if rl_checks["edf_beats_rr_p99_burst"] else "FAIL"
        print(f"request-latency checks ({rl_status}): {rl_checks}")
        payload["request_latency"] = {"rows": rl_rows, "checks": rl_checks}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)


if __name__ == "__main__":
    main()
