"""Table I: redundant data loading — Loaded-nodes / Test-nodes ratio.

Paper claim: with neighbor sampling, the same nodes are loaded across
mini-batches up to 465× (batch 256, fan-out 15-10-5 on Ogbn-products);
redundancy grows with fan-out — the quantity both caches exploit.

Beyond the paper's cross-batch ratio, each row also reports the
WITHIN-batch redundancy the unique-frontier dedup path removes:
``unique_loaded`` sums each batch's distinct input nodes (from the same
device-side sort-unique the dedup feature path uses) and
``duplication_factor = loaded / unique_loaded`` is the per-batch gather
reduction dedup delivers before any cache even gets involved.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FANOUTS, emit, make_engine
from repro.graph.sampling import device_graph, sample_blocks


def run(dataset="ogbn-products", batch_sizes=(256, 1024)):
    rows = []
    for bs in batch_sizes:
        for fo_name, fo in FANOUTS.items():
            eng = make_engine(dataset, fanouts=fo, batch_size=bs)
            ds = eng.dataset
            g = device_graph(ds.graph)
            key = jax.random.PRNGKey(0)
            loaded = 0
            unique_loaded = 0
            test_nodes = len(ds.test_idx)
            for seeds in eng._batches(None):
                key, sub = jax.random.split(key)
                block = sample_blocks(sub, g, jnp.asarray(seeds), fo, dedup=True)
                loaded += int(block.input_nodes.shape[0])
                unique_loaded += int(block.dedup.num_unique)
            ratio = loaded / max(test_nodes, 1)
            dup = loaded / max(unique_loaded, 1)
            rows.append(
                {
                    "batch_size": bs,
                    "fanout": fo_name,
                    "loaded": loaded,
                    "unique_loaded": unique_loaded,
                    "duplication_factor": round(dup, 2),
                    "test_nodes": test_nodes,
                    "load_over_test": round(ratio, 2),
                }
            )
            emit(
                f"redundancy/bs{bs}/{fo_name}",
                0.0,
                f"load_over_test={ratio:.1f};dup_factor={dup:.2f}",
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
