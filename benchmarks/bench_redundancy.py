"""Table I: redundant data loading — Loaded-nodes / Test-nodes ratio.

Paper claim: with neighbor sampling, the same nodes are loaded across
mini-batches up to 465× (batch 256, fan-out 15-10-5 on Ogbn-products);
redundancy grows with fan-out — the quantity both caches exploit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FANOUTS, emit, make_engine
from repro.graph.sampling import device_graph, sample_blocks


def run(dataset="ogbn-products", batch_sizes=(256, 1024)):
    rows = []
    for bs in batch_sizes:
        for fo_name, fo in FANOUTS.items():
            eng = make_engine(dataset, fanouts=fo, batch_size=bs)
            ds = eng.dataset
            g = device_graph(ds.graph)
            key = jax.random.PRNGKey(0)
            loaded = 0
            test_nodes = len(ds.test_idx)
            for seeds in eng._batches(None):
                key, sub = jax.random.split(key)
                block = sample_blocks(sub, g, jnp.asarray(seeds), fo)
                loaded += int(block.input_nodes.shape[0])
            ratio = loaded / max(test_nodes, 1)
            rows.append(
                {
                    "batch_size": bs,
                    "fanout": fo_name,
                    "loaded": loaded,
                    "test_nodes": test_nodes,
                    "load_over_test": round(ratio, 2),
                }
            )
            emit(f"redundancy/bs{bs}/{fo_name}", 0.0, f"load_over_test={ratio:.1f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
