"""§Roofline: per (arch × shape × mesh) three-term roofline from the dry-run.

Reads the JSON records ``launch/dryrun.py --out`` wrote, combines the
per-device HLO-derived FLOPs / dot-bytes / collective-bytes with the v5e
hardware constants, and emits the roofline table (markdown + json):

    compute    = HLO_FLOPs/dev  / 197 TFLOP/s
    memory     = HLO_dot_bytes/dev / 819 GB/s
    collective = collective_bytes/dev / 50 GB/s (one ICI link)

plus MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference) and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste).
"""

from __future__ import annotations

import glob
import json
import os

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HW
from repro.models.lm.model import abstract_params


def count_active_params(arch: str) -> tuple[int, int]:
    """(total_params, active_nonembed_params) — MoE experts scaled by k/E."""
    cfg = get_config(arch)
    params = abstract_params(cfg)
    total = 0
    active = 0
    moe_frac = (cfg.moe.top_k / cfg.moe.n_experts) if cfg.moe else 1.0

    def walk(path, leaf):
        nonlocal total, active
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        if name in ("embed", "lm_head"):
            return
        if name in ("we1", "we2", "we3"):
            active += int(n * moe_frac)
        else:
            active += n

    jax.tree_util.tree_map_with_path(walk, params)
    return total, active


def model_flops(arch: str, shape_name: str, devices: int) -> float:
    """Analytic per-device MODEL_FLOPS for the step the dry-run lowered."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    _, active = count_active_params(arch)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        flops = 6.0 * active * tokens
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        flops = 2.0 * active * tokens
    else:  # decode: one token per sequence
        flops = 2.0 * active * shape.global_batch
    return flops / devices


def suggest(dominant: str, arch: str, shape: str) -> str:
    if dominant == "collective":
        return (
            "reduce cross-device traffic: fewer all-gathers via better weight/"
            "activation sharding alignment (or 2D-sharded MoE dispatch)"
        )
    if dominant == "memory":
        return "cut HBM traffic: fuse KV reads (flash decode), quantize cache, widen batch"
    return "raise MXU utilization: larger per-device tiles, fewer remat recomputes"


def load_records(result_dir: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def roofline_rows(recs: list[dict]) -> list[dict]:
    rows = []
    for r in recs:
        h = r.get("hlo")
        if not h:
            continue
        dev = r["devices"]
        compute_t = h["flops_per_device"] / HW["peak_flops_bf16"]
        memory_t = h["dot_bytes_per_device"] / HW["hbm_bw"]
        coll_bytes = sum(h["collective_bytes_per_device"].values())
        coll_t = coll_bytes / HW["ici_bw_per_link"]
        terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
        dominant = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["shape"], dev)
        ratio = mf / h["flops_per_device"] if h["flops_per_device"] else float("nan")
        rows.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "mesh": r["mesh"],
                "compute_s": compute_t,
                "memory_s": memory_t,
                "collective_s": coll_t,
                "dominant": dominant,
                "model_flops_per_dev": mf,
                "hlo_flops_per_dev": h["flops_per_device"],
                "useful_ratio": ratio,
                "suggestion": suggest(dominant, r["arch"], r["shape"]),
            }
        )
    return rows


def to_markdown(rows: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | dominant | useful ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} |"
        )
    return "\n".join(out)


def main(result_dir: str = "results/dryrun_single", out_prefix: str = "results/roofline_single"):
    recs = load_records(result_dir)
    rows = roofline_rows(recs)
    with open(out_prefix + ".json", "w") as f:
        json.dump(rows, f, indent=1)
    md = to_markdown(rows)
    with open(out_prefix + ".md", "w") as f:
        f.write(md + "\n")
    print(md)
    return rows


if __name__ == "__main__":
    import sys

    main(*sys.argv[1:])
