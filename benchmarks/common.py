"""Shared benchmark plumbing.

Every bench prints CSV rows ``name,us_per_call,derived`` (harness contract)
plus a human-readable table.  Datasets are the synthetic Table-II stand-ins
at a laptop scale chosen so a full suite run stays in CI budget; the
directional claims (speedups, hit rates, preprocessing ratios) are what we
validate against the paper (see EXPERIMENTS.md for the claim mapping).
"""

from __future__ import annotations

import sys

from repro.core.config import EngineConfig
from repro.graph.datasets import load_dataset
from repro.runtime.gnn_engine import GNNInferenceEngine

# benchmark-scale knobs (one place to turn for deeper runs)
SCALE = 0.004
MAX_NODES = 60_000
MAX_BATCHES = 8
BATCH_SIZE = 512
FANOUTS = {"2,2,2": (2, 2, 2), "8,4,2": (8, 4, 2), "15,10,5": (15, 10, 5)}
DATASETS = ("reddit", "yelp", "amazon", "ogbn-products", "ogbn-papers100m")
CACHE_BYTES = 2_000_000


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
    sys.stdout.flush()


def geomean(ratios) -> float:
    """Geometric mean of positive ratios (clamped away from zero) — the
    reduction every wall-clock gate uses, so one noisy cell cannot
    dominate and underflow cannot poison the product."""
    ratios = list(ratios)
    if not ratios:
        raise ValueError("geomean needs at least one ratio")
    product = 1.0
    for r in ratios:
        product *= max(r, 1e-9)
    return product ** (1.0 / len(ratios))


def make_engine(
    dataset_name: str,
    *,
    model: str = "graphsage",
    fanouts=(8, 4, 2),
    batch_size: int = BATCH_SIZE,
    scale: float = SCALE,
    seed: int = 0,
) -> GNNInferenceEngine:
    ds = load_dataset(dataset_name, scale=scale, seed=seed, max_nodes=MAX_NODES)
    return GNNInferenceEngine(
        ds, model=model, fanouts=tuple(fanouts), batch_size=batch_size, seed=seed
    )


def run_policy(
    engine: GNNInferenceEngine,
    policy: str,
    cache_bytes: int = CACHE_BYTES,
    pipeline_depth: int = 1,
    config: EngineConfig | None = None,
    **kw,
):
    engine.prepare(policy, total_cache_bytes=cache_bytes, **kw)
    if config is None:
        config = EngineConfig(pipeline_depth=pipeline_depth)
    return engine.run(max_batches=MAX_BATCHES, config=config)


# Execution modes reported side by side: the paper's serial loop, the
# staged executor, and the staged executor with the miss-path prefetch
# stage.  Each entry is (label, EngineConfig) — the config is passed to
# ``GNNInferenceEngine.run`` verbatim, so modes can toggle any execution
# knob (depth, prefetch, use_kernel, dedup) without changing the plumbing.
MODES = (
    ("serial", EngineConfig(pipeline_depth=1)),
    ("pipelined", EngineConfig(pipeline_depth=2)),
    ("pipelined+prefetch", EngineConfig(pipeline_depth=2, prefetch=True)),
)

# The kernel-route pair the dedup gate compares: identical Pallas gather
# path, with and without the unique-frontier dedup (sorted-run row-block
# tiles).  Kept separate from MODES — the DMA kernel in interpret mode is
# orders slower than a native gather, so these run on their own contained
# workload rather than inside every end-to-end sweep.
KERNEL_MODES = (
    ("pipelined+kernel", EngineConfig(pipeline_depth=2, use_kernel=True)),
    ("pipelined+kernel+dedup", EngineConfig(pipeline_depth=2, use_kernel=True, dedup=True)),
)


def run_policy_modes(
    engine: GNNInferenceEngine,
    policy: str,
    cache_bytes: int = CACHE_BYTES,
    modes=MODES,
    **kw,
):
    """Prepare once, then run each (label, EngineConfig) execution mode.

    Outputs and hit rates are mode-invariant (equivalence-tested), so the
    reports differ only in where the miss bytes move and how the stages
    overlap.  The throwaway runs compile every distinct knob combination's
    programs (prefetch scatter, kernel route, dedup buckets) outside the
    timed windows, so compile time isn't charged to whichever mode runs
    first.
    """
    engine.prepare(policy, total_cache_bytes=cache_bytes, **kw)
    seen = set()
    for _, cfg in modes:
        knobs = cfg.replace(pipeline_depth=None)  # frozen dataclass → hashable
        if knobs not in seen:
            seen.add(knobs)
            engine.run(max_batches=2, config=knobs)
    return {label: engine.run(max_batches=MAX_BATCHES, config=cfg) for label, cfg in modes}
