"""Shared benchmark plumbing.

Every bench prints CSV rows ``name,us_per_call,derived`` (harness contract)
plus a human-readable table.  Datasets are the synthetic Table-II stand-ins
at a laptop scale chosen so a full suite run stays in CI budget; the
directional claims (speedups, hit rates, preprocessing ratios) are what we
validate against the paper (see EXPERIMENTS.md for the claim mapping).
"""

from __future__ import annotations

import sys

from repro.graph.datasets import load_dataset
from repro.runtime.gnn_engine import GNNInferenceEngine

# benchmark-scale knobs (one place to turn for deeper runs)
SCALE = 0.004
MAX_NODES = 60_000
MAX_BATCHES = 8
BATCH_SIZE = 512
FANOUTS = {"2,2,2": (2, 2, 2), "8,4,2": (8, 4, 2), "15,10,5": (15, 10, 5)}
DATASETS = ("reddit", "yelp", "amazon", "ogbn-products", "ogbn-papers100m")
CACHE_BYTES = 2_000_000


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
    sys.stdout.flush()


def make_engine(
    dataset_name: str,
    *,
    model: str = "graphsage",
    fanouts=(8, 4, 2),
    batch_size: int = BATCH_SIZE,
    scale: float = SCALE,
    seed: int = 0,
) -> GNNInferenceEngine:
    ds = load_dataset(dataset_name, scale=scale, seed=seed, max_nodes=MAX_NODES)
    return GNNInferenceEngine(
        ds, model=model, fanouts=tuple(fanouts), batch_size=batch_size, seed=seed
    )


def run_policy(
    engine: GNNInferenceEngine,
    policy: str,
    cache_bytes: int = CACHE_BYTES,
    pipeline_depth: int = 1,
    **kw,
):
    engine.prepare(policy, total_cache_bytes=cache_bytes, **kw)
    return engine.run(max_batches=MAX_BATCHES, pipeline_depth=pipeline_depth)


def run_policy_depths(
    engine: GNNInferenceEngine,
    policy: str,
    cache_bytes: int = CACHE_BYTES,
    depths: tuple[int, ...] = (1, 2),
    **kw,
):
    """Prepare once, then run at each pipeline depth (serial vs pipelined).

    Outputs/hit rates are depth-invariant, so the reports differ only in
    stage/wall timing — the serial-vs-pipelined benchmark axis.  A short
    throwaway run first compiles the small accounting/dispatch programs
    (identical across depths), so compile time isn't charged to whichever
    depth happens to run first.
    """
    engine.prepare(policy, total_cache_bytes=cache_bytes, **kw)
    engine.run(max_batches=2)
    return {d: engine.run(max_batches=MAX_BATCHES, pipeline_depth=d) for d in depths}
