"""Ablation (beyond-paper): each cache alone vs the dual cache at equal
budget — SCI (features only), ACI (adjacency only), DCI (Eq.1 split).

The paper compares DCI against SCI; adding ACI isolates what each cache
contributes: features carry most *bytes* (SCI ≈ DCI on modeled transfer),
the adjacency cache alone removes the sampling stage's host reads (adj hit
1.0) but leaves the dominant feature stream cold.  DCI's Eq.1 split gets
within a few % of the best single-purpose cache on BOTH axes at once.
"""

from __future__ import annotations

from benchmarks.common import CACHE_BYTES, emit, make_engine, run_policy


def run(dataset="ogbn-products"):
    rows = []
    for policy in ("sci", "aci", "dci"):
        eng = make_engine(dataset, fanouts=(8, 4, 2))
        rep = run_policy(eng, policy, cache_bytes=CACHE_BYTES)
        rows.append(
            {
                "policy": policy,
                "adj_hit": round(rep.adj_hit_rate, 3),
                "feat_hit": round(rep.feat_hit_rate, 3),
                "modeled_ms": round(rep.modeled_transfer_seconds() * 1e3, 3),
                "sample_s": round(rep.sample_seconds, 4),
            }
        )
        emit(
            f"ablation/{policy}",
            rep.total_seconds / rep.num_batches * 1e6,
            f"adj_hit={rep.adj_hit_rate:.2f};feat_hit={rep.feat_hit_rate:.2f};"
            f"modeled_ms={rep.modeled_transfer_seconds()*1e3:.2f}",
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
