"""Tracing overhead gate (beyond-paper observability layer).

Two budgets, both measured inside ONE process so the comparison never
crosses a machine boundary:

  * disabled path — a run handed no tracer goes through the shared
    ``NULL_TRACER`` no-op object.  We microbenchmark the no-op span
    enter/exit, multiply by the span count an *enabled* run of the same
    workload actually emits, and express that as a fraction of the
    untraced run's wall clock: the modeled cost of the null path must
    stay under 1% (in practice it is parts-per-million).
  * enabled path — the same engine/workload run back-to-back untraced
    then traced (+ a metrics registry); the traced wall clock must stay
    within 5% of the untraced one, and the outputs must be bit-for-bit
    identical (the trace only reads wall clocks and appends to host
    lists).

Both checks feed the committed-baseline regression gate
(``benchmarks/run.py --check-against``): the booleans must stay true,
and the measured ratios are snapshotted for drift visibility.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, make_engine
from repro.core.config import EngineConfig
from repro.core.trace import NULL_TRACER, MetricsRegistry, Tracer

DISABLED_BUDGET = 0.01  # modeled null-path cost as a fraction of run time
ENABLED_BUDGET = 1.05  # traced/untraced wall-clock ratio ceiling


def _null_span_cost_us(iters: int = 50_000) -> float:
    """Per-call cost of the NullTracer span enter/exit pair, in us."""
    span = NULL_TRACER.span  # the exact attribute the hot loops touch
    t0 = time.perf_counter()
    for _ in range(iters):
        with span("stage", lane="slot 0", args=None):
            pass
    return (time.perf_counter() - t0) / iters * 1e6


def run(*, batch_size: int = 256, max_batches: int = 6):
    """Measure disabled-path and enabled-path tracing overhead."""
    eng = make_engine("ogbn-products", batch_size=batch_size)
    eng.prepare("dci", total_cache_bytes=2_000_000)
    cfg = EngineConfig(pipeline_depth=2)
    eng.run(max_batches=2, config=cfg)  # compile outside the timed windows

    kw = dict(max_batches=max_batches, config=cfg, collect_outputs=True)
    t0 = time.perf_counter()
    eng.run(**kw)
    t_off = time.perf_counter() - t0
    out_off = [np.asarray(o) for o in eng.last_outputs]

    tracer = Tracer()
    metrics = MetricsRegistry()
    t0 = time.perf_counter()
    eng.run(**kw, tracer=tracer, metrics=metrics)
    t_on = time.perf_counter() - t0
    out_on = [np.asarray(o) for o in eng.last_outputs]

    # One more untraced run bounds same-session noise: the traced run is
    # gated against the *best* untraced sample, tightening the comparison
    # on jittery shared runners.
    t0 = time.perf_counter()
    eng.run(**kw)
    t_off = min(t_off, time.perf_counter() - t0)

    n_spans = sum(1 for e in tracer.events if e["ph"] == "X")
    span_cost_us = _null_span_cost_us()
    disabled_frac = (span_cost_us * 1e-6 * n_spans) / max(t_off, 1e-9)
    enabled_ratio = t_on / max(t_off, 1e-9)
    outputs_identical = len(out_off) == len(out_on) and all(
        np.array_equal(a, b) for a, b in zip(out_off, out_on)
    )

    rows = [
        {
            "null_span_cost_us": span_cost_us,
            "n_spans": n_spans,
            "t_untraced_s": t_off,
            "t_traced_s": t_on,
            "disabled_modeled_frac": disabled_frac,
            "enabled_ratio": enabled_ratio,
        }
    ]
    checks = {
        "trace_disabled_under_1pct": disabled_frac < DISABLED_BUDGET,
        "trace_enabled_within_5pct": enabled_ratio <= ENABLED_BUDGET,
        "trace_outputs_identical": bool(outputs_identical),
        "trace_disabled_modeled_frac": disabled_frac,
        "trace_enabled_ratio": enabled_ratio,
    }
    emit(
        "trace/overhead",
        t_on * 1e6 / max_batches,
        f"null_span={span_cost_us:.3f}us;spans={n_spans};"
        f"disabled_frac={disabled_frac:.6f};enabled_ratio={enabled_ratio:.3f};"
        f"identical={outputs_identical}",
    )
    return rows, checks
