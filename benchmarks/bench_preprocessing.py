"""Table IV + Fig. 10: preprocessing time — DCI vs RAIN vs DUCATI.

Paper claims and how they map to the scaled stand-ins:
  * Tab. IV (DCI ≪ RAIN, 52.8-98.7% cheaper): RAIN's LSH pass touches the
    WHOLE test set — O(#test batches) — while DCI pre-samples a constant
    ``n_presample`` batches regardless of test-set size.  At 1% dataset
    scale RAIN's absolute cost collapses (its python-level banding constants
    vanish), so we validate the structural claim: growing the dataset 3x at
    fixed batch size grows RAIN's prep proportionally while DCI's barely
    moves.
  * Fig. 10 (DCI ≥81% cheaper than DUCATI): DUCATI needs epoch-level
    statistics (4x pre-sampling here), two global O(n log n) value-curve
    sorts + polynomial fits, and a joint knapsack.  We check DCI < 50% of
    DUCATI at bench scale (the paper's 81-94% gap is at 2.4M-111M nodes
    where the knapsack machinery dominates).
"""

from __future__ import annotations

from benchmarks.common import CACHE_BYTES, emit, make_engine, run_policy


def run(datasets=("reddit", "ogbn-products"), batch_sizes=(128,)):
    rows = []
    for ds in datasets:
        for bs in batch_sizes:
            prep = {}
            total = {}
            for policy in ("dci", "rain", "ducati"):
                eng = make_engine(ds, batch_size=bs, fanouts=(4, 3, 2))
                rep = run_policy(eng, policy, cache_bytes=CACHE_BYTES)
                prep[policy] = rep.prep_seconds
                total[policy] = rep.total_seconds
            # structural scaling: 3x dataset size, same batch size
            big = {}
            for policy in ("dci", "rain"):
                eng_big = make_engine(ds, batch_size=bs, fanouts=(4, 3, 2), scale=0.012)
                big[policy] = run_policy(eng_big, policy, cache_bytes=CACHE_BYTES).prep_seconds
            rows.append(
                {
                    "dataset": ds,
                    "batch_size": bs,
                    "prep_dci_s": round(prep["dci"], 4),
                    "prep_rain_s": round(prep["rain"], 4),
                    "prep_ducati_s": round(prep["ducati"], 4),
                    "dci_vs_ducati": round(prep["dci"] / max(prep["ducati"], 1e-9), 3),
                    "rain_growth_3x_data": round(big["rain"] / max(prep["rain"], 1e-9), 3),
                    "dci_growth_3x_data": round(big["dci"] / max(prep["dci"], 1e-9), 3),
                    "runtime_dci_vs_ducati": round(total["dci"] / max(total["ducati"], 1e-9), 3),
                }
            )
            emit(
                f"preprocessing/{ds}/bs{bs}",
                prep["dci"] * 1e6,
                f"dci_over_ducati={rows[-1]['dci_vs_ducati']};"
                f"rain_growth={rows[-1]['rain_growth_3x_data']};"
                f"dci_growth={rows[-1]['dci_growth_3x_data']}",
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
