"""Fig. 11: cache hit rate vs number of pre-sampling mini-batches.

Paper claim: hit rates stabilize once ~8 pre-sampling batches are used —
mini-batch-level preprocessing is enough (no epoch-level statistics).
"""

from __future__ import annotations

from benchmarks.common import emit, make_engine

CAPACITY = 400_000  # deliberately tight (paper uses 0.4 GB at full scale)


def run(dataset="ogbn-products", presample_counts=(1, 2, 4, 8, 16, 32)):
    rows = []
    for n in presample_counts:
        eng = make_engine(dataset, fanouts=(8, 4, 2))
        eng.prepare("dci", total_cache_bytes=CAPACITY, n_presample=n)
        rep = eng.run(max_batches=8)
        rows.append(
            {
                "presample_batches": n,
                "adj_hit": round(rep.adj_hit_rate, 4),
                "feat_hit": round(rep.feat_hit_rate, 4),
                "prep_s": round(rep.prep_seconds, 4),
            }
        )
        emit(
            f"presample/{n}",
            rep.prep_seconds * 1e6,
            f"adj_hit={rep.adj_hit_rate:.3f};feat_hit={rep.feat_hit_rate:.3f}",
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
