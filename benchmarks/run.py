"""Benchmark entry point: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` prints CSV rows
``name,us_per_call,derived`` for every benchmark, then a summary of the
paper-claim checks (directional validation on the scaled stand-in
datasets; EXPERIMENTS.md maps each check to the paper's numbers).

Regression gate (CI):

    python -m benchmarks.run --write-baseline BENCH_baseline.json
    python -m benchmarks.run --check-against BENCH_baseline.json

Either flag runs only the *quick* benches (end2end on one dataset/model
across the serial / pipelined / pipelined+prefetch modes, plus a small
multi-stream run).  ``--check-against`` compares the machine-independent
metrics — hit rates, modeled speedups, relative pipeline/uplift ratios,
and the bit-for-bit invariance booleans — against the committed baseline
within tolerance bands, and exits nonzero on regression; absolute wall
times are never compared across machines.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (  # noqa: E402
    bench_breakdown,
    bench_cache_capacity,
    bench_drift,
    bench_end2end,
    bench_faults,
    bench_hit_rates,
    bench_preprocessing,
    bench_presample_batches,
    bench_redundancy,
    bench_ablation,
    bench_layerwise,
    bench_lm_serving_cache,
    bench_multistream,
    bench_trace,
)
from benchmarks.common import geomean  # noqa: E402

# ------------------------------------------------------- regression gate

# Tolerance bands for --check-against, calibrated on back-to-back runs of
# the quick benches.  The Eq. 1 capacity split is a function of *measured*
# presample stage times, so the resulting hit rates (adjacency especially
# — it gets the smaller, more split-sensitive share) drift a few percent
# run to run even on one machine; the bands absorb that while still
# catching real cache-filling regressions (a broken fill moves hit rates
# by 0.2+).  Wall-clock-derived ratios are gated on a geomean across
# policies, never per row — per-row wall clocks on shared CI runners
# jitter far beyond any useful per-row band.
FEAT_HIT_ABS_TOL = 0.05  # feature hit-rate drift (bulk of the budget, stabler)
# The adjacency share is the small, split-sensitive slice of the Eq. 1
# budget: back-to-back runs on shared 1-core CI runners land its hit rate
# anywhere in a ~0.3-wide window (measured stage times swing the split).
# 0.20 absorbs that while still failing on a broken fill (0.2+ shift with
# the feature band blown too, which a real regression also moves).
ADJ_HIT_ABS_TOL = 0.20  # adjacency hit-rate drift (split-sensitive share)
MODELED_REL_TOL = 0.25  # modeled (PCIe/HBM-projected) speedup drift
PIPELINE_GEOMEAN_FLOOR = 0.75  # per-mode geomean of cur/base pipeline speedups
UPLIFT_FRACTION = 0.6  # multi-stream uplift must keep this much of baseline
TAIL_P99_FRACTION = 0.6  # EDF-vs-RR burst p99 ratio must keep this much of baseline


def quick_bench() -> dict:
    """The quick-run rows the regression gate snapshots and compares."""
    print("# --- quick end2end (serial / pipelined / pipelined+prefetch) ---")
    e2e = bench_end2end.run(datasets=("ogbn-products",), models=("graphsage",))
    print("# --- quick multi-stream (shared vs private, +prefetch) ---")
    ms_rows, ms_checks = bench_multistream.run(
        num_streams=2, batches_per_stream=2, batch_size=128
    )
    print("# --- quick request latency (burst EDF-vs-RR tail gate) ---")
    rl_rows, rl_checks = bench_multistream.run_request_latency(batch_size=128)
    print("# --- quick sharded scaling (4 shards vs single device, modeled) ---")
    sh_rows, sh_checks = bench_multistream.run_sharded(
        num_shards=4, num_streams=2, batches_per_stream=2, batch_size=128
    )
    print("# --- quick layerwise crossover (sampling vs full-graph, modeled) ---")
    lw_rows, lw_checks = bench_layerwise.run(
        coverages=(0.1, 0.5, 1.0), batch_size=128, chunk_size=512
    )
    print("# --- quick tracing overhead (disabled <1% modeled, enabled within 5%) ---")
    tr_rows, tr_checks = bench_trace.run(batch_size=128, max_batches=4)
    print("# --- quick fault tolerance (fail-fast vs degraded availability) ---")
    fl_rows, fl_checks = bench_faults.run(
        num_streams=2, batches_per_stream=6, batch_size=64
    )
    return {
        "end2end": e2e,
        "multistream": {"rows": ms_rows, "checks": ms_checks},
        "request_latency": {"rows": rl_rows, "checks": rl_checks},
        "sharded": {"rows": sh_rows, "checks": sh_checks},
        "layerwise": {"rows": lw_rows, "checks": lw_checks},
        "trace": {"rows": tr_rows, "checks": tr_checks},
        "faults": {"rows": fl_rows, "checks": fl_checks},
    }


def _e2e_key(row: dict) -> str:
    return f"{row['dataset']}/{row['model']}/{row['policy']}/{row['mode']}"


def check_against(baseline: dict, current: dict) -> list[tuple[str, bool, str]]:
    """Compare a quick run against the committed baseline.

    Returns ``(criterion, ok, detail)`` triples — one per compared metric,
    plus one failure triple per baseline row the current run no longer
    produces (a silently dropped benchmark must fail the gate)."""
    results: list[tuple[str, bool, str]] = []
    cur_e2e = {_e2e_key(r): r for r in current["end2end"]}
    pipeline_ratios: dict[str, list[float]] = {}
    for row in baseline["end2end"]:
        key = _e2e_key(row)
        cur = cur_e2e.get(key)
        if cur is None:
            results.append((f"e2e/{key}", False, "row missing from current run"))
            continue
        for metric, tol in (("feat_hit", FEAT_HIT_ABS_TOL), ("adj_hit", ADJ_HIT_ABS_TOL)):
            diff = abs(cur[metric] - row[metric])
            results.append(
                (f"e2e/{key}/{metric}", diff <= tol, f"|{cur[metric]}-{row[metric]}|={diff:.4f}")
            )
        base_m, cur_m = row["speedup_modeled_vs_dgl"], cur["speedup_modeled_vs_dgl"]
        ok = cur_m >= base_m * (1 - MODELED_REL_TOL)
        results.append((f"e2e/{key}/speedup_modeled", ok, f"{cur_m} vs {base_m}"))
        pipeline_ratios.setdefault(row["mode"], []).append(
            cur["pipeline_speedup_vs_serial"] / max(row["pipeline_speedup_vs_serial"], 1e-9)
        )
    for mode, ratios in sorted(pipeline_ratios.items()):
        g = geomean(ratios)
        results.append(
            (
                f"e2e/pipeline_speedup_geomean/{mode}",
                g >= PIPELINE_GEOMEAN_FLOOR,
                f"{g:.3f} (floor {PIPELINE_GEOMEAN_FLOOR})",
            )
        )

    cur_ms = {r["mode"]: r for r in current["multistream"]["rows"]}
    for row in baseline["multistream"]["rows"]:
        cur = cur_ms.get(row["mode"])
        if cur is None:
            results.append((f"ms/{row['mode']}", False, "row missing from current run"))
            continue
        for metric, tol in (("feat_hit", FEAT_HIT_ABS_TOL), ("adj_hit", ADJ_HIT_ABS_TOL)):
            diff = abs(cur[metric] - row[metric])
            results.append((f"ms/{row['mode']}/{metric}", diff <= tol, f"diff={diff:.4f}"))
    base_checks = baseline["multistream"]["checks"]
    cur_checks = current["multistream"]["checks"]
    for flag in ("uplift_ge_1.2", "shared_hit_ge_private", "prefetch_hits_identical"):
        ok = bool(cur_checks.get(flag)) or not bool(base_checks.get(flag, True))
        results.append((f"ms/checks/{flag}", ok, str(cur_checks.get(flag))))
    base_u = base_checks["throughput_uplift_vs_private"]
    cur_u = cur_checks["throughput_uplift_vs_private"]
    # The uplift is wall-clock-derived, so a baseline from a faster dev
    # machine must not raise the bar above the project's own >=1.2
    # acceptance criterion: keeping 60% of the baseline OR clearing 1.2
    # both pass.  Losing the 1.2 claim outright is caught by the
    # uplift_ge_1.2 flag above regardless.
    floor = min(1.2, base_u * UPLIFT_FRACTION)
    results.append(
        (
            "ms/checks/throughput_uplift",
            cur_u >= floor,
            f"{cur_u} vs {base_u} (floor {floor:.3f})",
        )
    )

    # Tail-latency gate: the EDF-vs-round-robin burst p99 ratio is a pure
    # scheduling property (same engine, same trace, only admission order
    # differs), so it compares across machines where absolute p99s do not.
    # Baselines written before the request front-end existed skip the gate.
    base_rl = baseline.get("request_latency")
    if base_rl is not None:
        base_rl_checks = base_rl["checks"]
        cur_rl_checks = current["request_latency"]["checks"]
        flag = "edf_beats_rr_p99_burst"
        ok = bool(cur_rl_checks.get(flag)) or not bool(base_rl_checks.get(flag, True))
        results.append((f"rl/checks/{flag}", ok, str(cur_rl_checks.get(flag))))
        base_r = base_rl_checks["edf_vs_rr_p99_ratio_burst"]
        cur_r = cur_rl_checks["edf_vs_rr_p99_ratio_burst"]
        # Same discipline as the uplift floor: a hot baseline machine must
        # not raise the bar above the >=1.0 acceptance criterion itself.
        rl_floor = min(1.0, base_r * TAIL_P99_FRACTION)
        results.append(
            (
                "rl/checks/edf_vs_rr_p99_ratio",
                cur_r >= rl_floor,
                f"{cur_r} vs {base_r} (floor {rl_floor:.3f})",
            )
        )

    # Sharded-scaling gate: the equivalence booleans are exact (sharded
    # serving must stay bit-for-bit the single-device run), and the
    # modeled max-over-shards scaling ratio is machine-independent —
    # traffic skew, not wall clock, determines it.  Baselines written
    # before the sharded section existed skip the gate.
    base_sh = baseline.get("sharded")
    if base_sh is not None:
        base_sh_checks = base_sh["checks"]
        cur_sh_checks = current["sharded"]["checks"]
        for flag in (
            "sharded_scaling_ge_1.5",
            "sharded_hits_identical",
            "shard_sums_tile_global",
        ):
            ok = bool(cur_sh_checks.get(flag)) or not bool(base_sh_checks.get(flag, True))
            results.append((f"sh/checks/{flag}", ok, str(cur_sh_checks.get(flag))))
        base_s = base_sh_checks["sharded_modeled_scaling"]
        cur_s = cur_sh_checks["sharded_modeled_scaling"]
        # Do not let a lucky baseline raise the bar above the >=1.5
        # acceptance criterion itself (same discipline as the uplift floor).
        sh_floor = min(1.5, base_s * (1 - MODELED_REL_TOL))
        results.append(
            (
                "sh/checks/sharded_modeled_scaling",
                cur_s >= sh_floor,
                f"{cur_s} vs {base_s} (floor {sh_floor:.3f})",
            )
        )

    # Layer-wise crossover gate: the crossover's existence and the
    # full-coverage modeled ratio are byte-movement properties (the
    # PCIe/HBM projection), machine-independent like every other modeled
    # gate.  Baselines written before the layer-wise mode skip the gate.
    base_lw = baseline.get("layerwise")
    if base_lw is not None:
        base_lw_checks = base_lw["checks"]
        cur_lw_checks = current["layerwise"]["checks"]
        for flag in ("crossover_exists", "layerwise_wins_full_coverage"):
            ok = bool(cur_lw_checks.get(flag)) or not bool(base_lw_checks.get(flag, True))
            results.append((f"lw/checks/{flag}", ok, str(cur_lw_checks.get(flag))))
        base_r = base_lw_checks["layerwise_modeled_ratio_full_coverage"]
        cur_r = cur_lw_checks["layerwise_modeled_ratio_full_coverage"]
        # A hot baseline must not raise the bar above the >=1.0 acceptance
        # criterion itself (the crossover existing at all).
        lw_floor = min(1.0, base_r * (1 - MODELED_REL_TOL))
        results.append(
            (
                "lw/checks/layerwise_modeled_ratio",
                cur_r >= lw_floor,
                f"{cur_r} vs {base_r} (floor {lw_floor:.3f})",
            )
        )

    # Tracing-overhead gate: both ratios are SAME-session comparisons
    # (traced vs untraced in one process), so the booleans hold on any
    # machine; the raw ratios ride along for drift visibility only.
    # Baselines written before the tracing layer existed skip the gate.
    base_tr = baseline.get("trace")
    if base_tr is not None:
        cur_tr_checks = current["trace"]["checks"]
        for flag in (
            "trace_disabled_under_1pct",
            "trace_enabled_within_5pct",
            "trace_outputs_identical",
        ):
            ok = bool(cur_tr_checks.get(flag)) or not bool(
                base_tr["checks"].get(flag, True)
            )
            results.append((f"tr/checks/{flag}", ok, str(cur_tr_checks.get(flag))))

    # Fault-tolerance gate: every compared quantity replays from seeded
    # fault plans (a pure function of plan + call index), so the
    # availability numbers are exact on any machine — no tolerance bands.
    # Baselines written before the fault subsystem existed skip the gate.
    base_fl = baseline.get("faults")
    if base_fl is not None:
        base_fl_checks = base_fl["checks"]
        cur_fl_checks = current["faults"]["checks"]
        for flag in (
            "faults_zero_diff_identical",
            "faults_failfast_collapses",
            "faults_degraded_ge_0.99",
            "faults_refresh_rollback_servable",
            "faults_failover_identical",
            "faults_failover_sums_tile",
            "faults_failover_rejoined",
        ):
            ok = bool(cur_fl_checks.get(flag)) or not bool(base_fl_checks.get(flag, True))
            results.append((f"fl/checks/{flag}", ok, str(cur_fl_checks.get(flag))))
        # The availability contrast is THE claim of the fault layer: the
        # same 5% miss-path fault plan, fail-fast vs degraded+retry.
        # Deterministic replay makes both sides exact, so compare them to
        # the acceptance thresholds directly rather than to the baseline.
        cur_ff = cur_fl_checks["faults_failfast_availability"]
        cur_dg = cur_fl_checks["faults_degraded_availability"]
        results.append(
            (
                "fl/checks/availability_contrast",
                cur_dg >= 0.99 and cur_ff <= 0.5,
                f"degraded={cur_dg} (>=0.99) vs fail-fast={cur_ff} (<=0.5)",
            )
        )
    return results


def append_gate_history(path: str, results: list[tuple[str, bool, str]]) -> None:
    """Append one gate run's outcomes to a JSON history artifact.

    The gate itself is pass/fail within tolerance bands; the history file
    keeps every compared metric's *detail* string per run, so slow drift
    INSIDE the bands (e.g. a hit rate shedding 1% per week) is visible by
    diffing records over time instead of silently riding the tolerance."""
    record = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "git_sha": os.environ.get("GITHUB_SHA"),
        "run_id": os.environ.get("GITHUB_RUN_ID"),
        "passed": sum(1 for _, ok, _ in results if ok),
        "total": len(results),
        "checks": [{"name": n, "ok": ok, "detail": d} for n, ok, d in results],
    }
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []  # corrupt/unreadable history never blocks the gate
    history.append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# gate history appended to {path} ({len(history)} records)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--write-baseline",
        default=None,
        metavar="PATH",
        help="run the quick benches and snapshot their rows as the regression baseline",
    )
    ap.add_argument(
        "--check-against",
        default=None,
        metavar="PATH",
        help="run the quick benches and fail (exit 1) on regression vs this baseline",
    )
    ap.add_argument(
        "--gate-history",
        default=None,
        metavar="PATH",
        help="with --check-against: append this run's per-metric gate outcomes "
        "to a JSON history file (CI uploads it so drift inside the tolerance "
        "bands stays visible over time)",
    )
    args = ap.parse_args()

    if args.write_baseline or args.check_against:
        print("name,us_per_call,derived")
        current = quick_bench()
        if args.write_baseline:
            with open(args.write_baseline, "w") as f:
                json.dump({"schema": 1, **current}, f, indent=1)
            print(f"# baseline written to {args.write_baseline}")
        if args.check_against:
            with open(args.check_against) as f:
                baseline = json.load(f)
            results = check_against(baseline, current)
            failed = [r for r in results if not r[1]]
            print("# --- regression gate ---")
            for name, ok, detail in results:
                print(f"check,0.00,{name}={'PASS' if ok else 'FAIL'};{detail}")
            print(f"# {len(results) - len(failed)}/{len(results)} gate checks passed")
            if args.gate_history:
                append_gate_history(args.gate_history, results)
            if failed:
                sys.exit(1)
        return

    print("name,us_per_call,derived")

    print("# --- Tab.I redundant loading ---")
    redundancy = bench_redundancy.run(batch_sizes=(256, 1024))

    print("# --- Fig.1 time breakdown ---")
    breakdown = bench_breakdown.run(datasets=("reddit", "ogbn-products"))

    print("# --- unique-frontier dedup: row-block kernel vs per-row kernel ---")
    dedup_rows = bench_breakdown.run_dedup()

    print("# --- Fig.2 single-cache saturation ---")
    capacity = bench_cache_capacity.run()

    print("# --- Fig.7/8 end-to-end: DCI vs DGL/SCI/RAIN ---")
    end2end = bench_end2end.run(datasets=("reddit", "ogbn-products"), models=("graphsage", "gcn"))

    print("# --- Tab.IV/Fig.10 preprocessing: DCI vs RAIN vs DUCATI ---")
    prep = bench_preprocessing.run(datasets=("reddit", "ogbn-products"), batch_sizes=(64,))

    print("# --- Fig.9 hit rates vs capacity ---")
    hits = bench_hit_rates.run(capacities=(0, 250_000, 1_000_000, 4_000_000))

    print("# --- Fig.11 presample batches ---")
    presample = bench_presample_batches.run(presample_counts=(1, 2, 4, 8, 16))

    print("# --- ablation (beyond-paper): SCI vs ACI vs DCI ---")
    ablation = bench_ablation.run()

    print("# --- DCI-for-LM serving caches (beyond-paper) ---")
    lm_cache = bench_lm_serving_cache.run(budgets=(25_000, 100_000, 400_000))

    print("# --- multi-stream serving: shared vs private caches (beyond-paper) ---")
    _, ms_checks = bench_multistream.run(num_streams=4, batches_per_stream=4, batch_size=256)

    print("# --- request-level serving: arrival traces, admission, tail latency (beyond-paper) ---")
    _, rl_checks = bench_multistream.run_request_latency()

    print("# --- layer-wise full-graph vs sampling: coverage crossover (beyond-paper) ---")
    _, lw_checks = bench_layerwise.run(batch_size=256, chunk_size=1024)

    print("# --- tracing overhead: no-op path modeled <1%, enabled within 5% (beyond-paper) ---")
    _, tr_checks = bench_trace.run(batch_size=256)

    print("# --- fault tolerance: availability under injected failures (beyond-paper) ---")
    _, fl_checks = bench_faults.run()

    print("# --- online cache refresh under seed-distribution drift (beyond-paper) ---")
    drift_rows, drift_checks = bench_drift.run(batches_per_phase=8, batch_size=256)
    for r in drift_rows:
        if r.get("per_epoch"):
            # Per-epoch hit rates are the refresh story; the lifetime
            # aggregate would average away the adaptation.
            print(f"# drift {r['mode']}/{r['phase']} per-epoch: {r['per_epoch']}")

    # ---------------- claim checks (directional, scaled datasets) ----------
    checks = []
    by_fo = {(r["batch_size"], r["fanout"]): r["load_over_test"] for r in redundancy}
    checks.append(
        (
            "Tab.I redundancy grows with fan-out, shrinks with batch size",
            by_fo[(256, "2,2,2")] < by_fo[(256, "8,4,2")] < by_fo[(256, "15,10,5")]
            and by_fo[(1024, "15,10,5")] <= by_fo[(256, "15,10,5")],
        )
    )
    # Serial rows only: pipelined rows report dispatch-time stage splits,
    # not the paper's synchronized Fig. 1 decomposition.
    prep_ok = all(r["prep_frac"] > 0.5 for r in breakdown if r["pipeline_depth"] == 1)
    checks.append(("Fig.1 prep time >50% of total", prep_ok))
    by_dup = {
        (r["batch_size"], r["fanout"]): r["duplication_factor"] for r in redundancy
    }
    checks.append(
        (
            "Dedup: within-batch duplication > 1 and grows with fan-out",
            all(d > 1.0 for d in by_dup.values())
            and by_dup[(256, "2,2,2")] < by_dup[(256, "15,10,5")],
        )
    )
    dedup_geomean, dedup_ok = bench_breakdown.dedup_gate(dedup_rows)
    checks.append(
        (
            "Dedup: unique-frontier kernel gathers fewer rows, feature stage "
            f"no slower (geomean {dedup_geomean:.2f})",
            dedup_ok,
        )
    )
    sat = [r["feat_hit"] for r in capacity]
    checks.append(("Fig.2 hit rate monotone in capacity", sat == sorted(sat)))
    piped = [r["pipeline_speedup_vs_serial"] for r in end2end if r["mode"] == "pipelined"]
    geomean = 1.0
    for s in piped:
        geomean *= max(s, 1e-9)
    geomean **= 1.0 / max(len(piped), 1)
    checks.append(
        ("Pipelined executor no slower than serial (geomean, 5% noise floor)", geomean >= 0.95)
    )
    dci = [r for r in end2end if r["policy"] == "dci"]
    checks.append(
        (
            "Fig.7 DCI faster than DGL (modeled transfer)",
            all(r["speedup_modeled_vs_dgl"] > 1.0 for r in dci),
        ),
    )
    checks.append(("Fig.8 dual cache adds adjacency hits", all(r["adj_hit"] > 0 for r in dci)))
    checks.append(
        (
            "Tab.IV RAIN prep grows with test-set size, DCI stays flat",
            all(
                r["rain_growth_3x_data"] > 1.3 and r["dci_growth_3x_data"] < 2.0
                # the smallest stand-in (reddit at 0.4%: <1k nodes) is below
                # the wall-clock measurement floor for RAIN's ~2ms LSH pass
                for r in prep
                if r["dataset"] != "reddit"
            ),
        )
    )
    checks.append(
        ("Fig.10 DCI preprocessing < 50% of DUCATI", all(r["dci_vs_ducati"] < 0.5 for r in prep))
    )
    dci_hits = {(r["fanout"], r["capacity_B"]): r for r in hits if r["policy"] == "dci"}
    duc_hits = {(r["fanout"], r["capacity_B"]): r for r in hits if r["policy"] == "ducati"}
    close = all(
        abs(dci_hits[k]["feat_hit"] - duc_hits[k]["feat_hit"]) < 0.15 for k in dci_hits
    )
    checks.append(("Fig.9 DCI hit rates near DUCATI's", close))
    stable = abs(presample[-1]["feat_hit"] - presample[3]["feat_hit"]) < 0.05
    checks.append(("Fig.11 hit rate stable by ~8 presample batches", stable))

    abl = {r["policy"]: r for r in ablation}
    checks.append(
        (
            "Ablation: dual cache >= each single cache on its own axis",
            abl["dci"]["adj_hit"] > 0.3
            and abl["dci"]["feat_hit"] >= abl["sci"]["feat_hit"] - 0.1
            and abl["aci"]["feat_hit"] == 0.0,
        )
    )
    by_budget = {}
    for r in lm_cache:
        by_budget.setdefault(r["zipf_a"], []).append(r["embed_hit"])
    checks.append(
        (
            "LM cache: embed hit rate monotone in budget (both skews)",
            all(h == sorted(h) for h in by_budget.values()),
        )
    )
    checks.append(
        (
            "Multi-stream: shared cache >= 1.2x cold-start throughput + hit rate",
            ms_checks["uplift_ge_1.2"] and ms_checks["shared_hit_ge_private"],
        )
    )
    checks.append(
        (
            "Prefetch: identical hit accounting with the miss-path prefetch stage",
            ms_checks["prefetch_hits_identical"],
        )
    )
    checks.append(
        (
            "Request serving: EDF beats round-robin on burst p99 "
            f"(geomean {rl_checks['edf_vs_rr_p99_ratio_burst']:.2f}x)",
            rl_checks["edf_beats_rr_p99_burst"],
        )
    )
    checks.append(
        (
            "Layerwise: sampled cost crosses the flat full-graph cost as coverage grows "
            f"(full-coverage ratio {lw_checks['layerwise_modeled_ratio_full_coverage']:.2f}x, "
            f"crossover at {lw_checks['crossover_coverage']:.2f})",
            lw_checks["crossover_exists"] and lw_checks["layerwise_wins_full_coverage"],
        )
    )
    checks.append(
        (
            "Drift: online refresh beats the static cache post-shift, by delta re-fill",
            drift_checks["refreshed_beats_static_post_shift"]
            and drift_checks["delta_refill_no_full_build"],
        )
    )
    checks.append(
        (
            "Faults: degraded+retry serves >=0.99 availability where fail-fast collapses "
            f"(degraded {fl_checks['faults_degraded_availability']:.3f} vs "
            f"fail-fast {fl_checks['faults_failfast_availability']:.3f}), "
            "zero-diff with the injector idle",
            fl_checks["faults_degraded_ge_0.99"]
            and fl_checks["faults_failfast_collapses"]
            and fl_checks["faults_zero_diff_identical"]
            and fl_checks["faults_failover_identical"]
            and fl_checks["faults_refresh_rollback_servable"],
        )
    )
    checks.append(
        (
            "Tracing: disabled path modeled <1%, enabled within 5%, outputs identical "
            f"(enabled ratio {tr_checks['trace_enabled_ratio']:.3f}x)",
            tr_checks["trace_disabled_under_1pct"]
            and tr_checks["trace_enabled_within_5pct"]
            and tr_checks["trace_outputs_identical"],
        )
    )

    print("# --- paper-claim checks ---")
    failed = 0
    for name, ok in checks:
        print(f"check,0.00,{name}={'PASS' if ok else 'FAIL'}")
        failed += 0 if ok else 1
    print(f"# {len(checks) - failed}/{len(checks)} claim checks passed")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
